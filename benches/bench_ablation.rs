//! Bench: ablations — k, ℓ, AW policy and Ritz-end sweeps.
//!
//! The design-choice benchmarks DESIGN.md calls out: how the recycled
//! dimension k and storage depth ℓ trade iteration savings against O(nk)
//! per-iteration overhead, and what the AW staleness policy costs.

use krr::experiments::common::{ExpOpts, Workload};
use krr::solvers::recycle::{AwPolicy, RecycleConfig};
use krr::solvers::strategy::StrategyChoice;
use krr::gp::laplace::SolverBackend;
use krr::util::bench::{BenchConfig, BenchGroup};

fn main() {
    let o = ExpOpts {
        n: 192,
        seed: 6,
        amplitude: 1.0,
        lengthscale: 10.0,
        tol: 1e-5,
        k: 8,
        l: 12,
        max_newton: 8,
        backend: "native".into(),
        fast: false,
    };
    let w = Workload::build(&o);

    let mut g = BenchGroup::new("ablation — def-CG(k, l) parameter sweeps")
        .with_config(BenchConfig { warmup: 1, iters: 4, max_seconds: 150.0 });

    g.bench("k=0 (plain cg)", || {
        std::hint::black_box(w.fit(SolverBackend::Cg, &o));
    });
    for k in [2usize, 4, 8, 16] {
        g.bench(&format!("k={k} l=12"), || {
            std::hint::black_box(w.fit(
                SolverBackend::DefCg(RecycleConfig { k, l: 12, ..Default::default() }),
                &o,
            ));
        });
    }
    for l in [6usize, 12, 24] {
        g.bench(&format!("k=8 l={l}"), || {
            std::hint::black_box(w.fit(
                SolverBackend::DefCg(RecycleConfig { k: 8, l, ..Default::default() }),
                &o,
            ));
        });
    }
    for (pol, name) in [(AwPolicy::Refresh, "refresh"), (AwPolicy::Reuse, "reuse")] {
        g.bench(&format!("aw={name}"), || {
            std::hint::black_box(w.fit(
                SolverBackend::DefCg(RecycleConfig {
                    k: 8,
                    l: 12,
                    aw_policy: pol,
                    ..Default::default()
                }),
                &o,
            ));
        });
    }
    for (sel, name) in [
        (StrategyChoice::HarmonicLargest, "largest"),
        (StrategyChoice::RitzSmallest, "smallest"),
    ] {
        g.bench(&format!("ritz={name}"), || {
            let strategy = sel.clone();
            std::hint::black_box(w.fit(
                SolverBackend::DefCg(RecycleConfig {
                    k: 8,
                    l: 12,
                    strategy,
                    ..Default::default()
                }),
                &o,
            ));
        });
    }
    g.report();
}
