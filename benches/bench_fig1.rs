//! Bench: Fig. 1 — cost of the deflation machinery itself.
//!
//! Times the pieces behind the spectrum figure: harmonic-Ritz extraction
//! (the recycling overhead the paper bounds at O(n²(ℓ+1)k)), the dense
//! eigendecompositions used for the visualization, and the per-iteration
//! deflection cost of def-CG vs plain CG.

use krr::experiments::common::{ExpOpts, Workload};
use krr::experiments::fig1_spectrum;
use krr::solvers::ritz::{extract, RitzConfig, RitzSelect};
use krr::solvers::{self, DenseOp, SolveSpec};
use krr::util::bench::{BenchConfig, BenchGroup};
use krr::util::precision::to_f64;
use krr::util::rng::Rng;
use krr::linalg::mat::Mat;

fn main() {
    let o = ExpOpts {
        n: 192,
        seed: 3,
        amplitude: 1.0,
        lengthscale: 10.0,
        tol: 1e-6,
        k: 8,
        l: 12,
        max_newton: 4,
        backend: "native".into(),
        fast: false,
    };
    let w = Workload::build(&o);

    let mut g = BenchGroup::new("fig1 — deflation machinery cost")
        .with_config(BenchConfig { warmup: 1, iters: 8, max_seconds: 60.0 });

    // The full spectrum computation (what the figure renders).
    g.bench("spectrum A and P_W A (n=192)", || {
        std::hint::black_box(fig1_spectrum::compute(&w, &o));
    });

    // Harmonic-Ritz extraction alone.
    let mut rng = Rng::new(5);
    let a = Mat::rand_spd(o.n, 1e5, &mut rng);
    let b: Vec<f64> = (0..o.n).map(|i| 1.0 + to_f64(i % 7)).collect();
    let run = solvers::solve(
        &DenseOp::new(&a),
        &b,
        &SolveSpec::cg().with_tol(1e-10).with_store_l(o.l),
    );
    g.bench("harmonic-Ritz extraction (k=8, l=12)", || {
        std::hint::black_box(extract(
            None,
            &run.stored,
            o.n,
            &RitzConfig { k: o.k, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        ));
    });
    g.report();
}
