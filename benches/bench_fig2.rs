//! Bench: Fig. 2 — per-Newton-system solve cost, CG vs def-CG.
//!
//! Times individual Newton systems (not whole fits): system 1 (no recycled
//! basis, identical cost) and systems 2+ (def-CG deflated). Also reports
//! the iteration counts that drive the paper's right-hand panel.

use krr::experiments::common::{ExpOpts, Workload};
use krr::experiments::table1;
use krr::gp::laplace::SolverBackend;
use krr::util::bench::{BenchConfig, BenchGroup};

fn main() {
    let o = ExpOpts {
        n: 256,
        seed: 2,
        amplitude: 1.0,
        lengthscale: 10.0,
        tol: 1e-5,
        k: 8,
        l: 12,
        max_newton: 10,
        backend: "native".into(),
        fast: false,
    };
    let w = Workload::build(&o);

    // Iteration counts per system (the figure's right panel).
    let r = table1::compute(&w, &o);
    println!("inner iterations per Newton system (n={}):", o.n);
    println!("  cg    : {:?}", r.cg.steps.iter().map(|s| s.solver_iterations).collect::<Vec<_>>());
    println!(
        "  def-cg: {:?}",
        r.defcg.steps.iter().map(|s| s.solver_iterations).collect::<Vec<_>>()
    );
    let saved: isize = r
        .cg
        .steps
        .iter()
        .zip(&r.defcg.steps)
        .skip(1)
        .map(|(a, b)| a.solver_iterations as isize - b.solver_iterations as isize)
        .sum();
    println!("  saved by recycling (systems 2+): {saved} iterations\n");

    // Timing: full sequences, which is what the cumulative curves plot.
    let mut g = BenchGroup::new("fig2 — Newton sequence solve time")
        .with_config(BenchConfig { warmup: 1, iters: 5, max_seconds: 90.0 });
    g.bench("cg full sequence", || {
        std::hint::black_box(w.fit(SolverBackend::Cg, &o));
    });
    g.bench("def-cg full sequence", || {
        std::hint::black_box(w.fit(w.defcg_backend(&o), &o));
    });
    g.report();
}
