//! Bench: Fig. 3 — single-system solves at the tight tolerance (1e-8).
//!
//! Measures the paper's precision regime: one Newton system solved to
//! rel. residual 1e-8 by plain CG vs def-CG with a basis recycled from the
//! previous system. The deflated solve must be faster despite the O(nk)
//! per-iteration deflection overhead.

use krr::experiments::common::{ExpOpts, Workload};
use krr::gp::laplace::LaplaceOperator;
use krr::gp::likelihood::Logistic;
use krr::solvers::ritz::{extract, RitzConfig, RitzSelect};
use krr::solvers::{self, SolveSpec};
use krr::util::bench::{BenchConfig, BenchGroup};

fn main() {
    let o = ExpOpts {
        n: 256,
        seed: 4,
        amplitude: 1.0,
        lengthscale: 10.0,
        tol: 1e-8,
        k: 8,
        l: 12,
        max_newton: 3,
        backend: "native".into(),
        fast: false,
    };
    let w = Workload::build(&o);
    let dense = w.dense_kernel();
    let n = o.n;

    // System at f = 0 (first Newton step's operator).
    let lik = Logistic;
    let mut h = vec![0.0; n];
    lik.hess_diag(&vec![0.0; n], &mut h);
    let s: Vec<f64> = h.iter().map(|v| v.sqrt()).collect();
    let op = LaplaceOperator::new(&dense, &s);
    let b: Vec<f64> = w.data.y.iter().map(|&v| 0.5 * v).collect();

    // Recycled basis from a prior solve.
    let prior = solvers::solve(&op, &b, &SolveSpec::cg().with_tol(o.tol).with_store_l(o.l));
    let (defl, _) = extract(
        None,
        &prior.stored,
        n,
        &RitzConfig { k: o.k, select: RitzSelect::Largest, min_col_norm: 1e-12 },
    )
    .expect("ritz");

    let cg_spec = SolveSpec::cg().with_tol(1e-8);
    let def_spec = SolveSpec::defcg().with_deflation(defl).with_tol(1e-8);
    let plain = solvers::solve(&op, &b, &cg_spec);
    let deflated = solvers::solve(&op, &b, &def_spec);
    println!(
        "iterations to 1e-8 @ n={n}: cg = {}, def-cg = {} (saved {})\n",
        plain.iterations,
        deflated.iterations,
        plain.iterations as isize - deflated.iterations as isize
    );

    let mut g = BenchGroup::new("fig3 — single solve to rel. residual 1e-8")
        .with_config(BenchConfig { warmup: 1, iters: 8, max_seconds: 60.0 });
    g.bench("cg tol=1e-8", || {
        std::hint::black_box(solvers::solve(&op, &b, &cg_spec));
    });
    g.bench("def-cg(8,12) tol=1e-8", || {
        std::hint::black_box(solvers::solve(&op, &b, &def_spec));
    });
    g.report();
}
