//! Bench: Fig. 4 — iterative full-data methods vs subset baselines.
//!
//! Times a full Laplace fit (CG, def-CG) against the subset-of-data
//! method at the paper's fractions, and prints each method's final
//! accuracy (rel. error of log p(y|f) vs the exact Cholesky value), i.e.
//! both axes of the paper's scatter plot.

use krr::experiments::common::{ExpOpts, Workload};
use krr::gp::inducing::run_subset;
use krr::gp::laplace::SolverBackend;
use krr::util::bench::{BenchConfig, BenchGroup};
use krr::util::precision::to_f64;
use krr::util::rng::Rng;

fn main() {
    let o = ExpOpts {
        n: 256,
        seed: 5,
        amplitude: 1.0,
        lengthscale: 10.0,
        tol: 1e-6,
        k: 8,
        l: 12,
        max_newton: 10,
        backend: "native".into(),
        fast: false,
    };
    let w = Workload::build(&o);
    let exact = w.fit(SolverBackend::Cholesky, &o).final_log_lik();

    let mut g = BenchGroup::new("fig4 — accuracy vs cost methods")
        .with_config(BenchConfig { warmup: 1, iters: 5, max_seconds: 120.0 });

    println!("final rel. error of log p(y|f) vs exact ({exact:.3}):");
    let rel = |ll: f64| ((ll - exact).abs() / exact.abs()).max(1e-16);

    for frac in [0.05, 0.10, 0.25, 0.50] {
        let m = ((to_f64(o.n) * frac) as usize).max(4);
        let mut rng = Rng::new(9);
        let res = run_subset(&w.data, &w.kernel, m, o.max_newton, &mut rng);
        println!(
            "  subset {:>3.0}% (m={m:3}): {:.3e}",
            frac * 100.0,
            rel(res.trajectory.last().unwrap().full_log_lik)
        );
        g.bench(&format!("subset m={m}"), || {
            let mut rng = Rng::new(9);
            std::hint::black_box(run_subset(&w.data, &w.kernel, m, o.max_newton, &mut rng));
        });
    }
    let cg_fit = w.fit(SolverBackend::Cg, &o);
    let def_fit = w.fit(w.defcg_backend(&o), &o);
    println!("  cg  full data       : {:.3e}", rel(cg_fit.final_log_lik()));
    println!("  def-cg full data    : {:.3e}", rel(def_fit.final_log_lik()));

    g.bench("cg full data", || {
        std::hint::black_box(w.fit(SolverBackend::Cg, &o));
    });
    g.bench("def-cg full data", || {
        std::hint::black_box(w.fit(w.defcg_backend(&o), &o));
    });
    g.bench("cholesky full data", || {
        std::hint::black_box(w.fit(SolverBackend::Cholesky, &o));
    });
    g.report();
}
