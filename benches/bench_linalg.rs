//! Bench: linalg substrate micro-benchmarks (the L3 native hot paths).
//!
//! Reports throughput (Mops/s where meaningful) for the kernels the CG
//! loop and the experiment harness lean on: dot/axpy, dense matvec,
//! matmul, Cholesky, QR, symmetric eig, and the RBF Gram assembly.

use krr::gp::kernel::RbfKernel;
use krr::linalg::cholesky::Cholesky;
use krr::linalg::eig::sym_eig;
use krr::linalg::mat::Mat;
use krr::linalg::qr::Qr;
use krr::linalg::vec_ops::{axpy, dot};
use krr::solvers::{DenseOp, ParDenseOp, SpdOperator};
use krr::util::bench::{BenchConfig, BenchGroup};
use krr::util::pool::ThreadPool;
use krr::util::precision::to_f64;
use krr::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let mut rng = Rng::new(1);

    // Vector primitives.
    let mut g = BenchGroup::new("linalg — vector primitives (n = 100k)")
        .with_config(BenchConfig { warmup: 2, iters: 20, max_seconds: 20.0 });
    let n = 100_000;
    let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut c = b.clone();
    g.bench_with_work("dot", Some(2.0 * to_f64(n)), &mut || {
        std::hint::black_box(dot(&a, &b));
    });
    g.bench_with_work("axpy", Some(2.0 * to_f64(n)), &mut || {
        axpy(1.0001, &a, &mut c);
        std::hint::black_box(&c);
    });
    g.report();

    // Dense kernels.
    let mut g = BenchGroup::new("linalg — dense kernels")
        .with_config(BenchConfig { warmup: 1, iters: 10, max_seconds: 60.0 });
    for n in [256usize, 512, 1024] {
        let m = Mat::rand_spd(n, 1e4, &mut rng);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n];
        g.bench_with_work(&format!("matvec n={n}"), Some(2.0 * to_f64(n * n)), &mut || {
            m.matvec_into(&v, &mut y);
            std::hint::black_box(&y);
        });
    }
    for n in [128usize, 256] {
        let m1 = Mat::randn(n, n, &mut rng);
        let m2 = Mat::randn(n, n, &mut rng);
        g.bench_with_work(
            &format!("matmul n={n}"),
            Some(2.0 * to_f64(n * n * n)),
            &mut || {
                std::hint::black_box(m1.matmul(&m2));
            },
        );
    }
    for n in [128usize, 256, 512] {
        let m = Mat::rand_spd(n, 1e4, &mut rng);
        g.bench_with_work(
            &format!("cholesky n={n}"),
            Some(to_f64(n * n * n) / 3.0),
            &mut || {
                std::hint::black_box(Cholesky::factor(&m).unwrap());
            },
        );
    }
    {
        let n = 128;
        let m = Mat::rand_spd(n, 1e4, &mut rng);
        g.bench(&format!("sym_eig n={n}"), || {
            std::hint::black_box(sym_eig(&m).unwrap());
        });
        let tall = Mat::randn(512, 16, &mut rng);
        g.bench("qr 512x16", || {
            std::hint::black_box(Qr::factor(&tall).thin_q());
        });
    }
    g.report();

    // Parallel dense matvec: serial DenseOp vs pool-sharded ParDenseOp.
    // At n = 2048 the O(n²) row work dominates fork/join overhead; on
    // ≥ 4 cores the sharded path should win clearly (same row order, so
    // results are bitwise identical to serial). Repeated calls on one
    // operator exercise the parked-scratch reuse: after the first matvec
    // the operand copy recycles a single allocation instead of paying a
    // fresh Arc<Vec> heap round-trip per call, so the steady-state rows
    // below measure pure compute + copy.
    let mut g = BenchGroup::new("linalg — parallel dense matvec (n = 2048)")
        .with_config(BenchConfig { warmup: 2, iters: 20, max_seconds: 60.0 });
    {
        let n = 2048;
        // SPD via K + I on random features (cheaper to build than rand_spd
        // at this size; the matvec cost is identical).
        let feats = Mat::randn(n, 32, &mut rng);
        let mut k = RbfKernel::new(1.0, 5.0).gram(&feats);
        k.add_diag(1.0);
        let a = Arc::new(k);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n];
        let serial = DenseOp::new(&a);
        g.bench_with_work(
            &format!("serial DenseOp n={n}"),
            Some(2.0 * to_f64(n * n)),
            &mut || {
                serial.matvec(&v, &mut y);
                std::hint::black_box(&y);
            },
        );
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        for workers in [2usize, 4, cores.min(16)] {
            let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(workers)));
            g.bench_with_work(
                &format!("ParDenseOp n={n} workers={workers}"),
                Some(2.0 * to_f64(n * n)),
                &mut || {
                    par.matvec(&v, &mut y);
                    std::hint::black_box(&y);
                },
            );
        }
    }
    g.report();

    // Block application: apply_block vs the k-matvec column loop, at the
    // acceptance sizes n = 2048, k ∈ {4, 16, 64}. The block kernels
    // produce bitwise-identical outputs; the win is pure memory traffic
    // (each A row streamed once per 16-column panel instead of once per
    // column, and — for ParDenseOp — one fork/join per block instead of
    // one per column).
    let mut g = BenchGroup::new("linalg — apply_block vs matvec loop (n = 2048)")
        .with_config(BenchConfig { warmup: 1, iters: 10, max_seconds: 120.0 });
    {
        let n = 2048;
        let feats = Mat::randn(n, 32, &mut rng);
        let mut k = RbfKernel::new(1.0, 5.0).gram(&feats);
        k.add_diag(1.0);
        let a = Arc::new(k);
        let serial = DenseOp::new(&a);
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(cores.min(16))));
        for kcols in [4usize, 16, 64] {
            let xs = Mat::randn(n, kcols, &mut rng);
            let mut ys = Mat::zeros(n, kcols);
            let work = Some(2.0 * to_f64(n * n * kcols));
            let mut col = vec![0.0; n];
            let mut y = vec![0.0; n];
            g.bench_with_work(&format!("matvec-loop DenseOp k={kcols}"), work, &mut || {
                for j in 0..kcols {
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = xs[(i, j)];
                    }
                    serial.matvec(&col, &mut y);
                    ys.set_col(j, &y);
                }
                std::hint::black_box(&ys);
            });
            g.bench_with_work(&format!("apply_block DenseOp k={kcols}"), work, &mut || {
                serial.apply_block(&xs, &mut ys);
                std::hint::black_box(&ys);
            });
            g.bench_with_work(&format!("matvec-loop ParDenseOp k={kcols}"), work, &mut || {
                for j in 0..kcols {
                    for (i, c) in col.iter_mut().enumerate() {
                        *c = xs[(i, j)];
                    }
                    par.matvec(&col, &mut y);
                    ys.set_col(j, &y);
                }
                std::hint::black_box(&ys);
            });
            g.bench_with_work(&format!("apply_block ParDenseOp k={kcols}"), work, &mut || {
                par.apply_block(&xs, &mut ys);
                std::hint::black_box(&ys);
            });
        }
    }
    g.report();

    // Gram assembly (the L1 kernel's native counterpart).
    let mut g = BenchGroup::new("linalg — RBF Gram assembly (d = 784)")
        .with_config(BenchConfig { warmup: 1, iters: 5, max_seconds: 60.0 });
    for n in [128usize, 256, 512] {
        let x = Mat::randn(n, 784, &mut rng);
        let k = RbfKernel::new(1.0, 10.0);
        g.bench_with_work(
            &format!("gram n={n}"),
            Some(2.0 * to_f64(n * n) * 784.0),
            &mut || {
                std::hint::black_box(k.gram(&x));
            },
        );
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n];
        g.bench_with_work(
            &format!("gram_matvec (matrix-free) n={n}"),
            Some(2.0 * to_f64(n * n) * 784.0),
            &mut || {
                k.gram_matvec(&x, &v, &mut y);
                std::hint::black_box(&y);
            },
        );
    }
    g.report();
}
