//! Bench: coordinator throughput under sustained mixed load — the
//! service-level baseline every later scheduler/coordinator PR is
//! accountable to.
//!
//! Two workloads, both emitted to `BENCH_service.json`:
//!
//! * **distinct-operator**: S independent sequences, each with its own
//!   SPD operator, fed a pipelined ~70/30 interactive/batch stream of
//!   single-RHS requests — run at 1 and at 4 scheduler workers. Reports
//!   solves/sec, p50/p99 end-to-end latency per priority class, busy vs
//!   span seconds, utilization, and steal counts; the headline number is
//!   the 4-vs-1 worker throughput ratio (hardware permitting, ≥2×).
//! * **shared-operator**: 8 sequences sharing ONE operator `Arc` (the
//!   many-users-one-Gram-matrix shape), each submitting a 2-column block
//!   request — run with cross-sequence coalescing on and off. Reports
//!   total operator columns applied and the worst final residual for
//!   both runs: coalescing must cut matvecs at equal accuracy.
//!
//! `--smoke` (or `KRR_BENCH_FAST=1`) shrinks sizes for the CI
//! release-mode check, which only asserts the JSON exists and parses.

use krr::coordinator::SolveService;
use krr::linalg::mat::Mat;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::{SolveSpec, SpdOperator, StopReason};
use krr::util::json::Json;
use krr::util::precision::to_f64;
use krr::util::rng::Rng;
use krr::util::stats::percentile;
use std::sync::Arc;
use std::time::Instant;

/// Owning dense operator (fingerprint-less, so cross-sequence merging
/// in the shared workload rests on `Arc` identity alone).
struct OwnedDense(Mat);

impl SpdOperator for OwnedDense {
    fn n(&self) -> usize {
        self.0.rows()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.0.matvec_into(x, y);
    }
}

struct LoadShape {
    seqs: usize,
    reqs_per_seq: usize,
    n: usize,
}

struct RoundOut {
    solves_per_sec: f64,
    span_seconds: f64,
    side: Json,
}

/// One sustained-load round on a fresh service: `seqs` sequences with
/// distinct operators, `reqs_per_seq` pipelined submissions each,
/// ~70/30 interactive/batch. Returns throughput plus the JSON side.
fn distinct_op_round(workers: usize, shape: &LoadShape) -> RoundOut {
    let svc = SolveService::new(workers);
    let mut rng = Rng::new(2026);
    let cfg = RecycleConfig { k: 6, l: 10, ..Default::default() };
    let seqs: Vec<_> = (0..shape.seqs).map(|_| svc.open_sequence(cfg.clone())).collect();
    let ops: Vec<Arc<dyn SpdOperator + Send + Sync>> = (0..shape.seqs)
        .map(|_| {
            Arc::new(OwnedDense(Mat::rand_spd(shape.n, 1e4, &mut rng)))
                as Arc<dyn SpdOperator + Send + Sync>
        })
        .collect();
    let rhs: Vec<Vec<f64>> =
        (0..shape.seqs).map(|_| (0..shape.n).map(|_| rng.normal()).collect()).collect();

    let t0 = Instant::now();
    let mut futures = Vec::new();
    for _ in 0..shape.reqs_per_seq {
        for (s, seq) in seqs.iter().enumerate() {
            let interactive = rng.uniform() < 0.7;
            let mut spec = SolveSpec::defcg().with_tol(1e-8);
            if !interactive {
                spec = spec.batch();
            }
            futures.push((interactive, seq.submit(ops[s].clone(), rhs[s].clone(), None, spec)));
        }
    }
    let mut lat_interactive = Vec::new();
    let mut lat_batch = Vec::new();
    for (interactive, f) in futures {
        let (r, rep) = f.wait_report();
        assert_eq!(r.stop, StopReason::Converged);
        let lat = rep.queue_seconds + rep.solve_seconds;
        if interactive {
            lat_interactive.push(lat);
        } else {
            lat_batch.push(lat);
        }
    }
    let span = t0.elapsed().as_secs_f64();
    let snap = svc.metrics().snapshot();
    let total = to_f64(lat_interactive.len() + lat_batch.len());
    let class = |lats: &[f64]| {
        if lats.is_empty() {
            // An all-one-class draw (tiny smoke runs): no percentiles.
            return Json::obj(vec![("count", Json::num(0.0))]);
        }
        Json::obj(vec![
            ("count", Json::num(to_f64(lats.len()))),
            ("p50_seconds", Json::num(percentile(lats, 0.50))),
            ("p99_seconds", Json::num(percentile(lats, 0.99))),
        ])
    };
    RoundOut {
        solves_per_sec: total / span.max(1e-12),
        span_seconds: span,
        side: Json::obj(vec![
            ("workers", Json::num(to_f64(workers))),
            ("completed", Json::num(to_f64(snap.completed))),
            ("solves_per_sec", Json::num(total / span.max(1e-12))),
            ("span_seconds", Json::num(span)),
            ("busy_seconds", Json::num(snap.busy_seconds)),
            ("utilization", Json::num(snap.utilization())),
            ("steals", Json::num(to_f64(snap.steals))),
            ("total_matvecs", Json::num(to_f64(snap.total_matvecs))),
            ("interactive", class(&lat_interactive)),
            ("batch", class(&lat_batch)),
        ]),
    }
}

struct SharedOut {
    matvecs: f64,
    worst_residual: f64,
    side: Json,
}

/// The shared-operator workload: 8 sequences, ONE operator `Arc`, one
/// 2-column block request each, staged behind a dispatch pause so the
/// coalescer sees them together. With coalescing the leader merges the
/// peers' heads into one group solve (duplicate columns rank-drop and
/// ride nearly free); without it, 8 separate block solves run.
fn shared_op_round(coalesce: bool, n: usize) -> SharedOut {
    let svc = SolveService::new(1);
    svc.cross_sequence_coalescing(coalesce);
    let mut rng = Rng::new(77);
    let a = Mat::rand_spd(n, 1e3, &mut rng);
    let x_true = Mat::randn(n, 2, &mut rng);
    let b = a.matmul(&x_true);
    let op: Arc<dyn SpdOperator + Send + Sync> = Arc::new(OwnedDense(a));
    let cfg = RecycleConfig::default();
    let seqs: Vec<_> = (0..8).map(|_| svc.open_sequence(cfg.clone())).collect();
    let pause = svc.pause();
    let spec = SolveSpec::blockcg().with_tol(1e-9);
    let futures: Vec<_> =
        seqs.iter().map(|s| s.submit_block(op.clone(), b.clone(), spec.clone())).collect();
    drop(pause);
    let mut worst = 0.0f64;
    for f in futures {
        let r = f.wait();
        assert_eq!(r.stop, StopReason::Converged);
        worst = worst.max(r.final_residual());
    }
    let snap = svc.metrics().snapshot();
    SharedOut {
        matvecs: to_f64(snap.total_matvecs),
        worst_residual: worst,
        side: Json::obj(vec![
            ("coalescing", Json::num(if coalesce { 1.0 } else { 0.0 })),
            ("total_matvecs", Json::num(to_f64(snap.total_matvecs))),
            ("cross_seq_coalesced", Json::num(to_f64(snap.cross_seq_coalesced))),
            ("worst_final_residual", Json::num(worst)),
            ("completed", Json::num(to_f64(snap.completed))),
        ]),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("KRR_BENCH_FAST").is_ok_and(|v| v == "1");
    let shape = if smoke {
        LoadShape { seqs: 4, reqs_per_seq: 6, n: 48 }
    } else {
        LoadShape { seqs: 16, reqs_per_seq: 40, n: 96 }
    };
    let shared_n = if smoke { 48 } else { 128 };

    println!(
        "service bench ({} mode): {} sequences × {} requests, n = {}",
        if smoke { "smoke" } else { "full" },
        shape.seqs,
        shape.reqs_per_seq,
        shape.n
    );
    let w1 = distinct_op_round(1, &shape);
    let w4 = distinct_op_round(4, &shape);
    let speedup = w4.solves_per_sec / w1.solves_per_sec.max(1e-12);
    println!(
        "  distinct-op: {:.1} solves/s @ 1 worker ({:.2}s span), {:.1} solves/s @ 4 workers ({:.2}s span) — {speedup:.2}x",
        w1.solves_per_sec, w1.span_seconds, w4.solves_per_sec, w4.span_seconds
    );

    let merged = shared_op_round(true, shared_n);
    let split = shared_op_round(false, shared_n);
    println!(
        "  shared-op: {} column applies coalesced vs {} uncoalesced ({:.2}x), residuals {:.2e} / {:.2e}",
        merged.matvecs,
        split.matvecs,
        split.matvecs / merged.matvecs.max(1.0),
        merged.worst_residual,
        split.worst_residual
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("service_throughput")),
        ("smoke", Json::num(if smoke { 1.0 } else { 0.0 })),
        (
            "distinct_op",
            Json::obj(vec![
                ("sequences", Json::num(to_f64(shape.seqs))),
                ("requests_per_sequence", Json::num(to_f64(shape.reqs_per_seq))),
                ("n", Json::num(to_f64(shape.n))),
                ("workers_1", w1.side),
                ("workers_4", w4.side),
                ("speedup_4_vs_1", Json::num(speedup)),
            ]),
        ),
        (
            "shared_op",
            Json::obj(vec![
                ("sequences", Json::num(8.0)),
                ("n", Json::num(to_f64(shared_n))),
                ("coalesced", merged.side),
                ("uncoalesced", split.side),
                (
                    "matvec_ratio_uncoalesced_over_coalesced",
                    Json::num(split.matvecs / merged.matvecs.max(1.0)),
                ),
            ]),
        ),
    ]);
    std::fs::write("BENCH_service.json", doc.to_string_pretty())
        .expect("write BENCH_service.json");
    println!("  wrote BENCH_service.json");
}
