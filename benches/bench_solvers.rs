//! Bench: solver layer — CG iteration cost, deflation overhead, recycling
//! pipeline, and the engine matvec path (PJRT artifacts when built, the
//! native f32 fallback otherwise).

use krr::linalg::mat::Mat;
use krr::runtime::engine::{Engine, Tensor};
use krr::runtime::ops::EngineKernel;
use krr::solvers::recycle::{RecycleBudget, RecycleConfig, RecycleManager};
use krr::solvers::ritz::{extract, RitzConfig, RitzSelect};
use krr::solvers::strategy::StrategyChoice;
use krr::solvers::{self, DenseOp, SolveSpec};
use krr::util::bench::{BenchConfig, BenchGroup};
use krr::util::json::Json;
use krr::util::precision::{demote, to_f32, to_f64};
use krr::util::rng::Rng;
use std::sync::Arc;

/// Drifting SPD sequence (the bench-wide drift model: shrinking
/// symmetric perturbations of one base system).
fn drifting_systems(n: usize, count: usize, seed: u64) -> Vec<Mat> {
    let mut rng = Rng::new(seed);
    let a0 = Mat::rand_spd(n, 1e5, &mut rng);
    let mut delta = Mat::randn(n, n, &mut rng);
    delta.symmetrize();
    delta.scale_in_place(1e-3 / to_f64(n));
    (0..count)
        .map(|i| {
            let mut a = a0.clone();
            let mut d = delta.clone();
            d.scale_in_place(1.0 / (1.0 + to_f64(i)));
            a.add_in_place(&d);
            a.add_diag(1e-6);
            a
        })
        .collect()
}

/// Bounded vs unbounded recycling over the drifting 5-system sequence:
/// measures bytes held, per-system iterations, and total matvecs for an
/// unbounded k=16/ℓ=24 manager against a `RecycleBudget` capping the
/// footprint at 25% (4 basis + 6 stored column pairs), and emits
/// `BENCH_recycle_memory.json` for CI to archive. On this generic
/// log-spaced spectrum the budget *does* cost iterations — the honest
/// trade-off (see DESIGN.md "Memory model & budgets"); the ≤2-iteration
/// bound holds on paper-shaped outlier spectra and is pinned by the
/// `quarter_budget_loses_at_most_two_iterations_per_system` test.
fn recycle_memory_report(n: usize) {
    let systems = drifting_systems(n, 5, 9);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + to_f64(i % 7)).collect();
    let spec = SolveSpec::defcg().with_tol(1e-6);
    let run = |budget: Option<RecycleBudget>| {
        let mut cfg = RecycleConfig { k: 16, l: 24, ..Default::default() };
        if let Some(bgt) = budget {
            cfg.budget = bgt;
        }
        let mut mgr = RecycleManager::new(cfg);
        let mut iters = Vec::new();
        let mut bytes = Vec::new();
        let mut matvecs = 0usize;
        for a in &systems {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            assert_eq!(r.stop, krr::solvers::StopReason::Converged);
            iters.push(to_f64(r.iterations));
            matvecs += r.matvecs;
            bytes.push(to_f64(mgr.bytes_held()));
        }
        (iters, bytes, matvecs, mgr.truncations())
    };

    let (u_iters, u_bytes, u_matvecs, _) = run(None);
    let budget = RecycleBudget::capping_cols(n, 4, 6);
    let (b_iters, b_bytes, b_matvecs, b_truncs) = run(Some(budget));

    let side = |iters: &[f64], bytes: &[f64], matvecs: usize| {
        Json::obj(vec![
            ("iterations", Json::arr_num(iters)),
            ("bytes_held", Json::arr_num(bytes)),
            ("peak_bytes", Json::num(bytes.iter().cloned().fold(0.0, f64::max))),
            ("total_matvecs", Json::num(to_f64(matvecs))),
        ])
    };
    let doc = Json::obj(vec![
        ("bench", Json::str("recycle_memory")),
        ("n", Json::num(to_f64(n))),
        ("systems", Json::num(to_f64(systems.len()))),
        ("tol", Json::num(1e-6)),
        ("unbounded", side(&u_iters, &u_bytes, u_matvecs)),
        (
            "bounded",
            Json::obj(vec![
                ("basis_cols", Json::num(to_f64(budget.basis_cols(n)))),
                ("stored_cols", Json::num(to_f64(budget.stored_cols(n)))),
                ("truncations", Json::num(to_f64(b_truncs))),
                ("side", side(&b_iters, &b_bytes, b_matvecs)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_recycle_memory.json", doc.to_string_pretty())
        .expect("write BENCH_recycle_memory.json");
    println!("recycle memory (n = {n}, 5-system drift, tol 1e-6):");
    println!(
        "  unbounded k=16 l=24: iters {u_iters:?}, final {:.0} bytes",
        u_bytes.last().unwrap()
    );
    println!(
        "  bounded 4+6 cols:    iters {b_iters:?}, final {:.0} bytes, {b_truncs} truncations",
        b_bytes.last().unwrap()
    );
    println!("  wrote BENCH_recycle_memory.json");
}

/// Strategy comparison over the drifting 5-system sequence: every
/// selection rule (plus adaptive sizing) runs the same sequence under
/// the same k/ℓ, and the report records per-system iterations, total
/// matvecs, the final basis size, and the last strategy decision
/// (k chosen vs offered, predicted savings) — emitted as
/// `BENCH_strategy.json` for CI to archive.
fn strategy_report(n: usize) {
    let systems = drifting_systems(n, 5, 9);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + to_f64(i % 7)).collect();
    let spec = SolveSpec::defcg().with_tol(1e-6);
    let strategies = [
        ("harmonic-largest", StrategyChoice::HarmonicLargest),
        ("ritz-smallest", StrategyChoice::RitzSmallest),
        ("two-sided", StrategyChoice::TwoSided),
        ("adaptive-k", StrategyChoice::Auto),
    ];
    let mut rows = Vec::new();
    println!("strategy comparison (n = {n}, 5-system drift, tol 1e-6, k=8 l=12):");
    for (name, choice) in strategies {
        let mut mgr = RecycleManager::new(RecycleConfig {
            k: 8,
            l: 12,
            strategy: choice,
            ..Default::default()
        });
        let mut iters = Vec::new();
        let mut matvecs = 0usize;
        for a in &systems {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            assert_eq!(r.stop, krr::solvers::StopReason::Converged);
            iters.push(to_f64(r.iterations));
            matvecs += r.matvecs;
        }
        let d = mgr.last_decision();
        println!(
            "  {name:<16} iters {iters:?}, {matvecs} matvecs, k {} of {} offered",
            d.k_chosen, d.k_offered
        );
        rows.push(Json::obj(vec![
            ("strategy", Json::str(name)),
            ("iterations", Json::arr_num(&iters)),
            ("total_matvecs", Json::num(to_f64(matvecs))),
            ("final_k_active", Json::num(to_f64(mgr.k_active()))),
            ("k_offered", Json::num(to_f64(d.k_offered))),
            ("k_chosen", Json::num(to_f64(d.k_chosen))),
            ("predicted_savings", Json::num(d.predicted_savings())),
            ("strategy_shrinks", Json::num(to_f64(mgr.strategy_shrinks()))),
        ]));
    }
    let doc = Json::obj(vec![
        ("bench", Json::str("strategy")),
        ("n", Json::num(to_f64(n))),
        ("systems", Json::num(to_f64(systems.len()))),
        ("tol", Json::num(1e-6)),
        ("k", Json::num(8.0)),
        ("l", Json::num(12.0)),
        ("strategies", Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_strategy.json", doc.to_string_pretty())
        .expect("write BENCH_strategy.json");
    println!("  wrote BENCH_strategy.json");
}

fn main() {
    // `--smoke` (CI's release-mode check) runs only the memory and
    // strategy measurements at a CI-sized n and skips the timed groups.
    let smoke = std::env::args().any(|a| a == "--smoke");
    recycle_memory_report(if smoke { 192 } else { 512 });
    strategy_report(if smoke { 192 } else { 512 });
    if smoke {
        return;
    }

    let mut rng = Rng::new(2);
    let n = 512;
    let a = Mat::rand_spd(n, 1e5, &mut rng);
    let b: Vec<f64> = (0..n).map(|i| 1.0 + to_f64(i % 7)).collect();
    let op = DenseOp::new(&a);

    // Recycled basis for the def-CG cases.
    let run = solvers::solve(&op, &b, &SolveSpec::cg().with_tol(1e-8).with_store_l(12));
    let (defl, _) = extract(
        None,
        &run.stored,
        n,
        &RitzConfig { k: 8, select: RitzSelect::Largest, min_col_norm: 1e-12 },
    )
    .expect("ritz");

    // One entry point, four policies: the specs are the benchmark matrix.
    let cg_spec = SolveSpec::cg().with_tol(1e-6);
    let pcg_spec = SolveSpec::pcg().with_jacobi(&op).with_tol(1e-6);
    let def_spec = SolveSpec::defcg().with_deflation(defl).with_tol(1e-6);
    let composed_spec = def_spec.clone().with_jacobi(&op);

    let mut g = BenchGroup::new("solvers — single-system costs (n = 512)")
        .with_config(BenchConfig { warmup: 1, iters: 8, max_seconds: 90.0 });
    g.bench("cg tol=1e-6", || {
        std::hint::black_box(solvers::solve(&op, &b, &cg_spec));
    });
    g.bench("pcg-jacobi tol=1e-6", || {
        std::hint::black_box(solvers::solve(&op, &b, &pcg_spec));
    });
    g.bench("def-cg(8) tol=1e-6", || {
        std::hint::black_box(solvers::solve(&op, &b, &def_spec));
    });
    g.bench("def-cg(8)+jacobi tol=1e-6", || {
        std::hint::black_box(solvers::solve(&op, &b, &composed_spec));
    });
    g.bench("ritz extraction k=8 l=12", || {
        std::hint::black_box(extract(
            None,
            &run.stored,
            n,
            &RitzConfig { k: 8, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        ));
    });
    g.bench("recycle manager 4-system sequence", || {
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        for _ in 0..4 {
            std::hint::black_box(mgr.solve_next(&op, &b, None, &SolveSpec::defcg().with_tol(1e-6)));
        }
    });
    g.report();

    // Block paths: the multi-RHS solve and the AW refresh, both driven by
    // apply_block since the block-first redesign. The per-column matvec
    // loop baselines are what those paths compiled to before.
    let mut g = BenchGroup::new("solvers — block application paths (n = 512)")
        .with_config(BenchConfig { warmup: 1, iters: 8, max_seconds: 90.0 });
    {
        let mut rng = Rng::new(4);
        for s in [4usize, 16] {
            let bs = Mat::randn(n, s, &mut rng);
            g.bench(&format!("block-CG s={s} tol=1e-6"), || {
                std::hint::black_box(solvers::solve_block(
                    &op,
                    &bs,
                    &SolveSpec::blockcg().with_tol(1e-6),
                ));
            });
            g.bench(&format!("{s} independent CG solves tol=1e-6"), || {
                for j in 0..s {
                    std::hint::black_box(solvers::solve(&op, &bs.col(j), &cg_spec));
                }
            });
        }
        // AW refresh: one apply_block over the k-column basis vs the old
        // per-column loop.
        use krr::solvers::defcg::Deflation;
        use krr::solvers::SpdOperator;
        let w = krr::linalg::qr::Qr::factor(&Mat::randn(n, 8, &mut rng)).thin_q();
        let mut d = Deflation::new(w.clone(), Mat::zeros(n, 8));
        g.bench("AW refresh k=8 (apply_block)", || {
            std::hint::black_box(d.refresh(&op));
        });
        let mut aw_loop = Mat::zeros(n, 8);
        let mut y = vec![0.0; n];
        g.bench("AW refresh k=8 (matvec loop)", || {
            for j in 0..8 {
                op.matvec(&w.col(j), &mut y);
                aw_loop.set_col(j, &y);
            }
            std::hint::black_box(&aw_loop);
        });
    }
    g.report();

    // Block recycling: deflated vs plain block CG over a drifting
    // 5-system sequence (the coordinator's coalesced multi-RHS serving
    // path). The deflated run carries the recycle manager's basis, fed by
    // the block runs themselves; the plain run restarts cold per system.
    let mut g = BenchGroup::new("solvers — recycled block sequences (n = 512, 5 systems)")
        .with_config(BenchConfig { warmup: 1, iters: 4, max_seconds: 120.0 });
    {
        let mut rng = Rng::new(9);
        let mut delta = Mat::randn(n, n, &mut rng);
        delta.symmetrize();
        delta.scale_in_place(1e-3 / to_f64(n));
        let systems: Vec<Mat> = (0..5)
            .map(|i| {
                let mut ai = a.clone();
                let mut d = delta.clone();
                d.scale_in_place(1.0 / (1.0 + to_f64(i)));
                ai.add_in_place(&d);
                ai.add_diag(1e-6);
                ai
            })
            .collect();
        for s in [4usize, 16] {
            let bs = Mat::randn(n, s, &mut rng);
            let spec = SolveSpec::blockcg().with_tol(1e-6);
            g.bench(&format!("plain block-CG s={s}, 5-system drift"), || {
                for ai in &systems {
                    std::hint::black_box(solvers::solve_block(&DenseOp::new(ai), &bs, &spec));
                }
            });
            g.bench(&format!("deflated block-CG s={s}, 5-system drift (recycled)"), || {
                let mut mgr =
                    RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
                for ai in &systems {
                    std::hint::black_box(mgr.solve_block(&DenseOp::new(ai), &bs, &spec));
                }
            });
        }
    }
    g.report();

    // Engine path: PJRT artifacts when built, the native f32 fallback
    // otherwise — the bench runs offline either way.
    {
        let eng = Arc::new(Engine::auto("artifacts"));
        let backend = eng.backend_name();
        let sizes = eng.manifest().sizes.clone();
        // The largest size ≤ 512 keeps the native gram build quick while
        // still exercising a realistic resident-K workload.
        let ne = eng.manifest().best_size_for(512).unwrap_or(*sizes.iter().max().unwrap_or(&256));
        let dim = eng.manifest().dim;
        let mut data = vec![0.0f32; ne * dim];
        let mut r2 = Rng::new(3);
        for v in data.iter_mut() {
            *v = demote(r2.normal() * 0.3);
        }
        let x = Tensor::mat(ne, dim, data);
        let t0 = std::time::Instant::now();
        let ek = EngineKernel::from_features(eng, &x, 1.0, 10.0).expect("gram");
        println!(
            "engine ({backend}): gram_n{ne} built in {:.3}s (pjrt: includes XLA compile)",
            t0.elapsed().as_secs_f64()
        );
        let v: Vec<f32> = (0..ne).map(|i| to_f32(i % 5) - 2.0).collect();
        let s: Vec<f32> = vec![0.5; ne];
        let mut g = BenchGroup::new(&format!("solvers — engine ({backend}) matvec path"))
            .with_config(BenchConfig { warmup: 2, iters: 10, max_seconds: 60.0 });
        g.bench_with_work(
            &format!("engine kmatvec n={ne}"),
            Some(2.0 * to_f64(ne * ne)),
            &mut || {
                std::hint::black_box(ek.kmatvec_f32(&v).unwrap());
            },
        );
        g.bench_with_work(
            &format!("engine amatvec (fused I+SKS) n={ne}"),
            Some(2.0 * to_f64(ne * ne)),
            &mut || {
                std::hint::black_box(ek.amatvec_f32(&s, &v).unwrap());
            },
        );
        g.report();
    }
}
