//! Bench: Table 1 — end-to-end Laplace fits per solver backend.
//!
//! Regenerates the paper's Table-1 comparison as a timing benchmark:
//! full Newton sequences with Cholesky / CG / def-CG(8,12) at two problem
//! sizes. Expected ordering (cumulative time): Cholesky > CG > def-CG,
//! with the gap growing in n.

use krr::experiments::common::{ExpOpts, Workload};
use krr::gp::laplace::SolverBackend;
use krr::util::bench::{BenchConfig, BenchGroup};

fn opts(n: usize) -> ExpOpts {
    ExpOpts {
        n,
        seed: 1,
        amplitude: 1.0,
        lengthscale: 10.0,
        tol: 1e-5,
        k: 8,
        l: 12,
        max_newton: 10,
        backend: "native".into(),
        fast: false,
    }
}

fn main() {
    let mut g = BenchGroup::new("table1 — full Laplace fit per backend")
        .with_config(BenchConfig { warmup: 1, iters: 5, max_seconds: 120.0 });
    for n in [128usize, 256, 384] {
        let o = opts(n);
        let w = Workload::build(&o);
        g.bench(&format!("cholesky n={n}"), || {
            std::hint::black_box(w.fit(SolverBackend::Cholesky, &o));
        });
        g.bench(&format!("cg n={n}"), || {
            std::hint::black_box(w.fit(SolverBackend::Cg, &o));
        });
        g.bench(&format!("def-cg(8,12) n={n}"), || {
            std::hint::black_box(w.fit(w.defcg_backend(&o), &o));
        });
    }
    g.report();

    // Sanity: print the expected ordering for the largest size.
    let o = opts(384);
    let w = Workload::build(&o);
    let tc = w.fit(SolverBackend::Cholesky, &o).total_solve_seconds();
    let tg = w.fit(SolverBackend::Cg, &o).total_solve_seconds();
    let td = w.fit(w.defcg_backend(&o), &o).total_solve_seconds();
    println!("cumulative solve seconds @ n=384: cholesky {tc:.3} | cg {tg:.3} | def-cg {td:.3}");
}
