//! End-to-end driver: full GP classification on synthetic infinite-MNIST.
//!
//! ```text
//! cargo run --release --example gp_classification -- [n] [backend]
//! ```
//!
//! This is the repository's END-TO-END VALIDATION workload (recorded in
//! EXPERIMENTS.md): it exercises every layer on the paper's actual task —
//!
//!   data  → synthetic 3-vs-5 digits (28×28, 784-dim features)
//!   L1/L2 → RBF Gram + fused Newton-system matvecs (AOT artifacts when
//!           backend = engine; rust-native otherwise)
//!   L3    → Laplace/Newton loop with three solver backends; def-CG
//!           recycles its harmonic-Ritz subspace across Newton steps
//!
//! and reports the Table-1-style progression plus train/test accuracy.

use krr::data::digits::{generate, DigitsConfig};
use krr::gp::kernel::RbfKernel;
use krr::gp::laplace::{
    DenseKernel, KernelOp, LaplaceConfig, LaplaceFit, LaplaceGpc, SolverBackend,
};
use krr::gp::likelihood::Logistic;
use krr::runtime::engine::{Engine, Tensor};
use krr::runtime::ops::EngineKernel;
use krr::solvers::recycle::RecycleConfig;
use krr::util::precision::to_f64;
use krr::util::rng::Rng;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let backend = args.get(1).map(|s| s.as_str()).unwrap_or("native").to_string();
    let (amp, ls) = (1.0, 10.0);

    println!("GPC end-to-end: n = {n}, backend = {backend}, RBF(θ={amp}, λ={ls})\n");

    // Dataset: train + held-out test.
    let all = generate(&DigitsConfig { n: n + n / 4, seed: 7, ..Default::default() });
    let mut rng = Rng::new(1);
    let (train, test) = all.split(to_f64(n) / to_f64(all.n()), &mut rng);
    let train = krr::data::digits::Digits {
        x: train.x.take_rows(&(0..n.min(train.n())).collect::<Vec<_>>()),
        y: train.y[..n.min(train.n())].to_vec(),
    };
    println!("train = {} images, test = {} images", train.n(), test.n());

    // Kernel operator per backend.
    let kernel = RbfKernel::new(amp, ls);
    let engine_kernel: Option<EngineKernel>;
    let native_kernel: Option<DenseKernel>;
    let kop: &dyn KernelOp = if backend == "engine" {
        // PJRT artifacts when built (`make artifacts` + feature `pjrt`),
        // the native f32 engine otherwise — works fully offline.
        let eng = Arc::new(Engine::auto("artifacts"));
        println!("engine backend: {}", eng.backend_name());
        assert!(
            eng.manifest().sizes.contains(&train.n()),
            "n={} not an artifact size {:?}",
            train.n(),
            eng.manifest().sizes
        );
        let x32 = Tensor::mat(train.n(), train.x.cols(), train.x.to_f32());
        engine_kernel =
            Some(EngineKernel::from_features(eng, &x32, amp, ls).expect("gram on device"));
        engine_kernel.as_ref().unwrap()
    } else {
        native_kernel = Some(DenseKernel::new(kernel.gram(&train.x)));
        engine_kernel = None;
        native_kernel.as_ref().unwrap()
    };
    let _ = &engine_kernel;

    // Fit with def-CG(8,12) — the paper's configuration.
    let cfg = LaplaceConfig {
        solver: SolverBackend::DefCg(RecycleConfig { k: 8, l: 12, ..Default::default() }),
        solve_tol: 1e-5,
        newton_tol: 1.0,
        max_newton: 15,
        ..Default::default()
    };
    let mut gpc = LaplaceGpc::new(kop, &train.y, cfg);
    let fit = gpc.fit();
    report(&fit);

    // Train accuracy from the latent mode; test accuracy via the
    // cross-Gram predictive mean f* = K*ᵀ a.
    let lik = Logistic;
    let train_acc = accuracy(&train.y, &fit.f_hat);
    let cross = kernel.cross_gram(&train.x, &test.x);
    let f_test = gpc.predict_latent(&cross, &fit);
    let test_acc = accuracy(&test.y, &f_test);
    let mean_p: f64 = f_test.iter().map(|&f| lik.predict(f)).sum::<f64>() / to_f64(f_test.len());
    println!(
        "\ntrain accuracy = {:.2}%   test accuracy = {:.2}%   mean p(3|x) on test = {:.3}",
        100.0 * train_acc,
        100.0 * test_acc,
        mean_p
    );
    assert!(fit.converged, "Newton must converge");
    assert!(train_acc > 0.95, "train accuracy too low: {train_acc}");
    assert!(test_acc > 0.9, "test accuracy too low: {test_acc}");
    println!("OK");
}

fn report(fit: &LaplaceFit) {
    println!("It. | log p(y|f)   | inner iters | defl.dim | t_cum [s]");
    println!("----+--------------+-------------+----------+----------");
    for s in &fit.steps {
        println!(
            "{:3} | {:12.3} | {:11} | {:8} | {:.3}",
            s.newton_iter, s.log_lik, s.solver_iterations, s.deflation_dim, s.cumulative_seconds
        );
    }
    println!(
        "converged = {} after {} Newton steps, total inner iterations = {}",
        fit.converged,
        fit.steps.len(),
        fit.steps.iter().map(|s| s.solver_iterations).sum::<usize>()
    );
}

fn accuracy(y: &[f64], f: &[f64]) -> f64 {
    let correct = y.iter().zip(f).filter(|(&yi, &fi)| yi * fi > 0.0).count();
    to_f64(correct) / to_f64(y.len())
}
