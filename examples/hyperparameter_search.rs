//! Hyperparameter search — the paper's *outer* loop (§1).
//!
//! ```text
//! cargo run --release --example hyperparameter_search
//! ```
//!
//! Grid-searches the RBF (amplitude, lengthscale) over a synthetic-MNIST
//! GPC problem. Every grid point runs a full Laplace/Newton fit — itself a
//! sequence of SPD systems — so the whole search is a *sequence of
//! sequences*, exactly the workload subspace recycling targets. The run
//! compares total inner-solver iterations with plain CG vs def-CG.

use krr::data::digits::{generate, DigitsConfig};
use krr::gp::hyper::grid_search;
use krr::gp::laplace::SolverBackend;
use krr::solvers::recycle::RecycleConfig;

fn main() {
    let n = 200;
    let data = generate(&DigitsConfig { n, seed: 3, ..Default::default() });
    let amplitudes = [0.5, 1.0, 2.0];
    let lengthscales = [3.0, 10.0, 30.0];
    println!(
        "hyperparameter grid search: n = {n}, {}×{} grid\n",
        amplitudes.len(),
        lengthscales.len()
    );

    let cg = grid_search(&data, &amplitudes, &lengthscales, SolverBackend::Cg, 10);
    let defcg = grid_search(
        &data,
        &amplitudes,
        &lengthscales,
        SolverBackend::DefCg(RecycleConfig { k: 8, l: 12, ..Default::default() }),
        10,
    );

    println!("   θ    |    λ    |      Ψ      | cg iters | defcg iters");
    println!("--------+---------+-------------+----------+------------");
    for (a, b) in cg.evaluated.iter().zip(&defcg.evaluated) {
        println!(
            "{:7.2} | {:7.2} | {:11.3} | {:8} | {:10}",
            a.amplitude, a.lengthscale, a.psi, a.solver_iterations, b.solver_iterations
        );
    }

    let total_cg: usize = cg.evaluated.iter().map(|p| p.solver_iterations).sum();
    let total_def: usize = defcg.evaluated.iter().map(|p| p.solver_iterations).sum();
    println!(
        "\nbest (by Ψ): θ = {}, λ = {} (Ψ = {:.3})",
        cg.best.amplitude, cg.best.lengthscale, cg.best.psi
    );
    println!(
        "total inner iterations: cg = {total_cg}, def-cg = {total_def} \
         ({:.0}% saved within each fit's Newton sequence)",
        100.0 * (total_cg as f64 - total_def as f64) / total_cg as f64
    );
    assert_eq!(
        (cg.best.amplitude, cg.best.lengthscale),
        (defcg.best.amplitude, defcg.best.lengthscale),
        "both backends must find the same optimum"
    );
    assert!(total_def <= total_cg, "recycling should not cost iterations");
    println!("OK");
}
