//! Hyperparameter search — the paper's *outer* loop (§1).
//!
//! ```text
//! cargo run --release --example hyperparameter_search
//! ```
//!
//! Two stages over a synthetic-MNIST problem:
//!
//! 1. **GPC (amplitude, lengthscale) grid** — every grid point runs a full
//!    Laplace/Newton fit (itself a sequence of SPD systems), so the search
//!    is a *sequence of sequences*; compares plain CG vs def-CG totals.
//! 2. **Regression (amplitude, σ) grid via operator algebra** — at the
//!    best lengthscale, the entire `(θ, σ)` plane is solved as
//!    `ShiftedOp(ScaledOp(K, θ²), σ²)` views over ONE Gram matrix: zero
//!    kernel rebuilds (the old per-point `gram()` was the dominant cost),
//!    one recycle manager carrying the subspace across the whole plane.

use krr::data::digits::{generate, DigitsConfig};
use krr::gp::hyper::{grid_search, sigma_grid_search};
use krr::gp::laplace::SolverBackend;
use krr::solvers::recycle::RecycleConfig;
use krr::util::precision::to_f64;

fn main() {
    let n = 200;
    let data = generate(&DigitsConfig { n, seed: 3, ..Default::default() });
    let amplitudes = [0.5, 1.0, 2.0];
    let lengthscales = [3.0, 10.0, 30.0];
    println!(
        "hyperparameter grid search: n = {n}, {}×{} grid\n",
        amplitudes.len(),
        lengthscales.len()
    );

    let cg = grid_search(&data, &amplitudes, &lengthscales, SolverBackend::Cg, 10);
    let defcg = grid_search(
        &data,
        &amplitudes,
        &lengthscales,
        SolverBackend::DefCg(RecycleConfig { k: 8, l: 12, ..Default::default() }),
        10,
    );

    println!("   θ    |    λ    |      Ψ      | cg iters | defcg iters");
    println!("--------+---------+-------------+----------+------------");
    for (a, b) in cg.evaluated.iter().zip(&defcg.evaluated) {
        println!(
            "{:7.2} | {:7.2} | {:11.3} | {:8} | {:10}",
            a.amplitude, a.lengthscale, a.psi, a.solver_iterations, b.solver_iterations
        );
    }

    let total_cg: usize = cg.evaluated.iter().map(|p| p.solver_iterations).sum();
    let total_def: usize = defcg.evaluated.iter().map(|p| p.solver_iterations).sum();
    println!(
        "\nbest (by Ψ): θ = {}, λ = {} (Ψ = {:.3})",
        cg.best.amplitude, cg.best.lengthscale, cg.best.psi
    );
    println!(
        "total inner iterations: cg = {total_cg}, def-cg = {total_def} \
         ({:.0}% saved within each fit's Newton sequence)",
        100.0 * (to_f64(total_cg) - to_f64(total_def)) / to_f64(total_cg)
    );
    assert_eq!(
        (cg.best.amplitude, cg.best.lengthscale),
        (defcg.best.amplitude, defcg.best.lengthscale),
        "both backends must find the same optimum"
    );
    assert!(total_def <= total_cg, "recycling should not cost iterations");

    // Stage 2: the (θ, σ) regularization plane at the best lengthscale as
    // operator views over ONE Gram matrix. σ descends within each θ so
    // every system inherits a basis from an easier neighbour.
    let best_ls = cg.best.lengthscale;
    let amps = [0.5, 1.0, 2.0];
    let sigmas = [0.8, 0.6, 0.45, 0.35];
    println!(
        "\nregression σ-grid at λ = {best_ls}: {}×{} points, ONE gram build \
         (was {} builds when each point re-materialized θ²K + σ²I)",
        amps.len(),
        sigmas.len(),
        amps.len() * sigmas.len()
    );
    // The σ-grid runs through the solve service as Priority::Batch
    // requests with a 10 s per-grid-point deadline: a pathological point
    // comes back as a DeadlineExceeded partial answer (whose Krylov work
    // still feeds the recycled basis) instead of stalling the search.
    let recycled = sigma_grid_search(
        &data.x,
        &data.y,
        best_ls,
        &amps,
        &sigmas,
        RecycleConfig { k: 8, l: 12, ..Default::default() },
        1e-8,
        Some(std::time::Duration::from_secs(10)),
    );
    let plain = sigma_grid_search(
        &data.x,
        &data.y,
        best_ls,
        &amps,
        &sigmas,
        RecycleConfig { k: 0, l: 0, ..Default::default() },
        1e-8,
        Some(std::time::Duration::from_secs(10)),
    );
    println!("   θ    |    σ    |  −½yᵀα   | plain iters | recycled iters | k");
    println!("--------+---------+----------+-------------+----------------+---");
    for (p, r) in plain.iter().zip(&recycled) {
        println!(
            "{:7.2} | {:7.2} | {:8.2} | {:11} | {:14} | {:2}",
            r.amplitude, r.noise, r.data_fit, p.solver_iterations, r.solver_iterations,
            r.deflation_dim
        );
    }
    let tot_plain: usize = plain.iter().skip(1).map(|p| p.solver_iterations).sum();
    let tot_rec: usize = recycled.iter().skip(1).map(|p| p.solver_iterations).sum();
    println!(
        "\nσ-grid totals (points 2..): plain = {tot_plain}, recycled = {tot_rec} \
         ({:.0}% saved, with zero kernel rebuilds either way)",
        100.0 * (to_f64(tot_plain) - to_f64(tot_rec)) / to_f64(tot_plain)
    );
    assert!(
        tot_rec < tot_plain,
        "recycling across the σ-grid should save iterations"
    );
    println!("OK");
}
