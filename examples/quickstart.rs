//! Quickstart: one solve API, four policies, plus recycling across a
//! sequence of related SPD systems.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds a slowly drifting sequence of SPD matrices (the shape any outer
//! optimization loop produces) and solves it four ways through the single
//! `SolveSpec` entry point — plain CG, Jacobi-PCG, def-CG with recycling,
//! and def-CG through the coordinator service — printing the per-system
//! iteration counts. The recycled runs need visibly fewer iterations from
//! the second system on.

use krr::linalg::mat::Mat;
use krr::solvers::recycle::{RecycleConfig, RecycleManager};
use krr::solvers::{self, DenseOp, SolveSpec, SpdOperator};
use krr::util::precision::to_f64;
use krr::util::rng::Rng;

fn main() {
    let n = 300;
    let systems = 6;
    println!("quickstart: sequence of {systems} drifting SPD systems, n = {n}\n");

    // A_i = A_0 + (shrinking perturbation)_i — like a converging Newton loop.
    let mut rng = Rng::new(0);
    let a0 = Mat::rand_spd(n, 1e5, &mut rng);
    let mut delta = Mat::randn(n, n, &mut rng);
    delta.symmetrize();
    delta.scale_in_place(1e-4);
    let seq: Vec<Mat> = (0..systems)
        .map(|i| {
            let mut a = a0.clone();
            let mut d = delta.clone();
            d.scale_in_place(1.0 / (1.0 + to_f64(i)));
            a.add_in_place(&d);
            a.add_diag(1e-6);
            a
        })
        .collect();
    let b: Vec<f64> = (0..n).map(|i| 1.0 + to_f64((i * 7) % 11)).collect();

    // 1) Plain CG: every system starts from scratch.
    let cg_spec = SolveSpec::cg().with_tol(1e-8);
    let cg_iters: Vec<usize> = seq
        .iter()
        .map(|a| solvers::solve(&DenseOp::new(a), &b, &cg_spec).iterations)
        .collect();
    println!("plain CG      iterations/system: {cg_iters:?}");

    // 2) Jacobi-PCG: same entry point, the preconditioner is data on the
    //    spec (built from the operator's exact diagonal).
    let pcg_iters: Vec<usize> = seq
        .iter()
        .map(|a| {
            let op = DenseOp::new(a);
            let spec = SolveSpec::pcg().with_jacobi(&op).with_tol(1e-8);
            solvers::solve(&op, &b, &spec).iterations
        })
        .collect();
    println!("jacobi PCG    iterations/system: {pcg_iters:?}");

    // 3) def-CG(8, 12) with the recycle manager carrying W across systems.
    let def_spec = SolveSpec::defcg().with_tol(1e-8);
    let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
    let def_iters: Vec<usize> = seq
        .iter()
        .map(|a| mgr.solve_next(&DenseOp::new(a), &b, None, &def_spec).iterations)
        .collect();
    println!(
        "def-CG(8,12)  iterations/system: {def_iters:?}   (recycled k={})",
        mgr.k_active()
    );

    // 4) The same through the coordinator service (the deployable shape):
    //    every submit carries its own SolveSpec.
    struct Owned(Mat);
    impl SpdOperator for Owned {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.0.matvec_into(x, y);
        }
    }
    let svc = krr::coordinator::SolveService::new(2);
    let seqh = svc.open_sequence(RecycleConfig { k: 8, l: 12, ..Default::default() });
    let tickets: Vec<_> = seq
        .iter()
        .map(|a| {
            seqh.submit(
                std::sync::Arc::new(Owned(a.clone())),
                b.clone(),
                None,
                def_spec.clone(),
            )
        })
        .collect();
    let svc_iters: Vec<usize> = tickets.into_iter().map(|t| t.wait().iterations).collect();
    println!("via service   iterations/system: {svc_iters:?}");

    let saved: isize = cg_iters
        .iter()
        .zip(&def_iters)
        .skip(1)
        .map(|(c, d)| *c as isize - *d as isize)
        .sum();
    println!(
        "\nrecycling saved {saved} iterations over systems 2..{systems} \
         ({:.0}% of plain CG's work there)",
        100.0 * to_f64(saved) / to_f64(cg_iters.iter().skip(1).sum::<usize>())
    );
    assert!(saved > 0, "recycling should save iterations on this workload");
    println!("OK");
}
