//! The coordinator as a service: concurrent solve sequences sharing a pool.
//!
//! ```text
//! cargo run --release --example solver_service
//! ```
//!
//! Simulates a multi-tenant GP-fitting service: several clients each own a
//! *sequence* of related SPD systems (their model's Newton/hyperparameter
//! trajectory). Sequences are processed FIFO internally (recycling is
//! sequential) but run concurrently across clients on the shared worker
//! pool. The demo measures aggregate throughput and the per-client benefit
//! of recycling.

use krr::coordinator::SolveService;
use krr::gp::kernel::RbfKernel;
use krr::data::digits::{generate, DigitsConfig};
use krr::linalg::mat::Mat;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::{SolveSpec, SpdOperator};
use krr::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

/// The Newton operator A = I + SKS as an owned, shareable object.
struct NewtonOp {
    k: Mat,
    s: Vec<f64>,
}

impl SpdOperator for NewtonOp {
    fn n(&self) -> usize {
        self.s.len()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let n = self.s.len();
        let sx: Vec<f64> = (0..n).map(|i| self.s[i] * x[i]).collect();
        let ksx = self.k.matvec(&sx);
        for i in 0..n {
            y[i] = x[i] + self.s[i] * ksx[i];
        }
    }
}

fn main() {
    let n = 160;
    let clients = 4;
    let systems_per_client = 5;
    println!(
        "solver service: {clients} clients × {systems_per_client} systems, n = {n}, pool = 4 workers\n"
    );

    let svc = SolveService::new(4);
    let start = Instant::now();
    let mut handles = Vec::new();

    for c in 0..clients {
        // Each client: its own dataset/kernel => its own system sequence.
        let data = generate(&DigitsConfig { n, seed: 50 + c as u64, ..Default::default() });
        let k = RbfKernel::new(1.0, 8.0 + c as f64).gram(&data.x);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let mut rng = Rng::new(c as u64);

        // Drifting diagonal scalings mimic the Newton H^1/2 trajectory.
        let tickets: Vec<_> = (0..systems_per_client)
            .map(|i| {
                let s: Vec<f64> = (0..n)
                    .map(|j| 0.5 - 0.02 * (i as f64) + 0.001 * ((j % 10) as f64))
                    .collect();
                let op = Arc::new(NewtonOp { k: k.clone(), s });
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                seq.submit(op, b, None, SolveSpec::defcg().with_tol(1e-6))
            })
            .collect();
        handles.push((c, seq, tickets));
    }

    for (c, seq, tickets) in handles {
        let iters: Vec<usize> = tickets.into_iter().map(|t| t.wait().iterations).collect();
        let first = iters[0];
        let later: f64 =
            iters[1..].iter().sum::<usize>() as f64 / (iters.len() - 1) as f64;
        println!(
            "client {c}: iterations/system = {iters:?}  (first {first}, later mean {later:.1}, k = {})",
            seq.k_active()
        );
        assert!(
            later < first as f64,
            "client {c}: recycling gave no benefit"
        );
    }

    let wall = start.elapsed().as_secs_f64();
    let m = svc.metrics().snapshot();
    println!(
        "\nmetrics: {}/{} solves completed, {} matvecs, {} sequences still active",
        m.completed, m.submitted, m.total_matvecs, m.active_sequences
    );
    println!(
        "wall = {wall:.3}s, cumulative solver time = {:.3}s (parallel speedup ×{:.2})",
        m.total_seconds,
        m.total_seconds / wall
    );
    println!("OK");
}
