//! The coordinator as a service: the admission-controlled async API.
//!
//! ```text
//! cargo run --release --example solver_service
//! ```
//!
//! Simulates a multi-tenant GP-fitting service: several clients each own a
//! *sequence* of related SPD systems (their model's Newton/hyperparameter
//! trajectory), submitted as **batch** traffic, while an interactive
//! request arrives late and overtakes the queued batch work. The demo
//! drives the full request lifecycle — `SolveFuture::poll` progress
//! polling, mid-queue cancellation, a per-request deadline, and a
//! `shutdown(Drain)` teardown — and prints the lifecycle metrics
//! (busy vs span seconds, cancelled/deadline/rejected counters, queue +
//! per-class high-waters, worker count / steals / utilization from the
//! work-stealing scheduler) next to the per-client recycling benefit.

use krr::coordinator::{Shutdown, SolveService};
use krr::data::digits::{generate, DigitsConfig};
use krr::gp::kernel::RbfKernel;
use krr::linalg::mat::Mat;
use krr::solvers::recycle::RecycleConfig;
use krr::solvers::{SolveSpec, SpdOperator, StopReason};
use krr::util::precision::to_f64;
use krr::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The Newton operator A = I + SKS as an owned, shareable object.
struct NewtonOp {
    k: Mat,
    s: Vec<f64>,
}

impl SpdOperator for NewtonOp {
    fn n(&self) -> usize {
        self.s.len()
    }
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let n = self.s.len();
        let sx: Vec<f64> = (0..n).map(|i| self.s[i] * x[i]).collect();
        let ksx = self.k.matvec(&sx);
        for i in 0..n {
            y[i] = x[i] + self.s[i] * ksx[i];
        }
    }
}

fn main() {
    let n = 160;
    let clients = 4;
    let systems_per_client = 5;
    println!(
        "solver service: {clients} batch clients × {systems_per_client} systems + 1 interactive \
         request, n = {n}, pool = 2 workers\n"
    );

    // Small pool + modest admission cap: the queue actually builds up, so
    // priorities and the high-water gauge have something to show.
    let svc = SolveService::with_queue_cap(2, 64);
    let start = Instant::now();
    let mut handles = Vec::new();

    for c in 0..clients {
        // Each client: its own dataset/kernel => its own system sequence.
        let data = generate(&DigitsConfig { n, seed: 50 + c as u64, ..Default::default() });
        let k = RbfKernel::new(1.0, 8.0 + to_f64(c)).gram(&data.x);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let mut rng = Rng::new(c as u64);

        // Drifting diagonal scalings mimic the Newton H^1/2 trajectory.
        // Batch priority: this is pipelined throughput work.
        let futures: Vec<_> = (0..systems_per_client)
            .map(|i| {
                let s: Vec<f64> = (0..n)
                    .map(|j| 0.5 - 0.02 * to_f64(i) + 0.001 * to_f64(j % 10))
                    .collect();
                let op = Arc::new(NewtonOp { k: k.clone(), s });
                let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                seq.submit(op, b, None, SolveSpec::defcg().with_tol(1e-6).batch())
            })
            .collect();
        handles.push((c, seq, futures));
    }

    // An interactive request lands AFTER all the batch work is queued,
    // with a hard 5 s deadline — the priority pop serves it ahead of the
    // queued batch requests of its sequence.
    let (c0, seq0, _) = &handles[0];
    let data = generate(&DigitsConfig { n, seed: 50 + *c0 as u64, ..Default::default() });
    let k0 = RbfKernel::new(1.0, 8.0).gram(&data.x);
    let interactive = {
        let s: Vec<f64> = vec![0.5; n];
        let op = Arc::new(NewtonOp { k: k0, s });
        seq0.submit(
            op,
            vec![1.0; n],
            None,
            SolveSpec::defcg()
                .with_tol(1e-6)
                .with_deadline(Duration::from_secs(5)),
        )
    };

    // A request the caller loses interest in: cancel it right away. If it
    // is still queued it completes as Cancelled without running a single
    // matvec; if a worker already picked it up, it stops within one
    // operator application with the partial iterate.
    let doomed = {
        let s: Vec<f64> = vec![0.4; n];
        let data = generate(&DigitsConfig { n, seed: 99, ..Default::default() });
        let k = RbfKernel::new(1.0, 9.0).gram(&data.x);
        let seq = svc.open_sequence(RecycleConfig::default());
        let f = seq.submit(
            Arc::new(NewtonOp { k, s }),
            vec![1.0; n],
            None,
            SolveSpec::defcg().with_tol(1e-10).batch(),
        );
        f.cancel();
        f
    };

    // Non-blocking progress loop on the interactive future.
    let (ir, report) = loop {
        if let Some(out) = interactive.poll_report() {
            break out;
        }
        std::thread::sleep(Duration::from_millis(2));
    };
    println!(
        "interactive request: {:?} in {} iterations ({:.1} ms queued, {:.1} ms solving)\n",
        ir.stop,
        ir.iterations,
        report.queue_seconds * 1e3,
        report.solve_seconds * 1e3
    );
    assert_eq!(ir.stop, StopReason::Converged);

    let doomed_stop = doomed.wait().stop;
    println!("cancelled request resolved as {doomed_stop:?}");
    assert_eq!(doomed_stop, StopReason::Cancelled);

    for (c, seq, futures) in handles {
        let iters: Vec<usize> = futures.into_iter().map(|t| t.wait().iterations).collect();
        let first = iters[0];
        let later: f64 =
            to_f64(iters[1..].iter().sum::<usize>()) / to_f64(iters.len() - 1);
        println!(
            "client {c}: iterations/system = {iters:?}  (first {first}, later mean \
             {later:.1}, k = {})",
            seq.k_active()
        );
        assert!(
            later < to_f64(first),
            "client {c}: recycling gave no benefit"
        );
    }

    // Graceful teardown: everything accepted runs to completion, then new
    // submissions are refused.
    svc.shutdown(Shutdown::Drain);
    let wall = start.elapsed().as_secs_f64();
    let m = svc.metrics().snapshot();
    println!(
        "\nmetrics: {}/{} solves completed ({} cancelled, {} deadline-exceeded, {} rejected, \
         {} failed), {} matvecs",
        m.completed,
        m.submitted,
        m.cancelled,
        m.deadline_exceeded,
        m.rejected,
        m.failed,
        m.total_matvecs
    );
    println!(
        "queue: depth {} now, high-water {} (cap 64); class high-water: \
         {} interactive / {} batch",
        m.queue_depth, m.queue_high_water, m.interactive_high_water, m.batch_high_water
    );
    println!(
        "scheduler: {} workers, {} steals (idle workers pulling hot \
         sequences off busy ones), {} cross-sequence coalesced tickets",
        m.workers, m.steals, m.cross_seq_coalesced
    );
    println!(
        "wall = {wall:.3}s, solver busy = {:.3}s over a {:.3}s service span \
         (avg parallelism ×{:.2}, utilization {:.0}% of {} workers)",
        m.busy_seconds,
        m.span_seconds,
        m.busy_seconds / m.span_seconds.max(1e-9),
        m.utilization() * 100.0,
        m.workers
    );
    assert_eq!(m.queue_depth, 0, "drain must leave nothing queued");
    println!("OK");
}
