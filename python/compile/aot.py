"""AOT compiler: lower every L2 entry point to an HLO-text artifact.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

For each problem size n (and feature dim d = 784) this writes
`<name>_n{n}.hlo.txt` files plus a `manifest.json` describing inputs and
outputs, which the rust runtime (`rust/src/runtime/`) parses to compile
and invoke the executables.

INTERCHANGE FORMAT: HLO **text**, not `.serialize()`d protos — jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

All functions are lowered with `return_tuple=True`; the rust side unwraps
with `to_tuple1()`/`decompose_tuple()`.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DIM = 784  # 28x28 images, as in the paper's MNIST workload
DEFAULT_SIZES = [64, 128, 256, 512, 1024]

# Schedule selection: the (bm x n) grid pipeline is the real-TPU design
# (VMEM-sized tiles, double-buffered HBM streaming — see the kernel
# docstrings), but interpret-mode pallas lowers each grid step to an XLA
# while-loop iteration with dynamic slices, which costs ~30x wallclock on
# the CPU PJRT backend (measured: kmatvec n=1024, block 256 -> 6.3 ms vs
# single block -> 0.22 ms). Artifacts for the CPU runtime are therefore
# lowered with a monolithic block; flip this off to emit the TPU schedule.
CPU_SCHEDULE = True


def _block(n: int) -> int:
    return n if CPU_SCHEDULE else min(n, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def spec_of(s: jax.ShapeDtypeStruct):
    return {"shape": list(s.shape), "dtype": "f32"}


def entry_points(n: int):
    """The artifact family for one problem size.

    Returns {name: (fn, example_args, output_specs)}.
    """
    scalar = f32(1)
    blk = _block(n)
    return {
        f"gram_n{n}": (
            lambda x, amp, ls: (model.gram(x, amp[0], ls[0], block=blk),),
            [f32(n, DIM), scalar, scalar],
            [f32(n, n)],
        ),
        f"kmatvec_n{n}": (
            lambda k, v: (model.kmatvec(k, v, block=blk),),
            [f32(n, n), f32(n)],
            [f32(n)],
        ),
        f"amatvec_n{n}": (
            lambda k, s, p: (model.amatvec(k, s, p, block=blk),),
            [f32(n, n), f32(n), f32(n)],
            [f32(n)],
        ),
        f"gram_matvec_free_n{n}": (
            lambda x, v, amp, ls: (
                model.gram_matvec_free(x, v, amp[0], ls[0], block=blk),
            ),
            [f32(n, DIM), f32(n), scalar, scalar],
            [f32(n)],
        ),
        f"newton_stats_n{n}": (
            lambda k, f, y: model.newton_stats(k, f, y),
            [f32(n, n), f32(n), f32(n)],
            [f32(n), f32(n), f32(n), f32()],
        ),
        f"newton_update_n{n}": (
            lambda k, b_rw, s, z, y: model.newton_update(k, b_rw, s, z, y),
            [f32(n, n), f32(n), f32(n), f32(n), f32(n)],
            [f32(n), f32(n), f32(), f32()],
        ),
        f"cg_update_n{n}": (
            lambda x, r, p, ap, alpha: model.cg_update(x, r, p, ap, alpha[0]),
            [f32(n), f32(n), f32(n), f32(n), scalar],
            [f32(n), f32(n), f32()],
        ),
    }


def build(out_dir: str, sizes, verbose=True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"dim": DIM, "sizes": list(sizes), "artifacts": {}}
    for n in sizes:
        for name, (fn, args, outs) in entry_points(n).items():
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"][name] = {
                "file": fname,
                "n": n,
                "inputs": [spec_of(a) for a in args],
                "outputs": [spec_of(o) for o in outs],
            }
            if verbose:
                print(f"  lowered {name:<28} ({len(text)//1024} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in DEFAULT_SIZES),
        help="comma-separated problem sizes n",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    build(args.out, sizes)


if __name__ == "__main__":
    main()
