"""L1 Pallas kernel: fused CG vector update.

One CG iteration's vector tail is bandwidth-bound:

    x <- x + alpha p;   r <- r - alpha Ap;   rr <- r.r

Composed naively that is 6 HBM sweeps (read x,p / write x; read r,ap /
write r; read r). The fused kernel does it in one pass per row block
(2 reads amortized + 2 writes), emitting per-block partial sums of rr that
the L2 wrapper reduces — a grid-safe way to accumulate a scalar without
cross-step output races.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf_gram import pick_block


def _cg_update_kernel(alpha_ref, x_ref, r_ref, p_ref, ap_ref, xo_ref, ro_ref, rro_ref):
    alpha = alpha_ref[0]
    xn = x_ref[...] + alpha * p_ref[...]
    rn = r_ref[...] - alpha * ap_ref[...]
    xo_ref[...] = xn
    ro_ref[...] = rn
    rro_ref[...] = jnp.sum(rn * rn, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def cg_update(x, r, p, ap, alpha, block=512):
    """Fused update; returns (x', r', rr') with rr' = r'.r' (f32 scalar).

    `alpha` is a () or (1,) f32 array (dynamic — no recompilation per step).
    """
    (n,) = x.shape
    assert r.shape == (n,) and p.shape == (n,) and ap.shape == (n,)
    bm = pick_block(n, block)
    nblocks = n // bm
    alpha = jnp.reshape(alpha, (1,)).astype(jnp.float32)
    xo, ro, partials = pl.pallas_call(
        _cg_update_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        ],
        interpret=True,
    )(alpha, x, r, p, ap)
    return xo, ro, jnp.sum(partials)
