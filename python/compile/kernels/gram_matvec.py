"""L1 Pallas kernel: matrix-free RBF Gram matvec, y = K(X) v.

The large-n path (paper conclusion: 1e5-1e6 points): K is never
materialized. The grid walks row blocks of X; each step recomputes its
(bm x n) Gram slab in VMEM from the raw features — an (bm x d) x (d x n)
MXU matmul plus VPU exp — and immediately contracts it with v. HBM traffic
is O(n d) per step for the X operand instead of O(n^2) for K, trading
flops (recompute) for bandwidth, which is the right trade once the K
matrix no longer fits in HBM (or was never worth building).

Hyperparameters are dynamic (1,) inputs — see rbf_gram.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf_gram import _as_param, pick_block


def _gram_matvec_kernel(amp_ref, ls_ref, x1_ref, xt_ref, v_ref, sq_ref, o_ref):
    a = x1_ref[...]                                      # (bm, d)
    xt = xt_ref[...]                                     # (d, n)
    sq1 = jnp.sum(a * a, axis=1, keepdims=True)          # (bm, 1)
    sq2 = sq_ref[...][None, :]                           # (1, n) — precomputed
    cross = jnp.dot(a, xt, preferred_element_type=jnp.float32)   # (bm, n)
    d2 = jnp.maximum(sq1 + sq2 - 2.0 * cross, 0.0)
    amp = amp_ref[0]
    ls = ls_ref[0]
    inv = 1.0 / (2.0 * ls * ls)
    kblk = (amp * amp) * jnp.exp(-d2 * inv)
    o_ref[...] = jnp.dot(kblk, v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def gram_matvec(x, v, amplitude=1.0, lengthscale=1.0, block=128):
    """y = K v without materializing K. x: (n, d) f32, v: (n,) f32."""
    n, d = x.shape
    assert v.shape == (n,)
    bm = pick_block(n, block)
    xt = x.T  # hoisted once at L2; shared across all grid steps
    sq = jnp.sum(x * x, axis=1)  # (n,) hoisted — avoids per-step recompute
    return pl.pallas_call(
        _gram_matvec_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(_as_param(amplitude), _as_param(lengthscale), x, xt, v, sq)
