"""L1 Pallas kernel: tiled RBF Gram matrix.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the (i, j)
output plane; each step loads a (bm x d) row tile of X1 and a (bn x d) row
tile of X2 into VMEM, computes the cross term on the MXU
(`a @ b.T`: bm x d x bn MACs), forms squared distances with the
`|x|^2 + |y|^2 - 2 x.y` expansion on the VPU and exponentiates in place —
the n x n distance matrix never exists in HBM.

VMEM per step at (bm, bn, d) = (128, 128, 784) f32:
  2*128*784*4 B (tiles) + 128*128*4 B (out) ~ 0.9 MiB  << 16 MiB budget.
Arithmetic intensity ~ 2*bm*bn*d / (4*(bm+bn)*d + 4*bm*bn) ~ 120 flop/B:
compute-bound on the MXU.

The kernel hyperparameters (amplitude, lengthscale) are **dynamic (1,)
inputs**, not compile-time constants, so one AOT artifact serves the whole
hyperparameter outer loop (paper §1) without recompilation.

Kernels are lowered with interpret=True — the CPU PJRT client cannot run
Mosaic custom-calls; on a real TPU the same code lowers to Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(amp_ref, ls_ref, x1_ref, x2_ref, o_ref):
    a = x1_ref[...]                                     # (bm, d)
    b = x2_ref[...]                                     # (bn, d)
    sq1 = jnp.sum(a * a, axis=1, keepdims=True)         # (bm, 1)
    sq2 = jnp.sum(b * b, axis=1, keepdims=True).T       # (1, bn)
    cross = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(sq1 + sq2 - 2.0 * cross, 0.0)
    amp = amp_ref[0]
    ls = ls_ref[0]
    inv = 1.0 / (2.0 * ls * ls)
    o_ref[...] = (amp * amp) * jnp.exp(-d2 * inv)


def pick_block(n, preferred=128):
    """Largest divisor of n that is <= preferred (tile size heuristic)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


def _as_param(v):
    return jnp.reshape(jnp.asarray(v, dtype=jnp.float32), (1,))


@functools.partial(jax.jit, static_argnames=("block",))
def rbf_gram(x1, x2, amplitude=1.0, lengthscale=1.0, block=128):
    """Symmetric/cross RBF Gram via the tiled Pallas kernel.

    x1: (n1, d), x2: (n2, d). amplitude/lengthscale may be python floats or
    traced scalars. Returns (n1, n2) f32.
    """
    n1, d = x1.shape
    n2, d2 = x2.shape
    assert d == d2, "feature dims differ"
    bm = pick_block(n1, block)
    bn = pick_block(n2, block)
    grid = (n1 // bm, n2 // bn)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, n2), jnp.float32),
        interpret=True,
    )(_as_param(amplitude), _as_param(lengthscale), x1, x2)
