"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

These are the ground truth the pytest suite compares the Pallas kernels
against (`assert_allclose`), and double as readable documentation of what
each kernel computes. No pallas imports here — plain jax.numpy only.
"""

import jax.numpy as jnp


def rbf_gram_ref(x1, x2, amplitude, lengthscale):
    """K[i,j] = amp^2 * exp(-||x1_i - x2_j||^2 / (2 ls^2))."""
    sq1 = jnp.sum(x1 * x1, axis=1, keepdims=True)          # (n1, 1)
    sq2 = jnp.sum(x2 * x2, axis=1, keepdims=True).T        # (1, n2)
    d2 = jnp.maximum(sq1 + sq2 - 2.0 * (x1 @ x2.T), 0.0)
    return (amplitude * amplitude) * jnp.exp(-d2 / (2.0 * lengthscale * lengthscale))


def kmatvec_ref(k, v):
    """y = K v."""
    return k @ v


def spd_matvec_ref(k, s, p):
    """The Newton-system operator of the paper (Eq. 10), applied to p:

        y = (I + S K S) p = p + s * (K (s * p)),   S = diag(s).
    """
    return p + s * (k @ (s * p))


def cg_update_ref(x, r, p, ap, alpha):
    """Fused CG vector update (one iteration's bandwidth-bound tail):

        x' = x + alpha p;  r' = r - alpha ap;  rr' = r'.r'

    Returns (x', r', rr').
    """
    xn = x + alpha * p
    rn = r - alpha * ap
    return xn, rn, jnp.dot(rn, rn)


def gram_matvec_ref(x, v, amplitude, lengthscale):
    """Matrix-free y = K v with K the RBF Gram of rows of x."""
    return rbf_gram_ref(x, x, amplitude, lengthscale) @ v


def sigmoid_ref(z):
    """Numerically stable logistic sigmoid."""
    return jnp.where(z >= 0, 1.0 / (1.0 + jnp.exp(-z)), jnp.exp(z) / (1.0 + jnp.exp(z)))


def log_sigmoid_ref(z):
    """Numerically stable log sigma(z)."""
    return jnp.where(z >= 0, -jnp.log1p(jnp.exp(-z)), z - jnp.log1p(jnp.exp(z)))


def newton_stats_ref(k, f, y):
    """All per-Newton-step quantities of the paper's Eqs. (9)-(10):

        pi    = sigma(f)
        grad  = (y+1)/2 - pi
        h     = pi (1 - pi)                   (diagonal of H)
        s     = sqrt(h)
        b_rw  = h * f + grad
        rhs   = s * (K b_rw)                  (the paper's b, Eq. 9)
        loglik = sum log sigma(y f)

    Returns (rhs, s, b_rw, loglik).
    """
    pi = sigmoid_ref(f)
    grad = 0.5 * (y + 1.0) - pi
    h = pi * (1.0 - pi)
    s = jnp.sqrt(h)
    b_rw = h * f + grad
    rhs = s * (k @ b_rw)
    loglik = jnp.sum(log_sigmoid_ref(y * f))
    return rhs, s, b_rw, loglik
