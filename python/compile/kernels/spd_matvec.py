"""L1 Pallas kernels: blocked matvecs for the Newton system.

Two kernels:

* `kmatvec(k, v)`   — y = K v, K streamed through VMEM in row blocks.
* `spd_matvec(k, s, p)` — the paper's Eq. (10) operator applied matrix-free
  in one pass: y = p + s * (K (s*p)). The two Hadamard scalings and the
  identity-add fuse into the row-block epilogue, saving three extra
  HBM sweeps over n-vectors per CG iteration relative to composing
  elementwise ops around a plain matvec.

Bandwidth analysis (DESIGN.md §Perf): the matvec is memory-bound on K
(intensity = 2 flop / 4 B = 0.5); a row-block schedule with double
buffering (automatic under the Pallas grid pipeline) achieves the HBM
roofline. VMEM per step at bm=256, n=2048: 256*2048*4 = 2 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .rbf_gram import pick_block


def _kmatvec_kernel(k_ref, v_ref, o_ref):
    o_ref[...] = jnp.dot(k_ref[...], v_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block",))
def kmatvec(k, v, block=256):
    """y = K v with K (n, n) f32 streamed in (bm, n) row blocks."""
    n, n2 = k.shape
    assert n == n2 and v.shape == (n,)
    bm = pick_block(n, block)
    return pl.pallas_call(
        _kmatvec_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(k, v)


def _spd_kernel(k_ref, sp_ref, p_ref, s_ref, o_ref):
    # y_blk = p_blk + s_blk * (K_blk @ (s*p))
    kv = jnp.dot(k_ref[...], sp_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = p_ref[...] + s_ref[...] * kv


@functools.partial(jax.jit, static_argnames=("block",))
def spd_matvec(k, s, p, block=256):
    """y = (I + S K S) p, fused. k: (n,n); s, p: (n,)."""
    n, n2 = k.shape
    assert n == n2 and s.shape == (n,) and p.shape == (n,)
    bm = pick_block(n, block)
    sp = s * p  # one fused elementwise op at L2; lives in VMEM thereafter
    return pl.pallas_call(
        _spd_kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(k, sp, p, s)
