"""L2: JAX compute graphs for the GPC/Laplace workload.

Each public function here is a jit-able, fixed-shape entry point that
`aot.py` lowers to an HLO-text artifact. They compose the L1 Pallas
kernels (which lower inline into the same HLO because interpret-mode
pallas_call emits plain HLO ops) with the surrounding elementwise math,
so XLA fuses the whole step into a single executable the rust runtime
invokes.

Python never runs at serve time: these functions execute exactly once,
inside `jax.jit(...).lower(...)` during `make artifacts`.
"""

import jax.numpy as jnp

from .kernels import cg_fused, gram_matvec, rbf_gram, spd_matvec
from .kernels.ref import log_sigmoid_ref, sigmoid_ref


def gram(x, amplitude, lengthscale, block=128):
    """K = RBF Gram of X (n, d) — Pallas-tiled (L1: rbf_gram).

    amplitude/lengthscale are traced scalars: one artifact serves every
    hyperparameter setting.
    """
    return rbf_gram.rbf_gram(x, x, amplitude=amplitude, lengthscale=lengthscale, block=block)


def cross_gram(x1, x2, amplitude, lengthscale, block=128):
    """K12 between two point sets (used by the inducing-point example)."""
    return rbf_gram.rbf_gram(x1, x2, amplitude=amplitude, lengthscale=lengthscale, block=block)


def kmatvec(k, v, block=256):
    """y = K v (L1: blocked matvec)."""
    return spd_matvec.kmatvec(k, v, block=block)


def amatvec(k, s, p, block=256):
    """The Newton operator A p = p + s*(K(s*p)) — paper Eq. (10), fused."""
    return spd_matvec.spd_matvec(k, s, p, block=block)


def gram_matvec_free(x, v, amplitude, lengthscale, block=128):
    """Matrix-free K v straight from features (large-n path)."""
    return gram_matvec.gram_matvec(
        x, v, amplitude=amplitude, lengthscale=lengthscale, block=block
    )


def cg_update(x, r, p, ap, alpha):
    """Fused CG tail: x' = x+αp, r' = r−αAp, rr' = r'.r'."""
    return cg_fused.cg_update(x, r, p, ap, alpha)


def newton_stats(k, f, y):
    """Per-Newton-step quantities (paper Eqs. 9-10).

    Inputs: K (n,n), current latent f (n,), labels y (n,) in {-1,+1}.
    Returns (rhs, s, b_rw, loglik):
      s      = sqrt(pi(1-pi))        — diagonal of H^1/2
      b_rw   = H f + grad            — Newton RHS precursor
      rhs    = s * (K b_rw)          — the paper's b (Eq. 9)
      loglik = log p(y | f)
    The K matvec goes through the L1 blocked kernel; the elementwise
    pieces fuse around it.
    """
    pi = sigmoid_ref(f)
    grad = 0.5 * (y + 1.0) - pi
    h = pi * (1.0 - pi)
    s = jnp.sqrt(h)
    b_rw = h * f + grad
    kb = spd_matvec.kmatvec(k, b_rw)
    rhs = s * kb
    loglik = jnp.sum(log_sigmoid_ref(y * f))
    return rhs, s, b_rw, loglik


def newton_update(k, b_rw, s, z, y):
    """Post-solve Newton update: a = b_rw − s∘z, f' = K a; also returns
    log p(y|f') and ψ-quadratic term a.f' for the stopping rule."""
    a = b_rw - s * z
    f_new = spd_matvec.kmatvec(k, a)
    loglik = jnp.sum(log_sigmoid_ref(y * f_new))
    quad = jnp.dot(a, f_new)
    return f_new, a, loglik, quad
