"""AOT pipeline: artifacts lower, manifest is consistent, HLO text parses."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, sizes=[8], verbose=False)
    return out, manifest


def test_manifest_lists_all_entry_points(built):
    out, manifest = built
    names = set(manifest["artifacts"])
    for stem in [
        "gram",
        "kmatvec",
        "amatvec",
        "gram_matvec_free",
        "newton_stats",
        "newton_update",
        "cg_update",
    ]:
        assert f"{stem}_n8" in names, f"missing {stem}_n8"
    assert manifest["dim"] == aot.DIM
    assert manifest["sizes"] == [8]


def test_files_exist_and_are_hlo_text(built):
    out, manifest = built
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), path
        text = open(path).read()
        assert "HloModule" in text, f"{name} not HLO text"
        assert "ENTRY" in text
        # text interchange, not serialized proto
        assert not text.startswith("\x08")


def test_manifest_shapes_match_expectation(built):
    _, manifest = built
    g = manifest["artifacts"]["gram_n8"]
    assert g["inputs"][0]["shape"] == [8, aot.DIM]
    assert g["inputs"][1]["shape"] == [1]
    assert g["outputs"][0]["shape"] == [8, 8]
    ns = manifest["artifacts"]["newton_stats_n8"]
    assert ns["outputs"][3]["shape"] == []  # scalar loglik
    cu = manifest["artifacts"]["cg_update_n8"]
    assert len(cu["inputs"]) == 5
    assert len(cu["outputs"]) == 3


def test_manifest_roundtrips_json(built):
    out, manifest = built
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == json.loads(json.dumps(manifest))


def test_rebuild_is_deterministic(built, tmp_path):
    out1, m1 = built
    out2 = str(tmp_path / "again")
    m2 = aot.build(out2, sizes=[8], verbose=False)
    assert set(m1["artifacts"]) == set(m2["artifacts"])
    # HLO text should be stable given identical jax version + inputs
    f = m1["artifacts"]["kmatvec_n8"]["file"]
    t1 = open(os.path.join(out1, f)).read()
    t2 = open(os.path.join(out2, f)).read()
    assert t1 == t2
