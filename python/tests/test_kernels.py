"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and data; assert_allclose against ref.py is THE
core correctness signal for the compute layer (the rust integration tests
then check the AOT artifacts against the rust-native implementations).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import cg_fused, gram_matvec, rbf_gram, spd_matvec
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SIZES = [4, 8, 16, 24, 64, 128, 160]
DIMS = [1, 3, 16, 49]


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    n1=st.sampled_from(SIZES),
    n2=st.sampled_from(SIZES),
    d=st.sampled_from(DIMS),
    amp=st.floats(0.3, 3.0),
    ls=st.floats(0.3, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rbf_gram_matches_ref(n1, n2, d, amp, ls, seed):
    rng = np.random.default_rng(seed)
    x1, x2 = rand(rng, n1, d), rand(rng, n2, d)
    got = rbf_gram.rbf_gram(x1, x2, amplitude=amp, lengthscale=ls)
    want = ref.rbf_gram_ref(x1, x2, amp, ls)
    assert got.shape == (n1, n2)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_rbf_gram_symmetric_and_unit_diag():
    rng = np.random.default_rng(0)
    x = rand(rng, 32, 8)
    k = np.asarray(rbf_gram.rbf_gram(x, x, amplitude=2.0, lengthscale=1.0))
    assert_allclose(k, k.T, rtol=1e-6)
    assert_allclose(np.diag(k), 4.0 * np.ones(32), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from(SIZES), seed=st.integers(0, 2**31 - 1))
def test_kmatvec_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    k, v = rand(rng, n, n), rand(rng, n)
    got = spd_matvec.kmatvec(k, v)
    assert_allclose(np.asarray(got), np.asarray(ref.kmatvec_ref(k, v)), rtol=2e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from(SIZES), seed=st.integers(0, 2**31 - 1))
def test_spd_matvec_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    k = rand(rng, n, n)
    s = jnp.abs(rand(rng, n))
    p = rand(rng, n)
    got = spd_matvec.spd_matvec(k, s, p)
    assert_allclose(
        np.asarray(got), np.asarray(ref.spd_matvec_ref(k, s, p)), rtol=2e-5, atol=1e-5
    )


def test_spd_matvec_with_zero_s_is_identity():
    rng = np.random.default_rng(1)
    k, p = rand(rng, 16, 16), rand(rng, 16)
    got = spd_matvec.spd_matvec(k, jnp.zeros(16), p)
    assert_allclose(np.asarray(got), np.asarray(p), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from(SIZES),
    alpha=st.floats(-2.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_cg_update_matches_ref(n, alpha, seed):
    rng = np.random.default_rng(seed)
    x, r, p, ap = rand(rng, n), rand(rng, n), rand(rng, n), rand(rng, n)
    xn, rn, rr = cg_fused.cg_update(x, r, p, ap, jnp.float32(alpha))
    xw, rw, rrw = ref.cg_update_ref(x, r, p, ap, alpha)
    assert_allclose(np.asarray(xn), np.asarray(xw), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(rn), np.asarray(rw), rtol=1e-5, atol=1e-6)
    assert_allclose(float(rr), float(rrw), rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from(SIZES),
    d=st.sampled_from(DIMS),
    amp=st.floats(0.5, 2.0),
    ls=st.floats(0.5, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matvec_free_matches_ref(n, d, amp, ls, seed):
    rng = np.random.default_rng(seed)
    x, v = rand(rng, n, d), rand(rng, n)
    got = gram_matvec.gram_matvec(x, v, amplitude=amp, lengthscale=ls)
    want = ref.gram_matvec_ref(x, v, amp, ls)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_gram_matvec_free_agrees_with_materialized_kernel():
    rng = np.random.default_rng(2)
    x, v = rand(rng, 64, 16), rand(rng, 64)
    free = gram_matvec.gram_matvec(x, v, amplitude=1.3, lengthscale=2.0)
    dense = spd_matvec.kmatvec(rbf_gram.rbf_gram(x, x, 1.3, 2.0), v)
    assert_allclose(np.asarray(free), np.asarray(dense), rtol=2e-5, atol=2e-5)


def test_pick_block_divides():
    for n in [1, 7, 64, 100, 128, 999, 1024]:
        b = rbf_gram.pick_block(n, 128)
        assert n % b == 0
        assert 1 <= b <= min(n, 128)


@pytest.mark.parametrize("n", [16, 64])
def test_kernels_accept_nondefault_blocks(n):
    rng = np.random.default_rng(3)
    x = rand(rng, n, 4)
    for block in [1, 2, n]:
        k = rbf_gram.rbf_gram(x, x, 1.0, 1.0, block=block)
        want = ref.rbf_gram_ref(x, x, 1.0, 1.0)
        assert_allclose(np.asarray(k), np.asarray(want), rtol=1e-5, atol=1e-6)
