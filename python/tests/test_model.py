"""L2 correctness: model graphs vs numpy references and invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def rand_labels(rng, n):
    return jnp.asarray(rng.choice([-1.0, 1.0], size=n), dtype=jnp.float32)


def rand_spd_kernelish(rng, n):
    """An SPD K like an RBF Gram: PSD + unit-ish diagonal."""
    x = rand(rng, n, 5)
    return ref.rbf_gram_ref(x, x, 1.0, 2.0) + 1e-4 * jnp.eye(n)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**31 - 1))
def test_newton_stats_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    k = rand_spd_kernelish(rng, n)
    f, y = rand(rng, n), rand_labels(rng, n)
    rhs, s, b_rw, ll = model.newton_stats(k, f, y)
    rhs_w, s_w, b_w, ll_w = ref.newton_stats_ref(k, f, y)
    assert_allclose(np.asarray(rhs), np.asarray(rhs_w), rtol=2e-5, atol=1e-5)
    assert_allclose(np.asarray(s), np.asarray(s_w), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(b_rw), np.asarray(b_w), rtol=1e-5, atol=1e-6)
    assert_allclose(float(ll), float(ll_w), rtol=1e-5)


def test_newton_stats_at_zero_latent():
    # f = 0: pi = 1/2, h = 1/4, grad = y/2, loglik = -n log 2.
    n = 32
    rng = np.random.default_rng(0)
    k = rand_spd_kernelish(rng, n)
    y = rand_labels(rng, n)
    rhs, s, b_rw, ll = model.newton_stats(k, jnp.zeros(n), y)
    assert_allclose(np.asarray(s), 0.5 * np.ones(n), rtol=1e-6)
    assert_allclose(np.asarray(b_rw), np.asarray(y) / 2.0, rtol=1e-6)
    assert_allclose(float(ll), -n * np.log(2.0), rtol=1e-5)
    assert_allclose(np.asarray(rhs), 0.5 * np.asarray(k @ (y / 2.0)), rtol=2e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**31 - 1))
def test_newton_update_consistency(n, seed):
    rng = np.random.default_rng(seed)
    k = rand_spd_kernelish(rng, n)
    b_rw, s, z = rand(rng, n), jnp.abs(rand(rng, n)), rand(rng, n)
    y = rand_labels(rng, n)
    f_new, a, ll, quad = model.newton_update(k, b_rw, s, z, y)
    a_w = b_rw - s * z
    f_w = k @ a_w
    assert_allclose(np.asarray(a), np.asarray(a_w), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(f_new), np.asarray(f_w), rtol=2e-5, atol=1e-5)
    assert_allclose(float(quad), float(jnp.dot(a_w, f_w)), rtol=1e-4, atol=1e-4)
    assert float(ll) <= 0.0


def test_amatvec_is_spd_operator():
    # v.(A v) > 0 and symmetry via random probes.
    n = 48
    rng = np.random.default_rng(1)
    k = rand_spd_kernelish(rng, n)
    s = jnp.abs(rand(rng, n))
    u, v = rand(rng, n), rand(rng, n)
    au = model.amatvec(k, s, u)
    av = model.amatvec(k, s, v)
    # symmetry: u.(A v) == v.(A u)
    assert_allclose(float(jnp.dot(u, av)), float(jnp.dot(v, au)), rtol=1e-4)
    # positive definiteness (I + PSD)
    assert float(jnp.dot(u, au)) > 0.0


def test_gram_then_matvec_composes():
    n, d = 32, 7
    rng = np.random.default_rng(2)
    x, v = rand(rng, n, d), rand(rng, n)
    k = model.gram(x, jnp.float32(1.2), jnp.float32(1.7))
    y1 = model.kmatvec(k, v)
    y2 = model.gram_matvec_free(x, v, jnp.float32(1.2), jnp.float32(1.7))
    assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-5, atol=3e-5)
