//! L3 coordinator: an admission-controlled concurrent solve-service for
//! sequences of SPD systems.
//!
//! The paper's contribution lives at the level of *sequences*: information
//! flows from system `i` to system `i+1` through the recycled subspace.
//! This module packages that into a deployable service with a full
//! request lifecycle:
//!
//! * a [`service::SolveService`] owning a worker pool, the service-wide
//!   **admission cap** (queued + running requests;
//!   [`service::SubmitError::QueueFull`] is the backpressure signal), and
//!   [`service::SolveService::shutdown`] graceful teardown
//!   ([`service::Shutdown::Drain`] finishes accepted work,
//!   [`service::Shutdown::Abort`] cancels it);
//! * [`service::SequenceHandle`]s, one per solve sequence (e.g. one per
//!   Laplace optimization or per hyperparameter trajectory), each with its
//!   own [`crate::solvers::recycle::RecycleManager`] state;
//! * asynchronous completion: every submission returns a
//!   [`service::SolveFuture`] — non-blocking `poll`, blocking `wait` /
//!   `wait_timeout`, `cancel` via a shared
//!   [`crate::solvers::CancelToken`] — and every completion carries a
//!   structured [`service::SolveReport`] (stop reason, queue/solve
//!   wall-times, matvec bill, basis size, coalesce group width);
//! * per-request [`crate::solvers::SolveSpec`]s carrying the numerical
//!   policies **and** the lifecycle policies: a
//!   [`crate::solvers::Priority`] class (interactive requests overtake
//!   queued batch work) and a deadline/cancel
//!   [`crate::solvers::SolveControl`] that the kernels check once per
//!   iteration, so cancellation and deadlines take effect mid-solve with
//!   the partial iterate returned;
//! * operator-algebra-friendly submission: operators travel as
//!   `Arc<dyn SpdOperator + Send + Sync>`, so `solvers::algebra` views
//!   (shifted / scaled / low-rank-updated) over one shared base submit
//!   without re-materializing kernels;
//! * FIFO ordering within a priority class *within* a sequence
//!   (recycling is inherently sequential) and parallelism *across*
//!   sequences; consecutive same-operator block requests coalesce into
//!   one block solve under an all-of cancel group, and a dispatching
//!   leader can additionally claim matching block requests from *other*
//!   sequences sharing the same operator `Arc` (cross-sequence
//!   coalescing, [`service::SolveService::cross_sequence_coalescing`]);
//! * worker-panic containment: a panicking solve completes its future as
//!   [`crate::solvers::StopReason::Failed`] instead of hanging every
//!   caller behind it;
//! * service-level metrics ([`service::MetricsSnapshot`]): throughput,
//!   lifecycle counters (cancelled / deadline-exceeded / rejected /
//!   failed), the admission gauge and its high-water mark, and the
//!   `busy_seconds` (summed solver time) vs `span_seconds`
//!   (first-submit→last-complete wall clock) split.
//!
//! This is the shape a GP-serving system would use: many concurrent model
//! fits, each a sequence of related systems, sharing one compute engine
//! under explicit backpressure.
//!
//! # The two thread pools
//!
//! The service runs **two deliberately separate pools**, and the split is
//! load-bearing:
//!
//! * **Scheduler workers** (`krr-sched-{i}`, [`scheduler`], sized by the
//!   `workers` argument to [`service::SolveService::new`]): each owns a
//!   run queue of sequence cores and dispatches one task (or one
//!   coalesced group) per turn, stealing from siblings when idle. These
//!   threads *block* inside solves — that is fine, they are the solve
//!   capacity.
//! * **Compute pool** (`krr-compute-{i}`, built once at first use via
//!   `OnceLock` — not lazily under a mutex on the hot path): the
//!   fork/join shards of a single [`crate::solvers::ParDenseOp`] matvec.
//!   These jobs must never wait on solver-length work. Running matvec
//!   shards on the scheduler workers would deadlock the fork/join when
//!   every worker is a dispatcher blocked joining its own shards; running
//!   dispatchers on the compute pool would let one slow solve starve
//!   every other sequence's matvecs. Hence: dispatchers block, shards
//!   don't, and the pools never share threads.
//!
//! Sequence placement is **sticky**: a sequence's home worker is fixed at
//! `open_sequence` (round-robin), so its recycled `(W, AW)` basis is
//! re-touched by the same worker — warm caches — while work-stealing
//! keeps any single hot worker from serializing the service (idle workers
//! prefer victims with urgent work, then basis-free sequences, so a
//! stolen dispatch is cheap to run cold). See `DESIGN.md` §"Scheduler &
//! placement".

pub(crate) mod scheduler;
pub mod service;

pub use service::{
    MetricsSnapshot, PauseGuard, SequenceHandle, ServiceMetrics, Shutdown, SolveFuture,
    SolveReport, SolveService, SubmitError,
};
