//! L3 coordinator: a concurrent solve-service for sequences of SPD systems.
//!
//! The paper's contribution lives at the level of *sequences*: information
//! flows from system `i` to system `i+1` through the recycled subspace.
//! This module packages that into a deployable service:
//!
//! * a [`service::SolveService`] owning a worker pool and (optionally) the
//!   PJRT engine;
//! * [`service::SequenceHandle`]s, one per solve sequence (e.g. one per
//!   Laplace optimization or per hyperparameter trajectory), each with its
//!   own [`crate::solvers::recycle::RecycleManager`] state;
//! * per-request [`crate::solvers::SolveSpec`]s: one sequence queue serves
//!   heterogeneous workloads (plain CG, Jacobi-PCG, deflated, block CG);
//! * strict FIFO ordering *within* a sequence (recycling is inherently
//!   sequential) and parallelism *across* sequences;
//! * service-level metrics ([`service::MetricsSnapshot`]).
//!
//! This is the shape a GP-serving system would use: many concurrent model
//! fits, each a sequence of related systems, sharing one compute engine.

pub mod service;

pub use service::{MetricsSnapshot, SequenceHandle, ServiceMetrics, SolveService};
