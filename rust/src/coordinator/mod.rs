//! L3 coordinator: a concurrent solve-service for sequences of SPD systems.
//!
//! The paper's contribution lives at the level of *sequences*: information
//! flows from system `i` to system `i+1` through the recycled subspace.
//! This module packages that into a deployable service:
//!
//! * a [`service::SolveService`] owning a worker pool and (optionally) the
//!   PJRT engine;
//! * [`service::SequenceHandle`]s, one per solve sequence (e.g. one per
//!   Laplace optimization or per hyperparameter trajectory), each with its
//!   own [`crate::solvers::recycle::RecycleManager`] state;
//! * per-request [`crate::solvers::SolveSpec`]s: one sequence queue serves
//!   heterogeneous workloads (plain CG, Jacobi-PCG, deflated, block CG,
//!   and multi-RHS [`service::SequenceHandle::submit_block`] batches —
//!   consecutive same-operator block requests coalesce into one block
//!   solve);
//! * operator-algebra-friendly submission: operators travel as
//!   `Arc<dyn SpdOperator + Send + Sync>`, so `solvers::algebra` views
//!   (shifted / scaled / low-rank-updated) over one shared base submit
//!   without re-materializing kernels;
//! * strict FIFO ordering *within* a sequence (recycling is inherently
//!   sequential) and parallelism *across* sequences;
//! * service-level metrics ([`service::MetricsSnapshot`]), with block
//!   applies counted as one application per column so `total_matvecs`
//!   stays on one axis across request shapes.
//!
//! This is the shape a GP-serving system would use: many concurrent model
//! fits, each a sequence of related systems, sharing one compute engine.

pub mod service;

pub use service::{
    BlockSolveTicket, MetricsSnapshot, SequenceHandle, ServiceMetrics, SolveService, SolveTicket,
};
