//! The sharded work-stealing scheduler behind [`super::service::SolveService`].
//!
//! # Worker model
//!
//! The old coordinator spawned one *drainer closure* per active sequence
//! onto a shared [`crate::util::pool::ThreadPool`] and let that closure
//! loop until its sequence queue was empty. That shape serializes each
//! sequence (correct — recycling is inherently sequential) but has two
//! scaling defects: a sequence with a sustained request stream occupies
//! its pool worker **forever** (a busy pool starves late-opened
//! sequences outright), and there is no placement — a sequence's
//! recycled basis has no worker affinity, so nothing keeps the `(W, AW)`
//! panel hot in one core's cache.
//!
//! This module replaces that with an explicit scheduler:
//!
//! * **N workers, one run queue each.** A runnable sequence core is an
//!   [`Arc`] in exactly one run queue (or on exactly one worker's
//!   dispatch), never two places at once — the per-sequence
//!   serialization invariant survives by construction.
//! * **One dispatch = one task (or one coalesced group).** After each
//!   dispatch the core goes to the *back* of its home queue, so runnable
//!   sequences on a worker round-robin: a sequence with an infinite
//!   request stream can no longer starve its neighbours (the bounded-wait
//!   fairness guarantee the old model lacked).
//! * **Sticky placement.** Every core has a fixed *home* worker; pushes
//!   and post-dispatch requeues always target the home queue, so a
//!   sequence's recycled basis keeps being touched from the same worker
//!   thread even after a one-off steal.
//! * **Work stealing, basis-aware.** An idle worker scans the other run
//!   queues and steals a core. Victims are chosen to protect locality:
//!   urgent (interactive-holding) cores first, then cores whose
//!   [`SchedEntry::steal_cost`] is 0 — basis-free sequences lose nothing
//!   by running elsewhere — then the queue front as a last resort.
//!   Stolen cores still requeue to their *home* worker afterwards.
//! * **Claims.** A dispatching worker can atomically remove peer cores
//!   from the run queues ([`SchedCtx::claim`]) — the hook the service's
//!   cross-sequence block coalescer uses to pull same-operator work from
//!   other sequences into one group solve. Claimed cores stay scheduled
//!   and must be handed back via [`SchedCtx::requeue`] (or unscheduled
//!   by their owner) when the group completes.
//!
//! The scheduler is deliberately policy-free: what "one dispatch" means
//! (priority pops, dead-on-arrival completion, coalescing, panic
//! containment) lives entirely in the dispatch closure the service
//! installs. The hints ([`SchedEntry::urgent`], [`SchedEntry::steal_cost`])
//! are advisory ordering signals, never correctness inputs.
//!
//! # Shutdown
//!
//! Dropping the [`Scheduler`] sets the stop flag and joins the workers;
//! workers keep dispatching until every run queue is empty before
//! exiting (mirroring [`crate::util::pool::ThreadPool`]'s drain-on-drop),
//! so futures enqueued before the drop still complete.

use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::thread::JoinHandle;
use crate::util::sync::{lock_unpoisoned, Arc, Condvar, Mutex};
use std::collections::VecDeque;
use std::time::Duration;

/// A schedulable sequence core. Implemented by the service's per-sequence
/// state; the scheduler itself never looks inside a core beyond these
/// placement hints.
pub(crate) trait SchedEntry: Send + Sync + 'static {
    /// Fixed home worker index (sticky placement target). Values are
    /// taken modulo the worker count.
    fn home(&self) -> usize;

    /// Advisory cost of running this core away from its home worker —
    /// the resident recycled-basis size (0 = basis-free, cheapest to
    /// steal). Staleness only degrades steal choices, never correctness.
    fn steal_cost(&self) -> usize;

    /// Advisory count of urgent (interactive-class) requests queued on
    /// this core; workers serve cores with `urgent() > 0` before the
    /// rest of their run queue.
    fn urgent(&self) -> usize;
}

/// The dispatch callback: run ONE unit of work (one task or one
/// coalesced group) for `core`, then requeue or unschedule it. The
/// second argument is the scheduler context for requeues and
/// cross-sequence claims; the third is the executing worker's index.
pub(crate) type DispatchFn<C> = Box<dyn Fn(&Arc<C>, &SchedCtx<C>, usize) + Send + Sync + 'static>;

/// Shared scheduler state: the run queues, the park/wake machinery, and
/// the dispatch hook. Handed to the dispatch closure so it can requeue
/// and claim cores.
pub(crate) struct SchedCtx<C: SchedEntry> {
    /// One run queue per worker; a core is in at most one queue.
    queues: Vec<Mutex<VecDeque<Arc<C>>>>,
    /// Idle workers park here; pushes notify it (lock-then-notify, so a
    /// worker between its queue scan and its wait cannot miss a wakeup).
    park: Mutex<()>,
    park_cv: Condvar,
    stop: AtomicBool,
    /// Active [`SchedulerHold`] guards; workers dispatch nothing while
    /// this is nonzero (the deterministic-test quiesce mechanism).
    holds: AtomicUsize,
    /// Cores taken from a non-home run queue, cumulative.
    steals: AtomicU64,
    /// External steal observer (the service mirrors steals into its
    /// metrics without the scheduler knowing about `ServiceMetrics`).
    on_steal: Box<dyn Fn() + Send + Sync>,
    dispatch: DispatchFn<C>,
}

impl<C: SchedEntry> SchedCtx<C> {
    /// Enqueue `core` on its home worker's run queue and wake a worker.
    /// The caller guarantees the core is not already queued or being
    /// dispatched (the service's `scheduled` flag).
    pub(crate) fn requeue(&self, core: Arc<C>) {
        let w = core.home() % self.queues.len();
        lock_unpoisoned(&self.queues[w]).push_back(core);
        #[cfg(debug_assertions)]
        debug_assert!(self.audit_queues().is_ok(), "{:?}", self.audit_queues());
        let _g = lock_unpoisoned(&self.park);
        self.park_cv.notify_all();
    }

    /// Check the one-entry-anywhere invariant: no core is resident in two
    /// run queues at once. Takes every queue lock **simultaneously** (in
    /// index order — deadlock-free because every other path holds at most
    /// one queue lock at a time), so a core dispatched out of queue A and
    /// requeued into queue B mid-scan cannot masquerade as a duplicate.
    /// `debug_assert`-gated on the mutating paths; also callable directly
    /// from tests (see `Scheduler::audit_queues` and the service's
    /// `audit_scheduler`).
    pub(crate) fn audit_queues(&self) -> Result<(), String> {
        let guards: Vec<_> = self.queues.iter().map(lock_unpoisoned).collect();
        let mut seen: Vec<(usize, usize)> = Vec::new(); // (core ptr, queue idx)
        for (w, q) in guards.iter().enumerate() {
            for core in q.iter() {
                let p = Arc::as_ptr(core) as usize;
                if let Some((_, prev)) = seen.iter().find(|(sp, _)| *sp == p) {
                    return Err(format!(
                        "core {p:#x} resident in run queues {prev} and {w} at once"
                    ));
                }
                seen.push((p, w));
            }
        }
        Ok(())
    }

    /// Atomically remove up to `cap` cores matching `pred` from the run
    /// queues (scanned worker by worker; `pred` runs under each queue's
    /// lock and must not block — `try_lock` only). Claimed cores remain
    /// logically scheduled: the caller owns them until it requeues or
    /// unschedules them. This is the cross-sequence coalescing hook.
    pub(crate) fn claim(&self, cap: usize, mut pred: impl FnMut(&C) -> bool) -> Vec<Arc<C>> {
        let mut out = Vec::new();
        for q in &self.queues {
            if out.len() >= cap {
                break;
            }
            let mut q = lock_unpoisoned(q);
            let mut i = 0;
            while out.len() < cap {
                match q.get(i) {
                    None => break,
                    Some(c) if pred(c) => match q.remove(i) {
                        Some(core) => out.push(core),
                        // `get(i)` returned Some under the same lock, so
                        // `remove(i)` cannot miss; bail rather than spin.
                        None => break,
                    },
                    Some(_) => i += 1,
                }
            }
        }
        out
    }

    /// Cores taken from a non-home run queue since construction.
    pub(crate) fn steals(&self) -> u64 {
        self.steals.load(Ordering::SeqCst)
    }

    fn n_workers(&self) -> usize {
        self.queues.len()
    }

    /// Return a popped-but-undispatchable core (a hold arrived between
    /// the pop and the dispatch) to the FRONT of its home queue, so
    /// FIFO order is preserved across the pause.
    fn putback(&self, core: Arc<C>) {
        let w = core.home() % self.queues.len();
        lock_unpoisoned(&self.queues[w]).push_front(core);
        #[cfg(debug_assertions)]
        debug_assert!(self.audit_queues().is_ok(), "{:?}", self.audit_queues());
    }

    /// Pop from the worker's own queue: the first urgent-holding core if
    /// any, else the front (round-robin order). Every
    /// [`FAIRNESS_PERIOD`]-th pop (`fair == true`) serves the front
    /// unconditionally: urgency is a preference, not a guarantee, so a
    /// sequence with a perpetual interactive stream cannot starve a
    /// batch-only peer parked behind it on the same worker — the peer's
    /// wait is bounded by `FAIRNESS_PERIOD` dispatch turns.
    fn pop_local(&self, me: usize, fair: bool) -> Option<Arc<C>> {
        let mut q = lock_unpoisoned(&self.queues[me]);
        if q.is_empty() {
            return None;
        }
        let idx = if fair { 0 } else { q.iter().position(|c| c.urgent() > 0).unwrap_or(0) };
        q.remove(idx)
    }

    /// Steal from another worker's queue. Victim preference inside a
    /// queue: urgent cores (latency beats locality), then basis-free
    /// cores (`steal_cost() == 0`, nothing to keep hot), then the front.
    fn steal(&self, me: usize) -> Option<Arc<C>> {
        let n = self.queues.len();
        for off in 1..n {
            let v = (me + off) % n;
            let mut q = lock_unpoisoned(&self.queues[v]);
            if q.is_empty() {
                continue;
            }
            let idx = q
                .iter()
                .position(|c| c.urgent() > 0)
                .or_else(|| q.iter().position(|c| c.steal_cost() == 0))
                .unwrap_or(0);
            // `idx` came from `position` (or 0 on a non-empty queue)
            // under this lock, so the remove cannot miss.
            let Some(core) = q.remove(idx) else { continue };
            drop(q);
            self.steals.fetch_add(1, Ordering::SeqCst);
            (self.on_steal)();
            return Some(core);
        }
        None
    }

    fn any_queued(&self) -> bool {
        self.queues.iter().any(|q| !lock_unpoisoned(q).is_empty())
    }
}

/// RAII pause guard from [`Scheduler::hold`]: while any guard is alive,
/// workers dispatch nothing (in-flight dispatches finish; queues keep
/// accepting cores). Dropping the last guard resumes dispatching.
pub(crate) struct SchedulerHold<C: SchedEntry> {
    ctx: Arc<SchedCtx<C>>,
}

impl<C: SchedEntry> Drop for SchedulerHold<C> {
    fn drop(&mut self) {
        self.ctx.holds.fetch_sub(1, Ordering::SeqCst);
        let _g = lock_unpoisoned(&self.ctx.park);
        self.ctx.park_cv.notify_all();
    }
}

/// The worker pool + run queues. Owns the worker threads; dropping it
/// drains every run queue (dispatching the remaining cores) and joins.
pub(crate) struct Scheduler<C: SchedEntry> {
    ctx: Arc<SchedCtx<C>>,
    workers: Vec<JoinHandle<()>>,
}

impl<C: SchedEntry> Scheduler<C> {
    /// Spawn `workers` scheduler threads (named `krr-sched-{i}`).
    /// `on_steal` is called once per steal; `dispatch` runs one unit of
    /// work for a core (see [`SchedCtx`]).
    pub(crate) fn new(
        workers: usize,
        on_steal: Box<dyn Fn() + Send + Sync>,
        dispatch: DispatchFn<C>,
    ) -> Self {
        assert!(workers >= 1, "scheduler needs at least one worker");
        let ctx = Arc::new(SchedCtx {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            park: Mutex::new(()),
            park_cv: Condvar::new(),
            stop: AtomicBool::new(false),
            holds: AtomicUsize::new(0),
            steals: AtomicU64::new(0),
            on_steal,
            dispatch,
        });
        let handles = (0..workers)
            .map(|i| {
                let ctx = ctx.clone();
                crate::util::sync::thread::Builder::new()
                    .name(format!("krr-sched-{i}"))
                    .spawn(move || worker_loop(ctx, i))
                    .expect("spawn scheduler worker")
            })
            .collect();
        Scheduler { ctx, workers: handles }
    }

    /// Enqueue a core on its home worker (first scheduling of a core, or
    /// re-scheduling after it went idle).
    pub(crate) fn submit(&self, core: Arc<C>) {
        self.ctx.requeue(core);
    }

    /// Pause dispatching until the returned guard (and any other
    /// outstanding guard) is dropped. In-flight dispatches complete;
    /// submissions are still accepted and queue up. The deterministic
    /// replacement for the old park-a-pool-worker test gate.
    pub(crate) fn hold(&self) -> SchedulerHold<C> {
        self.ctx.holds.fetch_add(1, Ordering::SeqCst);
        SchedulerHold { ctx: self.ctx.clone() }
    }

    pub(crate) fn n_workers(&self) -> usize {
        self.ctx.n_workers()
    }

    /// Cores dispatched away from their home worker, cumulative.
    pub(crate) fn steals(&self) -> u64 {
        self.ctx.steals()
    }

    /// Test hook: check the one-entry-anywhere invariant right now. See
    /// [`SchedCtx::audit_queues`].
    pub(crate) fn audit_queues(&self) -> Result<(), String> {
        self.ctx.audit_queues()
    }
}

impl<C: SchedEntry> Drop for Scheduler<C> {
    fn drop(&mut self) {
        self.ctx.stop.store(true, Ordering::SeqCst);
        {
            let _g = lock_unpoisoned(&self.ctx.park);
            self.ctx.park_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Every N-th local pop ignores the urgency preference and serves the
/// queue front (see [`SchedCtx::pop_local`]): the anti-starvation
/// backstop for cross-sequence fairness on a shared worker.
const FAIRNESS_PERIOD: usize = 4;

fn worker_loop<C: SchedEntry>(ctx: Arc<SchedCtx<C>>, me: usize) {
    let mut ticks: usize = 0;
    loop {
        let stopping = ctx.stop.load(Ordering::SeqCst);
        if !stopping && ctx.holds.load(Ordering::SeqCst) > 0 {
            let g = lock_unpoisoned(&ctx.park);
            let _ = ctx
                .park_cv
                .wait_timeout(g, Duration::from_millis(25))
                .unwrap_or_else(|e| e.into_inner());
            continue;
        }
        // `ticks` counts successful pops, not loop iterations, so the
        // fair-pop cadence is deterministic in dispatch order and
        // unaffected by how often an idle worker rescans.
        let fair = ticks % FAIRNESS_PERIOD == FAIRNESS_PERIOD - 1;
        match ctx.pop_local(me, fair).or_else(|| ctx.steal(me)) {
            Some(core) => {
                ticks = ticks.wrapping_add(1);
                // A hold that arrived between the pop and here must not
                // lose the core or its queue position.
                if !stopping && ctx.holds.load(Ordering::SeqCst) > 0 {
                    ctx.putback(core);
                    continue;
                }
                // The dispatch closure contains its own per-solve panic
                // containment; this outer catch is the last-resort guard
                // that keeps a scheduler worker alive through a bug in
                // the dispatch plumbing itself.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (ctx.dispatch)(&core, &ctx, me);
                }));
                if r.is_err() {
                    crate::log_warn!(
                        "scheduler worker {me}: dispatch panicked outside solve containment"
                    );
                }
            }
            None => {
                // Exit only when stopping AND the full scan (own queue +
                // every steal victim) found nothing: a peer worker that
                // is still mid-dispatch may yet requeue a core, but that
                // peer will re-scan (and find it) before exiting itself.
                if stopping {
                    return;
                }
                let g = lock_unpoisoned(&ctx.park);
                // Re-check under the park lock: pushes notify under this
                // lock, so work pushed after the scan either shows up
                // here or its notify lands in the wait below.
                if ctx.any_queued() {
                    continue;
                }
                let _ = ctx
                    .park_cv
                    .wait_timeout(g, Duration::from_millis(25))
                    .unwrap_or_else(|e| e.into_inner());
            }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Instant;

    /// Minimal schedulable core: `work` units remaining; each dispatch
    /// consumes one, records (id, worker) and requeues while work
    /// remains, mirroring the service's one-task-per-dispatch contract.
    struct TestCore {
        id: usize,
        home: usize,
        urgent: AtomicUsize,
        cost: usize,
        work: AtomicUsize,
        scheduled: Mutex<bool>,
    }

    impl SchedEntry for TestCore {
        fn home(&self) -> usize {
            self.home
        }
        fn steal_cost(&self) -> usize {
            self.cost
        }
        fn urgent(&self) -> usize {
            self.urgent.load(Ordering::SeqCst)
        }
    }

    struct Harness {
        sched: Scheduler<TestCore>,
        log: Arc<Mutex<Vec<(usize, usize)>>>,
        done: Arc<(Mutex<usize>, Condvar)>,
    }

    /// Scheduler wired to a dispatch that pops one work unit, logs it,
    /// and requeues the core while work remains — the same
    /// requeue-or-unschedule protocol the service uses.
    fn harness(workers: usize, sleep_ms: u64) -> Harness {
        let log: Arc<Mutex<Vec<(usize, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let done = Arc::new((Mutex::new(0usize), Condvar::new()));
        let (log2, done2) = (log.clone(), done.clone());
        let dispatch: DispatchFn<TestCore> = Box::new(move |core, ctx, me| {
            if sleep_ms > 0 {
                std::thread::sleep(Duration::from_millis(sleep_ms));
            }
            lock_unpoisoned(&log2).push((core.id, me));
            if core.urgent.load(Ordering::SeqCst) > 0 {
                core.urgent.fetch_sub(1, Ordering::SeqCst);
            }
            let remaining = core.work.fetch_sub(1, Ordering::SeqCst) - 1;
            {
                let mut n = lock_unpoisoned(&done2.0);
                *n += 1;
                done2.1.notify_all();
            }
            if remaining > 0 {
                ctx.requeue(core.clone());
            } else {
                *lock_unpoisoned(&core.scheduled) = false;
            }
        });
        let sched = Scheduler::new(workers, Box::new(|| {}), dispatch);
        Harness { sched, log, done }
    }

    fn core(id: usize, home: usize, work: usize, urgent: usize, cost: usize) -> Arc<TestCore> {
        Arc::new(TestCore {
            id,
            home,
            urgent: AtomicUsize::new(urgent),
            cost,
            work: AtomicUsize::new(work),
            scheduled: Mutex::new(true),
        })
    }

    fn wait_done(done: &Arc<(Mutex<usize>, Condvar)>, n: usize) {
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut g = lock_unpoisoned(&done.0);
        while *g < n {
            assert!(Instant::now() < deadline, "scheduler test timed out at {}/{n}", *g);
            let (g2, _) = done.1.wait_timeout(g, Duration::from_millis(50)).unwrap();
            g = g2;
        }
    }

    #[test]
    fn single_worker_round_robins_across_cores() {
        let h = harness(1, 0);
        let a = core(1, 0, 3, 0, 0);
        let b = core(2, 0, 3, 0, 0);
        {
            let _hold = h.sched.hold();
            h.sched.submit(a);
            h.sched.submit(b);
        }
        wait_done(&h.done, 6);
        let order: Vec<usize> = lock_unpoisoned(&h.log).iter().map(|(id, _)| *id).collect();
        // One dispatch per turn, requeue at the back: strict alternation.
        assert_eq!(order, vec![1, 2, 1, 2, 1, 2]);
    }

    #[test]
    fn urgent_cores_jump_the_run_queue() {
        let h = harness(1, 0);
        let slow = core(1, 0, 1, 0, 0);
        let urgent = core(2, 0, 1, 1, 0);
        {
            let _hold = h.sched.hold();
            h.sched.submit(slow);
            h.sched.submit(urgent); // queued behind, but urgent() > 0
        }
        wait_done(&h.done, 2);
        let order: Vec<usize> = lock_unpoisoned(&h.log).iter().map(|(id, _)| *id).collect();
        assert_eq!(order, vec![2, 1], "urgent core must be dispatched first");
    }

    #[test]
    fn idle_workers_steal_and_prefer_basis_free_victims() {
        // Everything homes on worker 0 and each dispatch sleeps, so
        // worker 1 can only make progress by stealing. The basis-free
        // core (cost 0) must be the preferred victim over the costly one
        // queued ahead of it.
        let h = harness(2, 20);
        let busy = core(1, 0, 1, 0, 5);
        let costly = core(2, 0, 1, 0, 5);
        let free = core(3, 0, 1, 0, 0);
        {
            let _hold = h.sched.hold();
            h.sched.submit(busy);
            h.sched.submit(costly);
            h.sched.submit(free);
        }
        wait_done(&h.done, 3);
        assert!(h.sched.steals() >= 1, "an idle worker must steal cross-queue work");
        let log = lock_unpoisoned(&h.log).clone();
        let by_id = |id: usize| log.iter().find(|(i, _)| *i == id).unwrap().1;
        // Worker 1 ran something (steal happened) and whenever it stole
        // past the queue front, it took the basis-free core.
        if by_id(2) == 1 {
            // costly was stolen only if free was not available first —
            // i.e. free was already taken. Either way free must not have
            // been left for last on worker 0 while a costlier steal
            // happened around it.
            assert_eq!(by_id(3), 0);
        } else {
            assert!(by_id(1) == 1 || by_id(3) == 1);
        }
    }

    #[test]
    fn hold_pauses_dispatch_until_dropped() {
        let h = harness(2, 0);
        let hold = h.sched.hold();
        h.sched.submit(core(1, 0, 2, 0, 0));
        std::thread::sleep(Duration::from_millis(60));
        assert_eq!(*lock_unpoisoned(&h.done.0), 0, "held scheduler must not dispatch");
        drop(hold);
        wait_done(&h.done, 2);
    }

    #[test]
    fn claim_removes_matching_cores_atomically() {
        let h = harness(2, 0);
        let _hold = h.sched.hold();
        h.sched.submit(core(1, 0, 1, 0, 0));
        h.sched.submit(core(2, 1, 1, 0, 0));
        h.sched.submit(core(3, 0, 1, 0, 0));
        let claimed = h.sched.ctx.claim(8, |c| c.id != 2);
        let ids: Vec<usize> = claimed.iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![1, 3], "claim scans every queue, in order");
        // Hand one back; it must still get dispatched after the hold.
        h.sched.ctx.requeue(claimed[0].clone());
        for c in &claimed[1..] {
            *lock_unpoisoned(&c.scheduled) = false;
        }
        drop(_hold);
        wait_done(&h.done, 2); // core 2 + the requeued core 1
        let ran: Vec<usize> = lock_unpoisoned(&h.log).iter().map(|(id, _)| *id).collect();
        assert!(ran.contains(&1) && ran.contains(&2) && !ran.contains(&3));
    }

    #[test]
    fn drop_drains_queued_cores_before_joining() {
        let h = harness(2, 1);
        for i in 0..8 {
            h.sched.submit(core(i, i % 2, 1, 0, 0));
        }
        drop(h.sched); // must dispatch all 8, then join without hanging
        assert_eq!(*lock_unpoisoned(&h.done.0), 8);
    }

    #[test]
    fn many_cores_many_workers_all_complete() {
        let h = harness(4, 0);
        for i in 0..32 {
            h.sched.submit(core(i, i % 4, 5, 0, i % 3));
        }
        wait_done(&h.done, 32 * 5);
        // Per-core dispatch order is serial even across steals: each
        // core appears exactly `work` times.
        let log = lock_unpoisoned(&h.log);
        for i in 0..32 {
            assert_eq!(log.iter().filter(|(id, _)| *id == i).count(), 5);
        }
    }
}
