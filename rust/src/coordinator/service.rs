//! The solve-service implementation.

use crate::linalg::mat::Mat;
use crate::solvers::cg::CgConfig;
use crate::solvers::recycle::{RecycleConfig, RecycleManager, SystemStats};
use crate::solvers::{ParDenseOp, SolveResult, SpdOperator};
use crate::util::pool::ThreadPool;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A solve request: operator + right-hand side (+ per-solve config).
struct Task {
    op: Arc<dyn SpdOperator + Send + Sync>,
    b: Vec<f64>,
    x0: Option<Vec<f64>>,
    cfg: CgConfig,
    slot: Arc<ResultSlot>,
}

/// One-shot result slot (mini oneshot channel).
struct ResultSlot {
    value: Mutex<Option<SolveResult>>,
    cv: Condvar,
}

impl ResultSlot {
    fn new() -> Arc<Self> {
        Arc::new(ResultSlot { value: Mutex::new(None), cv: Condvar::new() })
    }

    fn put(&self, r: SolveResult) {
        *self.value.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }

    fn take(&self) -> SolveResult {
        let mut g = self.value.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        g.take().unwrap()
    }
}

/// Pending future for a submitted solve.
pub struct SolveTicket {
    slot: Arc<ResultSlot>,
}

impl SolveTicket {
    /// Block until the solve finishes.
    pub fn wait(self) -> SolveResult {
        self.slot.take()
    }
}

struct SequenceState {
    mgr: RecycleManager,
    queue: VecDeque<Task>,
    running: bool,
    closed: bool,
}

/// Aggregated service counters.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub solves: AtomicUsize,
    pub iterations: AtomicUsize,
    pub matvecs: AtomicUsize,
    pub solve_nanos: AtomicU64,
    pub sequences_opened: AtomicUsize,
}

impl ServiceMetrics {
    pub fn snapshot(&self) -> (usize, usize, usize, f64, usize) {
        (
            self.solves.load(Ordering::Relaxed),
            self.iterations.load(Ordering::Relaxed),
            self.matvecs.load(Ordering::Relaxed),
            self.solve_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            self.sequences_opened.load(Ordering::Relaxed),
        )
    }
}

/// The service: a shared pool plus per-sequence recycling state.
pub struct SolveService {
    pool: Arc<ThreadPool>,
    /// Lazily-built pool for sharded dense matvecs ([`ParDenseOp`]).
    /// Kept separate from the drainer pool: a drainer that blocked on
    /// shard joins queued behind other drainers on the *same* fixed-size
    /// pool would deadlock (nested fork/join).
    compute: Mutex<Option<Arc<ThreadPool>>>,
    metrics: Arc<ServiceMetrics>,
}

impl SolveService {
    pub fn new(workers: usize) -> Self {
        SolveService {
            pool: Arc::new(ThreadPool::new(workers)),
            compute: Mutex::new(None),
            metrics: Arc::new(ServiceMetrics::default()),
        }
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The dedicated compute pool for matvec sharding (created on first
    /// use, sized to the machine).
    pub fn compute_pool(&self) -> Arc<ThreadPool> {
        let mut g = self.compute.lock().unwrap();
        if g.is_none() {
            *g = Some(Arc::new(ThreadPool::default_size()));
        }
        g.as_ref().unwrap().clone()
    }

    /// Wrap a dense SPD matrix in a [`ParDenseOp`] sharded over the
    /// service's compute pool, ready to [`SequenceHandle::submit`].
    pub fn par_operator(&self, a: Mat) -> Arc<ParDenseOp> {
        Arc::new(ParDenseOp::new(Arc::new(a), self.compute_pool()))
    }

    /// Open a new sequence with its own recycled-subspace state.
    pub fn open_sequence(&self, cfg: RecycleConfig) -> SequenceHandle {
        self.metrics.sequences_opened.fetch_add(1, Ordering::Relaxed);
        SequenceHandle {
            state: Arc::new(Mutex::new(SequenceState {
                mgr: RecycleManager::new(cfg),
                queue: VecDeque::new(),
                running: false,
                closed: false,
            })),
            pool: self.pool.clone(),
            metrics: self.metrics.clone(),
        }
    }
}

/// Handle to one solve sequence. Submissions are processed strictly FIFO
/// (recycling transfers state from each solve to the next); distinct
/// sequences run concurrently on the shared pool.
#[derive(Clone)]
pub struct SequenceHandle {
    state: Arc<Mutex<SequenceState>>,
    pool: Arc<ThreadPool>,
    metrics: Arc<ServiceMetrics>,
}

impl SequenceHandle {
    /// Submit the next system of this sequence. Returns a ticket that can
    /// be waited on; submissions may be pipelined without waiting.
    pub fn submit(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Vec<f64>,
        x0: Option<Vec<f64>>,
        cfg: CgConfig,
    ) -> SolveTicket {
        let slot = ResultSlot::new();
        let task = Task { op, b, x0, cfg, slot: slot.clone() };
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "submit on closed sequence");
        st.queue.push_back(task);
        if !st.running {
            st.running = true;
            drop(st);
            self.spawn_drainer();
        }
        SolveTicket { slot }
    }

    fn spawn_drainer(&self) {
        let state = self.state.clone();
        let metrics = self.metrics.clone();
        self.pool.spawn(move || loop {
            let task = {
                let mut st = state.lock().unwrap();
                match st.queue.pop_front() {
                    Some(t) => t,
                    None => {
                        st.running = false;
                        return;
                    }
                }
            };
            // Run the solve outside the sequence lock is NOT possible: the
            // recycle manager *is* the sequence state. But the lock is per
            // sequence, so other sequences proceed in parallel.
            let result = {
                let mut st = state.lock().unwrap();
                st.mgr
                    .solve_next(task.op.as_ref(), &task.b, task.x0.as_deref(), &task.cfg)
            };
            metrics.solves.fetch_add(1, Ordering::Relaxed);
            metrics
                .iterations
                .fetch_add(result.iterations, Ordering::Relaxed);
            metrics.matvecs.fetch_add(result.matvecs, Ordering::Relaxed);
            metrics
                .solve_nanos
                .fetch_add((result.seconds * 1e9) as u64, Ordering::Relaxed);
            task.slot.put(result);
        });
    }

    /// Per-system statistics accumulated by this sequence's manager.
    pub fn history(&self) -> Vec<SystemStats> {
        self.state.lock().unwrap().mgr.history().to_vec()
    }

    /// Current recycled-basis dimension.
    pub fn k_active(&self) -> usize {
        self.state.lock().unwrap().mgr.k_active()
    }

    /// Close the sequence (subsequent submits panic).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::StopReason;
    use crate::util::rng::Rng;

    /// Owning dense operator for Arc'ing into the service.
    struct OwnedDense(Mat);

    impl SpdOperator for OwnedDense {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.0.matvec_into(x, y);
        }
    }

    fn spd(n: usize, seed: u64) -> Arc<OwnedDense> {
        let mut rng = Rng::new(seed);
        Arc::new(OwnedDense(Mat::rand_spd(n, 1e4, &mut rng)))
    }

    #[test]
    fn single_sequence_solves_in_order_with_recycling() {
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let op = spd(60, 1);
        let b = vec![1.0; 60];
        let cfg = CgConfig::with_tol(1e-8);
        let tickets: Vec<_> = (0..4)
            .map(|_| seq.submit(op.clone(), b.clone(), None, cfg.clone()))
            .collect();
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
        for r in &results {
            assert_eq!(r.stop, StopReason::Converged);
        }
        // Identical systems: solves after the first must be cheaper.
        assert!(results[3].iterations < results[0].iterations);
        let hist = seq.history();
        assert_eq!(hist.len(), 4);
        assert!(seq.k_active() > 0);
    }

    #[test]
    fn sequences_run_concurrently_and_keep_state_separate() {
        let svc = SolveService::new(4);
        let cfg = CgConfig::with_tol(1e-6);
        let mut handles = Vec::new();
        for s in 0..3 {
            let seq = svc.open_sequence(RecycleConfig { k: 4, l: 6, ..Default::default() });
            let op = spd(40, 100 + s);
            let b: Vec<f64> = (0..40).map(|i| (i + s as usize) as f64).collect();
            let t1 = seq.submit(op.clone(), b.clone(), None, cfg.clone());
            let t2 = seq.submit(op, b, None, cfg.clone());
            handles.push((seq, t1, t2));
        }
        for (seq, t1, t2) in handles {
            assert_eq!(t1.wait().stop, StopReason::Converged);
            assert_eq!(t2.wait().stop, StopReason::Converged);
            assert_eq!(seq.history().len(), 2);
        }
        let (solves, iters, matvecs, secs, seqs) = svc.metrics().snapshot();
        assert_eq!(solves, 6);
        assert_eq!(seqs, 3);
        assert!(iters > 0 && matvecs >= iters);
        assert!(secs >= 0.0);
    }

    #[test]
    fn pipelined_submissions_complete() {
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(30, 7);
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let b: Vec<f64> = (0..30).map(|j| ((i + j) % 5) as f64 + 1.0).collect();
                seq.submit(op.clone(), b, None, CgConfig::with_tol(1e-6))
            })
            .collect();
        for t in tickets {
            assert_eq!(t.wait().stop, StopReason::Converged);
        }
        assert_eq!(seq.history().len(), 8);
    }

    #[test]
    #[should_panic(expected = "closed sequence")]
    fn closed_sequence_rejects() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        seq.close();
        let op = spd(5, 9);
        let _ = seq.submit(op, vec![1.0; 5], None, CgConfig::default());
    }

    #[test]
    fn par_operator_matches_serial_solves() {
        let svc = SolveService::new(2);
        let mut rng = Rng::new(21);
        let n = 300; // above ParDenseOp::PAR_THRESHOLD: shards for real
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 9) as f64).collect();
        let cfg = CgConfig::with_tol(1e-10);

        let par = svc.par_operator(a.clone());
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let r_par = seq.submit(par, b.clone(), None, cfg.clone()).wait();
        assert_eq!(r_par.stop, StopReason::Converged);

        // Serial reference through a fresh sequence (same recycle state).
        let seq2 = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let r_ser = seq2.submit(spd_mat(a), b, None, cfg).wait();
        assert_eq!(r_ser.stop, StopReason::Converged);

        // Bitwise-identical matvecs => identical CG trajectories.
        assert_eq!(r_par.iterations, r_ser.iterations);
        for (u, v) in r_par.x.iter().zip(&r_ser.x) {
            assert_eq!(u, v);
        }
    }

    fn spd_mat(a: Mat) -> Arc<OwnedDense> {
        Arc::new(OwnedDense(a))
    }

    #[test]
    fn warm_start_passthrough() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(20, 11);
        let b = vec![2.0; 20];
        // First solve to get solution, then warm start from it.
        let x = seq
            .submit(op.clone(), b.clone(), None, CgConfig::with_tol(1e-10))
            .wait()
            .x;
        let warm = seq
            .submit(op, b, Some(x), CgConfig::with_tol(1e-10))
            .wait();
        assert!(warm.iterations <= 2, "warm start took {}", warm.iterations);
    }
}
