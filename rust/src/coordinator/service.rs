//! The solve-service implementation: an **admission-controlled async
//! job API** over per-sequence recycled solves, executed by the sharded
//! work-stealing scheduler in [`super::scheduler`].
//!
//! # Request lifecycle
//!
//! ```text
//! try_submit ──► bounded queue ──► priority-aware dispatch pop ──► solve
//!     │Err(QueueFull)                │cancel/deadline dead-on-arrival
//!     ▼                              ▼
//!  rejected                 completes without running
//! ```
//!
//! Every submission returns a [`SolveFuture`]: non-blocking
//! [`SolveFuture::poll`], blocking [`SolveFuture::wait`] /
//! [`SolveFuture::wait_timeout`], and [`SolveFuture::cancel`] backed by a
//! shared [`CancelToken`]. Cancellation and per-request deadlines
//! ([`SolveSpec::with_deadline`]) take effect **mid-solve**: every kernel
//! checks the spec's control once per iteration, so a cancel returns a
//! [`StopReason::Cancelled`] partial result within one operator
//! application, and an expired deadline returns the partial iterate as
//! [`StopReason::DeadlineExceeded`] — whose stored directions still feed
//! the sequence's recycle basis (partial Krylov work is kept; only
//! *cancelled* runs are never absorbed, so cancellation can never corrupt
//! a sequence's basis).
//!
//! # Admission and scheduling
//!
//! [`SolveService`] bounds the number of queued-plus-running requests
//! ([`SolveService::with_queue_cap`]); [`SequenceHandle::try_submit`]
//! refuses over-cap work with [`SubmitError::QueueFull`] instead of
//! buffering unboundedly.
//!
//! Admitted work is executed by `workers` scheduler threads with one run
//! queue each (see [`super::scheduler`] for the full worker model). Each
//! sequence is a *core* with a sticky home worker — its recycled
//! `(W, AW)` basis keeps being touched from one thread — and one
//! dispatch runs exactly one task (or one coalesced group) before the
//! core rotates to the back of its home run queue. Runnable sequences on
//! a worker therefore round-robin: across sequences, every class of work
//! has a bounded wait even under a sustained stream elsewhere. Idle
//! workers steal cores from their neighbours' queues, preferring urgent
//! (interactive-holding) cores and then basis-free ones, so stolen work
//! loses no basis locality it actually had.
//!
//! Within a sequence, each request carries a
//! [`Priority`](crate::solvers::Priority): dispatch serves the most
//! urgent class present and is FIFO within a class, so `Interactive`
//! requests overtake queued `Batch` work (strict two-class priority:
//! under a *sustained* interactive stream **in the same sequence**,
//! batch work waits — `Batch` means "yield to every interactive request"
//! by design; there is no aging). Priority pops pull interactive singles
//! *out* of batch block runs, leaving those adjacent — coalescing groups
//! stay intact. [`SolveService::shutdown`] supports graceful teardown:
//! [`Shutdown::Drain`] completes all queued work, [`Shutdown::Abort`]
//! cancels queued requests and raises the cancel flag of in-flight ones;
//! both then wait for the service to go idle and reject new submissions.
//!
//! Every completion carries a structured [`SolveReport`] (stop reason,
//! queue/solve wall-times, matvec bill, active basis size, coalesce
//! group size) alongside the numerical result.
//!
//! # Worker-panic safety
//!
//! A panic inside a solve (a poisoned operator, an internal assert) no
//! longer hangs the pipeline: the dispatcher catches the unwind,
//! completes that request's future with [`StopReason::Failed`] (start
//! iterate, infinite residual), recovers the possibly-poisoned sequence
//! state, and keeps dispatching — queued futures behind a failure still
//! complete.
//!
//! # Heterogeneous workloads and coalescing
//!
//! Every request carries its own [`SolveSpec`], so one sequence queue can
//! serve plain CG, Jacobi-preconditioned, deflated, block, and multi-RHS
//! [`SequenceHandle::submit_block`] requests interleaved, while the
//! sequence's [`RecycleManager`] carries the recycled subspace across
//! them. Operators are behind `Arc<dyn SpdOperator + Send + Sync>`, so
//! `solvers::algebra` views (`ShiftedOp(base.clone(), σ)` etc.) submit
//! directly — a σ-grid is a stream of requests over one shared base
//! operator, never a rebuilt kernel.
//!
//! Consecutive queued `submit_block` requests that share the same
//! operator (`Arc` identity) and the same block-relevant policy set (see
//! `coalescible` — including priority and deadline) are dispatched as
//! **one** block solve. The shared solve runs under an *all-of* cancel
//! group: one member's cancel cannot abort its neighbours' work; a
//! member cancelled while still queued is simply left out of the group.
//!
//! **Cross-sequence coalescing:** a dispatching block leader additionally
//! claims *other sequences'* cores from the run queues when their head
//! task is a block request on the **same operator `Arc`** with the same
//! policy set ([`SpdOperator::diag_fingerprint`] is used as a cheap
//! negative prefilter — unequal fingerprints prove distinct operators —
//! but `Arc` identity is the sole merge proof: equal fingerprints never
//! merge two distinct allocations). Many users sharing one Gram matrix
//! thus batch into one block solve across sequence boundaries, with
//! per-ticket column billing exactly as the in-sequence coalescer.
//! The group solve runs on the **leader's** recycle state: member
//! sequences' bases and histories are untouched (their reports carry the
//! leader's post-solve `k_active`). Disable with
//! [`SolveService::cross_sequence_coalescing`].
//!
//! # Locking
//!
//! Each sequence keeps its request queue and its solve state
//! ([`RecycleManager`]) behind **separate** mutexes. Submissions touch
//! only the queue lock, so they return immediately while a solve is in
//! flight; a sequence core is dispatched by at most one scheduler worker
//! at a time (it lives in at most one run queue), which serializes
//! solves under the solve lock, FIFO within a priority class. The
//! cross-sequence claim predicate only ever `try_lock`s peer queue locks
//! (under the scheduler's run-queue locks), so the lock graph stays
//! acyclic: queue-lock → run-queue-lock (enqueue) and
//! run-queue-lock → *try* queue-lock (claim) never deadlock.

use super::scheduler::{DispatchFn, SchedCtx, SchedEntry, Scheduler, SchedulerHold};
use crate::linalg::mat::Mat;
use crate::solvers::api::{Priority, SolveSpec};
use crate::solvers::blockcg::BlockSolveResult;
use crate::solvers::control::{CancelToken, SolveControl};
use crate::solvers::recycle::{AbsorbStats, RecycleConfig, RecycleManager, SystemStats};
use crate::solvers::strategy::StrategyDecision;
use crate::solvers::{ParDenseOp, SolveResult, SpdOperator, StopReason, StoredDirections};
use crate::util::pool::ThreadPool;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{lock_unpoisoned, Arc, Condvar, Mutex, OnceLock, TryLockError, Weak};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Why a submission was not accepted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The service's admission cap (queued + running requests) is
    /// reached. Back off, shed load, or retry later — this is the
    /// backpressure signal that replaces unbounded buffering.
    QueueFull,
    /// This sequence was [`SequenceHandle::close`]d.
    SequenceClosed,
    /// [`SolveService::shutdown`] was called; no new work is accepted.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "full admission queue"),
            SubmitError::SequenceClosed => write!(f, "closed sequence"),
            SubmitError::ShuttingDown => write!(f, "shutting-down service"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Graceful-teardown mode for [`SolveService::shutdown`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shutdown {
    /// Stop admitting work, finish everything already accepted, then
    /// return.
    Drain,
    /// Stop admitting work, complete still-queued requests as
    /// [`StopReason::Cancelled`] without running them, raise the cancel
    /// flag of in-flight solves (they stop within one operator
    /// application and complete as `Cancelled` partial results), then
    /// wait for the service to go idle.
    Abort,
}

/// Structured completion record carried by every [`SolveFuture`]
/// alongside the numerical result ([`SolveFuture::wait_report`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolveReport {
    /// How the solve ended (includes the lifecycle stops `Cancelled`,
    /// `DeadlineExceeded`, `Failed`).
    pub stop: StopReason,
    /// Wall-clock seconds the request spent queued before its dispatcher
    /// picked it up (0 for requests completed at submission time).
    pub queue_seconds: f64,
    /// Wall-clock seconds inside the solver (the shared group solve for
    /// coalesced members; 0 for requests that never ran).
    pub solve_seconds: f64,
    /// Operator applications billed to this request (a coalesced
    /// member's per-column share, like the result's `matvecs`).
    pub matvecs: usize,
    /// Recycled-basis dimension of the solving sequence right after this
    /// completion (0 for requests that never reached the solve state).
    /// A cross-sequence coalesced member reports the **leader's**
    /// post-solve basis dimension — the group solve ran on the leader's
    /// recycle state; the member's own sequence state was untouched.
    pub k_active: usize,
    /// Number of requests served by the same coalesced block solve
    /// (1 for single-RHS requests and uncoalesced blocks).
    pub group_size: usize,
    /// Columns removed by budget enforcement while absorbing this run
    /// (basis columns dropped by residual-optimal truncation plus panel
    /// columns removed by A-weighted compression; see
    /// [`crate::solvers::recycle::RecycleBudget`]). 0 when nothing was
    /// truncated or the request never reached the solve state.
    pub truncated_cols: usize,
    /// This run found its sequence's basis evicted by the service-wide
    /// byte accountant and ran degraded (plain CG re-warming the basis).
    pub post_eviction: bool,
    /// Name of the recycle-space strategy that sized the basis absorbed
    /// from this run (see [`crate::solvers::strategy`]); empty for
    /// requests that never reached the solve state or sequences before
    /// their first extraction.
    pub strategy: &'static str,
    /// Candidates the extraction offered the strategy (post budget
    /// truncation) while absorbing this run.
    pub k_offered: usize,
    /// Candidates the strategy retained (0 = fall back to plain CG).
    pub k_chosen: usize,
    /// Net iteration savings the strategy's κ-bound model predicted for
    /// the retained basis (0 when nothing was retained).
    pub predicted_savings: f64,
    /// Realized iteration savings of this run against the sequence's
    /// cold start (oldest retained history entry minus this run — the
    /// same payoff signal the byte accountant's evictor uses).
    pub realized_savings: f64,
}

/// Internal state of a future's one-shot result slot.
enum SlotState<T> {
    Pending,
    Ready(T, SolveReport),
    Taken,
}

/// One-shot result slot (mini oneshot channel) shared by a future and
/// the dispatcher that completes it.
///
/// `pub` + `#[doc(hidden)]` (not part of the supported API): the loom
/// suite (`rust/tests/loom_models.rs`) model-checks this exact state
/// machine — racing `try_take` callers must yield the result exactly
/// once — and it must check the shipped type, not a replica.
#[doc(hidden)]
pub struct Slot<T> {
    state: Mutex<SlotState<T>>,
    cv: Condvar,
}

impl<T> Slot<T> {
    #[doc(hidden)]
    pub fn new() -> Arc<Self> {
        Arc::new(Slot { state: Mutex::new(SlotState::Pending), cv: Condvar::new() })
    }

    #[doc(hidden)]
    pub fn put(&self, value: T, report: SolveReport) {
        *lock_unpoisoned(&self.state) = SlotState::Ready(value, report);
        self.cv.notify_all();
    }

    /// Non-blocking: the result if it is ready and not yet taken.
    #[doc(hidden)]
    pub fn try_take(&self) -> Option<(T, SolveReport)> {
        let mut g = lock_unpoisoned(&self.state);
        match std::mem::replace(&mut *g, SlotState::Taken) {
            SlotState::Ready(v, r) => Some((v, r)),
            SlotState::Pending => {
                *g = SlotState::Pending;
                None
            }
            SlotState::Taken => None,
        }
    }

    /// Block until the result is ready; panics if it was already taken
    /// by a successful [`Slot::try_take`] (each future yields its result
    /// exactly once).
    #[doc(hidden)]
    pub fn take(&self) -> (T, SolveReport) {
        let mut g = lock_unpoisoned(&self.state);
        loop {
            match std::mem::replace(&mut *g, SlotState::Taken) {
                SlotState::Ready(v, r) => return (v, r),
                SlotState::Taken => panic!("solve-future result already taken"),
                SlotState::Pending => {
                    *g = SlotState::Pending;
                    g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
    }

    /// Block until the result is ready or `timeout` elapses.
    #[doc(hidden)]
    pub fn take_timeout(&self, timeout: Duration) -> Option<(T, SolveReport)> {
        let until = Instant::now() + timeout;
        let mut g = lock_unpoisoned(&self.state);
        loop {
            match std::mem::replace(&mut *g, SlotState::Taken) {
                SlotState::Ready(v, r) => return Some((v, r)),
                SlotState::Taken => return None,
                SlotState::Pending => {
                    *g = SlotState::Pending;
                }
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            let (g2, _) = self
                .cv
                .wait_timeout(g, until - now)
                .unwrap_or_else(|e| e.into_inner());
            g = g2;
        }
    }
}

/// Handle to a pending solve: the async half of the request-lifecycle
/// API, returned by [`SequenceHandle::submit`] / `submit_block` (and
/// their `try_` variants). `T` is [`SolveResult`] for single-RHS
/// requests and [`BlockSolveResult`] for block requests.
///
/// The future yields its result **exactly once** — through whichever of
/// [`SolveFuture::poll`] / [`SolveFuture::wait`] /
/// [`SolveFuture::wait_timeout`] gets it first.
pub struct SolveFuture<T> {
    slot: Arc<Slot<T>>,
    token: CancelToken,
}

impl<T> SolveFuture<T> {
    /// Non-blocking: `Some(result)` once the solve completed (taking the
    /// result; later calls return `None`), `None` while it is still
    /// queued or running.
    pub fn poll(&self) -> Option<T> {
        self.slot.try_take().map(|(v, _)| v)
    }

    /// Non-blocking variant that also yields the [`SolveReport`].
    pub fn poll_report(&self) -> Option<(T, SolveReport)> {
        self.slot.try_take()
    }

    /// Block until the solve finishes.
    ///
    /// # Panics
    /// If the result was already taken by an earlier successful
    /// `poll`/`wait_timeout`.
    pub fn wait(self) -> T {
        self.slot.take().0
    }

    /// [`SolveFuture::wait`], also yielding the [`SolveReport`].
    pub fn wait_report(self) -> (T, SolveReport) {
        self.slot.take()
    }

    /// Block for at most `timeout`; `None` if the solve is still running
    /// (the request keeps running — pair with [`SolveFuture::cancel`] to
    /// give up on it).
    pub fn wait_timeout(&self, timeout: Duration) -> Option<T> {
        self.slot.take_timeout(timeout).map(|(v, _)| v)
    }

    /// Raise the request's cancel flag. A queued request completes as
    /// [`StopReason::Cancelled`] without ever running; a running one
    /// stops at its next per-iteration check (within one operator
    /// application) and returns its partial iterate. A member of a
    /// coalesced block group only stops the shared solve once **every**
    /// member cancelled. Idempotent; a completed request is unaffected.
    pub fn cancel(&self) {
        self.token.cancel();
    }

    /// The shared [`CancelToken`] behind [`SolveFuture::cancel`] — clone
    /// it into watchdogs or drop-guards that may outlive the future.
    pub fn cancel_token(&self) -> CancelToken {
        self.token.clone()
    }
}

/// A solve request: operator + per-request spec + cancel token + payload
/// (single RHS or a multi-RHS block).
struct Task {
    op: Arc<dyn SpdOperator + Send + Sync>,
    spec: SolveSpec,
    token: CancelToken,
    submitted_at: Instant,
    payload: Payload,
}

enum Payload {
    Single { b: Vec<f64>, x0: Option<Vec<f64>>, slot: Arc<Slot<SolveResult>> },
    Block { b: Mat, slot: Arc<Slot<BlockSolveResult>> },
}

impl Task {
    /// Complete this request **without running it** (cancelled or
    /// deadline-dead while queued, or swept by `shutdown(Abort)`): the
    /// start iterate is passed through and no recycle state is touched.
    /// The reported relative residual is the **unit placeholder 1.0**
    /// regardless of any `x0` — exact for the zero start, while the true
    /// residual of a warm start would cost the one operator application
    /// a dead request must never pay; callers that care must recompute
    /// `‖b − A·x‖/‖b‖` themselves.
    fn complete_unrun(self, stop: StopReason, metrics: &ServiceMetrics, queue_seconds: f64) {
        let report = SolveReport {
            stop,
            queue_seconds,
            solve_seconds: 0.0,
            matvecs: 0,
            k_active: 0,
            group_size: 1,
            truncated_cols: 0,
            post_eviction: false,
            strategy: "",
            k_offered: 0,
            k_chosen: 0,
            predicted_savings: 0.0,
            realized_savings: 0.0,
        };
        let n = self.op.n();
        metrics.note_completion(stop, self.spec.priority);
        match self.payload {
            Payload::Single { x0, slot, .. } => {
                slot.put(
                    SolveResult {
                        x: x0.unwrap_or_else(|| vec![0.0; n]),
                        residuals: vec![1.0],
                        iterations: 0,
                        matvecs: 0,
                        stop,
                        stored: StoredDirections::default(),
                        seconds: 0.0,
                    },
                    report,
                );
            }
            Payload::Block { b, slot } => {
                let cols = b.cols();
                slot.put(
                    BlockSolveResult {
                        x: Mat::zeros(n, cols),
                        residuals: vec![1.0],
                        iterations: 0,
                        block_matvecs: 0,
                        matvecs: 0,
                        col_matvecs: vec![0; cols],
                        stop,
                        stored: StoredDirections::default(),
                        seconds: 0.0,
                    },
                    report,
                );
            }
        }
    }
}

/// A member of a coalesced block group, carried from the gather phase to
/// result splitting.
struct BlockMember {
    b: Mat,
    slot: Arc<Slot<BlockSolveResult>>,
    queue_seconds: f64,
}

/// True when two queued block specs may share one coalesced group solve.
/// Every policy that reaches the block kernel or decides basis
/// consumption must match — including, since the async redesign, the
/// scheduling class and the deadline (members share one solve, so they
/// must share its time budget; cancel tokens do NOT block coalescing —
/// the group runs under an all-of cancel set instead). Preconditioner
/// and deflation compare by `Arc` identity (same shared policy object),
/// like the operator itself.
fn coalescible(a: &SolveSpec, b: &SolveSpec) -> bool {
    let same_precond = match (&a.precond, &b.precond) {
        (None, None) => true,
        (Some(x), Some(y)) => Arc::ptr_eq(x, y),
        _ => false,
    };
    let same_defl = match (&a.deflation, &b.deflation) {
        (None, None) => true,
        (Some(x), Some(y)) => Arc::ptr_eq(x, y),
        _ => false,
    };
    a.method == b.method
        && a.tol == b.tol
        && a.max_iters == b.max_iters
        && a.stall_window == b.stall_window
        && a.recompute_every == b.recompute_every
        && a.auto_jacobi == b.auto_jacobi
        && a.priority == b.priority
        && a.control.deadline == b.control.deadline
        && a.strategy == b.strategy
        && same_precond
        && same_defl
}

/// Cheap cross-sequence operator prefilter: unequal
/// [`SpdOperator::diag_fingerprint`]s prove two operators are distinct
/// (reject before the pointer comparison); equal or absent fingerprints
/// prove **nothing** — two independent wrappers over one matrix share a
/// fingerprint — so `Arc::ptr_eq` remains the sole merge proof.
fn same_operator(a: &(dyn SpdOperator + Send + Sync), b: &(dyn SpdOperator + Send + Sync)) -> bool {
    match (a.diag_fingerprint(), b.diag_fingerprint()) {
        (Some(x), Some(y)) => x == y,
        _ => true,
    }
}

/// Queue-side state of a sequence, guarded by a lock that is only ever
/// held for O(1)-ish pushes/pops — **never across a solve** — so
/// [`SequenceHandle::submit`] returns immediately even while a solve for
/// this sequence is in flight (the documented pipelining contract). The
/// solve-side state ([`RecycleManager`]) lives behind its own mutex.
struct SequenceState {
    queue: VecDeque<Task>,
    /// True while this sequence's core is in a run queue or on a
    /// worker's dispatch (including claimed by a cross-sequence group
    /// leader) — the core is in exactly one of those places at a time.
    /// An enqueue that flips this false→true owns the `Scheduler::submit`.
    scheduled: bool,
    closed: bool,
    /// Cancel tokens of the request(s) currently on a dispatcher (all
    /// members of a coalesced group that this sequence contributed).
    /// `shutdown(Abort)` raises these to stop in-flight solves
    /// mid-iteration.
    inflight: Vec<CancelToken>,
}

/// Index of the task a priority-aware pop takes from `queue`: the first
/// `Interactive` task if any, else the front (oldest `Batch`). With
/// exactly two classes this is one early-exiting scan — worst case
/// O(queue), which the admission cap bounds.
fn head_idx(queue: &VecDeque<Task>) -> usize {
    queue
        .iter()
        .position(|t| t.spec.priority == Priority::Interactive)
        .unwrap_or(0)
}

/// Everything the scheduler and the dispatch path need about one
/// sequence: the request queue, the recycle state, and the placement
/// hints. An `Arc<SeqCore>` is what circulates through the scheduler's
/// run queues.
struct SeqCore {
    state: Mutex<SequenceState>,
    mgr: Mutex<RecycleManager>,
    seq_id: u64,
    /// Fixed home worker (sticky placement): sequences are spread
    /// round-robin over the workers at open time.
    home: usize,
    /// Advisory mirror of the resident basis size ([`SchedEntry::steal_cost`]):
    /// refreshed from `k_active` after each settled solve, zeroed by the
    /// byte accountant's evictor. Staleness only degrades steal choices.
    basis_hint: AtomicUsize,
    /// Advisory count of queued `Interactive` tasks
    /// ([`SchedEntry::urgent`]), maintained under the state lock by
    /// [`SeqCore::push_task`] / [`SeqCore::take_task`] /
    /// [`SeqCore::drain_tasks`].
    urgent_hint: AtomicUsize,
}

impl SchedEntry for SeqCore {
    fn home(&self) -> usize {
        self.home
    }
    fn steal_cost(&self) -> usize {
        self.basis_hint.load(Ordering::Relaxed)
    }
    fn urgent(&self) -> usize {
        self.urgent_hint.load(Ordering::Relaxed)
    }
}

impl SeqCore {
    /// Push a task (caller holds the state lock), keeping the urgent
    /// hint in step with the queue's interactive count.
    fn push_task(&self, st: &mut SequenceState, task: Task) {
        if task.spec.priority == Priority::Interactive {
            self.urgent_hint.fetch_add(1, Ordering::Relaxed);
        }
        st.queue.push_back(task);
    }

    /// Remove the task at `idx` (caller holds the state lock), keeping
    /// the urgent hint in step. Saturating: the hint is advisory and
    /// must never underflow-wrap into "everything is urgent". Returns
    /// `None` on an out-of-range index; callers derive `idx` under the
    /// same lock, so a miss means the caller's invariant broke and the
    /// dispatch turn should stop rather than panic mid-queue.
    fn take_task(&self, st: &mut SequenceState, idx: usize) -> Option<Task> {
        let task = st.queue.remove(idx)?;
        if task.spec.priority == Priority::Interactive {
            let _ = self.urgent_hint.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(1))
            });
        }
        Some(task)
    }

    /// Drain the whole queue (caller holds the state lock) — the
    /// `shutdown(Abort)` sweep.
    fn drain_tasks(&self, st: &mut SequenceState) -> Vec<Task> {
        self.urgent_hint.store(0, Ordering::Relaxed);
        st.queue.drain(..).collect()
    }
}

/// Owns the sequence's slot in the `active_sequences` gauge. Held by the
/// `SequenceHandle` clones only (NOT by the scheduler), so the gauge
/// drops when the sequence is explicitly closed or every handle is gone —
/// whichever comes first, exactly once.
struct SeqCloser {
    metrics: Arc<ServiceMetrics>,
    retired: AtomicBool,
}

impl SeqCloser {
    fn retire(&self) {
        if !self.retired.swap(true, Ordering::SeqCst) {
            self.metrics.active_sequences.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

impl Drop for SeqCloser {
    fn drop(&mut self) {
        self.retire();
    }
}

/// Service-wide admission policy shared by every sequence handle.
struct Admission {
    /// Bound on queued-plus-running requests across the whole service.
    queue_cap: usize,
    /// Set by [`SolveService::shutdown`]; rejects new submissions.
    closed: AtomicBool,
}

/// One sequence's row in the [`ByteAccountant`] ledger.
struct AccountEntry {
    id: u64,
    /// Weak: the accountant must never keep a retired sequence's recycle
    /// state alive just to account for it. (The core holds an `Arc` to
    /// the accountant; this back-edge being weak keeps the graph
    /// cycle-free.)
    core: Weak<SeqCore>,
    /// [`RecycleManager::bytes_held`] as of this sequence's last settled
    /// solve (or last eviction).
    bytes: usize,
    /// Logical-clock tick of the last settled solve — the recency axis.
    last_used: u64,
    /// Observed iteration savings of this sequence's basis (cold-start
    /// iterations minus latest iterations, floored at 0) — the
    /// payoff-weighted tiebreak: between two equally cold sequences, the
    /// one whose basis demonstrably saves more work is evicted later.
    payoff: f64,
}

/// Service-wide recycling-memory accountant: tracks
/// [`RecycleManager::bytes_held`] per sequence and, when the total
/// exceeds the global cap, evicts cold sequences' bases (LRU by settle
/// tick, payoff-weighted: score = staleness / (1 + payoff)). Eviction is
/// graceful by construction — [`RecycleManager::evict_basis`] only drops
/// the basis and cached Jacobi, so the victim's next solve runs plain CG
/// and re-warms through the normal extraction; no request ever fails
/// because its sequence was evicted.
///
/// # Locking
///
/// Dispatchers call [`ByteAccountant::settle`] **after** releasing their
/// sequence's solve lock; `settle` holds the ledger lock and only ever
/// `try_lock`s victim managers. A victim mid-solve is therefore simply
/// skipped (it is demonstrably not cold), and the blocking-lock edge
/// "ledger → manager" never exists, so no lock-order cycle with the
/// dispatchers' "manager, then ledger" sequence is possible.
struct ByteAccountant {
    /// Global cap on summed `bytes_held` (`usize::MAX` = unbounded).
    cap: usize,
    /// Logical settle clock (one tick per settled solve).
    clock: AtomicU64,
    entries: Mutex<Vec<AccountEntry>>,
}

impl ByteAccountant {
    fn new(cap: usize) -> Self {
        ByteAccountant { cap, clock: AtomicU64::new(0), entries: Mutex::new(Vec::new()) }
    }

    fn register(&self, id: u64, core: &Arc<SeqCore>) {
        lock_unpoisoned(&self.entries).push(AccountEntry {
            id,
            core: Arc::downgrade(core),
            bytes: 0,
            last_used: 0,
            payoff: 0.0,
        });
    }

    /// Record sequence `id`'s post-solve footprint and, if the global
    /// total now exceeds the cap, evict cold sequences until it does not
    /// (or no evictable candidate remains). The settling sequence itself
    /// is never a victim — it is by definition the hottest, and evicting
    /// it would only force an immediate re-warm.
    fn settle(&self, id: u64, bytes: usize, payoff: f64, metrics: &ServiceMetrics) {
        let now = self.clock.fetch_add(1, Ordering::SeqCst) + 1;
        let mut entries = lock_unpoisoned(&self.entries);
        // Retired sequences (every handle dropped, core drained) freed
        // their manager — drop their rows instead of counting ghost
        // bytes.
        entries.retain(|e| e.core.strong_count() > 0);
        if let Some(e) = entries.iter_mut().find(|e| e.id == id) {
            e.bytes = bytes;
            e.last_used = now;
            e.payoff = payoff;
        }
        let mut total: usize = entries.iter().map(|e| e.bytes).sum();
        if total > self.cap {
            // Coldest first: highest staleness discounted by observed
            // payoff. One pass over a score-ordered candidate list —
            // busy victims (solve in flight) are skipped, not waited on.
            let score = |e: &AccountEntry| (now - e.last_used) as f64 / (1.0 + e.payoff);
            let mut order: Vec<usize> = (0..entries.len())
                .filter(|&i| entries[i].id != id && entries[i].bytes > 0)
                .collect();
            order.sort_by(|&a, &b| score(&entries[b]).total_cmp(&score(&entries[a])));
            for i in order {
                if total <= self.cap {
                    break;
                }
                let Some(c) = entries[i].core.upgrade() else {
                    total -= entries[i].bytes;
                    entries[i].bytes = 0;
                    continue;
                };
                if let Ok(mut mg) = c.mgr.try_lock() {
                    let freed = mg.evict_basis();
                    let remaining = mg.bytes_held();
                    drop(mg);
                    // The steal-cost hint must not keep advertising a
                    // basis that was just dropped.
                    c.basis_hint.store(0, Ordering::Relaxed);
                    total = total - entries[i].bytes + remaining;
                    entries[i].bytes = remaining;
                    // A victim that held only history frees nothing —
                    // that is bookkeeping, not an eviction.
                    if freed > 0 {
                        metrics.basis_evictions.fetch_add(1, Ordering::SeqCst);
                        crate::log_debug!(
                            "byte accountant evicted sequence {} basis ({} bytes held globally)",
                            entries[i].id,
                            total
                        );
                    }
                }
            }
        }
        metrics.bytes_held.store(total, Ordering::SeqCst);
    }
}

/// Aggregated service counters (lock-free atomics; see
/// [`ServiceMetrics::snapshot`] for a consistent-enough named view).
#[derive(Debug)]
pub struct ServiceMetrics {
    pub submitted: AtomicUsize,
    pub completed: AtomicUsize,
    /// Submissions refused at admission (queue full / closed sequence /
    /// shutting down).
    pub rejected: AtomicUsize,
    /// Completions with [`StopReason::Cancelled`].
    pub cancelled: AtomicUsize,
    /// Completions with [`StopReason::DeadlineExceeded`].
    pub deadline_exceeded: AtomicUsize,
    /// Completions with [`StopReason::Failed`] (worker panic).
    pub failed: AtomicUsize,
    pub active_sequences: AtomicUsize,
    pub matvecs: AtomicUsize,
    /// Summed per-solve wall time (overlapping concurrent solves each
    /// contribute their full duration — see `busy_seconds`).
    pub busy_nanos: AtomicU64,
    /// Requests currently queued or running (the admission gauge).
    pub queue_depth: AtomicUsize,
    /// High-water mark of `queue_depth`.
    pub queue_high_water: AtomicUsize,
    /// Accepted `Interactive` requests not yet completed.
    pub interactive_depth: AtomicUsize,
    /// Accepted `Batch` requests not yet completed.
    pub batch_depth: AtomicUsize,
    /// High-water mark of `interactive_depth`.
    pub interactive_high_water: AtomicUsize,
    /// High-water mark of `batch_depth`.
    pub batch_high_water: AtomicUsize,
    /// Scheduler worker count (fixed at construction) — the denominator
    /// callers need to turn `busy_seconds` into utilization.
    pub workers: usize,
    /// Sequence cores dispatched away from their home worker (mirrored
    /// from the scheduler's own counter via its steal observer).
    pub steals: AtomicU64,
    /// Block requests pulled from **other** sequences into a coalesced
    /// group solve by a cross-sequence leader.
    pub cross_seq_coalesced: AtomicUsize,
    /// Gauge: recycling bytes currently held across all live sequences
    /// (basis + cached Jacobi + history, by the audited
    /// [`RecycleManager::bytes_held`] formula), refreshed by the byte
    /// accountant after every settled solve.
    pub bytes_held: AtomicUsize,
    /// Recycled bases dropped by the service-wide byte accountant.
    pub basis_evictions: AtomicUsize,
    /// Budget-enforcement events inside the managers (basis truncations
    /// plus panel compressions).
    pub truncations: AtomicUsize,
    /// Post-eviction solves that needed more iterations than the solve
    /// right before them in their sequence — the observable cost of an
    /// eviction decision.
    pub post_eviction_iter_regressions: AtomicUsize,
    /// Harmonic-Ritz extractions that failed numerically inside the
    /// managers (the basis survives; the candidate batch is dropped).
    pub extraction_failures: AtomicU64,
    /// Strategy decisions that kept fewer columns than the budget
    /// offered (including shrinks all the way to k = 0 / plain CG).
    pub strategy_shrinks: AtomicU64,
    /// Predicted iteration savings summed over strategy decisions that
    /// kept a basis, in milli-iterations (÷1e3 at snapshot time).
    predicted_saved_milli_iters: AtomicU64,
    /// Realized iteration savings (cold-start iterations minus this
    /// solve's, clamped at 0) in milli-iterations (÷1e3 at snapshot).
    realized_saved_milli_iters: AtomicU64,
    /// Time origin for the span stamps below.
    epoch: Instant,
    /// Nanos-since-epoch (+1, 0 = unset) of the first accepted submit.
    first_submit_nanos: AtomicU64,
    /// Nanos-since-epoch (+1, 0 = none) of the latest completion.
    last_complete_nanos: AtomicU64,
    /// Wakes `wait_idle` (shutdown/drain waiters) on completions.
    idle: Mutex<()>,
    idle_cv: Condvar,
}

impl ServiceMetrics {
    fn new(workers: usize) -> Self {
        ServiceMetrics {
            submitted: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            cancelled: AtomicUsize::new(0),
            deadline_exceeded: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            active_sequences: AtomicUsize::new(0),
            matvecs: AtomicUsize::new(0),
            busy_nanos: AtomicU64::new(0),
            queue_depth: AtomicUsize::new(0),
            queue_high_water: AtomicUsize::new(0),
            interactive_depth: AtomicUsize::new(0),
            batch_depth: AtomicUsize::new(0),
            interactive_high_water: AtomicUsize::new(0),
            batch_high_water: AtomicUsize::new(0),
            workers,
            steals: AtomicU64::new(0),
            cross_seq_coalesced: AtomicUsize::new(0),
            bytes_held: AtomicUsize::new(0),
            basis_evictions: AtomicUsize::new(0),
            truncations: AtomicUsize::new(0),
            post_eviction_iter_regressions: AtomicUsize::new(0),
            extraction_failures: AtomicU64::new(0),
            strategy_shrinks: AtomicU64::new(0),
            predicted_saved_milli_iters: AtomicU64::new(0),
            realized_saved_milli_iters: AtomicU64::new(0),
            epoch: Instant::now(),
            first_submit_nanos: AtomicU64::new(0),
            last_complete_nanos: AtomicU64::new(0),
            idle: Mutex::new(()),
            idle_cv: Condvar::new(),
        }
    }

    fn stamp(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64 + 1
    }

    fn note_submitted(&self) {
        self.submitted.fetch_add(1, Ordering::SeqCst);
        let _ = self.first_submit_nanos.compare_exchange(
            0,
            self.stamp(),
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    }

    fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::SeqCst);
    }

    /// Raise the per-class depth gauge for an **accepted** request (call
    /// only on the enqueue path, after admission passed — exactly paired
    /// with the decrement in [`ServiceMetrics::note_completion`]).
    fn note_enqueued_class(&self, priority: Priority) {
        let (depth, high) = match priority {
            Priority::Interactive => (&self.interactive_depth, &self.interactive_high_water),
            Priority::Batch => (&self.batch_depth, &self.batch_high_water),
        };
        let d = depth.fetch_add(1, Ordering::SeqCst) + 1;
        high.fetch_max(d, Ordering::SeqCst);
    }

    /// Record one request completion (it left the queue-or-running set):
    /// stop-reason counters, the per-class depth gauge, the span stamp,
    /// the admission gauge, and the idle wakeup for `shutdown` waiters.
    fn note_completion(&self, stop: StopReason, priority: Priority) {
        match stop {
            StopReason::Cancelled => {
                self.cancelled.fetch_add(1, Ordering::SeqCst);
            }
            StopReason::DeadlineExceeded => {
                self.deadline_exceeded.fetch_add(1, Ordering::SeqCst);
            }
            StopReason::Failed => {
                self.failed.fetch_add(1, Ordering::SeqCst);
            }
            _ => {}
        }
        match priority {
            Priority::Interactive => {
                self.interactive_depth.fetch_sub(1, Ordering::SeqCst);
            }
            Priority::Batch => {
                self.batch_depth.fetch_sub(1, Ordering::SeqCst);
            }
        }
        // SeqCst, matching `snapshot`'s reads: once a snapshot observes
        // this completion in `completed`, it must also observe the span
        // stamp (otherwise busy time lands inside a span that excludes
        // the solve that produced it).
        self.last_complete_nanos.fetch_max(self.stamp(), Ordering::SeqCst);
        self.queue_depth.fetch_sub(1, Ordering::SeqCst);
        self.completed.fetch_add(1, Ordering::SeqCst);
        // Lock-then-notify so a `wait_idle` waiter between its pending
        // check and its wait cannot miss the wakeup.
        let _g = lock_unpoisoned(&self.idle);
        self.idle_cv.notify_all();
    }

    /// Solver busy time + matvec bill (once per *solve*: a coalesced
    /// group contributes its shared wall time once, while each member's
    /// completion is counted by [`ServiceMetrics::note_completion`]).
    fn add_busy(&self, seconds: f64, matvecs: usize) {
        self.matvecs.fetch_add(matvecs, Ordering::SeqCst);
        // SeqCst pairs with `snapshot` reading busy FIRST: any busy time
        // a snapshot sees was added strictly before its span reads.
        self.busy_nanos.fetch_add((seconds * 1e9) as u64, Ordering::SeqCst);
    }

    /// Block until no request is queued or running. The 50 ms re-check
    /// is a belt-and-braces bound on any lost wakeup.
    fn wait_idle(&self) {
        let mut g = lock_unpoisoned(&self.idle);
        loop {
            let submitted = self.submitted.load(Ordering::SeqCst);
            let completed = self.completed.load(Ordering::SeqCst);
            if submitted.saturating_sub(completed) == 0 {
                return;
            }
            let (g2, _) = self
                .idle_cv
                .wait_timeout(g, Duration::from_millis(50))
                .unwrap_or_else(|e| e.into_inner());
            g = g2;
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        // Read order is load-bearing for the `busy_seconds ≤
        // span_seconds × workers` invariant. The completion path writes
        // busy (`add_busy`), then the span stamp, then `completed` — so
        // the snapshot reads them in the REVERSE order: busy first, so
        // every nanosecond of busy time it reports was recorded before
        // the span reads; then completed/submitted; then the stamps.
        // A solve that has added busy time but not yet stamped its
        // completion is still in flight by the counters
        // (submitted > completed), and the span end is extended to *now*,
        // which is at or after that solve's true end — the old relaxed,
        // busy-last reads could instead pair fresh busy time with a stale
        // span and report utilization above the worker count.
        let busy = self.busy_nanos.load(Ordering::SeqCst);
        let completed = self.completed.load(Ordering::SeqCst);
        let submitted = self.submitted.load(Ordering::SeqCst);
        let first = self.first_submit_nanos.load(Ordering::SeqCst);
        let mut last = self.last_complete_nanos.load(Ordering::SeqCst);
        if submitted > completed {
            last = last.max(self.stamp());
        }
        MetricsSnapshot {
            submitted,
            completed,
            rejected: self.rejected.load(Ordering::SeqCst),
            cancelled: self.cancelled.load(Ordering::SeqCst),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::SeqCst),
            failed: self.failed.load(Ordering::SeqCst),
            active_sequences: self.active_sequences.load(Ordering::SeqCst),
            busy_seconds: busy as f64 * 1e-9,
            span_seconds: if first > 0 && last >= first {
                (last - first) as f64 * 1e-9
            } else {
                0.0
            },
            total_matvecs: self.matvecs.load(Ordering::SeqCst),
            queue_depth: self.queue_depth.load(Ordering::SeqCst),
            queue_high_water: self.queue_high_water.load(Ordering::SeqCst),
            interactive_depth: self.interactive_depth.load(Ordering::SeqCst),
            batch_depth: self.batch_depth.load(Ordering::SeqCst),
            interactive_high_water: self.interactive_high_water.load(Ordering::SeqCst),
            batch_high_water: self.batch_high_water.load(Ordering::SeqCst),
            workers: self.workers,
            steals: self.steals.load(Ordering::SeqCst) as usize,
            cross_seq_coalesced: self.cross_seq_coalesced.load(Ordering::SeqCst),
            bytes_held: self.bytes_held.load(Ordering::SeqCst),
            basis_evictions: self.basis_evictions.load(Ordering::SeqCst),
            truncations: self.truncations.load(Ordering::SeqCst),
            post_eviction_iter_regressions: self
                .post_eviction_iter_regressions
                .load(Ordering::SeqCst),
            extraction_failures: self.extraction_failures.load(Ordering::SeqCst)
                as usize,
            strategy_shrinks: self.strategy_shrinks.load(Ordering::SeqCst) as usize,
            predicted_saved_iters: self
                .predicted_saved_milli_iters
                .load(Ordering::SeqCst) as f64
                * 1e-3,
            realized_saved_iters: self.realized_saved_milli_iters.load(Ordering::SeqCst)
                as f64
                * 1e-3,
        }
    }
}

/// A named point-in-time view of the service counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests accepted by `submit`/`try_submit` (rejections excluded).
    pub submitted: usize,
    /// Requests whose future has been completed (any stop reason).
    pub completed: usize,
    /// Submissions refused at admission (queue full, closed sequence,
    /// shutting down).
    pub rejected: usize,
    /// Completions that ended as [`StopReason::Cancelled`].
    pub cancelled: usize,
    /// Completions that ended as [`StopReason::DeadlineExceeded`].
    pub deadline_exceeded: usize,
    /// Completions that ended as [`StopReason::Failed`] (worker panic).
    pub failed: usize,
    /// Sequences opened and not yet retired (a sequence retires when it
    /// is explicitly closed or when its last handle is dropped).
    pub active_sequences: usize,
    /// **Summed** wall-clock seconds inside solvers: two solves running
    /// concurrently for 1 s each contribute 2 s. The utilization /
    /// cost axis — compare against `span_seconds × workers`.
    pub busy_seconds: f64,
    /// Wall-clock seconds from the first accepted submission to the
    /// latest completion — real elapsed service time, never
    /// double-counted. `busy_seconds / span_seconds` is the average
    /// solver parallelism. (The old `total_seconds` field summed like
    /// `busy_seconds` while reading like `span_seconds`; the split
    /// removes the ambiguity.)
    pub span_seconds: f64,
    /// Cumulative operator applications across all solves (block applies
    /// counted per active column).
    pub total_matvecs: usize,
    /// Requests currently queued or running (the admission gauge).
    pub queue_depth: usize,
    /// High-water mark of `queue_depth` — how close the service came to
    /// its admission cap.
    pub queue_high_water: usize,
    /// Accepted `Interactive` requests not yet completed.
    pub interactive_depth: usize,
    /// Accepted `Batch` requests not yet completed.
    pub batch_depth: usize,
    /// High-water mark of `interactive_depth`.
    pub interactive_high_water: usize,
    /// High-water mark of `batch_depth` — how much throughput work was
    /// parked behind the interactive stream at the worst moment.
    pub batch_high_water: usize,
    /// Scheduler worker count (fixed at construction): the denominator
    /// of [`MetricsSnapshot::utilization`].
    pub workers: usize,
    /// Sequence cores dispatched away from their home worker by idle
    /// workers — how much the work-stealer had to rebalance.
    pub steals: usize,
    /// Block requests pulled from other sequences into a shared group
    /// solve by cross-sequence coalescing.
    pub cross_seq_coalesced: usize,
    /// Recycling bytes currently held across live sequences (basis +
    /// cached Jacobi + history, the audited
    /// [`RecycleManager::bytes_held`] formula), as of the last settled
    /// solve.
    pub bytes_held: usize,
    /// Recycled bases dropped by the service-wide byte accountant to get
    /// back under its global cap.
    pub basis_evictions: usize,
    /// Budget-enforcement events inside the sequence managers (basis
    /// truncations plus stored-panel compressions).
    pub truncations: usize,
    /// Post-eviction solves that regressed in iteration count relative
    /// to the solve right before them in their sequence.
    pub post_eviction_iter_regressions: usize,
    /// Harmonic-Ritz extractions that failed numerically inside the
    /// sequence managers (candidate batch dropped, basis kept).
    pub extraction_failures: usize,
    /// Strategy decisions that kept fewer basis columns than the budget
    /// offered — how often predictive sizing is actively trimming.
    pub strategy_shrinks: usize,
    /// Predicted iteration savings summed over strategy decisions that
    /// kept a basis (the κ-bound model's promise; compare with
    /// `realized_saved_iters` to audit the payoff model).
    pub predicted_saved_iters: f64,
    /// Realized iteration savings: per settled solve, the sequence's
    /// cold-start iteration count minus this solve's, clamped at zero,
    /// summed.
    pub realized_saved_iters: f64,
}

impl MetricsSnapshot {
    /// Requests accepted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.submitted.saturating_sub(self.completed)
    }

    /// Fraction of the worker-seconds the span offered that solvers
    /// actually used: `busy_seconds / (span_seconds × workers)`. 0.0
    /// before any work completes (empty span).
    pub fn utilization(&self) -> f64 {
        if self.span_seconds > 0.0 && self.workers > 0 {
            self.busy_seconds / (self.span_seconds * self.workers as f64)
        } else {
            0.0
        }
    }
}

/// RAII dispatch pause from [`SolveService::pause`]: while any guard is
/// alive, the scheduler workers dispatch nothing — in-flight solves
/// finish, submissions still enqueue (and are admission-checked as
/// usual), and dropping the last guard resumes dispatching. The
/// deterministic way to stage a queue before letting it drain, used
/// heavily by the coalescing and fairness tests.
pub struct PauseGuard {
    _hold: SchedulerHold<SeqCore>,
}

/// The service: a sharded work-stealing scheduler, per-sequence
/// recycling state, and the service-wide admission policy.
pub struct SolveService {
    sched: Arc<Scheduler<SeqCore>>,
    /// Dedicated pool for sharded dense matvecs ([`ParDenseOp`]),
    /// built once on first use (lock-free after that). Kept separate
    /// from the scheduler workers: a dispatcher that blocked on shard
    /// joins queued behind other dispatchers on the *same* fixed-size
    /// pool would deadlock (nested fork/join).
    compute: OnceLock<Arc<ThreadPool>>,
    metrics: Arc<ServiceMetrics>,
    admission: Arc<Admission>,
    /// Weak registry of sequence cores, for `shutdown(Abort)` sweeps.
    sequences: Mutex<Vec<Weak<SeqCore>>>,
    /// Service-wide recycling-memory ledger (cap `usize::MAX` unless
    /// built with [`SolveService::with_byte_cap`]).
    accountant: Arc<ByteAccountant>,
    /// Cross-sequence coalescing switch, read by the dispatch closure.
    cross_seq: Arc<AtomicBool>,
    next_seq_id: AtomicU64,
}

impl SolveService {
    /// Default admission cap (queued + running requests).
    pub const DEFAULT_QUEUE_CAP: usize = 4096;

    /// A service with `workers` scheduler threads and the default
    /// admission cap.
    pub fn new(workers: usize) -> Self {
        Self::with_queue_cap(workers, Self::DEFAULT_QUEUE_CAP)
    }

    /// A service whose admission cap is `queue_cap`: once that many
    /// requests are queued or running, [`SequenceHandle::try_submit`]
    /// returns [`SubmitError::QueueFull`] (and `submit` panics).
    pub fn with_queue_cap(workers: usize, queue_cap: usize) -> Self {
        Self::with_byte_cap(workers, queue_cap, usize::MAX)
    }

    /// A service that additionally bounds the **summed recycling
    /// footprint** across all sequences: once the total of every live
    /// sequence's [`RecycleManager::bytes_held`] exceeds
    /// `max_recycle_bytes`, the service evicts cold sequences' recycled
    /// bases (LRU with a payoff-weighted tiebreak) until it is back
    /// under the cap. Evicted sequences degrade gracefully — their next
    /// solve runs plain CG and re-warms the basis; no request errors.
    /// Eviction decisions are visible as
    /// [`MetricsSnapshot::basis_evictions`] /
    /// [`MetricsSnapshot::bytes_held`] and per-request as
    /// [`SolveReport::post_eviction`].
    pub fn with_byte_cap(workers: usize, queue_cap: usize, max_recycle_bytes: usize) -> Self {
        assert!(queue_cap >= 1, "admission cap must admit at least one request");
        let metrics = Arc::new(ServiceMetrics::new(workers));
        let accountant = Arc::new(ByteAccountant::new(max_recycle_bytes));
        let cross_seq = Arc::new(AtomicBool::new(true));
        let on_steal: Box<dyn Fn() + Send + Sync> = {
            let m = metrics.clone();
            Box::new(move || {
                m.steals.fetch_add(1, Ordering::SeqCst);
            })
        };
        let dispatch: DispatchFn<SeqCore> = {
            let metrics = metrics.clone();
            let accountant = accountant.clone();
            let cross_seq = cross_seq.clone();
            Box::new(move |core, ctx, _worker| {
                dispatch_one(core, ctx, &metrics, &accountant, &cross_seq);
            })
        };
        SolveService {
            sched: Arc::new(Scheduler::new(workers, on_steal, dispatch)),
            compute: OnceLock::new(),
            metrics,
            admission: Arc::new(Admission { queue_cap, closed: AtomicBool::new(false) }),
            sequences: Mutex::new(Vec::new()),
            accountant,
            cross_seq,
            next_seq_id: AtomicU64::new(0),
        }
    }

    /// The service's live counters.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Scheduler worker count (the `workers` this service was built
    /// with; also surfaced as [`MetricsSnapshot::workers`]).
    pub fn workers(&self) -> usize {
        self.sched.n_workers()
    }

    /// Test hook (`pub` + `#[doc(hidden)]`, not part of the supported
    /// API): check the scheduler's one-entry-anywhere invariant — no
    /// sequence core resident in two run queues at once — right now.
    /// `Err` carries a description of the duplicate. Integration tests
    /// hammer this concurrently with submit/steal/pause/requeue traffic;
    /// the same audit is `debug_assert`ed on the scheduler's own
    /// mutating paths.
    #[doc(hidden)]
    pub fn audit_scheduler(&self) -> Result<(), String> {
        self.sched.audit_queues()
    }

    /// Enable or disable cross-sequence block coalescing (enabled by
    /// default). Takes effect at the next dispatch; in-flight groups are
    /// unaffected. Disabling restores strict per-sequence solves —
    /// useful when per-sequence recycle-state isolation matters more
    /// than shared-operator throughput.
    pub fn cross_sequence_coalescing(&self, enabled: bool) {
        self.cross_seq.store(enabled, Ordering::SeqCst);
    }

    /// Pause dispatching until the returned guard is dropped: in-flight
    /// solves finish, queued and newly-submitted work waits. Guards
    /// stack — dispatch resumes when the last one drops.
    pub fn pause(&self) -> PauseGuard {
        PauseGuard { _hold: self.sched.hold() }
    }

    /// The dedicated compute pool for matvec sharding (created on first
    /// use, sized to the machine, threads named `krr-compute-{i}`).
    pub fn compute_pool(&self) -> Arc<ThreadPool> {
        self.compute
            .get_or_init(|| {
                Arc::new(ThreadPool::with_name(ThreadPool::auto_workers(), "krr-compute"))
            })
            .clone()
    }

    /// Wrap a dense SPD matrix in a [`ParDenseOp`] sharded over the
    /// service's compute pool, ready to [`SequenceHandle::submit`].
    pub fn par_operator(&self, a: Mat) -> Arc<ParDenseOp> {
        Arc::new(ParDenseOp::new(Arc::new(a), self.compute_pool()))
    }

    /// Open a new sequence with its own recycled-subspace state. Each
    /// request submitted to the handle carries its own [`SolveSpec`]; the
    /// `cfg` here fixes the sequence-level recycling hyperparameters
    /// (k, ℓ, AW policy). The sequence's home worker is assigned
    /// round-robin over the scheduler workers.
    pub fn open_sequence(&self, cfg: RecycleConfig) -> SequenceHandle {
        self.metrics.active_sequences.fetch_add(1, Ordering::SeqCst);
        let seq_id = self.next_seq_id.fetch_add(1, Ordering::SeqCst);
        let core = Arc::new(SeqCore {
            state: Mutex::new(SequenceState {
                queue: VecDeque::new(),
                scheduled: false,
                closed: false,
                inflight: Vec::new(),
            }),
            mgr: Mutex::new(RecycleManager::new(cfg)),
            seq_id,
            home: seq_id as usize % self.sched.n_workers(),
            basis_hint: AtomicUsize::new(0),
            urgent_hint: AtomicUsize::new(0),
        });
        {
            let mut seqs = lock_unpoisoned(&self.sequences);
            seqs.retain(|w| w.strong_count() > 0); // prune retired sequences
            seqs.push(Arc::downgrade(&core));
        }
        self.accountant.register(seq_id, &core);
        SequenceHandle {
            core,
            sched: self.sched.clone(),
            metrics: self.metrics.clone(),
            admission: self.admission.clone(),
            closer: Arc::new(SeqCloser {
                metrics: self.metrics.clone(),
                retired: AtomicBool::new(false),
            }),
        }
    }

    /// Graceful teardown. Both modes first stop admitting new work
    /// (subsequent `try_submit`s return [`SubmitError::ShuttingDown`]),
    /// then block until no request is queued or running:
    ///
    /// * [`Shutdown::Drain`] lets everything already accepted run to
    ///   completion;
    /// * [`Shutdown::Abort`] completes still-queued requests as
    ///   [`StopReason::Cancelled`] without running them and raises the
    ///   cancel flag of every in-flight solve, which stops within one
    ///   operator application and completes as a `Cancelled` partial
    ///   result.
    ///
    /// Idempotent; safe to call from any thread (not from a dispatcher).
    pub fn shutdown(&self, mode: Shutdown) {
        self.admission.closed.store(true, Ordering::SeqCst);
        // Barrier: acquire every sequence's queue lock once AFTER setting
        // the flag. An enqueue that passed its under-lock closed check
        // before the store completes its push + submitted-count while
        // still holding that lock, so it is visible to `wait_idle` once
        // the barrier has passed; an enqueue locking after the barrier
        // observes `closed` and is rejected. Without this, a racing
        // submit could be accepted after `wait_idle` already returned.
        let cores: Vec<_> = lock_unpoisoned(&self.sequences)
            .iter()
            .filter_map(|w| w.upgrade())
            .collect();
        for core in &cores {
            let (tasks, inflight) = {
                let mut st = lock_unpoisoned(&core.state);
                match mode {
                    Shutdown::Drain => (Vec::new(), Vec::new()),
                    Shutdown::Abort => (core.drain_tasks(&mut st), st.inflight.clone()),
                }
            };
            for t in &inflight {
                t.cancel();
            }
            for task in tasks {
                let qsec = task.submitted_at.elapsed().as_secs_f64();
                task.token.cancel();
                task.complete_unrun(StopReason::Cancelled, &self.metrics, qsec);
            }
        }
        // Swept cores still sitting in run queues dispatch against an
        // empty queue and simply unschedule themselves.
        self.metrics.wait_idle();
    }
}

/// Handle to one solve sequence. Within a priority class, submissions
/// are processed FIFO (recycling transfers state from each solve to the
/// next); `Interactive` requests overtake queued `Batch` ones. Distinct
/// sequences run concurrently across the scheduler workers, each from
/// its sticky home worker unless stolen.
///
/// The queue lock and the solve lock ([`RecycleManager`]) are separate:
/// submitting only touches the queue, so `submit`/`submit_block` return
/// immediately even while this sequence is deep inside a slow solve.
/// Only `history()`/`k_active()` wait on an in-flight solve (they read
/// the recycle state itself).
#[derive(Clone)]
pub struct SequenceHandle {
    core: Arc<SeqCore>,
    sched: Arc<Scheduler<SeqCore>>,
    metrics: Arc<ServiceMetrics>,
    admission: Arc<Admission>,
    closer: Arc<SeqCloser>,
}

impl SequenceHandle {
    /// Submit the next system of this sequence with its own per-request
    /// [`SolveSpec`] (method, tolerance, preconditioner, priority,
    /// deadline, …). Returns a [`SolveFuture`]; submissions may be
    /// pipelined without waiting. Panics when the request is not
    /// admitted — use [`SequenceHandle::try_submit`] for backpressure
    /// handling. See [`RecycleManager::solve_next`] for how each method
    /// interacts with the sequence's recycled basis.
    pub fn submit(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Vec<f64>,
        x0: Option<Vec<f64>>,
        spec: SolveSpec,
    ) -> SolveFuture<SolveResult> {
        match self.try_submit(op, b, x0, spec) {
            Ok(f) => f,
            Err(e) => panic!("submit on {e}"),
        }
    }

    /// Admission-checked [`SequenceHandle::submit`]: returns the future,
    /// or a [`SubmitError`] when the service's queue cap is reached, the
    /// sequence is closed, or the service is shutting down. A spec that
    /// already carries a [`CancelToken`] ([`SolveSpec::with_cancel`])
    /// keeps it as the future's token; otherwise a fresh one is created.
    pub fn try_submit(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Vec<f64>,
        x0: Option<Vec<f64>>,
        mut spec: SolveSpec,
    ) -> Result<SolveFuture<SolveResult>, SubmitError> {
        // Validate at the call site: a panic inside the dispatcher is a
        // Failed completion, but a dimension mismatch is a caller bug
        // and should fail loudly where it was made.
        assert_eq!(b.len(), op.n(), "rhs dimension mismatch");
        if let Some(x0) = &x0 {
            assert_eq!(x0.len(), op.n(), "x0 dimension mismatch");
        }
        let token = spec.control.token().cloned().unwrap_or_default();
        spec.control.set_token(token.clone());
        let slot = Slot::new();
        let task = Task {
            op,
            spec,
            token: token.clone(),
            submitted_at: Instant::now(),
            payload: Payload::Single { b, x0, slot: slot.clone() },
        };
        self.enqueue(task)?;
        Ok(SolveFuture { slot, token })
    }

    /// Submit a genuine multi-RHS block `A X = B` (one column per RHS) for
    /// this sequence, solved by rank-adaptive block CG through
    /// [`RecycleManager::solve_block`]. Block requests are first-class
    /// recycling citizens: the sequence's basis **deflates** the block
    /// solve (projected start + per-iteration deflation) and the run's
    /// stored block directions **feed** the next harmonic-Ritz
    /// extraction, so coalesced multi-RHS traffic enjoys the same
    /// iteration decay across a sequence as the single-RHS path. The
    /// spec's preconditioner (explicit or `auto_jacobi`) is honored too.
    /// Panics when the request is not admitted — use
    /// [`SequenceHandle::try_submit_block`] for backpressure handling.
    ///
    /// **Coalescing:** consecutive queued block requests on the same
    /// operator (`Arc` identity) with the same block-relevant policy set
    /// (tolerance, iteration cap, method, stall window,
    /// residual-replacement period, auto-Jacobi flag, priority,
    /// deadline, and preconditioner/deflation identity) are dispatched
    /// as a single block solve over their concatenated columns —
    /// same-sequence multi-RHS traffic shares the block Krylov space and
    /// the per-iteration `apply_block` data pass. A dispatching leader
    /// additionally pulls matching block requests from **other
    /// sequences** whose head-of-queue work shares the same operator
    /// `Arc` and policy set (see the module docs; disable with
    /// [`SolveService::cross_sequence_coalescing`]). Each future still
    /// receives exactly its own solution columns, and is billed exactly
    /// its own columns' operator applications (`col_matvecs` shares):
    /// duplicate or early-converging columns ride nearly free, with the
    /// group's basis-refresh overhead billed to the group's first
    /// member. Cancelling one member never aborts the shared solve; the
    /// group stops early only when every member cancelled.
    pub fn submit_block(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Mat,
        spec: SolveSpec,
    ) -> SolveFuture<BlockSolveResult> {
        match self.try_submit_block(op, b, spec) {
            Ok(f) => f,
            Err(e) => panic!("submit on {e}"),
        }
    }

    /// Admission-checked [`SequenceHandle::submit_block`].
    pub fn try_submit_block(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Mat,
        mut spec: SolveSpec,
    ) -> Result<SolveFuture<BlockSolveResult>, SubmitError> {
        assert_eq!(b.rows(), op.n(), "rhs block dimension mismatch");
        assert!(b.cols() >= 1, "rhs block needs at least one column");
        let token = spec.control.token().cloned().unwrap_or_default();
        spec.control.set_token(token.clone());
        let slot = Slot::new();
        let task = Task {
            op,
            spec,
            token: token.clone(),
            submitted_at: Instant::now(),
            payload: Payload::Block { b, slot: slot.clone() },
        };
        self.enqueue(task)?;
        Ok(SolveFuture { slot, token })
    }

    fn enqueue(&self, task: Task) -> Result<(), SubmitError> {
        if self.admission.closed.load(Ordering::SeqCst) {
            self.metrics.note_rejected();
            return Err(SubmitError::ShuttingDown);
        }
        // Reserve an admission slot (queued and running requests both
        // occupy one until their completion releases it).
        let depth = self.metrics.queue_depth.fetch_add(1, Ordering::SeqCst) + 1;
        if depth > self.admission.queue_cap {
            self.metrics.queue_depth.fetch_sub(1, Ordering::SeqCst);
            self.metrics.note_rejected();
            return Err(SubmitError::QueueFull);
        }
        self.metrics.queue_high_water.fetch_max(depth, Ordering::SeqCst);
        let mut st = lock_unpoisoned(&self.core.state);
        // Re-check shutdown UNDER the queue lock: `shutdown(Abort)` sweeps
        // each sequence queue under this same lock after setting the flag,
        // so a submit racing the sweep either lands before it (and is
        // swept to a Cancelled completion) or observes `closed` here and
        // is rejected — never accepted-and-run after shutdown returned.
        if self.admission.closed.load(Ordering::SeqCst) {
            drop(st);
            self.metrics.queue_depth.fetch_sub(1, Ordering::SeqCst);
            self.metrics.note_rejected();
            return Err(SubmitError::ShuttingDown);
        }
        if st.closed {
            drop(st);
            self.metrics.queue_depth.fetch_sub(1, Ordering::SeqCst);
            self.metrics.note_rejected();
            return Err(SubmitError::SequenceClosed);
        }
        self.metrics.note_submitted();
        self.metrics.note_enqueued_class(task.spec.priority);
        self.core.push_task(&mut st, task);
        // Schedule the core exactly once: the `scheduled` flag flips
        // false→true under the queue lock, and only back to false by a
        // dispatcher that (under this same lock) saw an empty queue — so
        // a core is never in two run queues, and no push is stranded.
        let schedule = !st.scheduled;
        if schedule {
            st.scheduled = true;
        }
        drop(st);
        if schedule {
            self.sched.submit(self.core.clone());
        }
        Ok(())
    }

    /// Per-system statistics accumulated by this sequence's manager.
    /// Waits for an in-flight solve (it reads the solve-side state).
    /// Requests completed without running (cancelled in queue, swept by
    /// `shutdown(Abort)`, failed) never appear here, and neither do
    /// requests this sequence contributed to **another** sequence's
    /// cross-coalesced group solve (the group ran on the leader's
    /// state).
    pub fn history(&self) -> Vec<SystemStats> {
        lock_unpoisoned(&self.core.mgr).history().to_vec()
    }

    /// Current recycled-basis dimension. Waits for an in-flight solve.
    pub fn k_active(&self) -> usize {
        lock_unpoisoned(&self.core.mgr).k_active()
    }

    /// Close the sequence (subsequent submits are rejected) and retire
    /// it from the `active_sequences` gauge. Idempotent; dropping the
    /// last handle without closing retires the gauge slot too.
    pub fn close(&self) {
        lock_unpoisoned(&self.core.state).closed = true;
        self.closer.retire();
    }
}

/// Most peers a cross-coalescing leader will claim per dispatch — keeps
/// the claim scan and the merged block width bounded under pathological
/// fan-in (the in-sequence gather is still unbounded, as before).
const CROSS_SEQ_CAP: usize = 32;

/// End-of-dispatch handoff: clear the inflight set and either rotate
/// the core to the BACK of its home run queue (more work queued — the
/// round-robin that bounds every sequence's wait between turns) or
/// mark it unscheduled (empty queue; the next enqueue re-submits it).
fn requeue_or_park(core: &Arc<SeqCore>, ctx: &SchedCtx<SeqCore>) {
    let mut st = lock_unpoisoned(&core.state);
    st.inflight.clear();
    if st.queue.is_empty() {
        st.scheduled = false;
        return;
    }
    drop(st);
    ctx.requeue(core.clone());
}

/// One dispatch turn for one sequence: pop the priority-aware head,
/// run it (solo or as a coalesced group leader), complete the futures,
/// and hand the core back to the scheduler. Runs on a `krr-sched`
/// worker; never holds the queue lock across a solve.
fn dispatch_one(
    core: &Arc<SeqCore>,
    ctx: &SchedCtx<SeqCore>,
    metrics: &ServiceMetrics,
    accountant: &ByteAccountant,
    cross_seq: &AtomicBool,
) {
    // Priority-aware pop: serve the most urgent class present, FIFO
    // within the class. With exactly two classes this is one
    // early-exiting scan — the first Interactive task wins, else the
    // front (oldest Batch). Worst case O(queue), which the admission
    // cap bounds; the lock is never held across a solve. `idx` is
    // remembered so a block leader can coalesce with the requests
    // right behind it.
    let (task, idx) = {
        let mut st = lock_unpoisoned(&core.state);
        if st.queue.is_empty() {
            // Drained (e.g. by shutdown's Abort sweep) between the
            // enqueue that scheduled us and now — just unschedule.
            st.scheduled = false;
            st.inflight.clear();
            return;
        }
        let idx = head_idx(&st.queue);
        // `head_idx` indexes a non-empty queue under this same lock, so
        // the take cannot miss; treat a miss like a drained queue.
        let Some(task) = core.take_task(&mut st, idx) else {
            st.scheduled = false;
            st.inflight.clear();
            return;
        };
        st.inflight = vec![task.token.clone()];
        (task, idx)
    };
    let dequeued = Instant::now();
    let queue_seconds = dequeued.saturating_duration_since(task.submitted_at).as_secs_f64();
    // Dead on arrival: cancelled or deadline-expired while queued —
    // complete without touching the solve state (no matvecs, no
    // history entry, no basis change).
    if task.token.is_cancelled() {
        task.complete_unrun(StopReason::Cancelled, metrics, queue_seconds);
        requeue_or_park(core, ctx);
        return;
    }
    if task.spec.control.deadline.is_some_and(|d| dequeued >= d) {
        task.complete_unrun(StopReason::DeadlineExceeded, metrics, queue_seconds);
        requeue_or_park(core, ctx);
        return;
    }
    let Task { op, spec, token, payload, .. } = task;
    // Counter baseline: the manager's counters are monotone, so the
    // delta across the solve is what THIS run did.
    let before = CounterBaseline::sample(&lock_unpoisoned(&core.mgr));
    match payload {
        Payload::Single { b, x0, slot } => {
            // The solve runs under the dedicated solve mutex, NOT the
            // queue lock — submissions pipeline freely while this solve
            // is in flight. A panicking solve (operator bug) is caught:
            // the future completes as Failed and the worker keeps
            // dispatching, so no caller ever waits on a dead worker.
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut mg = lock_unpoisoned(&core.mgr);
                mg.solve_next(op.as_ref(), &b, x0.as_deref(), &spec)
            }));
            match outcome {
                Ok(result) => {
                    let post = sample_post_solve(&lock_unpoisoned(&core.mgr));
                    post.note(metrics, &before);
                    // Settle AFTER the solve lock is released: the
                    // accountant only ever try_locks managers.
                    accountant.settle(core.seq_id, post.bytes, post.payoff, metrics);
                    metrics.add_busy(result.seconds, result.matvecs);
                    core.basis_hint.store(post.k_active, Ordering::Relaxed);
                    let report = SolveReport {
                        stop: result.stop,
                        queue_seconds,
                        solve_seconds: result.seconds,
                        matvecs: result.matvecs,
                        k_active: post.k_active,
                        group_size: 1,
                        truncated_cols: post.absorb.truncated_cols
                            + post.absorb.compressed_cols,
                        post_eviction: post.absorb.post_eviction,
                        strategy: post.decision.strategy,
                        k_offered: post.decision.k_offered,
                        k_chosen: post.decision.k_chosen,
                        predicted_savings: post.decision.predicted_savings(),
                        realized_savings: post.payoff,
                    };
                    metrics.note_completion(result.stop, spec.priority);
                    slot.put(result, report);
                }
                Err(_) => {
                    let report = SolveReport {
                        stop: StopReason::Failed,
                        queue_seconds,
                        solve_seconds: 0.0,
                        matvecs: 0,
                        k_active: 0,
                        group_size: 1,
                        truncated_cols: 0,
                        post_eviction: false,
                        strategy: "",
                        k_offered: 0,
                        k_chosen: 0,
                        predicted_savings: 0.0,
                        realized_savings: 0.0,
                    };
                    metrics.note_completion(StopReason::Failed, spec.priority);
                    slot.put(
                        SolveResult {
                            x: x0.unwrap_or_else(|| vec![0.0; op.n()]),
                            residuals: vec![f64::INFINITY],
                            iterations: 0,
                            matvecs: 0,
                            stop: StopReason::Failed,
                            stored: StoredDirections::default(),
                            seconds: 0.0,
                        },
                        report,
                    );
                }
            }
            requeue_or_park(core, ctx);
        }
        Payload::Block { b, slot } => {
            // Coalesce, stage 1 (in-sequence): pull every *consecutive*
            // queued block request (consecutive within this priority
            // class — the leader was the first task of the best class,
            // so its successors start right at `idx`) that shares this
            // operator and the full block-relevant policy set into one
            // group solve. Members already cancelled are left queued;
            // their own dequeue completes them as Cancelled.
            let mut members = vec![BlockMember { b, slot, queue_seconds }];
            let mut tokens = vec![token.clone()];
            {
                let mut st = lock_unpoisoned(&core.state);
                let mut cursor = idx;
                while let Some(next) = st.queue.get(cursor) {
                    let matches_group = matches!(&next.payload, Payload::Block { .. })
                        && Arc::ptr_eq(&next.op, &op)
                        && coalescible(&next.spec, &spec);
                    if !matches_group {
                        break;
                    }
                    // A member cancelled while still queued is skipped
                    // (left for its own dequeue, which completes it as
                    // Cancelled without running) WITHOUT breaking the
                    // group apart: the members behind it still coalesce.
                    if next.token.is_cancelled() {
                        cursor += 1;
                        continue;
                    }
                    let Some(next) = core.take_task(&mut st, cursor) else { break };
                    let qs =
                        dequeued.saturating_duration_since(next.submitted_at).as_secs_f64();
                    // The guard above saw a Block payload at `cursor`
                    // under this same lock, so this take is that task.
                    if let Payload::Block { b, slot } = next.payload {
                        tokens.push(next.token.clone());
                        members.push(BlockMember { b, slot, queue_seconds: qs });
                    }
                }
                st.inflight = tokens.clone();
            }
            // Coalesce, stage 2 (cross-sequence): claim queued peer
            // sequences whose priority-aware head is a block request on
            // the *same operator Arc* with the same policy set, and fold
            // their matching head runs into this group. The claim
            // predicate only try_locks peer queues (run-queue lock →
            // queue lock must never block, see the module docs) and uses
            // the fingerprint as a cheap negative prefilter before the
            // authoritative `Arc::ptr_eq`.
            let mut peers: Vec<Arc<SeqCore>> = Vec::new();
            if cross_seq.load(Ordering::SeqCst) {
                let claimed = ctx.claim(CROSS_SEQ_CAP, |peer| {
                    let pst = match peer.state.try_lock() {
                        Ok(g) => g,
                        Err(TryLockError::Poisoned(p)) => p.into_inner(),
                        Err(TryLockError::WouldBlock) => return false,
                    };
                    if pst.queue.is_empty() {
                        return false;
                    }
                    let Some(head) = pst.queue.get(head_idx(&pst.queue)) else {
                        return false;
                    };
                    matches!(&head.payload, Payload::Block { .. })
                        && !head.token.is_cancelled()
                        && same_operator(head.op.as_ref(), op.as_ref())
                        && Arc::ptr_eq(&head.op, &op)
                        && coalescible(&head.spec, &spec)
                });
                for peer in claimed {
                    // The leader holds no locks here, so a blocking lock
                    // is fine; the head may have changed since the claim
                    // (racing cancel), so re-gather from scratch.
                    let mut pst = lock_unpoisoned(&peer.state);
                    let mut ptokens = Vec::new();
                    let mut cursor = head_idx(&pst.queue);
                    while let Some(next) = pst.queue.get(cursor) {
                        let matches_group = matches!(&next.payload, Payload::Block { .. })
                            && Arc::ptr_eq(&next.op, &op)
                            && coalescible(&next.spec, &spec);
                        if !matches_group {
                            break;
                        }
                        if next.token.is_cancelled() {
                            cursor += 1;
                            continue;
                        }
                        let Some(next) = peer.take_task(&mut pst, cursor) else { break };
                        let qs = dequeued
                            .saturating_duration_since(next.submitted_at)
                            .as_secs_f64();
                        // Same-lock guard as above: the task at `cursor`
                        // was verified to carry a Block payload.
                        if let Payload::Block { b, slot } = next.payload {
                            ptokens.push(next.token.clone());
                            tokens.push(next.token.clone());
                            members.push(BlockMember { b, slot, queue_seconds: qs });
                        }
                    }
                    if ptokens.is_empty() {
                        // Head consumed/cancelled between claim and
                        // gather — give the peer straight back.
                        drop(pst);
                        ctx.requeue(peer);
                        continue;
                    }
                    metrics.cross_seq_coalesced.fetch_add(ptokens.len(), Ordering::SeqCst);
                    pst.inflight = ptokens;
                    drop(pst);
                    peers.push(peer);
                }
            }
            // The shared solve runs under an all-of cancel group (stops
            // only when every member across every sequence cancelled)
            // and the members' common deadline — on the LEADER's
            // recycle state; claimed peers' bases are untouched.
            let mut gspec = spec.clone();
            gspec.control = SolveControl::all_of(tokens, spec.control.deadline);
            let n = op.n();
            let total: usize = members.iter().map(|m| m.b.cols()).sum();
            let mut big = Mat::zeros(n, total);
            let mut off = 0;
            for m in &members {
                for j in 0..m.b.cols() {
                    big.set_col(off + j, &m.b.col(j));
                }
                off += m.b.cols();
            }
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut mg = lock_unpoisoned(&core.mgr);
                mg.solve_block(op.as_ref(), &big, &gspec)
            }));
            match outcome {
                Ok(result) => {
                    let post = sample_post_solve(&lock_unpoisoned(&core.mgr));
                    post.note(metrics, &before);
                    accountant.settle(core.seq_id, post.bytes, post.payoff, metrics);
                    metrics.add_busy(result.seconds, result.matvecs);
                    core.basis_hint.store(post.k_active, Ordering::Relaxed);
                    // Split the group result back into per-member
                    // slices. Each member is billed its own columns'
                    // applications (rank-dropped columns ride free); the
                    // group-level overhead that no column owns — the
                    // AW-refresh cost of the leader's recycled basis —
                    // lands on the first member so shares still sum to
                    // the group total the metrics recorded.
                    let col_share: usize = result.col_matvecs.iter().sum();
                    let mut overhead = result.matvecs - col_share;
                    let group_size = members.len();
                    let mut off = 0;
                    for m in members {
                        let cols = m.b.cols();
                        let mut x = Mat::zeros(n, cols);
                        for j in 0..cols {
                            x.set_col(j, &result.x.col(off + j));
                        }
                        let col_matvecs: Vec<usize> =
                            result.col_matvecs.iter().skip(off).take(cols).copied().collect();
                        off += cols;
                        let matvecs =
                            col_matvecs.iter().sum::<usize>() + std::mem::take(&mut overhead);
                        let report = SolveReport {
                            stop: result.stop,
                            queue_seconds: m.queue_seconds,
                            solve_seconds: result.seconds,
                            matvecs,
                            k_active: post.k_active,
                            group_size,
                            truncated_cols: post.absorb.truncated_cols
                                + post.absorb.compressed_cols,
                            post_eviction: post.absorb.post_eviction,
                            strategy: post.decision.strategy,
                            k_offered: post.decision.k_offered,
                            k_chosen: post.decision.k_chosen,
                            predicted_savings: post.decision.predicted_savings(),
                            realized_savings: post.payoff,
                        };
                        metrics.note_completion(result.stop, spec.priority);
                        m.slot.put(
                            BlockSolveResult {
                                x,
                                residuals: result.residuals.clone(),
                                iterations: result.iterations,
                                block_matvecs: result.block_matvecs,
                                matvecs,
                                col_matvecs,
                                stop: result.stop,
                                // The group's stored directions already
                                // fed the leader's sequence basis;
                                // per-member results do not re-export
                                // them.
                                stored: Default::default(),
                                seconds: result.seconds,
                            },
                            report,
                        );
                    }
                }
                Err(_) => {
                    let group_size = members.len();
                    for m in members {
                        let cols = m.b.cols();
                        let report = SolveReport {
                            stop: StopReason::Failed,
                            queue_seconds: m.queue_seconds,
                            solve_seconds: 0.0,
                            matvecs: 0,
                            k_active: 0,
                            group_size,
                            truncated_cols: 0,
                            post_eviction: false,
                            strategy: "",
                            k_offered: 0,
                            k_chosen: 0,
                            predicted_savings: 0.0,
                            realized_savings: 0.0,
                        };
                        metrics.note_completion(StopReason::Failed, spec.priority);
                        m.slot.put(
                            BlockSolveResult {
                                x: Mat::zeros(n, cols),
                                residuals: vec![f64::INFINITY],
                                iterations: 0,
                                block_matvecs: 0,
                                matvecs: 0,
                                col_matvecs: vec![0; cols],
                                stop: StopReason::Failed,
                                stored: StoredDirections::default(),
                                seconds: 0.0,
                            },
                            report,
                        );
                    }
                }
            }
            // Hand every claimed peer back to the scheduler before
            // rotating ourselves — a peer with a racing enqueue behind
            // its consumed head picks right back up.
            for peer in peers {
                requeue_or_park(&peer, ctx);
            }
            requeue_or_park(core, ctx);
        }
    }
}

/// Pre-solve snapshot of the manager's monotone counters, sampled in
/// one acquisition of the solve lock; [`PostSolve::note`] bills the
/// deltas across the solve to the service counters.
struct CounterBaseline {
    truncations: u64,
    extraction_failures: u64,
    strategy_shrinks: u64,
    predicted_total: f64,
}

impl CounterBaseline {
    fn sample(mg: &RecycleManager) -> Self {
        CounterBaseline {
            truncations: mg.truncations(),
            extraction_failures: mg.extraction_failures(),
            strategy_shrinks: mg.strategy_shrinks(),
            predicted_total: mg.predicted_savings_total(),
        }
    }
}

/// Everything a dispatcher needs from the manager right after a solve,
/// sampled in ONE acquisition of the solve lock (report fields, metric
/// deltas, and the byte accountant's inputs).
struct PostSolve {
    k_active: usize,
    absorb: AbsorbStats,
    bytes: usize,
    truncations: u64,
    /// Observed iteration savings of this sequence's basis: cold-start
    /// iterations minus the latest run's — the accountant's eviction
    /// tiebreak.
    payoff: f64,
    /// This was a post-eviction run AND it needed more iterations than
    /// the run before it: the observable cost of the eviction decision.
    regressed: bool,
    /// The strategy decision recorded by this run's absorb step.
    decision: StrategyDecision,
    extraction_failures: u64,
    strategy_shrinks: u64,
    predicted_total: f64,
}

fn sample_post_solve(mg: &RecycleManager) -> PostSolve {
    let h = mg.history();
    let payoff = match (h.first(), h.last()) {
        (Some(first), Some(last)) => (first.iterations as f64 - last.iterations as f64).max(0.0),
        _ => 0.0,
    };
    let absorb = mg.last_absorb();
    let regressed = absorb.post_eviction
        && matches!(h, [.., prev, last] if last.iterations > prev.iterations);
    PostSolve {
        k_active: mg.k_active(),
        absorb,
        bytes: mg.bytes_held(),
        truncations: mg.truncations(),
        payoff,
        regressed,
        decision: mg.last_decision(),
        extraction_failures: mg.extraction_failures(),
        strategy_shrinks: mg.strategy_shrinks(),
        predicted_total: mg.predicted_savings_total(),
    }
}

impl PostSolve {
    /// Fold this run's budget and strategy events into the service
    /// counters.
    fn note(&self, metrics: &ServiceMetrics, before: &CounterBaseline) {
        let delta = self.truncations.saturating_sub(before.truncations) as usize;
        if delta > 0 {
            metrics.truncations.fetch_add(delta, Ordering::SeqCst);
        }
        if self.regressed {
            metrics.post_eviction_iter_regressions.fetch_add(1, Ordering::SeqCst);
        }
        let failures = self.extraction_failures.saturating_sub(before.extraction_failures);
        if failures > 0 {
            metrics.extraction_failures.fetch_add(failures, Ordering::SeqCst);
        }
        let shrinks = self.strategy_shrinks.saturating_sub(before.strategy_shrinks);
        if shrinks > 0 {
            metrics.strategy_shrinks.fetch_add(shrinks, Ordering::SeqCst);
        }
        let predicted = (self.predicted_total - before.predicted_total).max(0.0);
        if predicted > 0.0 {
            metrics
                .predicted_saved_milli_iters
                .fetch_add((predicted * 1e3) as u64, Ordering::SeqCst);
        }
        if self.payoff > 0.0 {
            metrics
                .realized_saved_milli_iters
                .fetch_add((self.payoff * 1e3) as u64, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::StopReason;
    use crate::util::rng::Rng;

    /// Owning dense operator for Arc'ing into the service.
    struct OwnedDense(Mat);

    impl SpdOperator for OwnedDense {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.0.matvec_into(x, y);
        }
    }

    fn spd(n: usize, seed: u64) -> Arc<OwnedDense> {
        let mut rng = Rng::new(seed);
        Arc::new(OwnedDense(Mat::rand_spd(n, 1e4, &mut rng)))
    }

    fn spd_mat(a: Mat) -> Arc<OwnedDense> {
        Arc::new(OwnedDense(a))
    }

    /// Operator that parks every matvec until released, recording how
    /// many applications started — the deterministic probe for
    /// mid-solve cancellation and pipelining tests.
    struct SlowOp {
        a: Mat,
        started: Arc<AtomicBool>,
        release: Arc<AtomicBool>,
        calls: Arc<AtomicUsize>,
    }

    impl SlowOp {
        fn new(a: Mat) -> (Arc<Self>, Arc<AtomicBool>, Arc<AtomicBool>, Arc<AtomicUsize>) {
            let started = Arc::new(AtomicBool::new(false));
            let release = Arc::new(AtomicBool::new(false));
            let calls = Arc::new(AtomicUsize::new(0));
            let op = Arc::new(SlowOp {
                a,
                started: started.clone(),
                release: release.clone(),
                calls: calls.clone(),
            });
            (op, started, release, calls)
        }
    }

    impl SpdOperator for SlowOp {
        fn n(&self) -> usize {
            self.a.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.calls.fetch_add(1, Ordering::SeqCst);
            self.started.store(true, Ordering::SeqCst);
            while !self.release.load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
            self.a.matvec_into(x, y);
        }
    }

    #[test]
    fn single_sequence_solves_in_order_with_recycling() {
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let op = spd(60, 1);
        let b = vec![1.0; 60];
        let spec = SolveSpec::defcg().with_tol(1e-8);
        let futures: Vec<_> = (0..4)
            .map(|_| seq.submit(op.clone(), b.clone(), None, spec.clone()))
            .collect();
        let results: Vec<_> = futures.into_iter().map(|t| t.wait()).collect();
        for r in &results {
            assert_eq!(r.stop, StopReason::Converged);
        }
        // Identical systems: solves after the first must be cheaper.
        assert!(results[3].iterations < results[0].iterations);
        let hist = seq.history();
        assert_eq!(hist.len(), 4);
        assert!(seq.k_active() > 0);
    }

    #[test]
    fn reports_and_metrics_surface_strategy_decisions() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let op = spd(60, 9);
        let b = vec![1.0; 60];
        let spec = SolveSpec::defcg().with_tol(1e-8);
        let mut reports = Vec::new();
        for _ in 0..3 {
            let (r, rep) =
                seq.submit(op.clone(), b.clone(), None, spec.clone()).wait_report();
            assert_eq!(r.stop, StopReason::Converged);
            reports.push(rep);
        }
        // Every settled solve names the strategy that ranked its basis;
        // the default takes the budget's full offer.
        for rep in &reports {
            assert_eq!(rep.strategy, "harmonic-largest");
            assert!(rep.k_offered > 0, "extraction ran after each solve");
            assert_eq!(rep.k_chosen, rep.k_offered);
        }
        // Identical systems: by the third solve the basis is paying, and
        // the report carries the same cold-start-relative signal the
        // evictor uses.
        assert!(reports[2].realized_savings > 0.0);
        // Per-request override: the report names the adaptive strategy.
        let (r, rep) = seq
            .submit(op.clone(), b.clone(), None, spec.clone().auto_strategy())
            .wait_report();
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(rep.strategy, "adaptive-k");
        assert!(rep.k_chosen <= rep.k_offered);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.extraction_failures, 0);
        assert!(snap.realized_saved_iters > 0.0);
        assert!(snap.predicted_saved_iters >= 0.0);
    }

    #[test]
    fn sequences_run_concurrently_and_keep_state_separate() {
        let svc = SolveService::new(4);
        let spec = SolveSpec::defcg().with_tol(1e-6);
        let mut handles = Vec::new();
        for s in 0..3 {
            let seq = svc.open_sequence(RecycleConfig { k: 4, l: 6, ..Default::default() });
            let op = spd(40, 100 + s);
            let b: Vec<f64> = (0..40).map(|i| (i + s as usize) as f64).collect();
            let t1 = seq.submit(op.clone(), b.clone(), None, spec.clone());
            let t2 = seq.submit(op, b, None, spec.clone());
            handles.push((seq, t1, t2));
        }
        assert_eq!(svc.metrics().snapshot().active_sequences, 3);
        for (seq, t1, t2) in handles {
            assert_eq!(t1.wait().stop, StopReason::Converged);
            assert_eq!(t2.wait().stop, StopReason::Converged);
            assert_eq!(seq.history().len(), 2);
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.submitted, 6);
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.in_flight(), 0);
        assert_eq!(snap.queue_depth, 0, "completions release their admission slots");
        assert!(snap.queue_high_water >= 2);
        // The consume loop dropped every handle: the sequences retired.
        assert_eq!(snap.active_sequences, 0);
        assert!(snap.total_matvecs > 0);
        assert!(snap.busy_seconds >= 0.0);
        assert!(
            snap.span_seconds > 0.0,
            "first-submit→last-complete span must be recorded"
        );
        assert_eq!(snap.workers, 4);
    }

    #[test]
    fn mixed_method_workload_through_one_sequence_queue() {
        // The heterogeneous-workload promise: plain, Jacobi-preconditioned,
        // deflated, and block requests interleave through ONE sequence
        // queue, sharing (or bypassing) the recycled basis per method.
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let op = spd(70, 5);
        let b = vec![1.0; 70];
        let jacobi = SolveSpec::pcg().with_jacobi(op.as_ref()).with_tol(1e-8);
        let specs = vec![
            SolveSpec::defcg().with_tol(1e-8), // seeds the basis
            SolveSpec::cg().with_tol(1e-8),    // plain, still feeds W
            jacobi,                            // preconditioned
            SolveSpec::defcg().with_tol(1e-8), // consumes the basis
            SolveSpec::blockcg().with_tol(1e-8), // deflated 1-col block, feeds too
        ];
        let futures: Vec<_> = specs
            .into_iter()
            .map(|spec| seq.submit(op.clone(), b.clone(), None, spec))
            .collect();
        let results: Vec<_> = futures.into_iter().map(|t| t.wait()).collect();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.stop, StopReason::Converged, "request {i}");
        }
        // The deflated request after the feeders beats the cold one.
        assert!(
            results[3].iterations < results[0].iterations,
            "recycled def-CG {} >= cold def-CG {}",
            results[3].iterations,
            results[0].iterations
        );
        assert_eq!(seq.history().len(), 5);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.submitted, 5);
        assert_eq!(snap.completed, 5);
        assert_eq!(snap.active_sequences, 1);
        seq.close();
        assert_eq!(svc.metrics().snapshot().active_sequences, 0);
        seq.close(); // idempotent
        assert_eq!(svc.metrics().snapshot().active_sequences, 0);
    }

    #[test]
    fn pipelined_submissions_complete() {
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(30, 7);
        let futures: Vec<_> = (0..8)
            .map(|i| {
                let b: Vec<f64> = (0..30).map(|j| ((i + j) % 5) as f64 + 1.0).collect();
                seq.submit(op.clone(), b, None, SolveSpec::defcg().with_tol(1e-6))
            })
            .collect();
        for t in futures {
            assert_eq!(t.wait().stop, StopReason::Converged);
        }
        assert_eq!(seq.history().len(), 8);
    }

    #[test]
    fn submit_block_solves_multi_rhs_and_counts_per_column() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(31);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let op = spd_mat(a);
        let (r, report) = seq
            .submit_block(op, b, SolveSpec::blockcg().with_tol(1e-10))
            .wait_report();
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.x.max_abs_diff(&x_true) < 1e-5);
        // Per-column accounting: the sum of the per-column applies, never
        // more than the full-block bound (columns that converge early stop
        // paying).
        assert_eq!(r.matvecs, r.col_matvecs.iter().sum::<usize>());
        assert!(r.matvecs <= 3 * r.block_matvecs);
        // The structured report mirrors the result and the queue stats.
        assert_eq!(report.stop, StopReason::Converged);
        assert_eq!(report.matvecs, r.matvecs);
        assert_eq!(report.group_size, 1);
        assert!(report.queue_seconds >= 0.0);
        assert!(report.solve_seconds >= 0.0);
        assert!(report.k_active > 0, "the block solve fed the basis");
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.total_matvecs, r.matvecs, "metrics count columns, not block applies");
        assert_eq!(seq.history().len(), 1);
        assert!(seq.k_active() > 0, "a block solve must feed the sequence basis");
    }

    #[test]
    fn consecutive_block_submissions_coalesce_into_one_solve() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(32);
        let n = 300;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let x_true = Mat::randn(n, 5, &mut rng);
        let b = a.matmul(&x_true);
        let op = spd_mat(a);
        // Deterministically hold dispatch back so all three block
        // requests are queued before the worker sees any of them.
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let futures: Vec<_> = (0..3)
            .map(|g| {
                let cols: Vec<usize> = match g {
                    0 => vec![0, 1],
                    1 => vec![2],
                    _ => vec![3, 4],
                };
                let mut bg = Mat::zeros(n, cols.len());
                for (dst, &src) in cols.iter().enumerate() {
                    bg.set_col(dst, &b.col(src));
                }
                seq.submit_block(op.clone(), bg, spec.clone())
            })
            .collect();
        drop(pause);
        let results: Vec<_> = futures.into_iter().map(|t| t.wait_report()).collect();
        for (g, (r, report)) in results.iter().enumerate() {
            assert_eq!(r.stop, StopReason::Converged, "group {g}");
            assert_eq!(report.group_size, 3, "group {g} must report the coalesce width");
            assert_eq!(report.matvecs, r.matvecs);
        }
        let results: Vec<_> = results.into_iter().map(|(r, _)| r).collect();
        // Each future got exactly its own columns back.
        assert!((results[0].x.col(0)[0] - x_true[(0, 0)]).abs() < 1e-4);
        assert!(results[0].x.max_abs_diff(&{
            let mut m = Mat::zeros(n, 2);
            m.set_col(0, &x_true.col(0));
            m.set_col(1, &x_true.col(1));
            m
        }) < 1e-4);
        assert!((results[1].x.col(0)[5] - x_true[(5, 2)]).abs() < 1e-4);
        // Coalesced: the sequence history saw ONE block solve, and the
        // three groups share its iteration trace.
        let hist = seq.history();
        assert_eq!(hist.len(), 1, "3 block submissions must coalesce into 1 solve");
        assert_eq!(results[0].iterations, results[1].iterations);
        assert_eq!(results[0].residuals, results[2].residuals);
        // Per-future matvec shares sum EXACTLY to the group total in the
        // metrics, with dropped columns paying only the applies they were
        // active for.
        let share: usize = results.iter().map(|r| r.matvecs).sum();
        assert!(share <= 5 * results[0].block_matvecs);
        assert_eq!(hist[0].matvecs, share);
        for r in &results {
            assert!(!r.final_residual().is_nan());
            assert_eq!(r.col_matvecs.len(), r.x.cols());
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.total_matvecs, share);
    }

    #[test]
    fn mismatched_block_policies_do_not_coalesce() {
        // Same operator and tolerance, but ticket B asks for a stall
        // window (any block-relevant policy difference would do):
        // coalescing them would silently run B under A's policy, so they
        // must drain as two separate group solves.
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(42);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 2, &mut rng));
        let op = spd_mat(a);
        // Pause dispatch so all three requests queue first.
        let pause = svc.pause();
        let spec_a = SolveSpec::blockcg().with_tol(1e-9);
        let spec_b = SolveSpec::blockcg().with_tol(1e-9).with_stall_window(50);
        let t1 = seq.submit_block(op.clone(), b.clone(), spec_a.clone());
        let t2 = seq.submit_block(op.clone(), b.clone(), spec_b);
        let t3 = seq.submit_block(op.clone(), b.clone(), spec_a);
        drop(pause);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(t3.wait().stop, StopReason::Converged);
        // 1 and 2 must not merge (different stall window); 2 and 3 must
        // not merge either — three separate solves in the history.
        assert_eq!(seq.history().len(), 3, "policy-mismatched blocks must not coalesce");
    }

    #[test]
    fn mismatched_deadlines_do_not_coalesce() {
        // A deadline is part of the block-relevant policy set: members
        // share one solve, so they must share its time budget.
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(43);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 2, &mut rng));
        let op = spd_mat(a);
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let t1 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        let t2 = seq.submit_block(
            op.clone(),
            b.clone(),
            spec.clone().with_deadline(Duration::from_secs(3600)),
        );
        drop(pause);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(seq.history().len(), 2, "different deadlines must not coalesce");
    }

    #[test]
    fn queued_cancelled_member_is_skipped_without_splitting_the_group() {
        // A member cancelled while still queued is left out of the group
        // but must NOT break it apart: the members behind it still
        // coalesce into the leader's solve (one history entry), and the
        // cancelled one completes unrun at its own dequeue.
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(48);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 2, &mut rng));
        let op = spd_mat(a);
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let t1 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        let t2 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        let t3 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        t2.cancel(); // cancelled while provably still queued (dispatch paused)
        drop(pause);
        let (r1, rep1) = t1.wait_report();
        let r2 = t2.wait();
        let (r3, rep3) = t3.wait_report();
        assert_eq!(r1.stop, StopReason::Converged);
        assert_eq!(r3.stop, StopReason::Converged);
        assert_eq!(r2.stop, StopReason::Cancelled);
        assert_eq!(r2.matvecs, 0, "the queued-cancelled member never ran");
        assert_eq!(rep1.group_size, 2, "members 1 and 3 still form ONE group");
        assert_eq!(rep3.group_size, 2);
        assert_eq!(
            seq.history().len(),
            1,
            "skipping a cancelled member must not split the group into two solves"
        );
        assert_eq!(svc.metrics().snapshot().cancelled, 1);
    }

    #[test]
    fn coalesced_member_cancel_needs_every_member() {
        // All-of cancel semantics: with two members coalesced into one
        // group solve, cancelling ONE future must not abort the shared
        // solve — the other member still converges. (Cancelling a member
        // while it is still queued instead excludes it from the group.)
        let mut rng = Rng::new(44);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 2, &mut rng));
        let (op, started, release, _calls) = SlowOp::new(a);
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        // Pause dispatch so both requests queue, then coalesce.
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let t1 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        let t2 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        drop(pause);
        // Wait until the group solve is provably inside the operator,
        // cancel ONE member, then release the operator.
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        t2.cancel();
        release.store(true, Ordering::SeqCst);
        let (r1, rep1) = t1.wait_report();
        let r2 = t2.wait();
        assert_eq!(rep1.group_size, 2, "the two requests coalesced");
        assert_eq!(r1.stop, StopReason::Converged, "one cancel must not abort the group");
        // The cancelled member rode the same group solve to completion
        // (its flag was raised too late to exclude it from the group).
        assert_eq!(r2.stop, StopReason::Converged);
        assert_eq!(seq.history().len(), 1);
    }

    #[test]
    fn coalesced_group_stops_when_every_member_cancels() {
        let mut rng = Rng::new(45);
        let n = 30;
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b = a.matmul(&Mat::randn(n, 2, &mut rng));
        let (op, started, release, calls) = SlowOp::new(a);
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-12);
        let t1 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        let t2 = seq.submit_block(op.clone(), b.clone(), spec.clone());
        drop(pause);
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        t1.cancel();
        t2.cancel();
        let at_cancel = calls.load(Ordering::SeqCst);
        release.store(true, Ordering::SeqCst);
        let r1 = t1.wait();
        let r2 = t2.wait();
        assert_eq!(r1.stop, StopReason::Cancelled);
        assert_eq!(r2.stop, StopReason::Cancelled);
        // Within one *block* application of the (complete) cancel: the
        // in-flight apply_block finishes its remaining columns (≤ 4
        // here), then the per-iteration check stops the group.
        assert!(
            calls.load(Ordering::SeqCst) <= at_cancel + 4,
            "group kept applying the operator after every member cancelled"
        );
        // Cancelled work is never absorbed into the sequence basis.
        assert_eq!(seq.k_active(), 0);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.cancelled, 2);
    }

    #[test]
    fn interactive_requests_jump_batch_queue() {
        // Priority-aware pop: with batch work queued first, a later
        // interactive request must run first once dispatch resumes.
        struct TagOp {
            a: Mat,
            tag: usize,
            log: Arc<Mutex<Vec<usize>>>,
            logged: AtomicBool,
        }
        impl SpdOperator for TagOp {
            fn n(&self) -> usize {
                self.a.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                if !self.logged.swap(true, Ordering::SeqCst) {
                    lock_unpoisoned(&self.log).push(self.tag);
                }
                self.a.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(46);
        let a = Mat::rand_spd(25, 1e3, &mut rng);
        let log = Arc::new(Mutex::new(Vec::new()));
        let mk = |tag: usize| {
            Arc::new(TagOp {
                a: a.clone(),
                tag,
                log: log.clone(),
                logged: AtomicBool::new(false),
            })
        };
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        // Pause the one worker so the queue builds up before draining.
        let pause = svc.pause();
        let b = vec![1.0; 25];
        let batch = SolveSpec::cg().with_tol(1e-8).batch();
        let t1 = seq.submit(mk(1), b.clone(), None, batch.clone());
        let t2 = seq.submit(mk(2), b.clone(), None, batch);
        let t3 = seq.submit(mk(3), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
        drop(pause);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(t3.wait().stop, StopReason::Converged);
        assert_eq!(
            *lock_unpoisoned(&log),
            vec![3, 1, 2],
            "interactive overtakes queued batch work; batch stays FIFO"
        );
    }

    #[test]
    fn try_submit_applies_backpressure_at_the_admission_cap() {
        let svc = SolveService::with_queue_cap(1, 2);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(47);
        let (op, started, release, _calls) = SlowOp::new(Mat::rand_spd(20, 100.0, &mut rng));
        let b = vec![1.0; 20];
        let spec = SolveSpec::cg().with_tol(1e-8);
        let t1 = seq.try_submit(op.clone(), b.clone(), None, spec.clone()).unwrap();
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // Slot 1 is running, slot 2 queues, slot 3 must be refused.
        let t2 = seq.try_submit(op.clone(), b.clone(), None, spec.clone()).unwrap();
        let err = seq
            .try_submit(op.clone(), b.clone(), None, spec.clone())
            .unwrap_err();
        assert_eq!(err, SubmitError::QueueFull);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.rejected, 1);
        assert_eq!(snap.queue_depth, 2);
        assert_eq!(snap.queue_high_water, 2);
        assert_eq!(snap.submitted, 2, "rejected requests are not counted as submitted");
        release.store(true, Ordering::SeqCst);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        // Completions released their admission slots.
        assert_eq!(svc.metrics().snapshot().queue_depth, 0);
        // With the queue drained, admission works again.
        let t4 = seq.try_submit(op, b, None, spec).unwrap();
        assert_eq!(t4.wait().stop, StopReason::Converged);
    }

    #[test]
    fn submit_returns_immediately_during_inflight_solve() {
        // The pipelining contract: `submit` must enqueue and return while
        // a previous solve of the SAME sequence is still running — the
        // dispatcher may not hold the queue lock across a solve. The slow
        // operator parks its first matvec until released; if submission
        // blocked on the in-flight solve, the second submit below would
        // deadlock (watchdog-released after 10 s, failing the assert).
        let mut rng = Rng::new(41);
        let n = 20;
        let a = Mat::rand_spd(n, 100.0, &mut rng);
        let (op, started, release, _calls) = SlowOp::new(a);
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let b = vec![1.0; n];
        let spec = SolveSpec::cg().with_tol(1e-8);
        let t1 = seq.submit(op.clone(), b.clone(), None, spec.clone());
        // Wait until the worker is provably inside the first solve.
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // Watchdog: if the old queue-lock-across-solve behavior came
        // back, unblock the solve after a grace period so the test fails
        // with a message instead of hanging the suite.
        let watchdog = {
            let release = release.clone();
            std::thread::spawn(move || {
                let t0 = std::time::Instant::now();
                while !release.load(Ordering::SeqCst) {
                    if t0.elapsed() > std::time::Duration::from_secs(10) {
                        release.store(true, Ordering::SeqCst);
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            })
        };
        let t2 = seq.submit(op.clone(), b.clone(), None, spec.clone());
        let t3 = seq.submit_block(
            op.clone(),
            {
                let mut m = Mat::zeros(n, 2);
                m.set_col(0, &b);
                m.set_col(1, &b);
                m
            },
            SolveSpec::blockcg().with_tol(1e-8),
        );
        assert!(
            !release.load(Ordering::SeqCst),
            "submit/submit_block blocked on the in-flight solve"
        );
        release.store(true, Ordering::SeqCst);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(t3.wait().stop, StopReason::Converged);
        assert_eq!(seq.history().len(), 3);
        watchdog.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "closed sequence")]
    fn closed_sequence_rejects() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        seq.close();
        let op = spd(5, 9);
        let _ = seq.submit(op, vec![1.0; 5], None, SolveSpec::defcg());
    }

    #[test]
    fn closed_sequence_try_submit_returns_error() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        seq.close();
        let op = spd(5, 10);
        let err = seq
            .try_submit(op, vec![1.0; 5], None, SolveSpec::defcg())
            .unwrap_err();
        assert_eq!(err, SubmitError::SequenceClosed);
        assert_eq!(svc.metrics().snapshot().rejected, 1);
        assert_eq!(svc.metrics().snapshot().queue_depth, 0, "rejection released its slot");
    }

    #[test]
    fn par_operator_matches_serial_solves() {
        let svc = SolveService::new(2);
        let mut rng = Rng::new(21);
        let n = 300; // above ParDenseOp::PAR_THRESHOLD: shards for real
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 9) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-10);

        let par = svc.par_operator(a.clone());
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let r_par = seq.submit(par, b.clone(), None, spec.clone()).wait();
        assert_eq!(r_par.stop, StopReason::Converged);

        // Serial reference through a fresh sequence (same recycle state).
        let seq2 = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let r_ser = seq2.submit(spd_mat(a), b, None, spec).wait();
        assert_eq!(r_ser.stop, StopReason::Converged);

        // Bitwise-identical matvecs => identical CG trajectories.
        assert_eq!(r_par.iterations, r_ser.iterations);
        for (u, v) in r_par.x.iter().zip(&r_ser.x) {
            assert_eq!(u, v);
        }
    }

    #[test]
    fn warm_start_passthrough() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(20, 11);
        let b = vec![2.0; 20];
        // First solve to get solution, then warm start from it.
        let x = seq
            .submit(op.clone(), b.clone(), None, SolveSpec::defcg().with_tol(1e-10))
            .wait()
            .x;
        let warm = seq
            .submit(op, b, Some(x), SolveSpec::defcg().with_tol(1e-10))
            .wait();
        assert!(warm.iterations <= 2, "warm start took {}", warm.iterations);
    }

    #[test]
    fn worker_panic_completes_future_as_failed_and_keeps_draining() {
        // The wait-forever fix: an operator that panics mid-solve used to
        // kill the drainer loop, leaving this and every queued future
        // hanging. Now the panicking request completes as Failed and the
        // requests behind it still run.
        struct PanickingOp(usize);
        impl SpdOperator for PanickingOp {
            fn n(&self) -> usize {
                self.0
            }
            fn matvec(&self, _x: &[f64], _y: &mut [f64]) {
                panic!("injected operator failure");
            }
        }
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let n = 20;
        let bad = Arc::new(PanickingOp(n));
        let good = spd(n, 12);
        let b = vec![1.0; n];
        // Queue the failing request AND a healthy one behind it before
        // either runs.
        let t_bad = seq.submit(bad, b.clone(), None, SolveSpec::cg().with_tol(1e-8));
        let t_good = seq.submit(good.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
        let (r_bad, rep_bad) = t_bad.wait_report();
        assert_eq!(r_bad.stop, StopReason::Failed);
        assert_eq!(rep_bad.stop, StopReason::Failed);
        assert!(r_bad.final_residual().is_infinite(), "a failed solve must not look converged");
        assert_eq!(r_bad.x, vec![0.0; n], "start iterate passed through");
        let r_good = t_good.wait();
        assert_eq!(r_good.stop, StopReason::Converged, "queued work behind a panic still runs");
        // And the sequence keeps accepting + solving after the failure.
        let again = seq.submit(good, b, None, SolveSpec::cg().with_tol(1e-8)).wait();
        assert_eq!(again.stop, StopReason::Converged);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.queue_depth, 0);
    }

    #[test]
    fn block_worker_panic_fails_every_group_member() {
        struct PanickingOp(usize);
        impl SpdOperator for PanickingOp {
            fn n(&self) -> usize {
                self.0
            }
            fn matvec(&self, _x: &[f64], _y: &mut [f64]) {
                panic!("injected block operator failure");
            }
        }
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let n = 10;
        let op = Arc::new(PanickingOp(n));
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-8);
        let ones = |cols: usize| Mat::from_fn(n, cols, |_, _| 1.0);
        let t1 = seq.submit_block(op.clone(), ones(2), spec.clone());
        let t2 = seq.submit_block(op.clone(), ones(1), spec);
        drop(pause);
        let r1 = t1.wait();
        let r2 = t2.wait();
        assert_eq!(r1.stop, StopReason::Failed);
        assert_eq!(r2.stop, StopReason::Failed);
        assert_eq!(r1.x.cols(), 2, "each member still gets its own-shaped result");
        assert_eq!(r2.x.cols(), 1);
        assert_eq!(svc.metrics().snapshot().failed, 2);
    }

    /// Service-wide byte cap: with 8 active sequences and a cap that fits
    /// roughly one recycled basis, the accountant evicts cold sequences
    /// (eviction counter > 0), every solve still converges, and an
    /// evicted sequence degrades to plain CG for one solve and then
    /// re-warms its basis. Each sequence gets its own dimension, so any
    /// cross-sequence `(W, AW)` leak would break a solve outright.
    #[test]
    fn global_byte_cap_evicts_cold_sequences_but_all_solves_converge() {
        let cap = 5_000; // ≈ one k=6 basis at these dimensions
        let svc = SolveService::with_byte_cap(2, SolveService::DEFAULT_QUEUE_CAP, cap);
        let cfg = RecycleConfig { k: 6, l: 10, ..Default::default() };
        let seqs: Vec<_> = (0..8).map(|_| svc.open_sequence(cfg.clone())).collect();
        let spec = SolveSpec::defcg().with_tol(1e-8);

        for (i, seq) in seqs.iter().enumerate() {
            let n = 40 + 2 * i;
            let op = spd(n, 100 + i as u64);
            let b = vec![1.0; n];
            for _ in 0..3 {
                let (r, report) =
                    seq.submit(op.clone(), b.clone(), None, spec.clone()).wait_report();
                assert_eq!(r.stop, StopReason::Converged);
                assert!(!report.post_eviction, "no eviction before the cap is hit twice over");
            }
        }

        let snap = svc.metrics().snapshot();
        assert!(snap.basis_evictions > 0, "global cap never evicted anything");
        assert!(snap.bytes_held > 0);
        // The cap fits one basis: every sequence except the last settler
        // was evicted, and each kept its (cheap) history.
        for (i, seq) in seqs.iter().enumerate() {
            assert_eq!(seq.history().len(), 3);
            if i < 7 {
                assert_eq!(seq.k_active(), 0, "sequence {i} should have been evicted");
            }
        }
        assert!(seqs[7].k_active() > 0, "the settling sequence is never its own victim");

        // The evicted sequence 0 degrades gracefully: its next solve is
        // plain CG (flagged post-eviction in the report), converges, and
        // re-warms the basis from its own panel.
        let n = 40;
        let op = spd(n, 100);
        let (r, report) = seqs[0].submit(op, vec![1.0; n], None, spec).wait_report();
        assert_eq!(r.stop, StopReason::Converged);
        assert!(report.post_eviction, "first post-eviction solve must be flagged");
        assert!(seqs[0].k_active() > 0, "basis re-warms from the degraded run's panel");
    }

    /// Hammer `snapshot` from another thread while a 1-worker service
    /// solves a stream of requests: the reported utilization must never
    /// exceed the worker count, i.e. `busy_seconds ≤ span_seconds` here.
    /// (The old relaxed busy-last read order could pair fresh busy time
    /// with a stale span and report busy > span.)
    #[test]
    fn snapshot_never_reports_busy_exceeding_span_on_one_worker() {
        let svc = Arc::new(SolveService::new(1));
        let seq = svc.open_sequence(RecycleConfig { k: 4, l: 6, ..Default::default() });
        let n = 60;
        let op = spd(n, 9);
        let b = vec![1.0; n];
        let spec = SolveSpec::defcg().with_tol(1e-10);

        let done = Arc::new(AtomicBool::new(false));
        let violations = Arc::new(AtomicUsize::new(0));
        let reader = {
            let svc = svc.clone();
            let done = done.clone();
            let violations = violations.clone();
            std::thread::spawn(move || {
                while !done.load(Ordering::SeqCst) {
                    let snap = svc.metrics().snapshot();
                    // 1 µs of slack for the nanos→f64 conversions.
                    if snap.busy_seconds > snap.span_seconds + 1e-6 {
                        violations.fetch_add(1, Ordering::SeqCst);
                    }
                }
            })
        };

        for _ in 0..60 {
            let r = seq.submit(op.clone(), b.clone(), None, spec.clone()).wait();
            assert_eq!(r.stop, StopReason::Converged);
        }
        done.store(true, Ordering::SeqCst);
        reader.join().unwrap();
        assert_eq!(
            violations.load(Ordering::SeqCst),
            0,
            "snapshot reported busy_seconds > span_seconds on a 1-worker service"
        );
    }

    /// Cross-sequence coalescing with exact billing: two sequences queue
    /// block requests on the SAME operator `Arc`; the dispatching leader
    /// folds the peer's head run into one group solve (one history entry
    /// total, leader-side), each future gets exactly its own columns,
    /// and per-ticket matvec shares sum exactly to the service totals.
    #[test]
    fn cross_sequence_blocks_coalesce_with_exact_billing() {
        let svc = SolveService::new(1);
        let sa = svc.open_sequence(RecycleConfig::default());
        let sb = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(60);
        let n = 60;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let op: Arc<dyn SpdOperator + Send + Sync> = spd_mat(a);
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let mut ba = Mat::zeros(n, 2);
        ba.set_col(0, &b.col(0));
        ba.set_col(1, &b.col(1));
        let mut bb = Mat::zeros(n, 1);
        bb.set_col(0, &b.col(2));
        let ta = sa.submit_block(op.clone(), ba, spec.clone());
        let tb = sb.submit_block(op.clone(), bb, spec);
        drop(pause);
        let (ra, rep_a) = ta.wait_report();
        let (rb, rep_b) = tb.wait_report();
        assert_eq!(ra.stop, StopReason::Converged);
        assert_eq!(rb.stop, StopReason::Converged);
        assert_eq!(rep_a.group_size, 2, "the two sequences' blocks merged into one group");
        assert_eq!(rep_b.group_size, 2);
        // Each ticket got exactly its own columns.
        assert!((ra.x.col(0)[0] - x_true[(0, 0)]).abs() < 1e-4);
        assert!((ra.x.col(1)[3] - x_true[(3, 1)]).abs() < 1e-4);
        assert!((rb.x.col(0)[5] - x_true[(5, 2)]).abs() < 1e-4);
        // The group ran ONCE, on exactly one sequence's recycle state
        // (the leader's — which sequence leads depends on queue order).
        assert_eq!(
            sa.history().len() + sb.history().len(),
            1,
            "a cross-sequence group must be one solve on one manager"
        );
        // Exact billing: per-ticket shares sum to the service total, and
        // each report mirrors its result.
        assert_eq!(rep_a.matvecs, ra.matvecs);
        assert_eq!(rep_b.matvecs, rb.matvecs);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.total_matvecs, ra.matvecs + rb.matvecs);
        assert_eq!(snap.cross_seq_coalesced, 1, "one peer ticket joined the leader's group");
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.queue_depth, 0);
    }

    /// ALL-OF across sequences: cancelling one sequence's member of a
    /// cross-coalesced group must not abort the other sequence's member.
    #[test]
    fn cross_sequence_member_cancel_never_aborts_other_sequences() {
        let mut rng = Rng::new(61);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 1, &mut rng));
        let (op, started, release, _calls) = SlowOp::new(a);
        let op: Arc<dyn SpdOperator + Send + Sync> = op;
        let svc = SolveService::new(1);
        let sa = svc.open_sequence(RecycleConfig::default());
        let sb = svc.open_sequence(RecycleConfig::default());
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let ta = sa.submit_block(op.clone(), b.clone(), spec.clone());
        let tb = sb.submit_block(op.clone(), b.clone(), spec);
        drop(pause);
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        tb.cancel();
        release.store(true, Ordering::SeqCst);
        let (ra, rep_a) = ta.wait_report();
        let rb = tb.wait();
        assert_eq!(rep_a.group_size, 2, "the two sequences coalesced");
        assert_eq!(
            ra.stop,
            StopReason::Converged,
            "another sequence's cancel must not abort this member"
        );
        // The cancelled member rode the shared solve to completion (its
        // flag was raised after the group had already dequeued it).
        assert_eq!(rb.stop, StopReason::Converged);
        assert_eq!(svc.metrics().snapshot().cross_seq_coalesced, 1);
        assert_eq!(sa.history().len() + sb.history().len(), 1);
    }

    /// The kill switch: with cross-sequence coalescing disabled, the same
    /// staged two-sequence workload runs as two separate solves.
    #[test]
    fn cross_sequence_coalescing_can_be_disabled() {
        let svc = SolveService::new(1);
        svc.cross_sequence_coalescing(false);
        let sa = svc.open_sequence(RecycleConfig::default());
        let sb = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(62);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 1, &mut rng));
        let op: Arc<dyn SpdOperator + Send + Sync> = spd_mat(a);
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let ta = sa.submit_block(op.clone(), b.clone(), spec.clone());
        let tb = sb.submit_block(op.clone(), b.clone(), spec);
        drop(pause);
        assert_eq!(ta.wait().stop, StopReason::Converged);
        assert_eq!(tb.wait().stop, StopReason::Converged);
        assert_eq!(sa.history().len(), 1, "each sequence solved its own block");
        assert_eq!(sb.history().len(), 1);
        assert_eq!(svc.metrics().snapshot().cross_seq_coalesced, 0);
    }

    /// The merge key is operator *identity*, not the fingerprint: two
    /// distinct `ParDenseOp` Arcs over the SAME matrix share a diagonal
    /// fingerprint, yet must never cross-coalesce (equal fingerprints
    /// prove nothing; `Arc::ptr_eq` is the sole proof of same operator).
    #[test]
    fn distinct_operator_arcs_never_cross_coalesce() {
        let svc = SolveService::new(1);
        let mut rng = Rng::new(63);
        let n = 40;
        let am = Arc::new(Mat::rand_spd(n, 1e3, &mut rng));
        let b = am.matmul(&Mat::randn(n, 1, &mut rng));
        let op1: Arc<dyn SpdOperator + Send + Sync> =
            Arc::new(ParDenseOp::new(am.clone(), svc.compute_pool()));
        let op2: Arc<dyn SpdOperator + Send + Sync> =
            Arc::new(ParDenseOp::new(am.clone(), svc.compute_pool()));
        // Same matrix ⇒ same fingerprint: exactly the aliasing case the
        // Arc-identity check exists for.
        assert!(op1.diag_fingerprint().is_some());
        assert_eq!(op1.diag_fingerprint(), op2.diag_fingerprint());
        let sa = svc.open_sequence(RecycleConfig::default());
        let sb = svc.open_sequence(RecycleConfig::default());
        let pause = svc.pause();
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let ta = sa.submit_block(op1, b.clone(), spec.clone());
        let tb = sb.submit_block(op2, b.clone(), spec);
        drop(pause);
        assert_eq!(ta.wait().stop, StopReason::Converged);
        assert_eq!(tb.wait().stop, StopReason::Converged);
        assert_eq!(sa.history().len(), 1, "distinct Arcs must solve separately");
        assert_eq!(sb.history().len(), 1);
        assert_eq!(svc.metrics().snapshot().cross_seq_coalesced, 0);
    }

    /// The new per-class gauges: queued work shows up under its priority
    /// class while staged, drains to zero, and leaves high-water marks.
    #[test]
    fn class_depth_gauges_track_queue_composition() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(25, 64);
        let b = vec![1.0; 25];
        let pause = svc.pause();
        let batch = SolveSpec::cg().with_tol(1e-8).batch();
        let t1 = seq.submit(op.clone(), b.clone(), None, batch.clone());
        let t2 = seq.submit(op.clone(), b.clone(), None, batch);
        let t3 = seq.submit(op.clone(), b.clone(), None, SolveSpec::cg().with_tol(1e-8));
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.workers, 1);
        assert_eq!(snap.batch_depth, 2);
        assert_eq!(snap.interactive_depth, 1);
        drop(pause);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(t3.wait().stop, StopReason::Converged);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.batch_depth, 0, "completions drain the class gauges");
        assert_eq!(snap.interactive_depth, 0);
        assert!(snap.batch_high_water >= 2);
        assert!(snap.interactive_high_water >= 1);
        assert_eq!(snap.steals, 0, "one worker has nobody to steal from");
        assert!(snap.utilization() >= 0.0);
    }
}







