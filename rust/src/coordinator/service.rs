//! The solve-service implementation.
//!
//! Every request carries its own [`SolveSpec`], so one sequence queue can
//! serve a heterogeneous workload — plain CG, Jacobi-preconditioned,
//! deflated, block, and multi-RHS [`SequenceHandle::submit_block`]
//! requests interleave freely while the sequence's [`RecycleManager`]
//! carries the recycled subspace across them. Operators are behind
//! `Arc<dyn SpdOperator + Send + Sync>`, so the `solvers::algebra` views
//! (`ShiftedOp(base.clone(), σ)` etc.) submit directly — a σ-grid is a
//! stream of requests over one shared base operator, never a rebuilt
//! kernel.
//!
//! Multi-RHS coalescing: consecutive queued `submit_block` requests that
//! share the same operator (`Arc` identity) and the same block-relevant
//! policy set (see `coalescible`) are drained as **one** block solve — the block Krylov
//! space sees all their columns at once and the operator pays one
//! `apply_block` data pass per iteration for the whole group. Block
//! solves ride the sequence's recycled basis like every other request
//! (deflated block CG in, harmonic-Ritz directions out), so a stream of
//! coalesced block groups converges faster system over system.
//!
//! Locking: each sequence keeps its request queue and its solve state
//! ([`RecycleManager`]) behind **separate** mutexes. Submissions touch
//! only the queue lock, so they return immediately while a solve is in
//! flight; the single drainer per sequence serializes solves FIFO under
//! the solve lock.

use crate::linalg::mat::Mat;
use crate::solvers::api::SolveSpec;
use crate::solvers::blockcg::BlockSolveResult;
use crate::solvers::recycle::{RecycleConfig, RecycleManager, SystemStats};
use crate::solvers::{ParDenseOp, SolveResult, SpdOperator};
use crate::util::pool::ThreadPool;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A solve request: operator + per-request spec + payload (single RHS or
/// a multi-RHS block).
struct Task {
    op: Arc<dyn SpdOperator + Send + Sync>,
    spec: SolveSpec,
    payload: Payload,
}

/// True when two queued block specs may share one coalesced group solve.
/// Every policy that reaches the block kernel or decides basis
/// consumption must match — not just tolerance and iteration cap, now
/// that block requests carry preconditioning, deflation, method, and the
/// stall window. Preconditioner and deflation compare by `Arc` identity
/// (same shared policy object), like the operator itself.
fn coalescible(a: &SolveSpec, b: &SolveSpec) -> bool {
    let same_precond = match (&a.precond, &b.precond) {
        (None, None) => true,
        (Some(x), Some(y)) => Arc::ptr_eq(x, y),
        _ => false,
    };
    let same_defl = match (&a.deflation, &b.deflation) {
        (None, None) => true,
        (Some(x), Some(y)) => Arc::ptr_eq(x, y),
        _ => false,
    };
    a.method == b.method
        && a.tol == b.tol
        && a.max_iters == b.max_iters
        && a.stall_window == b.stall_window
        && a.recompute_every == b.recompute_every
        && a.auto_jacobi == b.auto_jacobi
        && same_precond
        && same_defl
}

enum Payload {
    Single { b: Vec<f64>, x0: Option<Vec<f64>>, slot: Arc<Slot<SolveResult>> },
    Block { b: Mat, slot: Arc<Slot<BlockSolveResult>> },
}

/// One-shot result slot (mini oneshot channel).
struct Slot<T> {
    value: Mutex<Option<T>>,
    cv: Condvar,
}

impl<T> Slot<T> {
    fn new() -> Arc<Self> {
        Arc::new(Slot { value: Mutex::new(None), cv: Condvar::new() })
    }

    fn put(&self, r: T) {
        *self.value.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }

    fn take(&self) -> T {
        let mut g = self.value.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        g.take().unwrap()
    }
}

/// Pending future for a submitted solve.
pub struct SolveTicket {
    slot: Arc<Slot<SolveResult>>,
}

impl SolveTicket {
    /// Block until the solve finishes.
    pub fn wait(self) -> SolveResult {
        self.slot.take()
    }
}

/// Pending future for a submitted multi-RHS block solve.
pub struct BlockSolveTicket {
    slot: Arc<Slot<BlockSolveResult>>,
}

impl BlockSolveTicket {
    /// Block until the block solve finishes. When the request was
    /// coalesced with neighbours, the returned `x` holds exactly this
    /// request's columns; `iterations`/`residuals`/`seconds` describe the
    /// shared group solve, and `matvecs`/`col_matvecs` are this request's
    /// per-column share — the applies its own columns were active for
    /// (duplicate or early-converging columns ride nearly free), with the
    /// group's basis-refresh overhead billed to the group's first ticket.
    pub fn wait(self) -> BlockSolveResult {
        self.slot.take()
    }
}

/// Queue-side state of a sequence, guarded by a lock that is only ever
/// held for O(1) pushes/pops — **never across a solve** — so
/// [`SequenceHandle::submit`] returns immediately even while a solve for
/// this sequence is in flight (the documented pipelining contract). The
/// solve-side state ([`RecycleManager`]) lives behind its own mutex.
struct SequenceState {
    queue: VecDeque<Task>,
    running: bool,
    closed: bool,
}

/// Owns the sequence's slot in the `active_sequences` gauge. Held by the
/// `SequenceHandle` clones only (NOT by the drainer), so the gauge drops
/// when the sequence is explicitly closed or every handle is gone —
/// whichever comes first, exactly once.
struct SeqCloser {
    metrics: Arc<ServiceMetrics>,
    retired: AtomicBool,
}

impl SeqCloser {
    fn retire(&self) {
        if !self.retired.swap(true, Ordering::Relaxed) {
            self.metrics.active_sequences.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

impl Drop for SeqCloser {
    fn drop(&mut self) {
        self.retire();
    }
}

/// Aggregated service counters (lock-free atomics; see
/// [`ServiceMetrics::snapshot`] for a consistent-enough named view).
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub submitted: AtomicUsize,
    pub completed: AtomicUsize,
    pub active_sequences: AtomicUsize,
    pub matvecs: AtomicUsize,
    pub solve_nanos: AtomicU64,
}

/// A named point-in-time view of the service counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Requests accepted by [`SequenceHandle::submit`].
    pub submitted: usize,
    /// Requests whose solve has finished (ticket resolvable).
    pub completed: usize,
    /// Sequences opened and not yet retired (a sequence retires when it
    /// is explicitly closed or when its last handle is dropped).
    pub active_sequences: usize,
    /// Cumulative wall-clock seconds spent inside solvers.
    pub total_seconds: f64,
    /// Cumulative operator applications across all solves.
    pub total_matvecs: usize,
}

impl MetricsSnapshot {
    /// Requests accepted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.submitted.saturating_sub(self.completed)
    }
}

impl ServiceMetrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            active_sequences: self.active_sequences.load(Ordering::Relaxed),
            total_seconds: self.solve_nanos.load(Ordering::Relaxed) as f64 * 1e-9,
            total_matvecs: self.matvecs.load(Ordering::Relaxed),
        }
    }
}

/// The service: a shared pool plus per-sequence recycling state.
pub struct SolveService {
    pool: Arc<ThreadPool>,
    /// Lazily-built pool for sharded dense matvecs ([`ParDenseOp`]).
    /// Kept separate from the drainer pool: a drainer that blocked on
    /// shard joins queued behind other drainers on the *same* fixed-size
    /// pool would deadlock (nested fork/join).
    compute: Mutex<Option<Arc<ThreadPool>>>,
    metrics: Arc<ServiceMetrics>,
}

impl SolveService {
    pub fn new(workers: usize) -> Self {
        SolveService {
            pool: Arc::new(ThreadPool::new(workers)),
            compute: Mutex::new(None),
            metrics: Arc::new(ServiceMetrics::default()),
        }
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// The dedicated compute pool for matvec sharding (created on first
    /// use, sized to the machine).
    pub fn compute_pool(&self) -> Arc<ThreadPool> {
        let mut g = self.compute.lock().unwrap();
        if g.is_none() {
            *g = Some(Arc::new(ThreadPool::default_size()));
        }
        g.as_ref().unwrap().clone()
    }

    /// Wrap a dense SPD matrix in a [`ParDenseOp`] sharded over the
    /// service's compute pool, ready to [`SequenceHandle::submit`].
    pub fn par_operator(&self, a: Mat) -> Arc<ParDenseOp> {
        Arc::new(ParDenseOp::new(Arc::new(a), self.compute_pool()))
    }

    /// Open a new sequence with its own recycled-subspace state. Each
    /// request submitted to the handle carries its own [`SolveSpec`]; the
    /// `cfg` here fixes the sequence-level recycling hyperparameters
    /// (k, ℓ, AW policy).
    pub fn open_sequence(&self, cfg: RecycleConfig) -> SequenceHandle {
        self.metrics.active_sequences.fetch_add(1, Ordering::Relaxed);
        SequenceHandle {
            state: Arc::new(Mutex::new(SequenceState {
                queue: VecDeque::new(),
                running: false,
                closed: false,
            })),
            mgr: Arc::new(Mutex::new(RecycleManager::new(cfg))),
            pool: self.pool.clone(),
            metrics: self.metrics.clone(),
            closer: Arc::new(SeqCloser {
                metrics: self.metrics.clone(),
                retired: AtomicBool::new(false),
            }),
        }
    }
}

/// Handle to one solve sequence. Submissions are processed strictly FIFO
/// (recycling transfers state from each solve to the next); distinct
/// sequences run concurrently on the shared pool.
///
/// The queue lock (`state`) and the solve lock (`mgr`) are separate:
/// submitting only touches the queue, so `submit`/`submit_block` return
/// immediately even while this sequence's drainer is deep inside a slow
/// solve. Only `history()`/`k_active()` wait on an in-flight solve (they
/// read the recycle state itself).
#[derive(Clone)]
pub struct SequenceHandle {
    state: Arc<Mutex<SequenceState>>,
    mgr: Arc<Mutex<RecycleManager>>,
    pool: Arc<ThreadPool>,
    metrics: Arc<ServiceMetrics>,
    closer: Arc<SeqCloser>,
}

impl SequenceHandle {
    /// Submit the next system of this sequence with its own per-request
    /// [`SolveSpec`] (method, tolerance, preconditioner, …). Returns a
    /// ticket that can be waited on; submissions may be pipelined without
    /// waiting. See [`RecycleManager::solve_next`] for how each method
    /// interacts with the sequence's recycled basis.
    pub fn submit(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Vec<f64>,
        x0: Option<Vec<f64>>,
        spec: SolveSpec,
    ) -> SolveTicket {
        // Validate at the call site: a panic inside the drainer would
        // poison the sequence mutex and leave the ticket waiting forever.
        assert_eq!(b.len(), op.n(), "rhs dimension mismatch");
        if let Some(x0) = &x0 {
            assert_eq!(x0.len(), op.n(), "x0 dimension mismatch");
        }
        let slot = Slot::new();
        let task = Task { op, spec, payload: Payload::Single { b, x0, slot: slot.clone() } };
        self.enqueue(task);
        SolveTicket { slot }
    }

    /// Submit a genuine multi-RHS block `A X = B` (one column per RHS) for
    /// this sequence, solved by rank-adaptive block CG through
    /// [`RecycleManager::solve_block`]. Block requests are first-class
    /// recycling citizens: the sequence's basis **deflates** the block
    /// solve (projected start + per-iteration deflation) and the run's
    /// stored block directions **feed** the next harmonic-Ritz
    /// extraction, so coalesced multi-RHS traffic enjoys the same
    /// iteration decay across a sequence as the single-RHS path. The
    /// spec's preconditioner (explicit or `auto_jacobi`) is honored too.
    ///
    /// **Coalescing:** consecutive queued block requests on the same
    /// operator (`Arc` identity) with the same block-relevant policy set
    /// (tolerance, iteration cap, method, stall window,
    /// residual-replacement period, auto-Jacobi flag, and
    /// preconditioner/deflation identity) are drained as a single
    /// block solve over their concatenated columns —
    /// same-sequence multi-RHS traffic shares the block Krylov space and
    /// the per-iteration `apply_block` data pass. Each ticket still
    /// receives exactly its own solution columns, and is billed exactly
    /// its own columns' operator applications (`col_matvecs` shares):
    /// duplicate or early-converging columns ride nearly free.
    pub fn submit_block(
        &self,
        op: Arc<dyn SpdOperator + Send + Sync>,
        b: Mat,
        spec: SolveSpec,
    ) -> BlockSolveTicket {
        assert_eq!(b.rows(), op.n(), "rhs block dimension mismatch");
        assert!(b.cols() >= 1, "rhs block needs at least one column");
        let slot = Slot::new();
        let task = Task { op, spec, payload: Payload::Block { b, slot: slot.clone() } };
        self.enqueue(task);
        BlockSolveTicket { slot }
    }

    fn enqueue(&self, task: Task) {
        let mut st = self.state.lock().unwrap();
        assert!(!st.closed, "submit on closed sequence");
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        st.queue.push_back(task);
        if !st.running {
            st.running = true;
            drop(st);
            self.spawn_drainer();
        }
    }

    fn spawn_drainer(&self) {
        let state = self.state.clone();
        let mgr = self.mgr.clone();
        let metrics = self.metrics.clone();
        self.pool.spawn(move || loop {
            let task = {
                let mut st = state.lock().unwrap();
                match st.queue.pop_front() {
                    Some(t) => t,
                    None => {
                        st.running = false;
                        return;
                    }
                }
            };
            match task.payload {
                Payload::Single { b, x0, slot } => {
                    // The solve runs under the dedicated solve mutex, NOT
                    // the queue lock — submissions pipeline freely while
                    // this solve is in flight, and there is exactly one
                    // drainer per sequence so FIFO recycling order is
                    // preserved. Distinct sequences proceed in parallel.
                    let result = {
                        let mut mg = mgr.lock().unwrap();
                        mg.solve_next(task.op.as_ref(), &b, x0.as_deref(), &task.spec)
                    };
                    metrics.completed.fetch_add(1, Ordering::Relaxed);
                    metrics.matvecs.fetch_add(result.matvecs, Ordering::Relaxed);
                    metrics
                        .solve_nanos
                        .fetch_add((result.seconds * 1e9) as u64, Ordering::Relaxed);
                    slot.put(result);
                }
                Payload::Block { b, slot } => {
                    // Coalesce: pull every *consecutive* queued block
                    // request that shares this operator and the full
                    // block-relevant policy set into one group solve.
                    let mut rhs = vec![(b, slot)];
                    {
                        let mut st = state.lock().unwrap();
                        while st.queue.front().is_some_and(|next| {
                            matches!(&next.payload, Payload::Block { .. })
                                && Arc::ptr_eq(&next.op, &task.op)
                                && coalescible(&next.spec, &task.spec)
                        }) {
                            let next = st.queue.pop_front().unwrap();
                            match next.payload {
                                Payload::Block { b, slot } => rhs.push((b, slot)),
                                Payload::Single { .. } => unreachable!(),
                            }
                        }
                    }
                    let n = task.op.n();
                    let total: usize = rhs.iter().map(|(b, _)| b.cols()).sum();
                    let mut big = Mat::zeros(n, total);
                    let mut off = 0;
                    for (b, _) in &rhs {
                        for j in 0..b.cols() {
                            big.set_col(off + j, &b.col(j));
                        }
                        off += b.cols();
                    }
                    let result = {
                        let mut mg = mgr.lock().unwrap();
                        mg.solve_block(task.op.as_ref(), &big, &task.spec)
                    };
                    metrics.completed.fetch_add(rhs.len(), Ordering::Relaxed);
                    metrics.matvecs.fetch_add(result.matvecs, Ordering::Relaxed);
                    metrics
                        .solve_nanos
                        .fetch_add((result.seconds * 1e9) as u64, Ordering::Relaxed);
                    // Split the group result back into per-ticket slices.
                    // Each ticket is billed its own columns' applications
                    // (rank-dropped columns ride free); the group-level
                    // overhead that no column owns — the AW-refresh cost
                    // of the sequence's recycled basis — lands on the
                    // first ticket so shares still sum to the group total
                    // the metrics recorded.
                    let col_share: usize = result.col_matvecs.iter().sum();
                    let mut overhead = result.matvecs - col_share;
                    let mut off = 0;
                    for (b, slot) in rhs {
                        let cols = b.cols();
                        let mut x = Mat::zeros(n, cols);
                        let mut col_matvecs = Vec::with_capacity(cols);
                        for j in 0..cols {
                            x.set_col(j, &result.x.col(off + j));
                            col_matvecs.push(result.col_matvecs[off + j]);
                        }
                        off += cols;
                        let matvecs =
                            col_matvecs.iter().sum::<usize>() + std::mem::take(&mut overhead);
                        slot.put(BlockSolveResult {
                            x,
                            residuals: result.residuals.clone(),
                            iterations: result.iterations,
                            block_matvecs: result.block_matvecs,
                            matvecs,
                            col_matvecs,
                            stop: result.stop,
                            // The group's stored directions already fed
                            // the sequence basis; per-ticket results do
                            // not re-export them.
                            stored: Default::default(),
                            seconds: result.seconds,
                        });
                    }
                }
            }
        });
    }

    /// Per-system statistics accumulated by this sequence's manager.
    /// Waits for an in-flight solve (it reads the solve-side state).
    pub fn history(&self) -> Vec<SystemStats> {
        self.mgr.lock().unwrap().history().to_vec()
    }

    /// Current recycled-basis dimension. Waits for an in-flight solve.
    pub fn k_active(&self) -> usize {
        self.mgr.lock().unwrap().k_active()
    }

    /// Close the sequence (subsequent submits panic) and retire it from
    /// the `active_sequences` gauge. Idempotent; dropping the last handle
    /// without closing retires the gauge slot too.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.closer.retire();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::StopReason;
    use crate::util::rng::Rng;

    /// Owning dense operator for Arc'ing into the service.
    struct OwnedDense(Mat);

    impl SpdOperator for OwnedDense {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.0.matvec_into(x, y);
        }
    }

    fn spd(n: usize, seed: u64) -> Arc<OwnedDense> {
        let mut rng = Rng::new(seed);
        Arc::new(OwnedDense(Mat::rand_spd(n, 1e4, &mut rng)))
    }

    #[test]
    fn single_sequence_solves_in_order_with_recycling() {
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let op = spd(60, 1);
        let b = vec![1.0; 60];
        let spec = SolveSpec::defcg().with_tol(1e-8);
        let tickets: Vec<_> = (0..4)
            .map(|_| seq.submit(op.clone(), b.clone(), None, spec.clone()))
            .collect();
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
        for r in &results {
            assert_eq!(r.stop, StopReason::Converged);
        }
        // Identical systems: solves after the first must be cheaper.
        assert!(results[3].iterations < results[0].iterations);
        let hist = seq.history();
        assert_eq!(hist.len(), 4);
        assert!(seq.k_active() > 0);
    }

    #[test]
    fn sequences_run_concurrently_and_keep_state_separate() {
        let svc = SolveService::new(4);
        let spec = SolveSpec::defcg().with_tol(1e-6);
        let mut handles = Vec::new();
        for s in 0..3 {
            let seq = svc.open_sequence(RecycleConfig { k: 4, l: 6, ..Default::default() });
            let op = spd(40, 100 + s);
            let b: Vec<f64> = (0..40).map(|i| (i + s as usize) as f64).collect();
            let t1 = seq.submit(op.clone(), b.clone(), None, spec.clone());
            let t2 = seq.submit(op, b, None, spec.clone());
            handles.push((seq, t1, t2));
        }
        assert_eq!(svc.metrics().snapshot().active_sequences, 3);
        for (seq, t1, t2) in handles {
            assert_eq!(t1.wait().stop, StopReason::Converged);
            assert_eq!(t2.wait().stop, StopReason::Converged);
            assert_eq!(seq.history().len(), 2);
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.submitted, 6);
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.in_flight(), 0);
        // The consume loop dropped every handle: the sequences retired.
        assert_eq!(snap.active_sequences, 0);
        assert!(snap.total_matvecs > 0);
        assert!(snap.total_seconds >= 0.0);
    }

    #[test]
    fn mixed_method_workload_through_one_sequence_queue() {
        // The heterogeneous-workload promise: plain, Jacobi-preconditioned,
        // deflated, and block requests interleave through ONE sequence
        // queue, sharing (or bypassing) the recycled basis per method.
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let op = spd(70, 5);
        let b = vec![1.0; 70];
        let jacobi = SolveSpec::pcg().with_jacobi(op.as_ref()).with_tol(1e-8);
        let specs = vec![
            SolveSpec::defcg().with_tol(1e-8), // seeds the basis
            SolveSpec::cg().with_tol(1e-8),    // plain, still feeds W
            jacobi,                            // preconditioned
            SolveSpec::defcg().with_tol(1e-8), // consumes the basis
            SolveSpec::blockcg().with_tol(1e-8), // deflated 1-col block, feeds too
        ];
        let tickets: Vec<_> = specs
            .into_iter()
            .map(|spec| seq.submit(op.clone(), b.clone(), None, spec))
            .collect();
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.stop, StopReason::Converged, "request {i}");
        }
        // The deflated request after the feeders beats the cold one.
        assert!(
            results[3].iterations < results[0].iterations,
            "recycled def-CG {} >= cold def-CG {}",
            results[3].iterations,
            results[0].iterations
        );
        assert_eq!(seq.history().len(), 5);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.submitted, 5);
        assert_eq!(snap.completed, 5);
        assert_eq!(snap.active_sequences, 1);
        seq.close();
        assert_eq!(svc.metrics().snapshot().active_sequences, 0);
        seq.close(); // idempotent
        assert_eq!(svc.metrics().snapshot().active_sequences, 0);
    }

    #[test]
    fn pipelined_submissions_complete() {
        let svc = SolveService::new(2);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(30, 7);
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let b: Vec<f64> = (0..30).map(|j| ((i + j) % 5) as f64 + 1.0).collect();
                seq.submit(op.clone(), b, None, SolveSpec::defcg().with_tol(1e-6))
            })
            .collect();
        for t in tickets {
            assert_eq!(t.wait().stop, StopReason::Converged);
        }
        assert_eq!(seq.history().len(), 8);
    }

    #[test]
    fn submit_block_solves_multi_rhs_and_counts_per_column() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(31);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let op = spd_mat(a);
        let r = seq
            .submit_block(op, b, SolveSpec::blockcg().with_tol(1e-10))
            .wait();
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.x.max_abs_diff(&x_true) < 1e-5);
        // Per-column accounting: the sum of the per-column applies, never
        // more than the full-block bound (columns that converge early stop
        // paying).
        assert_eq!(r.matvecs, r.col_matvecs.iter().sum::<usize>());
        assert!(r.matvecs <= 3 * r.block_matvecs);
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.submitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.total_matvecs, r.matvecs, "metrics count columns, not block applies");
        assert_eq!(seq.history().len(), 1);
        assert!(seq.k_active() > 0, "a block solve must feed the sequence basis");
    }

    #[test]
    fn consecutive_block_submissions_coalesce_into_one_solve() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(32);
        let n = 300;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let x_true = Mat::randn(n, 5, &mut rng);
        let b = a.matmul(&x_true);
        let op = spd_mat(a);
        // Deterministically hold the drainer back: the service has ONE
        // drainer worker, and a gate job parked on it means the sequence
        // drainer (queued behind the gate) cannot start until we release
        // it — by which point all three block requests are queued.
        let gate = Arc::new(AtomicBool::new(false));
        let held = {
            let gate = gate.clone();
            seq.pool.spawn(move || {
                while !gate.load(Ordering::Relaxed) {
                    std::thread::yield_now();
                }
            })
        };
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let tickets: Vec<_> = (0..3)
            .map(|g| {
                let cols: Vec<usize> = match g {
                    0 => vec![0, 1],
                    1 => vec![2],
                    _ => vec![3, 4],
                };
                let mut bg = Mat::zeros(n, cols.len());
                for (dst, &src) in cols.iter().enumerate() {
                    bg.set_col(dst, &b.col(src));
                }
                seq.submit_block(op.clone(), bg, spec.clone())
            })
            .collect();
        gate.store(true, Ordering::Relaxed);
        held.join();
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
        for (g, r) in results.iter().enumerate() {
            assert_eq!(r.stop, StopReason::Converged, "group {g}");
        }
        // Each ticket got exactly its own columns back.
        assert!((results[0].x.col(0)[0] - x_true[(0, 0)]).abs() < 1e-4);
        assert!(results[0].x.max_abs_diff(&{
            let mut m = Mat::zeros(n, 2);
            m.set_col(0, &x_true.col(0));
            m.set_col(1, &x_true.col(1));
            m
        }) < 1e-4);
        assert!((results[1].x.col(0)[5] - x_true[(5, 2)]).abs() < 1e-4);
        // Coalesced: the sequence history saw ONE block solve, and the
        // three groups share its iteration trace.
        let hist = seq.history();
        assert_eq!(hist.len(), 1, "3 block submissions must coalesce into 1 solve");
        assert_eq!(results[0].iterations, results[1].iterations);
        assert_eq!(results[0].residuals, results[2].residuals);
        // Per-ticket matvec shares sum EXACTLY to the group total in the
        // metrics, with dropped columns paying only the applies they were
        // active for.
        let share: usize = results.iter().map(|r| r.matvecs).sum();
        assert!(share <= 5 * results[0].block_matvecs);
        assert_eq!(hist[0].matvecs, share);
        for r in &results {
            assert!(!r.final_residual().is_nan());
            assert_eq!(r.col_matvecs.len(), r.x.cols());
        }
        let snap = svc.metrics().snapshot();
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.total_matvecs, share);
    }

    #[test]
    fn mismatched_block_policies_do_not_coalesce() {
        // Same operator and tolerance, but ticket B asks for a stall
        // window (any block-relevant policy difference would do):
        // coalescing them would silently run B under A's policy, so they
        // must drain as two separate group solves.
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(42);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = a.matmul(&Mat::randn(n, 2, &mut rng));
        let op = spd_mat(a);
        // Park the single drainer worker so both requests queue first.
        let gate = Arc::new(AtomicBool::new(false));
        let held = {
            let gate = gate.clone();
            seq.pool.spawn(move || {
                while !gate.load(Ordering::Relaxed) {
                    std::thread::yield_now();
                }
            })
        };
        let spec_a = SolveSpec::blockcg().with_tol(1e-9);
        let spec_b = SolveSpec::blockcg().with_tol(1e-9).with_stall_window(50);
        let t1 = seq.submit_block(op.clone(), b.clone(), spec_a.clone());
        let t2 = seq.submit_block(op.clone(), b.clone(), spec_b);
        let t3 = seq.submit_block(op.clone(), b.clone(), spec_a);
        gate.store(true, Ordering::Relaxed);
        held.join();
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(t3.wait().stop, StopReason::Converged);
        // 1 and 2 must not merge (different stall window); 2 and 3 must
        // not merge either — three separate solves in the history.
        assert_eq!(seq.history().len(), 3, "policy-mismatched blocks must not coalesce");
    }

    #[test]
    fn submit_returns_immediately_during_inflight_solve() {
        // The pipelining contract: `submit` must enqueue and return while
        // a previous solve of the SAME sequence is still running — the
        // drainer may not hold the queue lock across a solve. The slow
        // operator parks its first matvec until released; if submission
        // blocked on the in-flight solve, the second submit below would
        // deadlock (watchdog-released after 10 s, failing the assert).
        struct SlowOp {
            a: Mat,
            started: Arc<AtomicBool>,
            release: Arc<AtomicBool>,
        }
        impl SpdOperator for SlowOp {
            fn n(&self) -> usize {
                self.a.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.started.store(true, Ordering::SeqCst);
                while !self.release.load(Ordering::SeqCst) {
                    std::thread::yield_now();
                }
                self.a.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(41);
        let n = 20;
        let a = Mat::rand_spd(n, 100.0, &mut rng);
        let started = Arc::new(AtomicBool::new(false));
        let release = Arc::new(AtomicBool::new(false));
        let op = Arc::new(SlowOp {
            a: a.clone(),
            started: started.clone(),
            release: release.clone(),
        });
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let b = vec![1.0; n];
        let spec = SolveSpec::cg().with_tol(1e-8);
        let t1 = seq.submit(op.clone(), b.clone(), None, spec.clone());
        // Wait until the drainer is provably inside the first solve.
        while !started.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // Watchdog: if the old queue-lock-across-solve behavior came
        // back, unblock the solve after a grace period so the test fails
        // with a message instead of hanging the suite.
        let watchdog = {
            let release = release.clone();
            std::thread::spawn(move || {
                let t0 = std::time::Instant::now();
                while !release.load(Ordering::SeqCst) {
                    if t0.elapsed() > std::time::Duration::from_secs(10) {
                        release.store(true, Ordering::SeqCst);
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            })
        };
        let t2 = seq.submit(op.clone(), b.clone(), None, spec.clone());
        let t3 = seq.submit_block(
            op.clone(),
            {
                let mut m = Mat::zeros(n, 2);
                m.set_col(0, &b);
                m.set_col(1, &b);
                m
            },
            SolveSpec::blockcg().with_tol(1e-8),
        );
        assert!(
            !release.load(Ordering::SeqCst),
            "submit/submit_block blocked on the in-flight solve"
        );
        release.store(true, Ordering::SeqCst);
        assert_eq!(t1.wait().stop, StopReason::Converged);
        assert_eq!(t2.wait().stop, StopReason::Converged);
        assert_eq!(t3.wait().stop, StopReason::Converged);
        assert_eq!(seq.history().len(), 3);
        watchdog.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "closed sequence")]
    fn closed_sequence_rejects() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        seq.close();
        let op = spd(5, 9);
        let _ = seq.submit(op, vec![1.0; 5], None, SolveSpec::defcg());
    }

    #[test]
    fn par_operator_matches_serial_solves() {
        let svc = SolveService::new(2);
        let mut rng = Rng::new(21);
        let n = 300; // above ParDenseOp::PAR_THRESHOLD: shards for real
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 9) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-10);

        let par = svc.par_operator(a.clone());
        let seq = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let r_par = seq.submit(par, b.clone(), None, spec.clone()).wait();
        assert_eq!(r_par.stop, StopReason::Converged);

        // Serial reference through a fresh sequence (same recycle state).
        let seq2 = svc.open_sequence(RecycleConfig { k: 6, l: 10, ..Default::default() });
        let r_ser = seq2.submit(spd_mat(a), b, None, spec).wait();
        assert_eq!(r_ser.stop, StopReason::Converged);

        // Bitwise-identical matvecs => identical CG trajectories.
        assert_eq!(r_par.iterations, r_ser.iterations);
        for (u, v) in r_par.x.iter().zip(&r_ser.x) {
            assert_eq!(u, v);
        }
    }

    fn spd_mat(a: Mat) -> Arc<OwnedDense> {
        Arc::new(OwnedDense(a))
    }

    #[test]
    fn warm_start_passthrough() {
        let svc = SolveService::new(1);
        let seq = svc.open_sequence(RecycleConfig::default());
        let op = spd(20, 11);
        let b = vec![2.0; 20];
        // First solve to get solution, then warm start from it.
        let x = seq
            .submit(op.clone(), b.clone(), None, SolveSpec::defcg().with_tol(1e-10))
            .wait()
            .x;
        let warm = seq
            .submit(op, b, Some(x), SolveSpec::defcg().with_tol(1e-10))
            .wait();
        assert!(warm.iterations <= 2, "warm start took {}", warm.iterations);
    }
}
