//! Synthetic "infinite MNIST": deformed stroke-rendered digits 3 and 5.
//!
//! Each sample starts from a polyline stroke template of the digit, gets a
//! random affine distortion (rotation, anisotropic scale, shear,
//! translation), per-vertex elastic jitter, and is rasterized with a
//! Gaussian pen onto a 28×28 grid; finally pixel noise is added. Labels
//! are +1 for "3" and −1 for "5" (binary GPC, as in the paper's §3).

use crate::linalg::mat::Mat;
use crate::util::rng::Rng;

/// Image side length (28 like MNIST) — feature dimension is SIDE².
pub const SIDE: usize = 28;
/// Feature dimension (784).
pub const DIM: usize = SIDE * SIDE;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct DigitsConfig {
    /// Number of samples (balanced between the two classes).
    pub n: usize,
    pub seed: u64,
    /// Max rotation angle (radians) of the random affine.
    pub max_rotation: f64,
    /// Scale jitter: factors drawn from [1−s, 1+s].
    pub scale_jitter: f64,
    /// Max shear coefficient.
    pub max_shear: f64,
    /// Max translation in pixels.
    pub max_translate: f64,
    /// Std of per-vertex elastic displacement (pixels).
    pub elastic_std: f64,
    /// Std of additive pixel noise.
    pub pixel_noise: f64,
    /// Gaussian pen radius (pixels).
    pub pen_sigma: f64,
}

impl Default for DigitsConfig {
    fn default() -> Self {
        DigitsConfig {
            n: 200,
            seed: 0,
            max_rotation: 0.25,
            scale_jitter: 0.15,
            max_shear: 0.2,
            max_translate: 2.0,
            elastic_std: 0.6,
            pixel_noise: 0.03,
            pen_sigma: 0.9,
        }
    }
}

/// A generated dataset: features X (n × 784, values in [0, ~1]) and
/// labels y ∈ {−1, +1}ⁿ (+1 = "3", −1 = "5").
#[derive(Clone, Debug)]
pub struct Digits {
    pub x: Mat,
    pub y: Vec<f64>,
}

impl Digits {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Split into (train, test) by a shuffled index at `train_frac`.
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Digits, Digits) {
        let n = self.n();
        let n_train = ((n as f64) * train_frac).round() as usize;
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let take = |ids: &[usize]| Digits {
            x: self.x.take_rows(ids),
            y: ids.iter().map(|&i| self.y[i]).collect(),
        };
        (take(&idx[..n_train]), take(&idx[n_train..]))
    }

    /// Subsample m points (used by the inducing-point baseline).
    pub fn subset(&self, m: usize, rng: &mut Rng) -> (Digits, Vec<usize>) {
        let idx = rng.sample_indices(self.n(), m);
        (
            Digits { x: self.x.take_rows(&idx), y: idx.iter().map(|&i| self.y[i]).collect() },
            idx,
        )
    }
}

/// Stroke template for digit "3": two stacked open bows, as polyline
/// vertices in unit coordinates ([0,1]², y downward).
fn template_three() -> Vec<(f64, f64)> {
    vec![
        (0.25, 0.18),
        (0.45, 0.12),
        (0.65, 0.16),
        (0.72, 0.28),
        (0.66, 0.42),
        (0.48, 0.48),
        (0.66, 0.54),
        (0.74, 0.68),
        (0.66, 0.82),
        (0.45, 0.88),
        (0.24, 0.82),
    ]
}

/// Stroke template for digit "5": top bar, left descender, lower bowl.
fn template_five() -> Vec<(f64, f64)> {
    vec![
        (0.70, 0.12),
        (0.32, 0.12),
        (0.30, 0.20),
        (0.28, 0.46),
        (0.45, 0.42),
        (0.62, 0.46),
        (0.72, 0.58),
        (0.70, 0.74),
        (0.55, 0.86),
        (0.34, 0.84),
        (0.25, 0.74),
    ]
}

/// Render one deformed digit into `img` (SIDE×SIDE, row-major).
fn render(template: &[(f64, f64)], cfg: &DigitsConfig, rng: &mut Rng, img: &mut [f64]) {
    debug_assert_eq!(img.len(), DIM);
    for p in img.iter_mut() {
        *p = 0.0;
    }
    // Random affine about the image center.
    let theta = rng.uniform_in(-cfg.max_rotation, cfg.max_rotation);
    let (sin, cos) = theta.sin_cos();
    let sx = 1.0 + rng.uniform_in(-cfg.scale_jitter, cfg.scale_jitter);
    let sy = 1.0 + rng.uniform_in(-cfg.scale_jitter, cfg.scale_jitter);
    let shear = rng.uniform_in(-cfg.max_shear, cfg.max_shear);
    let tx = rng.uniform_in(-cfg.max_translate, cfg.max_translate);
    let ty = rng.uniform_in(-cfg.max_translate, cfg.max_translate);
    let side = SIDE as f64;

    // Transform template vertices to pixel space with elastic jitter.
    let pts: Vec<(f64, f64)> = template
        .iter()
        .map(|&(u, v)| {
            let (cx, cy) = (u - 0.5, v - 0.5);
            let (rx, ry) = (cos * cx - sin * cy, sin * cx + cos * cy);
            let (ax, ay) = (sx * (rx + shear * ry), sy * ry);
            (
                (ax + 0.5) * side + tx + rng.normal() * cfg.elastic_std,
                (ay + 0.5) * side + ty + rng.normal() * cfg.elastic_std,
            )
        })
        .collect();

    // Rasterize each segment with a Gaussian pen, sampling along its length.
    let sigma2 = cfg.pen_sigma * cfg.pen_sigma;
    let reach = (3.0 * cfg.pen_sigma).ceil() as isize;
    for seg in pts.windows(2) {
        let (x0, y0) = seg[0];
        let (x1, y1) = seg[1];
        let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt();
        let steps = (len * 2.0).ceil().max(1.0) as usize;
        for s in 0..=steps {
            let t = s as f64 / steps as f64;
            let (px, py) = (x0 + t * (x1 - x0), y0 + t * (y1 - y0));
            let (ci, cj) = (py.round() as isize, px.round() as isize);
            for di in -reach..=reach {
                for dj in -reach..=reach {
                    let (i, j) = (ci + di, cj + dj);
                    if i < 0 || j < 0 || i >= SIDE as isize || j >= SIDE as isize {
                        continue;
                    }
                    let d2 = (i as f64 - py).powi(2) + (j as f64 - px).powi(2);
                    let v = (-d2 / (2.0 * sigma2)).exp();
                    let idx = i as usize * SIDE + j as usize;
                    img[idx] = img[idx].max(v);
                }
            }
        }
    }

    // Pixel noise, clamped to keep the value range MNIST-like.
    if cfg.pixel_noise > 0.0 {
        for p in img.iter_mut() {
            *p = (*p + rng.normal() * cfg.pixel_noise).clamp(0.0, 1.0);
        }
    }
}

/// Generate a balanced dataset of deformed 3s (+1) and 5s (−1).
pub fn generate(cfg: &DigitsConfig) -> Digits {
    let mut rng = Rng::new(cfg.seed);
    let mut x = Mat::zeros(cfg.n, DIM);
    let mut y = vec![0.0; cfg.n];
    let three = template_three();
    let five = template_five();
    let mut img = vec![0.0; DIM];
    for i in 0..cfg.n {
        let is_three = i % 2 == 0;
        render(if is_three { &three } else { &five }, cfg, &mut rng, &mut img);
        x.row_mut(i).copy_from_slice(&img);
        y[i] = if is_three { 1.0 } else { -1.0 };
    }
    // Shuffle so class labels are not index-correlated.
    let mut idx: Vec<usize> = (0..cfg.n).collect();
    rng.shuffle(&mut idx);
    Digits { x: x.take_rows(&idx), y: idx.iter().map(|&i| y[i]).collect() }
}

/// Render an image to ASCII art (debugging / demo output).
pub fn ascii_art(row: &[f64]) -> String {
    let ramp: &[u8] = b" .:-=+*#%@";
    let mut s = String::with_capacity(DIM + SIDE);
    for i in 0..SIDE {
        for j in 0..SIDE {
            let v = row[i * SIDE + j].clamp(0.0, 1.0);
            let c = ramp[((v * (ramp.len() - 1) as f64).round() as usize).min(ramp.len() - 1)];
            s.push(c as char);
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernel::RbfKernel;
    use crate::linalg::vec_ops::norm2;

    #[test]
    fn generates_requested_size_and_balance() {
        let ds = generate(&DigitsConfig { n: 100, seed: 1, ..Default::default() });
        assert_eq!(ds.n(), 100);
        assert_eq!(ds.x.rows(), 100);
        assert_eq!(ds.x.cols(), DIM);
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count();
        assert_eq!(pos, 50);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&DigitsConfig { n: 20, seed: 7, ..Default::default() });
        let b = generate(&DigitsConfig { n: 20, seed: 7, ..Default::default() });
        assert_eq!(a.x.max_abs_diff(&b.x), 0.0);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&DigitsConfig { n: 20, seed: 1, ..Default::default() });
        let b = generate(&DigitsConfig { n: 20, seed: 2, ..Default::default() });
        assert!(a.x.max_abs_diff(&b.x) > 0.1);
    }

    #[test]
    fn pixels_in_unit_range_and_nontrivial() {
        let ds = generate(&DigitsConfig { n: 30, seed: 3, ..Default::default() });
        for i in 0..30 {
            let row = ds.x.row(i);
            assert!(row.iter().all(|&v| (0.0..=1.0).contains(&v)));
            // stroke must light up a reasonable number of pixels
            let lit = row.iter().filter(|&&v| v > 0.5).count();
            assert!((20..400).contains(&lit), "lit = {lit}");
        }
    }

    #[test]
    fn classes_are_separated_in_feature_space() {
        // Mean within-class distance must be smaller than between-class:
        // the clustering structure that shapes the Gram spectrum.
        let ds = generate(&DigitsConfig { n: 60, seed: 4, ..Default::default() });
        let mut within = 0.0;
        let mut between = 0.0;
        let (mut nw, mut nb) = (0, 0);
        for i in 0..ds.n() {
            for j in 0..i {
                let mut d = vec![0.0; DIM];
                crate::linalg::vec_ops::sub(ds.x.row(i), ds.x.row(j), &mut d);
                let dist = norm2(&d);
                if ds.y[i] == ds.y[j] {
                    within += dist;
                    nw += 1;
                } else {
                    between += dist;
                    nb += 1;
                }
            }
        }
        let (within, between) = (within / nw as f64, between / nb as f64);
        assert!(
            between > within * 1.05,
            "between {between} not > within {within}"
        );
    }

    #[test]
    fn gram_spectrum_decays() {
        // The RBF Gram over this data must have a decaying spectrum with a
        // heavy top — the structure def-CG exploits.
        let ds = generate(&DigitsConfig { n: 40, seed: 5, ..Default::default() });
        let k = RbfKernel::new(1.0, 10.0).gram(&ds.x);
        let eig = crate::linalg::eig::sym_eig(&k).unwrap();
        let total: f64 = eig.values.iter().sum();
        let top5: f64 = eig.values.iter().rev().take(5).sum();
        assert!(top5 / total > 0.5, "top-5 mass = {}", top5 / total);
    }

    #[test]
    fn split_partitions_dataset() {
        let ds = generate(&DigitsConfig { n: 50, seed: 6, ..Default::default() });
        let mut rng = Rng::new(1);
        let (tr, te) = ds.split(0.8, &mut rng);
        assert_eq!(tr.n(), 40);
        assert_eq!(te.n(), 10);
    }

    #[test]
    fn subset_selects_m_rows() {
        let ds = generate(&DigitsConfig { n: 50, seed: 6, ..Default::default() });
        let mut rng = Rng::new(2);
        let (sub, idx) = ds.subset(10, &mut rng);
        assert_eq!(sub.n(), 10);
        for (r, &i) in idx.iter().enumerate() {
            assert_eq!(sub.y[r], ds.y[i]);
        }
    }

    #[test]
    fn ascii_art_renders() {
        let ds = generate(&DigitsConfig { n: 2, seed: 8, ..Default::default() });
        let art = ascii_art(ds.x.row(0));
        assert_eq!(art.lines().count(), SIDE);
        assert!(art.contains('@') || art.contains('%') || art.contains('#'));
    }
}
