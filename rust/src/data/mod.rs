//! Datasets and workload generators.
//!
//! The paper evaluates on *infinite MNIST* (Loosli et al., 2007): an
//! unbounded stream of deformed MNIST digits, from which the authors drew
//! 36 551 images of threes and fives. That tool (and MNIST itself) is not
//! available in this offline environment, so [`digits`] implements the
//! closest synthetic equivalent: parametric stroke templates for the
//! digits 3 and 5 rendered to 28×28 grayscale and perturbed by random
//! affine + elastic deformations and pixel noise — the same recipe
//! infinite MNIST uses to inflate the original set. What matters for the
//! paper's claims is the *spectrum* of the RBF Gram matrix over clustered
//! 784-dimensional image data, which this generator reproduces
//! (two classes, within-class deformation manifolds, identical dimension
//! and value range). See DESIGN.md §3 for the substitution argument.

pub mod digits;
