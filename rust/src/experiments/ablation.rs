//! Ablations beyond the paper: the design choices DESIGN.md calls out.
//!
//! 1. **k sweep** — recycled-subspace dimension vs total inner iterations
//!    (the paper fixes k = 8; cost grows O(nk) per iteration).
//! 2. **ℓ sweep** — how many stored directions the Ritz extraction needs.
//! 3. **AW policy** — refresh (exact deflation, k matvecs/system) vs
//!    reuse (free but inexact; the instability the paper discusses).
//! 4. **Ritz end** — deflating the largest vs smallest harmonic Ritz
//!    values on the GPC spectrum (bounded below by 1 ⇒ largest wins).

use crate::experiments::common::{ExpOpts, Workload};
use crate::gp::laplace::{LaplaceFit, SolverBackend};
use crate::solvers::recycle::{AwPolicy, RecycleConfig};
use crate::solvers::strategy::StrategyChoice;
use crate::util::table::{Align, Table};

fn total_inner_iters(fit: &LaplaceFit) -> usize {
    fit.steps.iter().map(|s| s.solver_iterations).sum()
}

fn total_matvecs(fit: &LaplaceFit) -> usize {
    fit.steps.iter().map(|s| s.solver_matvecs).sum()
}

pub fn run_config(w: &Workload, o: &ExpOpts, rc: RecycleConfig) -> LaplaceFit {
    w.fit(SolverBackend::DefCg(rc), o)
}

pub fn run(o: &ExpOpts) {
    let w = Workload::build(o);
    let cg = w.fit(SolverBackend::Cg, o);
    let base_iters = total_inner_iters(&cg);
    println!("baseline CG: {base_iters} total inner iterations, {:.3}s\n", cg.total_solve_seconds());

    // (1) k sweep.
    let mut t = Table::new(
        &format!("Ablation 1 — recycled dimension k (ℓ={}, n={})", o.l, o.n),
        &["k", "inner iters", "matvecs", "vs CG", "time [s]"],
    )
    .align(0, Align::Left);
    for k in [0usize, 2, 4, 8, 12, 16] {
        let fit = if k == 0 {
            w.fit(SolverBackend::Cg, o)
        } else {
            run_config(&w, o, RecycleConfig { k, l: o.l, ..Default::default() })
        };
        let it = total_inner_iters(&fit);
        t.row(vec![
            format!("{k}"),
            format!("{it}"),
            format!("{}", total_matvecs(&fit)),
            format!("{:+.0}%", 100.0 * (it as f64 - base_iters as f64) / base_iters as f64),
            format!("{:.3}", fit.total_solve_seconds()),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv("ablation_k");

    // (2) ℓ sweep.
    let mut t = Table::new(
        &format!("Ablation 2 — stored iterations ℓ (k={}, n={})", o.k, o.n),
        &["l", "inner iters", "matvecs", "time [s]"],
    )
    .align(0, Align::Left);
    for l in [4usize, 8, 12, 16, 24] {
        let fit = run_config(&w, o, RecycleConfig { k: o.k, l, ..Default::default() });
        t.row(vec![
            format!("{l}"),
            format!("{}", total_inner_iters(&fit)),
            format!("{}", total_matvecs(&fit)),
            format!("{:.3}", fit.total_solve_seconds()),
        ]);
    }
    println!("{}", t.render());
    let _ = t.save_csv("ablation_l");

    // (3) AW policy + (4) Ritz end.
    let mut t = Table::new(
        &format!("Ablation 3/4 — AW policy × Ritz end (k={}, ℓ={})", o.k, o.l),
        &["policy", "ritz end", "inner iters", "matvecs", "converged steps"],
    )
    .align(0, Align::Left)
    .align(1, Align::Left);
    for (pol, pname) in [(AwPolicy::Refresh, "refresh"), (AwPolicy::Reuse, "reuse")] {
        for (sel, sname) in [
            (StrategyChoice::HarmonicLargest, "largest"),
            (StrategyChoice::RitzSmallest, "smallest"),
        ] {
            let fit = run_config(
                &w,
                o,
                RecycleConfig {
                    k: o.k,
                    l: o.l,
                    strategy: sel,
                    aw_policy: pol,
                    ..Default::default()
                },
            );
            let conv = fit
                .steps
                .iter()
                .filter(|s| s.residual_trace.last().map(|r| *r <= o.tol).unwrap_or(true))
                .count();
            t.row(vec![
                pname.to_string(),
                sname.to_string(),
                format!("{}", total_inner_iters(&fit)),
                format!("{}", total_matvecs(&fit)),
                format!("{}/{}", conv, fit.steps.len()),
            ]);
        }
    }
    println!("{}", t.render());
    let _ = t.save_csv("ablation_policy");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn largest_ritz_beats_smallest_on_gpc_spectrum() {
        // A = I + SKS has spectrum bounded below by 1 with a heavy top:
        // deflating the largest eigenvalues must help at least as much.
        let o = ExpOpts {
            n: 96,
            seed: 6,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-5,
            k: 6,
            l: 10,
            max_newton: 6,
            backend: "native".into(),
            fast: true,
        };
        let w = Workload::build(&o);
        let largest = run_config(
            &w,
            &o,
            RecycleConfig {
                k: 6,
                l: 10,
                strategy: StrategyChoice::HarmonicLargest,
                ..Default::default()
            },
        );
        let smallest = run_config(
            &w,
            &o,
            RecycleConfig {
                k: 6,
                l: 10,
                strategy: StrategyChoice::RitzSmallest,
                ..Default::default()
            },
        );
        assert!(
            total_inner_iters(&largest) <= total_inner_iters(&smallest),
            "largest {} > smallest {}",
            total_inner_iters(&largest),
            total_inner_iters(&smallest)
        );
    }

    #[test]
    fn bigger_k_does_not_hurt_iterations() {
        let o = ExpOpts {
            n: 96,
            seed: 6,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-5,
            k: 6,
            l: 12,
            max_newton: 6,
            backend: "native".into(),
            fast: true,
        };
        let w = Workload::build(&o);
        let k2 = run_config(&w, &o, RecycleConfig { k: 2, l: 12, ..Default::default() });
        let k8 = run_config(&w, &o, RecycleConfig { k: 8, l: 12, ..Default::default() });
        assert!(
            total_inner_iters(&k8) <= total_inner_iters(&k2) + 2,
            "k=8 {} much worse than k=2 {}",
            total_inner_iters(&k8),
            total_inner_iters(&k2)
        );
    }
}
