//! Shared experiment plumbing: workload construction and backend setup.

use crate::data::digits::{generate, Digits, DigitsConfig};
use crate::gp::kernel::RbfKernel;
use crate::gp::laplace::{
    DenseKernel, KernelOp, LaplaceConfig, LaplaceFit, LaplaceGpc, SolverBackend,
};
use crate::runtime::engine::{Engine, Tensor};
use crate::runtime::ops::EngineKernel;
use crate::solvers::recycle::RecycleConfig;
use crate::util::cli::{Args, Cli};
use std::sync::Arc;

/// Parsed experiment options (shared flag set across all experiments).
#[derive(Clone)]
pub struct ExpOpts {
    pub n: usize,
    pub seed: u64,
    pub amplitude: f64,
    pub lengthscale: f64,
    pub tol: f64,
    pub k: usize,
    pub l: usize,
    pub max_newton: usize,
    pub backend: String,
    pub fast: bool,
}

pub fn parse_args(program: &str, rest: &[String]) -> ExpOpts {
    let cli = Cli::new(program, "paper experiment (see DESIGN.md §5)")
        .opt("n", "512", "problem size (engine backend needs an artifact size)")
        .opt("seed", "0", "rng seed for the synthetic dataset")
        .opt("amp", "4.0", "RBF amplitude θ (4.0 puts the Newton systems in the paper's 20-60-iteration regime)")
        .opt("ls", "10.0", "RBF lengthscale λ")
        .opt("tol", "1e-5", "inner-solve relative tolerance")
        .opt("k", "8", "def-CG recycled subspace dimension")
        .opt("l", "12", "def-CG stored iterations ℓ")
        .opt("max-newton", "12", "Newton iteration cap")
        .opt("backend", "native", "compute backend: native | engine")
        .flag("fast", "shrink the workload for smoke runs");
    let args: Args = match cli.parse(rest) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(if e.0.contains("USAGE") { 0 } else { 2 });
        }
    };
    let fast = args.get_flag("fast");
    let mut n = args.get_usize("n");
    if fast && n > 128 {
        n = 128;
    }
    ExpOpts {
        n,
        seed: args.get_u64("seed"),
        amplitude: args.get_f64("amp"),
        lengthscale: args.get_f64("ls"),
        tol: args.get_f64("tol"),
        k: args.get_usize("k"),
        l: args.get_usize("l"),
        max_newton: args.get_usize("max-newton"),
        backend: args.get("backend").to_string(),
        fast,
    }
}

/// The GPC workload: dataset + kernel backend.
pub struct Workload {
    pub data: Digits,
    pub kernel: RbfKernel,
    backend: BackendImpl,
}

enum BackendImpl {
    Native(DenseKernel),
    Engine(EngineKernel),
}

impl Workload {
    /// Build the dataset and the kernel operator per `--backend`.
    pub fn build(o: &ExpOpts) -> Workload {
        let data = generate(&DigitsConfig { n: o.n, seed: o.seed, ..Default::default() });
        let kernel = RbfKernel::new(o.amplitude, o.lengthscale);
        let backend = match o.backend.as_str() {
            "engine" => {
                // PJRT artifacts when `make artifacts` has run (and the
                // `pjrt` feature is on); the built-in native engine with
                // the same call surface otherwise — runs fully offline.
                let eng = Arc::new(Engine::auto("artifacts"));
                // Say which backend actually serves the run: a silent
                // native fallback would mislabel timing comparisons.
                crate::log_info!("--backend engine resolved to: {}", eng.backend_name());
                assert!(
                    eng.manifest().sizes.contains(&o.n),
                    "engine backend: n={} not in artifact sizes {:?}",
                    o.n,
                    eng.manifest().sizes
                );
                let x32 = Tensor::mat(o.n, data.x.cols(), data.x.to_f32());
                BackendImpl::Engine(
                    EngineKernel::from_features(eng, &x32, o.amplitude, o.lengthscale)
                        .expect("gram build"),
                )
            }
            "native" => {
                let k = kernel.gram(&data.x);
                if o.n >= 512 {
                    // The ≥512-dim experiments shard the dense matvec
                    // across a machine-sized pool (ParDenseOp); results
                    // are bit-identical to the serial path.
                    let pool = Arc::new(crate::util::pool::ThreadPool::default_size());
                    BackendImpl::Native(DenseKernel::parallel(k, pool))
                } else {
                    BackendImpl::Native(DenseKernel::new(k))
                }
            }
            other => panic!("unknown backend '{other}' (native|engine)"),
        };
        Workload { data, kernel, backend }
    }

    pub fn kernel_op(&self) -> &dyn KernelOp {
        match &self.backend {
            BackendImpl::Native(k) => k,
            BackendImpl::Engine(k) => k,
        }
    }

    /// Dense K is required for the Cholesky baseline; on the engine
    /// backend it is downloaded once from device memory.
    pub fn dense_kernel(&self) -> DenseKernel {
        match &self.backend {
            BackendImpl::Native(k) => DenseKernel::new(k.dense().unwrap().clone()),
            BackendImpl::Engine(k) => {
                let t = k.download_gram().expect("download gram");
                DenseKernel::new(crate::linalg::mat::Mat::from_f32(
                    t.shape[0], t.shape[1], &t.data,
                ))
            }
        }
    }

    /// Run a full Laplace fit with the given solver backend.
    pub fn fit(&self, solver: SolverBackend, o: &ExpOpts) -> LaplaceFit {
        // The paper stops Newton at ΔΨ < 1 with n = 36 551; Ψ scales
        // linearly in n, so at our scaled-down sizes the equivalent
        // criterion is ΔΨ < n/36551 (clamped) — otherwise the sequence is
        // cut short and the recycling dynamics the figures show never
        // develop.
        let newton_tol = (o.n as f64 / 36_551.0).clamp(0.005, 1.0);
        let cfg = LaplaceConfig {
            solver,
            solve_tol: o.tol,
            newton_tol,
            max_newton: o.max_newton,
            max_solver_iters: 0,
        };
        match (&self.backend, &cfg.solver) {
            // Cholesky needs the dense matrix; hand it the dense kernel.
            (_, SolverBackend::Cholesky) => {
                let dk = self.dense_kernel();
                LaplaceGpc::new(&dk, &self.data.y, cfg).fit()
            }
            (BackendImpl::Native(k), _) => LaplaceGpc::new(k, &self.data.y, cfg).fit(),
            (BackendImpl::Engine(k), _) => LaplaceGpc::new(k, &self.data.y, cfg).fit(),
        }
    }

    /// The def-CG backend spec for these options.
    pub fn defcg_backend(&self, o: &ExpOpts) -> SolverBackend {
        SolverBackend::DefCg(RecycleConfig { k: o.k, l: o.l, ..Default::default() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(n: usize) -> ExpOpts {
        ExpOpts {
            n,
            seed: 0,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-5,
            k: 4,
            l: 8,
            max_newton: 8,
            backend: "native".into(),
            fast: true,
        }
    }

    #[test]
    fn workload_builds_and_fits_native() {
        let o = opts(64);
        let w = Workload::build(&o);
        assert_eq!(w.data.n(), 64);
        let fit = w.fit(SolverBackend::Cg, &o);
        assert!(!fit.steps.is_empty());
        assert!(fit.final_log_lik().is_finite());
    }

    #[test]
    fn parse_args_defaults() {
        let o = parse_args("t", &[]);
        assert_eq!(o.n, 512);
        assert_eq!(o.k, 8);
        assert_eq!(o.l, 12);
        assert_eq!(o.backend, "native");
    }

    #[test]
    fn fast_flag_caps_n() {
        let o = parse_args("t", &["--fast".to_string(), "--n".to_string(), "4096".to_string()]);
        assert!(o.fast);
        assert_eq!(o.n, 128);
    }
}
