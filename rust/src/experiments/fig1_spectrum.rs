//! Fig. 1: the spectrum of A vs the deflated operator P_W A.
//!
//! The paper's figure visualizes "implicit preconditioning": after solving
//! the first system with plain CG and extracting W (harmonic Ritz vectors
//! of the largest eigenvalues), applying the projector
//! `P_W = I − AW(WᵀAW)⁻¹Wᵀ` removes the top-k eigenvalues of A while
//! leaving the remainder untouched. We reproduce it by computing the dense
//! spectra of `A` and `P_W A` (which is symmetric: `(P_W A)ᵀ = P_W A` for
//! symmetric A) on a moderate-n GPC system.

use crate::experiments::common::{ExpOpts, Workload};
use crate::experiments::plot::{render as plot, Series};
use crate::gp::likelihood::Logistic;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::eig::sym_eig;
use crate::linalg::mat::Mat;
use crate::solvers::ritz::{extract, RitzConfig, RitzSelect};
use crate::solvers::{self, DenseOp, SolveSpec};
use crate::util::table::{sci, Align, Table};

pub struct Fig1Result {
    /// Eigenvalues of A, ascending.
    pub spectrum_a: Vec<f64>,
    /// Eigenvalues of P_W A, ascending.
    pub spectrum_pa: Vec<f64>,
    pub k: usize,
    /// κ(A) and κ_eff(P_W A) restricted to the non-deflated part.
    pub kappa: f64,
    pub kappa_eff: f64,
}

pub fn compute(w: &Workload, o: &ExpOpts) -> Fig1Result {
    // Build the first Newton system's A = I + SKS at f = 0 (H = I/4).
    let n = o.n;
    let dense = w.dense_kernel();
    let k_mat = {
        use crate::gp::laplace::KernelOp;
        dense.dense().expect("dense kernel").clone()
    };
    let lik = Logistic;
    let f0 = vec![0.0; n];
    let mut h = vec![0.0; n];
    lik.hess_diag(&f0, &mut h);
    let s: Vec<f64> = h.iter().map(|v| v.sqrt()).collect();
    let mut a = Mat::from_fn(n, n, |i, j| s[i] * k_mat[(i, j)] * s[j]);
    a.add_diag(1.0);

    // First solve with plain CG, storing ℓ directions; extract k Ritz
    // vectors for the largest eigenvalues (the paper's Fig. 1 choice).
    let b: Vec<f64> = w.data.y.iter().map(|&v| v * 0.5).collect();
    let spec = SolveSpec::cg().with_tol(o.tol).with_store_l(o.l);
    let r = solvers::solve(&DenseOp::new(&a), &b, &spec);
    let (defl, _) = extract(
        None,
        &r.stored,
        n,
        &RitzConfig { k: o.k, select: RitzSelect::Largest, min_col_norm: 1e-12 },
    )
    .expect("ritz extraction");

    // P_W A = A − AW (WᵀAW)⁻¹ (AW)ᵀ  (symmetric).
    let wtaw = {
        let mut m = defl.w.t_matmul(&defl.aw);
        m.symmetrize();
        m
    };
    let ch = Cholesky::factor(&wtaw).expect("WᵀAW SPD");
    // M = AW (WᵀAW)⁻¹ (AW)ᵀ
    let solved = ch.solve_mat(&defl.aw.transpose()); // (k × n)
    let m = defl.aw.matmul(&solved);
    let mut pa = a.clone();
    for i in 0..n {
        for j in 0..n {
            pa[(i, j)] -= m[(i, j)];
        }
    }
    pa.symmetrize();

    let spectrum_a = sym_eig(&a).expect("eig A").values;
    let spectrum_pa = sym_eig(&pa).expect("eig PA").values;

    let kappa = spectrum_a[n - 1] / spectrum_a[0];
    // Effective condition number of the deflated operator: the k deflated
    // directions have eigenvalue ≈ 0 and sort to the *bottom* of spec(P A);
    // κ_eff is max/min over the surviving (non-near-zero) part.
    let top_pa = spectrum_pa[n - 1];
    let surviving: Vec<f64> = spectrum_pa
        .iter()
        .copied()
        .filter(|&v| v > 1e-8 * top_pa)
        .collect();
    let kappa_eff = if surviving.is_empty() {
        f64::NAN
    } else {
        surviving[surviving.len() - 1] / surviving[0]
    };
    Fig1Result { spectrum_a, spectrum_pa, k: defl.k(), kappa, kappa_eff }
}

pub fn run(o: &ExpOpts) {
    // Dense eigendecompositions: cap n for tractability.
    let mut o2 = o.clone();
    if o2.n > 384 && !o2.fast {
        o2.n = 384;
    }
    let w = Workload::build(&o2);
    let r = compute(&w, &o2);
    let n = r.spectrum_a.len();

    // Chart: eigenvalue index vs log10 eigenvalue, both spectra.
    let sa = Series::new(
        "spec(A)",
        '*',
        r.spectrum_a.iter().enumerate().map(|(i, &v)| (i as f64, v)).collect(),
    );
    let sp = Series::new(
        "spec(P_W A)",
        'o',
        r.spectrum_pa.iter().enumerate().map(|(i, &v)| (i as f64, v.max(1e-16))).collect(),
    );
    println!(
        "{}",
        plot(
            &format!("Fig 1 — deflation removes the top-{} eigenvalues (n={})", r.k, n),
            &[sa, sp],
            72,
            20,
            true
        )
    );
    println!(
        "κ(A) = {:.3e}   κ_eff(P_W A) = {:.3e}   (improvement ×{:.1})",
        r.kappa,
        r.kappa_eff,
        r.kappa / r.kappa_eff.max(1e-300)
    );

    let mut t = Table::new("Fig 1 data — top of the spectra", &["idx", "λ(A)", "λ(P_W A)"])
        .align(0, Align::Left);
    for i in (n.saturating_sub(2 * r.k))..n {
        t.row(vec![
            format!("{i}"),
            sci(r.spectrum_a[i]),
            sci(r.spectrum_pa[i]),
        ]);
    }
    println!("{}", t.render());
    let mut full = Table::new("", &["idx", "lambda_a", "lambda_pa"]);
    for i in 0..n {
        full.row(vec![
            format!("{i}"),
            format!("{:e}", r.spectrum_a[i]),
            format!("{:e}", r.spectrum_pa[i]),
        ]);
    }
    if let Ok(p) = full.save_csv("fig1_spectrum") {
        println!("(csv: {})", p.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deflation_removes_top_eigenvalues_only() {
        let o = ExpOpts {
            n: 80,
            seed: 2,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-8,
            k: 6,
            l: 12,
            max_newton: 1,
            backend: "native".into(),
            fast: true,
        };
        let w = Workload::build(&o);
        let r = compute(&w, &o);
        let n = r.spectrum_a.len();
        assert!(r.k > 0);

        // (1) The top-k eigenvalues of P_W A are far below the top of A:
        // they were "removed" (sent to ~0, below the spectrum's floor 1).
        let top_a = r.spectrum_a[n - 1];
        // P A has k near-zero eigenvalues (the deflated directions).
        let near_zero = r
            .spectrum_pa
            .iter()
            .filter(|&&v| v.abs() < 1e-6 * top_a)
            .count();
        assert!(
            near_zero >= r.k,
            "expected ≥{} near-zero eigenvalues, found {near_zero}",
            r.k
        );

        // (2) The bottom of the spectrum is untouched: A's smallest
        // eigenvalue (≥ 1 by construction) survives in P_W A.
        let bottom_pa = r
            .spectrum_pa
            .iter()
            .copied()
            .filter(|v| *v > 1e-6 * top_a)
            .fold(f64::MAX, f64::min);
        assert!(
            (bottom_pa - r.spectrum_a[0]).abs() / r.spectrum_a[0] < 0.05,
            "bottom moved: {} vs {}",
            bottom_pa,
            r.spectrum_a[0]
        );

        // (3) Effective condition number improves.
        assert!(r.kappa_eff < r.kappa, "κ_eff {} !< κ {}", r.kappa_eff, r.kappa);
    }
}
