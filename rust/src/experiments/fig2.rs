//! Fig. 2 — Left: CPU time per Newton iteration (Cholesky / CG / def-CG).
//!          Right: inner iterations per system (CG vs def-CG).
//!
//! Paper's reading: per-iteration time of the iterative solvers falls as
//! the Newton optimizer converges (systems get easier); def-CG saves ≥12
//! iterations (~25%) per system from the second system on; savings
//! stagnate late in the sequence.

use crate::experiments::common::{ExpOpts, Workload};
use crate::experiments::plot::{render as plot, Series};
use crate::experiments::table1;
use crate::util::table::{fix, Align, Table};

pub fn run(o: &ExpOpts) {
    let w = Workload::build(o);
    let r = table1::compute(&w, o);

    // Left panel: per-iteration solve time.
    let series_time: Vec<Series> = [
        ("cholesky", '#', &r.chol),
        ("cg", '*', &r.cg),
        ("def-cg", 'o', &r.defcg),
    ]
    .into_iter()
    .map(|(name, m, fit)| {
        Series::new(
            name,
            m,
            fit.steps
                .iter()
                .map(|s| (s.newton_iter as f64, s.solve_seconds.max(1e-9)))
                .collect(),
        )
    })
    .collect();
    println!(
        "{}",
        plot(
            &format!("Fig 2 (left) — solve seconds per Newton iteration, n={}", o.n),
            &series_time,
            64,
            16,
            true
        )
    );

    // Right panel: inner iterations per system.
    let series_iters: Vec<Series> = [("cg", '*', &r.cg), ("def-cg", 'o', &r.defcg)]
        .into_iter()
        .map(|(name, m, fit)| {
            Series::new(
                name,
                m,
                fit.steps
                    .iter()
                    .map(|s| (s.newton_iter as f64, s.solver_iterations as f64))
                    .collect(),
            )
        })
        .collect();
    println!(
        "{}",
        plot(
            "Fig 2 (right) — inner iterations per system (tol 1e-5)",
            &series_iters,
            64,
            16,
            false
        )
    );

    // Numeric table + CSV.
    let mut t = Table::new(
        "Fig 2 data",
        &["It.", "chol t[s]", "cg t[s]", "defcg t[s]", "cg iters", "defcg iters", "saved", "saved %"],
    )
    .align(0, Align::Left);
    let rows = r.cg.steps.len().max(r.defcg.steps.len()).max(r.chol.steps.len());
    let mut total_saved = 0isize;
    for i in 0..rows {
        let ct = r.chol.steps.get(i).map(|s| fix(s.solve_seconds, 4)).unwrap_or("-".into());
        let gt = r.cg.steps.get(i).map(|s| fix(s.solve_seconds, 4)).unwrap_or("-".into());
        let dt = r.defcg.steps.get(i).map(|s| fix(s.solve_seconds, 4)).unwrap_or("-".into());
        let gi = r.cg.steps.get(i).map(|s| s.solver_iterations);
        let di = r.defcg.steps.get(i).map(|s| s.solver_iterations);
        let (saved, pct) = match (gi, di) {
            (Some(g), Some(d)) => {
                let s = g as isize - d as isize;
                total_saved += s;
                (format!("{s}"), format!("{:.0}%", 100.0 * s as f64 / g.max(1) as f64))
            }
            _ => ("-".into(), "-".into()),
        };
        t.row(vec![
            format!("{}", i + 1),
            ct,
            gt,
            dt,
            gi.map(|v| v.to_string()).unwrap_or("-".into()),
            di.map(|v| v.to_string()).unwrap_or("-".into()),
            saved,
            pct,
        ]);
    }
    println!("{}", t.render());
    println!("total inner iterations saved by recycling: {total_saved}");
    if let Ok(p) = t.save_csv("fig2") {
        println!("(csv: {})", p.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::table1::compute;

    #[test]
    fn defcg_saves_iterations_after_first_system() {
        let o = ExpOpts {
            n: 96,
            seed: 3,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-5,
            k: 6,
            l: 10,
            max_newton: 8,
            backend: "native".into(),
            fast: true,
        };
        let w = Workload::build(&o);
        let r = compute(&w, &o);
        // The paper's Fig 2 (right): def-CG needs fewer iterations than CG
        // for systems 2.. (system 1 is identical).
        assert_eq!(
            r.cg.steps[0].solver_iterations,
            r.defcg.steps[0].solver_iterations,
            "first systems must match"
        );
        let n_steps = r.cg.steps.len().min(r.defcg.steps.len());
        let mut saved = 0isize;
        for i in 1..n_steps {
            saved += r.cg.steps[i].solver_iterations as isize
                - r.defcg.steps[i].solver_iterations as isize;
        }
        assert!(saved > 0, "no net iteration saving ({saved})");
    }
}
