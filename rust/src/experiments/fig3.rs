//! Fig. 3 — relative-residual convergence traces at tol 1e-8.
//!
//! One curve per (solver, Newton system): the paper shows def-CG's curves
//! are *steeper* (faster asymptotic rate), not merely shifted down by the
//! initial projection — i.e. deflation genuinely lowers the effective
//! condition number. The x-axis is cumulative inner iteration count so
//! consecutive systems line up left-to-right.

use crate::experiments::common::{ExpOpts, Workload};
use crate::experiments::plot::{render as plot, Series};
use crate::gp::laplace::{LaplaceFit, SolverBackend};
use crate::util::table::Table;

pub fn run(o: &ExpOpts) {
    // Fig 3 uses the tight tolerance; force it unless the user overrode.
    let mut o2 = o.clone();
    if o2.tol > 1e-8 {
        o2.tol = 1e-8;
    }
    if o2.backend == "engine" {
        // f32 artifacts cannot reach 1e-8; the paper's precision experiment
        // runs on the f64 native path (see runtime::ops doc).
        crate::log_warn!("fig3 at tol 1e-8 requires f64: switching to native backend");
        o2.backend = "native".into();
    }
    let w = Workload::build(&o2);
    let cg = w.fit(SolverBackend::Cg, &o2);
    let defcg = w.fit(w.defcg_backend(&o2), &o2);

    let series = |fit: &LaplaceFit, name: &str, marker: char| -> Series {
        let mut pts = Vec::new();
        let mut offset = 0usize;
        for s in &fit.steps {
            for (j, &res) in s.residual_trace.iter().enumerate() {
                pts.push(((offset + j) as f64, res.max(1e-16)));
            }
            offset += s.residual_trace.len();
        }
        Series::new(name, marker, pts)
    };
    println!(
        "{}",
        plot(
            &format!(
                "Fig 3 — relative residual per inner iteration across {} Newton systems (tol 1e-8, n={})",
                cg.steps.len(),
                o2.n
            ),
            &[series(&cg, "cg", '*'), series(&defcg, "def-cg", 'o')],
            76,
            22,
            true
        )
    );

    // Per-system convergence-rate table: mean log10 residual reduction per
    // iteration (the "slope" the paper points at).
    let slope = |s: &crate::gp::laplace::NewtonStepStats| -> f64 {
        let tr = &s.residual_trace;
        if tr.len() < 2 {
            return 0.0;
        }
        let first = tr.first().unwrap().max(1e-300);
        let last = tr.last().unwrap().max(1e-300);
        (last / first).log10() / (tr.len() - 1) as f64
    };
    let mut t = Table::new(
        "Fig 3 data — per-system iterations and slopes",
        &["system", "cg iters", "cg slope", "defcg iters", "defcg slope"],
    );
    let rows = cg.steps.len().min(defcg.steps.len());
    for i in 0..rows {
        t.row(vec![
            format!("{}", i + 1),
            format!("{}", cg.steps[i].solver_iterations),
            format!("{:.4}", slope(&cg.steps[i])),
            format!("{}", defcg.steps[i].solver_iterations),
            format!("{:.4}", slope(&defcg.steps[i])),
        ]);
    }
    println!("{}", t.render());
    if let Ok(p) = t.save_csv("fig3") {
        println!("(csv: {})", p.display());
    }

    // Full traces to CSV for external plotting.
    let mut traces = Table::new("", &["solver", "system", "iter", "rel_residual"]);
    for (name, fit) in [("cg", &cg), ("defcg", &defcg)] {
        for (sys, s) in fit.steps.iter().enumerate() {
            for (j, &res) in s.residual_trace.iter().enumerate() {
                traces.row(vec![
                    name.to_string(),
                    format!("{}", sys + 1),
                    format!("{j}"),
                    format!("{res:e}"),
                ]);
            }
        }
    }
    if let Ok(p) = traces.save_csv("fig3_traces") {
        println!("(csv: {})", p.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defcg_converges_steeper_than_cg_at_tight_tol() {
        let o = ExpOpts {
            n: 96,
            seed: 4,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-8,
            k: 6,
            l: 10,
            max_newton: 6,
            backend: "native".into(),
            fast: true,
        };
        let w = Workload::build(&o);
        let cg = w.fit(SolverBackend::Cg, &o);
        let defcg = w.fit(w.defcg_backend(&o), &o);
        // Average per-iteration log-reduction over systems 2..: def-CG's
        // slope must be at least as steep (more negative).
        let mean_slope = |fit: &LaplaceFit| -> f64 {
            let mut s = 0.0;
            let mut c = 0;
            for step in fit.steps.iter().skip(1) {
                let tr = &step.residual_trace;
                if tr.len() >= 2 {
                    s += (tr.last().unwrap().max(1e-300) / tr[0].max(1e-300)).log10()
                        / (tr.len() - 1) as f64;
                    c += 1;
                }
            }
            s / c.max(1) as f64
        };
        let (sc, sd) = (mean_slope(&cg), mean_slope(&defcg));
        assert!(
            sd <= sc + 1e-6,
            "def-cg slope {sd} not steeper than cg slope {sc}"
        );
        // And all residual traces end below tolerance.
        for s in cg.steps.iter().chain(defcg.steps.iter()) {
            assert!(s.residual_trace.last().unwrap() <= &1e-8);
        }
    }
}
