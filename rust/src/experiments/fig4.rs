//! Fig. 4 — accuracy vs cumulative solve cost: iterative methods against
//! subset-of-data (inducing-point) approximations.
//!
//! Each dot is one Newton iterate of one method; x = cumulative CPU time
//! spent in the linear solves, y = relative error of log p(y|f) against
//! the exact value (Cholesky on the full set at convergence). Expected
//! shape: subsets are fast but plateau at a finite error (orders of
//! magnitude above the iterative solvers); CG/def-CG cost about as much
//! as a 25–50% subset but reach ~machine-precision-of-tolerance accuracy.

use crate::experiments::common::{ExpOpts, Workload};
use crate::experiments::plot::{render as plot, Series};
use crate::gp::inducing::run_subset;
use crate::gp::laplace::{LaplaceFit, SolverBackend};
use crate::util::rng::Rng;
use crate::util::table::{sci, Align, Table};

/// Subset fractions, as in the paper's Fig. 4 (percentages of n).
pub const FRACTIONS: [f64; 4] = [0.05, 0.10, 0.25, 0.50];

pub fn run(o: &ExpOpts) {
    let w = Workload::build(o);

    // Exact reference: full-data Cholesky to convergence.
    let exact = w.fit(SolverBackend::Cholesky, o);
    let exact_ll = exact.final_log_lik();
    crate::log_info!("fig4: exact log p(y|f) = {exact_ll:.4}");

    let rel = |ll: f64| ((ll - exact_ll).abs() / exact_ll.abs()).max(1e-16);

    // Iterative trajectories.
    let traj = |fit: &LaplaceFit| -> Vec<(f64, f64)> {
        fit.steps
            .iter()
            .map(|s| (s.cumulative_seconds.max(1e-9), rel(s.log_lik)))
            .collect()
    };
    let cg = w.fit(SolverBackend::Cg, o);
    let defcg = w.fit(w.defcg_backend(o), o);
    let mut series = vec![
        Series::new("cg", '*', traj(&cg)),
        Series::new("def-cg", 'o', traj(&defcg)),
        Series::new("cholesky", '#', traj(&exact)),
    ];

    // Subset baselines.
    let markers = ['a', 'b', 'c', 'd'];
    let mut table = Table::new(
        &format!("Fig 4 data — final accuracy vs cost (n={}, exact ll={:.3})", o.n, exact_ll),
        &["method", "final rel.err", "cum. solve t [s]"],
    )
    .align(0, Align::Left);
    for (fi, &frac) in FRACTIONS.iter().enumerate() {
        let m = ((o.n as f64 * frac).round() as usize).max(4);
        let mut rng = Rng::new(o.seed + 1000 + fi as u64);
        let sub = run_subset(&w.data, &w.kernel, m, o.max_newton, &mut rng);
        let pts: Vec<(f64, f64)> = sub
            .trajectory
            .iter()
            .map(|p| (p.cumulative_seconds.max(1e-9), rel(p.full_log_lik)))
            .collect();
        if let Some(last) = pts.last() {
            table.row(vec![
                format!("subset m={m} ({:.0}%)", frac * 100.0),
                sci(last.1),
                format!("{:.4}", last.0),
            ]);
        }
        series.push(Series::new(&format!("subset {:.0}%", frac * 100.0), markers[fi], pts));
    }
    for (name, fit) in [("cg", &cg), ("def-cg", &defcg), ("cholesky", &exact)] {
        if let Some(s) = fit.steps.last() {
            table.row(vec![
                name.to_string(),
                sci(rel(s.log_lik)),
                format!("{:.4}", s.cumulative_seconds),
            ]);
        }
    }

    println!(
        "{}",
        plot(
            "Fig 4 — rel. error of log p(y|f) vs cumulative solve time (log y)",
            &series,
            76,
            22,
            true
        )
    );
    println!("{}", table.render());
    if let Ok(p) = table.save_csv("fig4") {
        println!("(csv: {})", p.display());
    }

    // All trajectory dots to CSV.
    let mut dots = Table::new("", &["method", "newton_iter", "seconds", "rel_err"]);
    let mut put = |name: &str, pts: &[(f64, f64)]| {
        for (i, (t, e)) in pts.iter().enumerate() {
            dots.row(vec![
                name.to_string(),
                format!("{}", i + 1),
                format!("{t:e}"),
                format!("{e:e}"),
            ]);
        }
    };
    for s in &series {
        put(&s.name, &s.points);
    }
    if let Ok(p) = dots.save_csv("fig4_dots") {
        println!("(csv: {})", p.display());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iterative_beats_subsets_on_accuracy() {
        let o = ExpOpts {
            n: 96,
            seed: 5,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-6,
            k: 4,
            l: 8,
            max_newton: 10,
            backend: "native".into(),
            fast: true,
        };
        let w = Workload::build(&o);
        let exact = w.fit(SolverBackend::Cholesky, &o);
        let exact_ll = exact.final_log_lik();
        let cg = w.fit(SolverBackend::Cg, &o);
        let cg_err = (cg.final_log_lik() - exact_ll).abs() / exact_ll.abs();

        let mut rng = Rng::new(7);
        let sub = run_subset(&w.data, &w.kernel, 10, 10, &mut rng);
        let sub_err =
            (sub.trajectory.last().unwrap().full_log_lik - exact_ll).abs() / exact_ll.abs();

        // The paper's headline (Fig 4): iterative full-data methods are
        // orders of magnitude more accurate than small subsets.
        assert!(
            cg_err * 100.0 < sub_err,
            "cg err {cg_err} not ≪ subset err {sub_err}"
        );
    }
}
