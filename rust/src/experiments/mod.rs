//! Experiment harness: one module per table/figure of the paper.
//!
//! | paper artifact | module | CLI |
//! |---|---|---|
//! | Table 1 (Cholesky vs CG vs def-CG per Newton iter) | [`table1`] | `krr table1` |
//! | Fig. 1 (spectrum of A vs deflated P_W A) | [`fig1_spectrum`] | `krr fig1` |
//! | Fig. 2 (time per Newton iter; iterations per system) | [`fig2`] | `krr fig2` |
//! | Fig. 3 (residual traces at tol 1e-8) | [`fig3`] | `krr fig3` |
//! | Fig. 4 (accuracy vs cost incl. subset baselines) | [`fig4`] | `krr fig4` |
//! | ablations (k, ℓ, AW policy, Ritz end) | [`ablation`] | `krr ablation` |
//!
//! Each experiment prints aligned tables (and ASCII charts for the
//! figures) and writes CSV under `results/`.

pub mod ablation;
pub mod common;
pub mod fig1_spectrum;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod plot;
pub mod table1;

use crate::util::cli::Cli;

/// Binary entry point (dispatches subcommands).
pub fn cli_main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    match cmd {
        "table1" => table1::run(&common::parse_args("krr table1", &rest)),
        "fig1" => fig1_spectrum::run(&common::parse_args("krr fig1", &rest)),
        "fig2" => fig2::run(&common::parse_args("krr fig2", &rest)),
        "fig3" => fig3::run(&common::parse_args("krr fig3", &rest)),
        "fig4" => fig4::run(&common::parse_args("krr fig4", &rest)),
        "ablation" => ablation::run(&common::parse_args("krr ablation", &rest)),
        "demo-digits" => demo_digits(&rest),
        "serve-demo" => serve_demo(),
        _ => {
            eprintln!(
                "krr — Krylov subspace recycling for sequences of SPD systems\n\
                 \n\
                 USAGE: krr <command> [options]   (each command accepts --help)\n\
                 \n\
                 COMMANDS:\n\
                 \x20 table1       reproduce Table 1 (Cholesky vs CG vs def-CG)\n\
                 \x20 fig1         reproduce Fig. 1 (deflated spectrum)\n\
                 \x20 fig2         reproduce Fig. 2 (cost & iterations per Newton step)\n\
                 \x20 fig3         reproduce Fig. 3 (residual convergence, tol 1e-8)\n\
                 \x20 fig4         reproduce Fig. 4 (accuracy vs cost, subset baselines)\n\
                 \x20 ablation     k/ℓ/policy sweeps beyond the paper\n\
                 \x20 demo-digits  render synthetic infinite-MNIST samples\n\
                 \x20 serve-demo   run the concurrent solve-service demo"
            );
            std::process::exit(2);
        }
    }
}

fn demo_digits(rest: &[String]) {
    let cli = Cli::new("krr demo-digits", "render synthetic digits as ASCII art")
        .opt("n", "4", "number of samples")
        .opt("seed", "0", "rng seed");
    let args = match cli.parse(rest) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let ds = crate::data::digits::generate(&crate::data::digits::DigitsConfig {
        n: args.get_usize("n"),
        seed: args.get_u64("seed"),
        ..Default::default()
    });
    for i in 0..ds.n() {
        println!(
            "label: {}\n{}",
            if ds.y[i] > 0.0 { "3 (+1)" } else { "5 (-1)" },
            crate::data::digits::ascii_art(ds.x.row(i))
        );
    }
}

fn serve_demo() {
    use crate::coordinator::SolveService;
    use crate::linalg::mat::Mat;
    use crate::solvers::recycle::RecycleConfig;
    use crate::solvers::{SolveSpec, SpdOperator};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    struct Owned(Mat);
    impl SpdOperator for Owned {
        fn n(&self) -> usize {
            self.0.rows()
        }
        fn matvec(&self, x: &[f64], y: &mut [f64]) {
            self.0.matvec_into(x, y);
        }
    }

    let svc = SolveService::new(4);
    println!("solve-service demo: 4 concurrent sequences × 6 systems each");
    let mut handles = Vec::new();
    for s in 0..4u64 {
        let seq = svc.open_sequence(RecycleConfig::default());
        let mut rng = Rng::new(s);
        let op = Arc::new(Owned(Mat::rand_spd(200, 1e5, &mut rng)));
        let tickets: Vec<_> = (0..6)
            .map(|i| {
                let b: Vec<f64> = (0..200).map(|j| ((i + j) % 9) as f64 + 1.0).collect();
                seq.submit(op.clone(), b, None, SolveSpec::defcg().with_tol(1e-6))
            })
            .collect();
        handles.push((seq, tickets));
    }
    for (s, (seq, tickets)) in handles.into_iter().enumerate() {
        let iters: Vec<usize> = tickets.into_iter().map(|t| t.wait().iterations).collect();
        println!("  sequence {s}: iterations/system = {iters:?} (k={})", seq.k_active());
    }
    let m = svc.metrics().snapshot();
    println!(
        "metrics: {}/{} solves completed, {} matvecs, {:.3}s busy / {:.3}s span, {} active sequences",
        m.completed, m.submitted, m.total_matvecs, m.busy_seconds, m.span_seconds,
        m.active_sequences
    );
}
