//! ASCII charts for the "figure" experiments.
//!
//! Renders one or more named series as a terminal line chart — enough to
//! see the paper's qualitative shapes (slopes, crossovers) directly in the
//! test log, with the exact numbers in the accompanying CSV.

/// A named data series (x, y).
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
    pub marker: char,
}

impl Series {
    pub fn new(name: &str, marker: char, points: Vec<(f64, f64)>) -> Series {
        Series { name: name.to_string(), points, marker }
    }
}

/// Render series into an ASCII grid. `log_y` plots log10(y).
pub fn render(title: &str, series: &[Series], width: usize, height: usize, log_y: bool) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for &(x, y) in &s.points {
            let y = if log_y { y.max(1e-300).log10() } else { y };
            if x.is_finite() && y.is_finite() {
                pts.push((x, y));
            }
        }
    }
    if pts.is_empty() {
        return format!("## {title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for &(x, y) in &s.points {
            let yv = if log_y { y.max(1e-300).log10() } else { y };
            if !x.is_finite() || !yv.is_finite() {
                continue;
            }
            let col = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let row = ((ymax - yv) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = s.marker;
        }
    }
    let ylab = |v: f64| -> String {
        if log_y {
            format!("1e{v:+.0}")
        } else {
            format!("{v:9.3}")
        }
    };
    let mut out = format!("## {title}\n");
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - (ymax - ymin) * i as f64 / (height - 1) as f64;
        let lab = if i % 3 == 0 { ylab(yv) } else { String::new() };
        out.push_str(&format!("{lab:>9} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "{:>9} +{}+\n{:>9}  x: [{:.3} .. {:.3}]   ",
        "", "-".repeat(width), "", xmin, xmax
    ));
    for s in series {
        out.push_str(&format!("{}={}  ", s.marker, s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_two_series() {
        let a = Series::new("cg", '*', (0..20).map(|i| (i as f64, (20 - i) as f64)).collect());
        let b = Series::new("defcg", 'o', (0..20).map(|i| (i as f64, (20 - i) as f64 / 2.0)).collect());
        let s = render("test chart", &[a, b], 40, 10, false);
        assert!(s.contains("*"));
        assert!(s.contains("o"));
        assert!(s.contains("cg"));
        assert_eq!(s.lines().count(), 10 + 3);
    }

    #[test]
    fn log_scale_renders_exponents() {
        let a = Series::new(
            "resid",
            '*',
            (0..10).map(|i| (i as f64, 10f64.powi(-i))).collect(),
        );
        let s = render("log chart", &[a], 30, 8, true);
        assert!(s.contains("1e"), "{s}");
    }

    #[test]
    fn empty_series_safe() {
        let s = render("empty", &[Series::new("x", '*', vec![])], 10, 5, false);
        assert!(s.contains("no data"));
    }

    #[test]
    fn constant_series_safe() {
        let a = Series::new("c", '*', vec![(1.0, 5.0), (2.0, 5.0)]);
        let s = render("const", &[a], 20, 6, false);
        assert!(s.contains('*'));
    }
}
