//! Table 1: Cholesky vs CG vs def-CG(k, ℓ) across Newton iterations.
//!
//! For every Newton iteration the paper reports, per solver:
//! `log p(y|f)`, the relative error δ of that log-likelihood against the
//! Cholesky (exact) value at the same iteration, and cumulative solve
//! time. Expected shape: iterative ≪ direct in time; def-CG < CG in time
//! and inner iterations from the second system on; δ ~ 1e-3 at tol 1e-5.

use crate::experiments::common::{ExpOpts, Workload};
use crate::gp::laplace::{LaplaceFit, SolverBackend};
use crate::util::table::{fix, sci, Align, Table};

pub struct Table1Result {
    pub chol: LaplaceFit,
    pub cg: LaplaceFit,
    pub defcg: LaplaceFit,
}

pub fn compute(w: &Workload, o: &ExpOpts) -> Table1Result {
    crate::log_info!("table1: n={} backend={} tol={}", o.n, o.backend, o.tol);
    let chol = w.fit(SolverBackend::Cholesky, o);
    let cg = w.fit(SolverBackend::Cg, o);
    let defcg = w.fit(w.defcg_backend(o), o);
    Table1Result { chol, cg, defcg }
}

pub fn render(r: &Table1Result, o: &ExpOpts) -> Table {
    let mut t = Table::new(
        &format!(
            "Table 1 — GPC Newton progress, n={}, tol={:.0e}, def-CG(k={}, l={})",
            o.n, o.tol, o.k, o.l
        ),
        &[
            "It.",
            "chol log p(y|f)",
            "chol t[s]",
            "cg log p(y|f)",
            "cg δ",
            "cg t[s]",
            "defcg log p(y|f)",
            "defcg δ",
            "defcg t[s]",
        ],
    )
    .align(0, Align::Left);
    let rows = r.chol.steps.len().max(r.cg.steps.len()).max(r.defcg.steps.len());
    for i in 0..rows {
        let cell = |fit: &LaplaceFit, f: &dyn Fn(&crate::gp::laplace::NewtonStepStats) -> String| {
            fit.steps.get(i).map(|s| f(s)).unwrap_or_else(|| "-".into())
        };
        let chol_ll = r.chol.steps.get(i).map(|s| s.log_lik);
        let delta = |fit: &LaplaceFit| -> String {
            match (fit.steps.get(i), chol_ll) {
                (Some(s), Some(c)) => sci((s.log_lik - c).abs() / c.abs()),
                _ => "-".into(),
            }
        };
        t.row(vec![
            format!("{}", i + 1),
            cell(&r.chol, &|s| fix(s.log_lik, 3)),
            cell(&r.chol, &|s| fix(s.cumulative_seconds, 3)),
            cell(&r.cg, &|s| fix(s.log_lik, 3)),
            delta(&r.cg),
            cell(&r.cg, &|s| fix(s.cumulative_seconds, 3)),
            cell(&r.defcg, &|s| fix(s.log_lik, 3)),
            delta(&r.defcg),
            cell(&r.defcg, &|s| fix(s.cumulative_seconds, 3)),
        ]);
    }
    t
}

pub fn run(o: &ExpOpts) {
    let w = Workload::build(o);
    let r = compute(&w, o);
    let t = render(&r, o);
    println!("{}", t.render());
    if let Ok(p) = t.save_csv("table1") {
        println!("(csv: {})", p.display());
    }
    // Headline summary mirroring the paper's reading of the table.
    let sum_iters = |f: &LaplaceFit| f.steps.iter().map(|s| s.solver_iterations).sum::<usize>();
    println!(
        "\nsummary: chol {:.3}s | cg {:.3}s ({} inner iters) | defcg {:.3}s ({} inner iters)",
        r.chol.total_solve_seconds(),
        r.cg.total_solve_seconds(),
        sum_iters(&r.cg),
        r.defcg.total_solve_seconds(),
        sum_iters(&r.defcg),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> ExpOpts {
        ExpOpts {
            n: 96,
            seed: 1,
            amplitude: 1.0,
            lengthscale: 10.0,
            tol: 1e-5,
            k: 6,
            l: 10,
            max_newton: 8,
            backend: "native".into(),
            fast: true,
        }
    }

    #[test]
    fn table1_shapes_hold_at_small_n() {
        let o = opts();
        let w = Workload::build(&o);
        let r = compute(&w, &o);
        // All three converge to nearly the same final log-likelihood.
        let (c, g, d) = (
            r.chol.final_log_lik(),
            r.cg.final_log_lik(),
            r.defcg.final_log_lik(),
        );
        assert!((g - c).abs() / c.abs() < 1e-2, "cg {g} vs chol {c}");
        assert!((d - c).abs() / c.abs() < 1e-2, "defcg {d} vs chol {c}");
        // def-CG must use no more inner iterations than CG in total
        // (strictly fewer from the second system on).
        let cg_iters: usize = r.cg.steps.iter().skip(1).map(|s| s.solver_iterations).sum();
        let def_iters: usize = r.defcg.steps.iter().skip(1).map(|s| s.solver_iterations).sum();
        assert!(def_iters <= cg_iters, "defcg {def_iters} > cg {cg_iters}");
        // Rendered table has one row per Newton iteration.
        let t = render(&r, &o);
        assert!(t.n_rows() >= 2);
    }
}
