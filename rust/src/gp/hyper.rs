//! Hyperparameter adaptation — the paper's *outer* loop (§1, §3).
//!
//! "The outer loop will find the optimal hyperparameters for the kernel
//! and the inner will find the f that maximize Ψ." Each candidate
//! `(θ, λ)` changes the Gram matrix, producing yet another sequence of
//! related SPD systems; the recycled subspace can be carried not only
//! across Newton steps but across *hyperparameter* steps, because
//! neighbouring kernels have similar dominant eigenspaces.
//!
//! This module implements a grid search over `(amplitude, lengthscale)`
//! scored by the Laplace objective `Ψ(f̂)` (the evidence without the
//! `−½log|B|` Occam term, which the paper's experiments also omit —
//! Fig. 2's caption notes only the first two terms of Eq. 8 are computed).

use crate::data::digits::Digits;
use crate::gp::kernel::RbfKernel;
use crate::gp::laplace::{DenseKernel, LaplaceConfig, LaplaceGpc, SolverBackend};
use std::time::Instant;

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct HyperPoint {
    pub amplitude: f64,
    pub lengthscale: f64,
    /// Ψ(f̂) at the Laplace mode.
    pub psi: f64,
    pub log_lik: f64,
    /// Total inner-solver iterations spent.
    pub solver_iterations: usize,
    pub seconds: f64,
}

/// Result of a grid search.
#[derive(Clone, Debug)]
pub struct HyperSearchResult {
    pub evaluated: Vec<HyperPoint>,
    pub best: HyperPoint,
}

/// Grid-search kernel hyperparameters, running a full Laplace fit per
/// candidate with the given backend. Returns every evaluation plus the
/// best point by Ψ.
pub fn grid_search(
    data: &Digits,
    amplitudes: &[f64],
    lengthscales: &[f64],
    backend: SolverBackend,
    max_newton: usize,
) -> HyperSearchResult {
    assert!(!amplitudes.is_empty() && !lengthscales.is_empty());
    let mut evaluated = Vec::new();
    for &amp in amplitudes {
        for &ls in lengthscales {
            let kernel = RbfKernel::new(amp, ls);
            let gram = kernel.gram(&data.x);
            let kern = DenseKernel::new(gram);
            let cfg = LaplaceConfig {
                solver: backend.clone(),
                newton_tol: 1e-2,
                max_newton,
                ..Default::default()
            };
            let start = Instant::now();
            let mut gpc = LaplaceGpc::new(&kern, &data.y, cfg);
            let fit = gpc.fit();
            let seconds = start.elapsed().as_secs_f64();
            let psi = fit.steps.last().map(|s| s.psi).unwrap_or(f64::NEG_INFINITY);
            evaluated.push(HyperPoint {
                amplitude: amp,
                lengthscale: ls,
                psi,
                log_lik: fit.final_log_lik(),
                solver_iterations: fit.steps.iter().map(|s| s.solver_iterations).sum(),
                seconds,
            });
        }
    }
    let best = evaluated
        .iter()
        .cloned()
        .max_by(|a, b| a.psi.partial_cmp(&b.psi).unwrap())
        .unwrap();
    HyperSearchResult { evaluated, best }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digits::{generate, DigitsConfig};

    #[test]
    fn grid_search_finds_reasonable_lengthscale() {
        let ds = generate(&DigitsConfig { n: 60, seed: 10, ..Default::default() });
        let res = grid_search(
            &ds,
            &[1.0],
            &[0.1, 10.0, 1000.0],
            SolverBackend::Cholesky,
            8,
        );
        assert_eq!(res.evaluated.len(), 3);
        // λ = 0.1 on 784-dim images makes K ≈ I (no structure) and λ = 1000
        // makes K ≈ all-ones (no discrimination); the mid value must win.
        assert_eq!(res.best.lengthscale, 10.0, "best = {:?}", res.best);
    }

    #[test]
    fn all_grid_points_scored_finite() {
        let ds = generate(&DigitsConfig { n: 30, seed: 11, ..Default::default() });
        let res = grid_search(&ds, &[0.5, 2.0], &[5.0, 20.0], SolverBackend::Cg, 6);
        assert_eq!(res.evaluated.len(), 4);
        for p in &res.evaluated {
            assert!(p.psi.is_finite());
            assert!(p.log_lik.is_finite());
            assert!(p.log_lik <= 0.0); // log of probabilities
        }
    }
}
