//! Hyperparameter adaptation — the paper's *outer* loop (§1, §3).
//!
//! "The outer loop will find the optimal hyperparameters for the kernel
//! and the inner will find the f that maximize Ψ." Each candidate
//! `(θ, λ)` changes the Gram matrix, producing yet another sequence of
//! related SPD systems; the recycled subspace can be carried not only
//! across Newton steps but across *hyperparameter* steps, because
//! neighbouring kernels have similar dominant eigenspaces.
//!
//! This module implements two grid searches:
//!
//! * [`grid_search`] — `(amplitude, lengthscale)` for GP **classification**,
//!   scored by the Laplace objective `Ψ(f̂)` (the evidence without the
//!   `−½log|B|` Occam term, which the paper's experiments also omit —
//!   Fig. 2's caption notes only the first two terms of Eq. 8 are
//!   computed). Each lengthscale changes the Gram matrix structurally, so
//!   a rebuild per lengthscale is genuine work.
//! * [`sigma_grid_search`] — `(amplitude, noise σ)` for GP **regression**
//!   over a *fixed* lengthscale. Here no grid point needs its own kernel:
//!   `θ²K + σ²I = ShiftedOp(ScaledOp(K, θ²), σ²)` is a cheap operator
//!   view over ONE unit-amplitude Gram matrix (built once), and a single
//!   [`crate::solvers::recycle::RecycleManager`] carries the recycled subspace across the whole
//!   plane of views — the paper's "sequence of parameter estimates"
//!   scenario with zero kernel re-materialization.

use crate::coordinator::SolveService;
use crate::data::digits::Digits;
use crate::gp::kernel::RbfKernel;
use crate::gp::laplace::{DenseKernel, LaplaceConfig, LaplaceGpc, SolverBackend};
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::dot;
use crate::solvers::recycle::RecycleConfig;
use crate::solvers::{ScaledOp, ShiftedOp, SolveSpec, SpdOperator, StopReason};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct HyperPoint {
    pub amplitude: f64,
    pub lengthscale: f64,
    /// Ψ(f̂) at the Laplace mode.
    pub psi: f64,
    pub log_lik: f64,
    /// Total inner-solver iterations spent.
    pub solver_iterations: usize,
    pub seconds: f64,
}

/// Result of a grid search.
#[derive(Clone, Debug)]
pub struct HyperSearchResult {
    pub evaluated: Vec<HyperPoint>,
    pub best: HyperPoint,
}

/// Grid-search kernel hyperparameters, running a full Laplace fit per
/// candidate with the given backend. Returns every evaluation plus the
/// best point by Ψ.
pub fn grid_search(
    data: &Digits,
    amplitudes: &[f64],
    lengthscales: &[f64],
    backend: SolverBackend,
    max_newton: usize,
) -> HyperSearchResult {
    assert!(!amplitudes.is_empty() && !lengthscales.is_empty());
    let mut evaluated = Vec::new();
    for &amp in amplitudes {
        for &ls in lengthscales {
            let kernel = RbfKernel::new(amp, ls);
            let gram = kernel.gram(&data.x);
            let kern = DenseKernel::new(gram);
            let cfg = LaplaceConfig {
                solver: backend.clone(),
                newton_tol: 1e-2,
                max_newton,
                ..Default::default()
            };
            let start = Instant::now();
            let mut gpc = LaplaceGpc::new(&kern, &data.y, cfg);
            let fit = gpc.fit();
            let seconds = start.elapsed().as_secs_f64();
            let psi = fit.steps.last().map(|s| s.psi).unwrap_or(f64::NEG_INFINITY);
            evaluated.push(HyperPoint {
                amplitude: amp,
                lengthscale: ls,
                psi,
                log_lik: fit.final_log_lik(),
                solver_iterations: fit.steps.iter().map(|s| s.solver_iterations).sum(),
                seconds,
            });
        }
    }
    let best = best_point(&evaluated);
    HyperSearchResult { evaluated, best }
}

/// The Ψ-best evaluated point. Under `total_cmp` a NaN Ψ sorts *above*
/// +inf, so a bare `max_by` would crown a diverged fit; NaN points are
/// filtered out instead (falling back to the first point when every fit
/// diverged, so callers still get a deterministic answer rather than a
/// panic — the old `partial_cmp(..).unwrap()` aborted the whole search).
fn best_point(evaluated: &[HyperPoint]) -> HyperPoint {
    evaluated
        .iter()
        .filter(|p| !p.psi.is_nan())
        .max_by(|a, b| a.psi.total_cmp(&b.psi))
        .or_else(|| evaluated.first())
        .expect("grid search evaluated at least one point")
        .clone()
}

/// One evaluated `(amplitude θ, noise σ)` grid point of
/// [`sigma_grid_search`].
#[derive(Clone, Debug)]
pub struct SigmaPoint {
    pub amplitude: f64,
    pub noise: f64,
    /// Data-fit part of the log marginal likelihood, `−½ yᵀα`.
    pub data_fit: f64,
    /// α = (θ²K + σ²I)⁻¹ y for this grid point (the partial iterate when
    /// the point's deadline expired — see `stop`).
    pub alpha: Vec<f64>,
    pub solver_iterations: usize,
    /// Recycled-basis dimension active at this point.
    pub deflation_dim: usize,
    /// How the point's solve ended: `Converged`, or `DeadlineExceeded`
    /// when the per-point budget ran out (the partial solve still fed
    /// its directions to the basis, so the next point benefits anyway).
    pub stop: StopReason,
}

/// Grid-search the `(amplitude, σ)` regularization plane of GP
/// **regression** at a fixed lengthscale, with every grid point an
/// operator-algebra **view** over one shared Gram matrix.
///
/// The unit-amplitude Gram `K` is assembled exactly once; each candidate
/// `(θ, σ)` then solves `(θ²K + σ²I) α = y` through
/// `ShiftedOp(ScaledOp(K, θ²), σ²)` — `O(n)` extra work per application,
/// exact `O(n)` diagonal (so Jacobi stays cheap), and **no kernel
/// rebuild**. All solves share one recycled sequence
/// ([`crate::solvers::recycle::RecycleManager`] behind a
/// [`SolveService`] handle): neighbouring grid
/// points have nearby spectra (a scaled-and-shifted family even shares
/// eigenvectors along the σ axis), so the recycled subspace transfers
/// across the whole grid and later points converge in fewer iterations.
///
/// Grid order is amplitude-major, σ descending within each amplitude —
/// descending σ makes each system slightly *harder* than the last, the
/// regime where carrying a basis from the easier neighbour pays most.
///
/// The grid runs through a [`SolveService`] sequence: every point is a
/// [`crate::solvers::Priority::Batch`] request (a grid search is
/// throughput work — interactive traffic sharing the service overtakes
/// it), and `point_budget` arms a **per-grid-point deadline**. A point
/// whose budget expires comes back as
/// [`StopReason::DeadlineExceeded`] with the partial `α` it reached —
/// and because deadline-stopped runs still feed their direction panel to
/// the recycle basis, the budget caps tail latency without throwing the
/// partial Krylov work away.
pub fn sigma_grid_search(
    x: &Mat,
    y: &[f64],
    lengthscale: f64,
    amplitudes: &[f64],
    noises: &[f64],
    recycle: RecycleConfig,
    tol: f64,
    point_budget: Option<Duration>,
) -> Vec<SigmaPoint> {
    assert_eq!(x.rows(), y.len());
    assert!(!amplitudes.is_empty() && !noises.is_empty());
    // The ONE kernel assembly of the whole search, shared by every grid
    // point as an Arc'd base operator.
    let k = RbfKernel::new(1.0, lengthscale).gram(x);
    let svc = SolveService::new(1);
    let base = svc.par_operator(k); // bitwise-equal to the serial DenseOp
    let seq = svc.open_sequence(recycle);
    let mut out = Vec::with_capacity(amplitudes.len() * noises.len());
    for &amp in amplitudes {
        for &noise in noises {
            let op: Arc<dyn SpdOperator + Send + Sync> =
                Arc::new(ShiftedOp::new(ScaledOp::new(base.clone(), amp * amp), noise * noise));
            // Read BEFORE the solve: a completed solve feeds the basis,
            // so reading after would report the dimension available to
            // the NEXT grid point (the first, undeflated point would
            // show a nonzero k).
            let deflation_dim = seq.k_active();
            // Batch priority + a deadline armed per request (the
            // deadline is absolute, so it is built here, not once
            // outside the loop). Submit-then-wait keeps the recycling
            // order explicit and gives each point its full budget.
            let mut spec = SolveSpec::defcg().with_tol(tol).batch();
            if let Some(budget) = point_budget {
                spec = spec.with_deadline(budget);
            }
            let r = seq.submit(op, y.to_vec(), None, spec).wait();
            out.push(SigmaPoint {
                amplitude: amp,
                noise,
                data_fit: -0.5 * dot(y, &r.x),
                alpha: r.x,
                solver_iterations: r.iterations,
                deflation_dim,
                stop: r.stop,
            });
        }
    }
    seq.close();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digits::{generate, DigitsConfig};

    fn point(psi: f64) -> HyperPoint {
        HyperPoint {
            amplitude: 1.0,
            lengthscale: 1.0,
            psi,
            log_lik: 0.0,
            solver_iterations: 0,
            seconds: 0.0,
        }
    }

    #[test]
    fn best_point_ignores_nan_psi() {
        // Regression: a single diverged fit (NaN Ψ) used to panic the
        // whole grid search via `partial_cmp(..).unwrap()`; and a naive
        // `total_cmp` max would crown the NaN (it sorts above +inf).
        let pts = vec![point(-3.0), point(f64::NAN), point(-1.0), point(-2.0)];
        assert_eq!(best_point(&pts).psi, -1.0);
        // -inf (the "fit produced no steps" sentinel) loses to any
        // finite Ψ but still beats being NaN.
        let pts = vec![point(f64::NEG_INFINITY), point(f64::NAN)];
        assert_eq!(best_point(&pts).psi, f64::NEG_INFINITY);
        // All-NaN grid: deterministic fallback, no panic.
        assert!(best_point(&[point(f64::NAN)]).psi.is_nan());
    }

    #[test]
    fn grid_search_finds_reasonable_lengthscale() {
        let ds = generate(&DigitsConfig { n: 60, seed: 10, ..Default::default() });
        let res = grid_search(
            &ds,
            &[1.0],
            &[0.1, 10.0, 1000.0],
            SolverBackend::Cholesky,
            8,
        );
        assert_eq!(res.evaluated.len(), 3);
        // λ = 0.1 on 784-dim images makes K ≈ I (no structure) and λ = 1000
        // makes K ≈ all-ones (no discrimination); the mid value must win.
        assert_eq!(res.best.lengthscale, 10.0, "best = {:?}", res.best);
    }

    #[test]
    fn sigma_grid_matches_cholesky_on_materialized_systems() {
        use crate::linalg::cholesky::Cholesky;
        let ds = generate(&DigitsConfig { n: 50, seed: 12, ..Default::default() });
        let pts = sigma_grid_search(
            &ds.x,
            &ds.y,
            10.0,
            &[0.8, 1.5],
            &[0.6, 0.4],
            RecycleConfig { k: 6, l: 10, ..Default::default() },
            1e-10,
            None,
        );
        assert_eq!(pts.len(), 4);
        for p in &pts {
            assert_eq!(p.stop, StopReason::Converged);
        }
        let k1 = RbfKernel::new(1.0, 10.0).gram(&ds.x);
        for p in &pts {
            // Materialize θ²K + σ²I and solve directly.
            let mut m = k1.clone();
            m.scale_in_place(p.amplitude * p.amplitude);
            m.add_diag(p.noise * p.noise);
            let want = Cholesky::factor(&m).unwrap().solve(&ds.y);
            for (a, w) in p.alpha.iter().zip(&want) {
                assert!((a - w).abs() < 1e-6, "θ={} σ={}: {a} vs {w}", p.amplitude, p.noise);
            }
            assert!(p.data_fit.is_finite());
        }
    }

    #[test]
    fn sigma_grid_recycling_saves_iterations() {
        let ds = generate(&DigitsConfig { n: 90, seed: 13, ..Default::default() });
        let amps = [1.0];
        let noises = [0.8, 0.7, 0.6, 0.5, 0.45, 0.4];
        let with = sigma_grid_search(
            &ds.x,
            &ds.y,
            10.0,
            &amps,
            &noises,
            RecycleConfig { k: 8, l: 12, ..Default::default() },
            1e-8,
            // A generous per-point budget: exercises the deadline plumbing
            // without ever firing on a healthy run.
            Some(std::time::Duration::from_secs(60)),
        );
        let without = sigma_grid_search(
            &ds.x,
            &ds.y,
            10.0,
            &amps,
            &noises,
            RecycleConfig { k: 0, l: 0, ..Default::default() },
            1e-8,
            None,
        );
        let tot = |pts: &[SigmaPoint]| -> usize {
            pts.iter().skip(1).map(|p| p.solver_iterations).sum()
        };
        assert!(
            tot(&with) < tot(&without),
            "recycled {} >= plain {}",
            tot(&with),
            tot(&without)
        );
        // First grid point identical (no basis yet); later points report
        // an active basis.
        assert_eq!(with[0].solver_iterations, without[0].solver_iterations);
        assert!(with.last().unwrap().deflation_dim > 0);
    }

    #[test]
    fn all_grid_points_scored_finite() {
        let ds = generate(&DigitsConfig { n: 30, seed: 11, ..Default::default() });
        let res = grid_search(&ds, &[0.5, 2.0], &[5.0, 20.0], SolverBackend::Cg, 6);
        assert_eq!(res.evaluated.len(), 4);
        for p in &res.evaluated {
            assert!(p.psi.is_finite());
            assert!(p.log_lik.is_finite());
            assert!(p.log_lik <= 0.0); // log of probabilities
        }
    }
}
