//! Inducing-point (subset-of-data) baseline — the paper's §3.1 comparison.
//!
//! The linear-cost alternative to iterative solvers: pick `m < n`
//! representer points `X_m`, run the full Laplace optimization on the
//! m-subset only (`O(m³)` per Newton step via Cholesky), then *induce* the
//! latent values of the remaining points through the conditional mean
//! `E[f_{n−m} | f_m] = K_{(n−m)m} K_mm⁻¹ f_m` and score `log p(y | f)` on
//! the **entire** training set. Fast, but with a finite, uncorrectable
//! approximation error — the trade-off Fig. 4 plots.

use crate::data::digits::Digits;
use crate::gp::kernel::RbfKernel;
use crate::gp::laplace::{DenseKernel, LaplaceConfig, LaplaceGpc, SolverBackend};
use crate::gp::likelihood::Logistic;
use crate::linalg::cholesky::Cholesky;
use crate::util::rng::Rng;
use std::time::Instant;

/// One Newton-trajectory point of the subset method (a dot in Fig. 4).
#[derive(Clone, Debug)]
pub struct SubsetTrajectoryPoint {
    pub newton_iter: usize,
    /// log p(y | f) over the FULL training set with induced latents.
    pub full_log_lik: f64,
    /// Cumulative linear-solve seconds so far.
    pub cumulative_seconds: f64,
}

/// Result of the subset-of-data Laplace run.
#[derive(Clone, Debug)]
pub struct SubsetResult {
    pub m: usize,
    pub trajectory: Vec<SubsetTrajectoryPoint>,
    /// Induced latents over the full set at the final iterate.
    pub f_full: Vec<f64>,
}

/// Run the inducing-point baseline with `m` randomly selected points.
///
/// `kernel` must match the kernel used by the full-data methods for the
/// comparison to be meaningful.
pub fn run_subset(
    data: &Digits,
    kernel: &RbfKernel,
    m: usize,
    max_newton: usize,
    rng: &mut Rng,
) -> SubsetResult {
    let n = data.n();
    assert!(m >= 2 && m <= n, "subset size out of range");
    let (sub, idx) = data.subset(m, rng);

    // K_mm (+ jitter for numerical safety at small lengthscales).
    let mut kmm = kernel.gram(&sub.x);
    kmm.add_diag(1e-8);
    // Cross-covariances K_nm between ALL training points and the subset —
    // rows ordered like `data`.
    let knm = kernel.cross_gram(&data.x, &sub.x);
    let kmm_ch = Cholesky::factor(&kmm).expect("K_mm SPD");

    let lik = Logistic;

    // Laplace on the subset, recording the induced full-set log-lik per
    // Newton iteration. We re-run the fit with increasing iteration caps to
    // reconstruct the trajectory; m is small so the cost is acceptable, and
    // we time only the final full run's solves (the others are warm
    // re-measurements of identical prefixes).
    let kern = DenseKernel::new(kernel.gram(&sub.x));
    let mut gpc = LaplaceGpc::new(
        &kern,
        &sub.y,
        LaplaceConfig {
            solver: SolverBackend::Cholesky,
            newton_tol: 1e-3,
            max_newton,
            ..Default::default()
        },
    );
    let start = Instant::now();
    let fit = gpc.fit();
    let _total = start.elapsed().as_secs_f64();

    // Replay the trajectory: recompute f_m at each Newton prefix.
    // (LaplaceFit stores per-step stats; to get intermediate f we re-run
    // with capped max_newton — each prefix run repeats the same
    // deterministic iterations.)
    let mut trajectory = Vec::new();
    let mut cumulative = 0.0;
    for step in 1..=fit.steps.len() {
        let mut gpc_i = LaplaceGpc::new(
            &kern,
            &sub.y,
            LaplaceConfig {
                solver: SolverBackend::Cholesky,
                newton_tol: 0.0, // run exactly `step` iterations
                max_newton: step,
                ..Default::default()
            },
        );
        let t0 = Instant::now();
        let fit_i = gpc_i.fit();
        // Only count the *last* step's solve time (prefix steps were already
        // counted in earlier trajectory points).
        let step_time = fit_i.steps.last().map(|s| s.solve_seconds).unwrap_or(0.0);
        let _ = t0;
        cumulative += step_time;

        // Induce latents for all points: f_full = K_nm K_mm⁻¹ f_m.
        let alpha = kmm_ch.solve(&fit_i.f_hat);
        let f_full = knm.matvec(&alpha);
        let full_log_lik = lik.log_lik(&data.y, &f_full);
        trajectory.push(SubsetTrajectoryPoint {
            newton_iter: step,
            full_log_lik,
            cumulative_seconds: cumulative,
        });
    }

    let alpha = kmm_ch.solve(&fit.f_hat);
    let f_full = knm.matvec(&alpha);
    let _ = idx;
    SubsetResult { m, trajectory, f_full }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digits::{generate, DigitsConfig};

    fn dataset(n: usize) -> Digits {
        generate(&DigitsConfig { n, seed: 9, ..Default::default() })
    }

    #[test]
    fn subset_runs_and_improves_over_iterations() {
        let ds = dataset(80);
        let kernel = RbfKernel::new(1.0, 10.0);
        let mut rng = Rng::new(1);
        let res = run_subset(&ds, &kernel, 20, 10, &mut rng);
        assert_eq!(res.m, 20);
        assert!(!res.trajectory.is_empty());
        let first = res.trajectory.first().unwrap().full_log_lik;
        let last = res.trajectory.last().unwrap().full_log_lik;
        // Subset-Newton maximizes the subset's Ψ, so the FULL-set log-lik
        // is not strictly monotone; it must however not degrade materially.
        assert!(
            last >= first - 0.02 * first.abs(),
            "degraded materially: {first} -> {last}"
        );
        assert_eq!(res.f_full.len(), 80);
    }

    #[test]
    fn larger_subsets_fit_better() {
        let ds = dataset(100);
        let kernel = RbfKernel::new(1.0, 10.0);
        let mut rng = Rng::new(2);
        let small = run_subset(&ds, &kernel, 10, 12, &mut rng);
        let mut rng = Rng::new(2);
        let large = run_subset(&ds, &kernel, 60, 12, &mut rng);
        let ll_small = small.trajectory.last().unwrap().full_log_lik;
        let ll_large = large.trajectory.last().unwrap().full_log_lik;
        assert!(
            ll_large > ll_small,
            "m=60 ll {ll_large} not better than m=10 ll {ll_small}"
        );
    }

    #[test]
    fn full_subset_approaches_full_laplace() {
        // m = n: the "subset" method degenerates to the exact method; the
        // induced latents should equal the subset fit's latents (same set).
        let ds = dataset(40);
        let kernel = RbfKernel::new(1.0, 10.0);
        let mut rng = Rng::new(3);
        let res = run_subset(&ds, &kernel, 40, 15, &mut rng);
        // Full-data exact Laplace for reference:
        let kern = DenseKernel::new(kernel.gram(&ds.x));
        let mut gpc = LaplaceGpc::new(
            &kern,
            &ds.y,
            LaplaceConfig {
                solver: SolverBackend::Cholesky,
                newton_tol: 1e-3,
                max_newton: 15,
                ..Default::default()
            },
        );
        let fit = gpc.fit();
        let ll_sub = res.trajectory.last().unwrap().full_log_lik;
        let ll_exact = fit.final_log_lik();
        assert!(
            (ll_sub - ll_exact).abs() / ll_exact.abs() < 0.05,
            "subset(m=n) ll {ll_sub} vs exact {ll_exact}"
        );
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_oversized_subset() {
        let ds = dataset(10);
        let mut rng = Rng::new(4);
        let _ = run_subset(&ds, &RbfKernel::new(1.0, 1.0), 11, 5, &mut rng);
    }
}
