//! Covariance kernels and Gram-matrix assembly (native path).
//!
//! The paper uses the Gaussian/RBF kernel
//! `k(xᵢ, xⱼ) = θ² exp(−‖xᵢ − xⱼ‖² / 2λ²)` (§3). The same computation is
//! implemented as an L1 Pallas kernel (`python/compile/kernels/rbf_gram.py`)
//! for the AOT path; this native implementation is the reference the
//! integration tests compare the artifact against, and the fallback when
//! running without artifacts.

use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::dot;

/// RBF (squared-exponential) kernel with amplitude θ and lengthscale λ.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RbfKernel {
    /// Amplitude θ (the kernel value at zero distance is θ²).
    pub amplitude: f64,
    /// Lengthscale λ.
    pub lengthscale: f64,
}

impl RbfKernel {
    pub fn new(amplitude: f64, lengthscale: f64) -> Self {
        assert!(amplitude > 0.0 && lengthscale > 0.0);
        RbfKernel { amplitude, lengthscale }
    }

    /// k(x, y) for two feature vectors.
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        assert_eq!(x.len(), y.len());
        let mut d2 = 0.0;
        for i in 0..x.len() {
            let d = x[i] - y[i];
            d2 += d * d;
        }
        self.amplitude * self.amplitude * (-d2 / (2.0 * self.lengthscale * self.lengthscale)).exp()
    }

    /// Symmetric Gram matrix over rows of X (n × d), via the
    /// ‖x‖² + ‖y‖² − 2xᵀy expansion.
    ///
    /// The inner product block is a register-blocked symmetric product
    /// (SYRK-style): only the lower triangle is computed (half the flops
    /// of a general matmul) and a 2×2 register block computes four dot
    /// products per pass, amortizing each row-stream read over two
    /// outputs. Perf log in EXPERIMENTS.md §Perf.
    pub fn gram(&self, x: &Mat) -> Mat {
        let n = x.rows();
        let sq: Vec<f64> = (0..n).map(|i| dot(x.row(i), x.row(i))).collect();
        let a2 = self.amplitude * self.amplitude;
        let inv2l2 = 1.0 / (2.0 * self.lengthscale * self.lengthscale);
        let mut k = Mat::zeros(n, n);
        let fill = |sqi: f64, sqj: f64, g: f64| -> f64 {
            let d2 = (sqi + sqj - 2.0 * g).max(0.0);
            a2 * (-d2 * inv2l2).exp()
        };
        let mut i = 0;
        while i < n {
            let has_i1 = i + 1 < n;
            let (xi0, xi1) = (x.row(i), x.row(if has_i1 { i + 1 } else { i }));
            let mut j = 0;
            while j <= i {
                let has_j1 = j + 1 < n;
                let (xj0, xj1) = (x.row(j), x.row(if has_j1 { j + 1 } else { j }));
                // Four simultaneous dot products over one pass of d.
                let (mut s00, mut s01, mut s10, mut s11) = (0.0, 0.0, 0.0, 0.0);
                for t in 0..x.cols() {
                    let (a0, a1) = (xi0[t], xi1[t]);
                    let (b0, b1) = (xj0[t], xj1[t]);
                    s00 += a0 * b0;
                    s01 += a0 * b1;
                    s10 += a1 * b0;
                    s11 += a1 * b1;
                }
                // Fill every lower-triangle entry the 2×2 block covers.
                let v00 = fill(sq[i], sq[j], s00);
                k[(i, j)] = v00;
                k[(j, i)] = v00;
                if has_j1 && j + 1 <= i {
                    let v01 = fill(sq[i], sq[j + 1], s01);
                    k[(i, j + 1)] = v01;
                    k[(j + 1, i)] = v01;
                }
                if has_i1 {
                    let v10 = fill(sq[i + 1], sq[j], s10);
                    k[(i + 1, j)] = v10;
                    k[(j, i + 1)] = v10;
                    if has_j1 && j + 1 <= i + 1 {
                        let v11 = fill(sq[i + 1], sq[j + 1], s11);
                        k[(i + 1, j + 1)] = v11;
                        k[(j + 1, i + 1)] = v11;
                    }
                }
                j += 2;
            }
            i += 2;
        }
        k
    }

    /// Cross Gram matrix between rows of X1 (n1 × d) and X2 (n2 × d).
    pub fn cross_gram(&self, x1: &Mat, x2: &Mat) -> Mat {
        assert_eq!(x1.cols(), x2.cols());
        let (n1, n2) = (x1.rows(), x2.rows());
        let sq1: Vec<f64> = (0..n1).map(|i| dot(x1.row(i), x1.row(i))).collect();
        let sq2: Vec<f64> = (0..n2).map(|i| dot(x2.row(i), x2.row(i))).collect();
        let g = x1.matmul(&x2.transpose());
        let a2 = self.amplitude * self.amplitude;
        let inv2l2 = 1.0 / (2.0 * self.lengthscale * self.lengthscale);
        Mat::from_fn(n1, n2, |i, j| {
            let d2 = (sq1[i] + sq2[j] - 2.0 * g[(i, j)]).max(0.0);
            a2 * (-d2 * inv2l2).exp()
        })
    }

    /// Matrix-free Gram matvec: y = K v computed in row blocks without
    /// materializing K (`O(n²d)` flops, `O(n·block)` extra memory). This is
    /// the large-n path the paper's conclusion alludes to (10⁵–10⁶ points).
    pub fn gram_matvec(&self, x: &Mat, v: &[f64], y: &mut [f64]) {
        let n = x.rows();
        assert_eq!(v.len(), n);
        assert_eq!(y.len(), n);
        let sq: Vec<f64> = (0..n).map(|i| dot(x.row(i), x.row(i))).collect();
        let a2 = self.amplitude * self.amplitude;
        let inv2l2 = 1.0 / (2.0 * self.lengthscale * self.lengthscale);
        const BLOCK: usize = 64;
        for ib in (0..n).step_by(BLOCK) {
            let iend = (ib + BLOCK).min(n);
            for yi in y[ib..iend].iter_mut() {
                *yi = 0.0;
            }
            for j in 0..n {
                let vj = v[j];
                if vj == 0.0 {
                    continue;
                }
                let xj = x.row(j);
                for i in ib..iend {
                    let g = dot(x.row(i), xj);
                    let d2 = (sq[i] + sq[j] - 2.0 * g).max(0.0);
                    y[i] += vj * a2 * (-d2 * inv2l2).exp();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky::Cholesky;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn eval_at_zero_distance_is_amplitude_squared() {
        let k = RbfKernel::new(2.0, 1.5);
        let x = [1.0, -3.0, 2.0];
        assert!((k.eval(&x, &x) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn eval_decays_with_distance() {
        let k = RbfKernel::new(1.0, 1.0);
        let a = [0.0];
        assert!(k.eval(&a, &[1.0]) > k.eval(&a, &[2.0]));
        // k(x,y) = exp(-d²/2)
        assert!((k.eval(&a, &[1.0]) - (-0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn gram_matches_pairwise_eval() {
        forall("gram == pairwise", 10, |g| {
            let n = g.usize_in(1, 15);
            let d = g.usize_in(1, 8);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let x = Mat::randn(n, d, &mut rng);
            let k = RbfKernel::new(g.f64_in(0.5, 3.0), g.f64_in(0.5, 3.0));
            let gram = k.gram(&x);
            let mut ok = true;
            for i in 0..n {
                for j in 0..n {
                    ok &= (gram[(i, j)] - k.eval(x.row(i), x.row(j))).abs() < 1e-10;
                }
            }
            ok
        });
    }

    #[test]
    fn gram_is_psd() {
        let mut rng = Rng::new(5);
        let x = Mat::randn(20, 4, &mut rng);
        let k = RbfKernel::new(1.0, 2.0);
        let mut gram = k.gram(&x);
        gram.add_diag(1e-8); // jitter for strictness
        assert!(Cholesky::factor(&gram).is_ok());
    }

    #[test]
    fn cross_gram_consistent_with_gram() {
        let mut rng = Rng::new(6);
        let x = Mat::randn(10, 3, &mut rng);
        let k = RbfKernel::new(1.3, 0.9);
        let full = k.gram(&x);
        let cross = k.cross_gram(&x, &x);
        // Summation orders differ between the SYRK path and cross_gram.
        assert!(full.max_abs_diff(&cross) < 1e-10);
    }

    #[test]
    fn gram_matvec_matches_materialized() {
        forall("K v matrix-free == dense", 8, |g| {
            let n = g.usize_in(2, 40);
            let d = g.usize_in(1, 6);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let x = Mat::randn(n, d, &mut rng);
            let k = RbfKernel::new(1.0, 1.5);
            let v = g.normal_vec(n);
            let dense = k.gram(&x).matvec(&v);
            let mut y = vec![0.0; n];
            k.gram_matvec(&x, &v, &mut y);
            dense.iter().zip(&y).all(|(u, w)| (u - w).abs() < 1e-9)
        });
    }
}
