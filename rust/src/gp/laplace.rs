//! Laplace approximation for GP classification via Newton's method.
//!
//! Implements the numerically stable formulation of Kuss & Rasmussen
//! (2006) / Rasmussen & Williams Alg. 3.1, which the paper adopts in §3:
//! each Newton iteration solves one SPD system
//!
//! ```text
//!   A⁽ⁱ⁾ z = b⁽ⁱ⁾,   A⁽ⁱ⁾ = I + H^½ K H^½   (Eq. 10)
//!   b⁽ⁱ⁾ = H^½ K (H f⁽ⁱ⁾ + ∇ log p(y|f⁽ⁱ⁾))  (Eq. 9)
//! ```
//!
//! then updates `a = (Hf + ∇) − H^½ z`, `f ← K a`. The eigenvalues of `A`
//! lie in `[1, 1 + n·max(K)/4]`, so the system is well conditioned from
//! below and the interesting spectrum is at the top — which is why the
//! recycled basis deflates the **largest** harmonic Ritz values.
//!
//! The linear-solver backend is pluggable ([`SolverBackend`]); with
//! [`SolverBackend::DefCg`] the Newton loop *is* the paper's sequence of
//! related systems, and a [`RecycleManager`] carries `W` across them.

use crate::gp::likelihood::Logistic;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::dot;
use crate::solvers::api::{self, SolveSpec};
use crate::solvers::recycle::{RecycleConfig, RecycleManager};
use crate::solvers::{ParDenseOp, SolveResult, SpdOperator};
use crate::util::pool::ThreadPool;
use std::sync::Arc;
use std::time::Instant;

/// Abstract access to the kernel Gram matrix `K`.
///
/// `matvec` is all the iterative path needs; `dense` must be available for
/// the Cholesky baseline. The XLA-artifact engine implements this trait in
/// `runtime::ops` with `K` resident in device memory.
pub trait KernelOp: Sync {
    fn n(&self) -> usize;
    /// y = K v.
    fn matvec(&self, v: &[f64], y: &mut [f64]);
    /// `Y = K X` for a block of columns — same column-equivalence
    /// contract as [`SpdOperator::apply_block`]: the default loops
    /// [`KernelOp::matvec`] over columns, and overrides may only change
    /// how K is streamed, never the per-column float sequence.
    /// [`DenseKernel`] overrides with the cache-blocked (and, when
    /// constructed parallel, pool-sharded) panel kernel; the engine-backed
    /// kernels in `runtime::ops` keep the default (the artifact surface is
    /// vector-at-a-time).
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        crate::solvers::apply_block_via(self.n(), &mut |x, y| self.matvec(x, y), xs, ys)
    }
    /// Dense K if this operator has one (native path).
    fn dense(&self) -> Option<&Mat> {
        None
    }
}

/// In-core dense kernel matrix, with an optional pool-sharded parallel
/// matvec for the ≥512-dim workloads (shards match the serial row order
/// bit-for-bit, so results are backend-independent).
pub struct DenseKernel {
    k: Arc<Mat>,
    par: Option<ParDenseOp>,
}

impl DenseKernel {
    pub fn new(k: Mat) -> Self {
        assert!(k.is_square());
        DenseKernel { k: Arc::new(k), par: None }
    }

    /// Dense kernel whose matvec is row-sharded across `pool`.
    pub fn parallel(k: Mat, pool: Arc<ThreadPool>) -> Self {
        assert!(k.is_square());
        let k = Arc::new(k);
        let par = ParDenseOp::new(k.clone(), pool);
        DenseKernel { k, par: Some(par) }
    }
}

impl KernelOp for DenseKernel {
    fn n(&self) -> usize {
        self.k.rows()
    }

    fn matvec(&self, v: &[f64], y: &mut [f64]) {
        match &self.par {
            Some(p) => p.matvec(v, y),
            None => self.k.matvec_into(v, y),
        }
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        match &self.par {
            Some(p) => p.apply_block(xs, ys),
            None => self.k.block_matvec_into(xs, ys),
        }
    }

    fn dense(&self) -> Option<&Mat> {
        Some(self.k.as_ref())
    }
}

/// The Newton-system operator `A = I + S K S`, `S = diag(h^½)`, applied
/// matrix-free: `A·v = v + s ∘ (K (s ∘ v))`. One `K`-matvec per apply.
pub struct LaplaceOperator<'a> {
    k: &'a dyn KernelOp,
    s: &'a [f64],
}

impl<'a> LaplaceOperator<'a> {
    pub fn new(k: &'a dyn KernelOp, s: &'a [f64]) -> Self {
        assert_eq!(k.n(), s.len());
        LaplaceOperator { k, s }
    }
}

impl<'a> SpdOperator for LaplaceOperator<'a> {
    fn n(&self) -> usize {
        self.s.len()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let n = self.s.len();
        // tmp = s ∘ x — reuse y as scratch.
        for i in 0..n {
            y[i] = self.s[i] * x[i];
        }
        let mut ky = vec![0.0; n];
        self.k.matvec(y, &mut ky);
        for i in 0..n {
            y[i] = x[i] + self.s[i] * ky[i];
        }
    }

    /// Fused block form `Y = X + S∘(K(S∘X))`: one block kernel
    /// application for all columns (the diagonal scalings are `O(nk)` on
    /// contiguous rows). Per column this performs exactly the
    /// single-vector float sequence, so the column-equivalence contract
    /// holds whenever the kernel's [`KernelOp::apply_block`] honors it.
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        let n = self.s.len();
        assert_eq!(xs.rows(), n, "apply_block dim");
        assert_eq!(ys.rows(), n, "apply_block dim");
        assert_eq!(xs.cols(), ys.cols(), "apply_block dim");
        // SX: row i scaled by sᵢ (row-major rows are contiguous).
        let mut sx = xs.clone();
        for i in 0..n {
            let si = self.s[i];
            for v in sx.row_mut(i) {
                *v *= si;
            }
        }
        let mut ksx = Mat::zeros(n, xs.cols());
        self.k.apply_block(&sx, &mut ksx);
        for i in 0..n {
            let si = self.s[i];
            let (xrow, krow) = (xs.row(i), ksx.row(i));
            for (j, yv) in ys.row_mut(i).iter_mut().enumerate() {
                *yv = xrow[j] + si * krow[j];
            }
        }
    }

    /// Exact diagonal `a_ii = 1 + sᵢ² K_ii` when the kernel exposes a
    /// dense Gram matrix; falls back to basis-vector probing otherwise
    /// (see the [`SpdOperator::diag`] contract).
    fn diag(&self, out: &mut [f64]) {
        match self.k.dense() {
            Some(km) => {
                km.diag_into(out);
                for (o, si) in out.iter_mut().zip(self.s) {
                    *o = 1.0 + si * si * *o;
                }
            }
            None => crate::solvers::probe_diag(self, out),
        }
    }
}

/// Which linear solver runs inside each Newton step. Iterative backends
/// are dispatched through the unified [`SolveSpec`] API.
#[derive(Clone, Debug)]
pub enum SolverBackend {
    /// Dense Cholesky on the materialized `A` — the paper's exact column.
    Cholesky,
    /// Plain conjugate gradients.
    Cg,
    /// Jacobi-preconditioned CG. Uses the Newton operator's exact diagonal
    /// `1 + sᵢ² K_ii`; an ablation baseline — the paper's point (§2.1) is
    /// that this diagonal is nearly constant, so Jacobi helps little here.
    Pcg,
    /// Deflated CG(k, ℓ) with harmonic-Ritz recycling across Newton steps.
    DefCg(RecycleConfig),
}

impl SolverBackend {
    pub fn name(&self) -> String {
        match self {
            SolverBackend::Cholesky => "cholesky".into(),
            SolverBackend::Cg => "cg".into(),
            SolverBackend::Pcg => "pcg-jacobi".into(),
            SolverBackend::DefCg(c) => format!("def-cg(k={},l={})", c.k, c.l),
        }
    }
}

/// Laplace/Newton configuration.
#[derive(Clone, Debug)]
pub struct LaplaceConfig {
    pub solver: SolverBackend,
    /// Relative-residual tolerance of the inner linear solves (paper: 1e-5,
    /// Fig 3 uses 1e-8).
    pub solve_tol: f64,
    /// Newton stop: ΔΨ below this (paper: 1.0).
    pub newton_tol: f64,
    /// Hard cap on Newton iterations.
    pub max_newton: usize,
    /// Iteration cap forwarded to the inner iterative solver (0 = auto).
    pub max_solver_iters: usize,
}

impl Default for LaplaceConfig {
    fn default() -> Self {
        LaplaceConfig {
            solver: SolverBackend::Cg,
            solve_tol: 1e-5,
            newton_tol: 1.0,
            max_newton: 25,
            max_solver_iters: 0,
        }
    }
}

/// Per-Newton-step record (one row of the paper's Table 1).
#[derive(Clone, Debug)]
pub struct NewtonStepStats {
    pub newton_iter: usize,
    /// log p(y | f) after the step.
    pub log_lik: f64,
    /// Ψ(f) = log p(y|f) − ½ aᵀ f (the paper's "first two terms" of Eq. 8).
    pub psi: f64,
    /// Inner-solver iterations (0 for Cholesky).
    pub solver_iterations: usize,
    pub solver_matvecs: usize,
    /// Relative residual trace of the inner solve (Fig. 3).
    pub residual_trace: Vec<f64>,
    /// Active recycled-subspace dimension during this step.
    pub deflation_dim: usize,
    /// Wall time of this step's linear solve.
    pub solve_seconds: f64,
    /// Cumulative linear-solve time so far (Table 1's `t`).
    pub cumulative_seconds: f64,
}

/// Result of a full Laplace fit.
#[derive(Clone, Debug)]
pub struct LaplaceFit {
    /// Posterior mode (latent function values at the training points).
    pub f_hat: Vec<f64>,
    /// `a = K⁻¹ f̂` as maintained by the stable iteration.
    pub a_hat: Vec<f64>,
    pub steps: Vec<NewtonStepStats>,
    pub converged: bool,
}

impl LaplaceFit {
    pub fn final_log_lik(&self) -> f64 {
        self.steps.last().map(|s| s.log_lik).unwrap_or(f64::NAN)
    }

    pub fn total_solve_seconds(&self) -> f64 {
        self.steps.last().map(|s| s.cumulative_seconds).unwrap_or(0.0)
    }
}

/// GP classification with a Laplace approximation.
pub struct LaplaceGpc<'a> {
    k: &'a dyn KernelOp,
    y: &'a [f64],
    cfg: LaplaceConfig,
    lik: Logistic,
    recycler: Option<RecycleManager>,
}

impl<'a> LaplaceGpc<'a> {
    pub fn new(k: &'a dyn KernelOp, y: &'a [f64], cfg: LaplaceConfig) -> Self {
        assert_eq!(k.n(), y.len());
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0), "labels must be ±1");
        let recycler = match &cfg.solver {
            SolverBackend::DefCg(rc) => Some(RecycleManager::new(rc.clone())),
            _ => None,
        };
        LaplaceGpc { k, y, cfg, lik: Logistic, recycler }
    }

    /// Access the recycle manager (after a run) for diagnostics.
    pub fn recycler(&self) -> Option<&RecycleManager> {
        self.recycler.as_ref()
    }

    /// Run Newton to convergence; returns the fit with per-step stats.
    pub fn fit(&mut self) -> LaplaceFit {
        let n = self.k.n();
        let mut f = vec![0.0; n];
        let mut a_hat = vec![0.0; n];
        let mut steps: Vec<NewtonStepStats> = Vec::new();
        let mut cumulative = 0.0f64;
        let mut psi_prev = f64::NEG_INFINITY;
        let mut converged = false;

        let mut grad = vec![0.0; n];
        let mut h = vec![0.0; n];
        let mut converged_at = 0;

        for it in 1..=self.cfg.max_newton {
            // Newton-system coefficients at the current f.
            self.lik.grad(self.y, &f, &mut grad);
            self.lik.hess_diag(&f, &mut h);
            let s: Vec<f64> = h.iter().map(|&v| v.sqrt()).collect();

            // b_rw = H f + ∇;  rhs = s ∘ (K b_rw)  (paper Eq. 9).
            let b_rw: Vec<f64> = (0..n).map(|i| h[i] * f[i] + grad[i]).collect();
            let mut kb = vec![0.0; n];
            self.k.matvec(&b_rw, &mut kb);
            let rhs: Vec<f64> = (0..n).map(|i| s[i] * kb[i]).collect();

            // Solve A z = rhs with the configured backend.
            let solve_start = Instant::now();
            let (z, solve_stats) = self.solve_system(&s, &rhs);
            let solve_seconds = solve_start.elapsed().as_secs_f64();
            cumulative += solve_seconds;

            // a = b_rw − s ∘ z;  f ← K a.
            for i in 0..n {
                a_hat[i] = b_rw[i] - s[i] * z[i];
            }
            self.k.matvec(&a_hat, &mut f);

            let log_lik = self.lik.log_lik(self.y, &f);
            let psi = log_lik - 0.5 * dot(&a_hat, &f);

            steps.push(NewtonStepStats {
                newton_iter: it,
                log_lik,
                psi,
                solver_iterations: solve_stats.iterations,
                solver_matvecs: solve_stats.matvecs,
                residual_trace: solve_stats.residuals,
                deflation_dim: solve_stats.deflation_dim,
                solve_seconds,
                cumulative_seconds: cumulative,
            });

            // ΔΨ stopping rule (paper: ΔΨ < 1).
            let dpsi = psi - psi_prev;
            if it > 1 && dpsi.abs() < self.cfg.newton_tol {
                converged = true;
                converged_at = it;
                break;
            }
            psi_prev = psi;
        }
        let _ = converged_at;

        LaplaceFit { f_hat: f, a_hat, steps, converged }
    }

    /// One inner solve, dispatched per backend.
    fn solve_system(&mut self, s: &[f64], rhs: &[f64]) -> (Vec<f64>, InnerStats) {
        let n = self.k.n();
        match &self.cfg.solver {
            SolverBackend::Cholesky => {
                let k = self
                    .k
                    .dense()
                    .expect("Cholesky backend requires a dense kernel matrix");
                // A = I + S K S materialized.
                let mut a = Mat::from_fn(n, n, |i, j| s[i] * k[(i, j)] * s[j]);
                a.add_diag(1.0);
                let ch = Cholesky::factor(&a).expect("A = I + SKS must be SPD");
                let z = ch.solve(rhs);
                (z, InnerStats { iterations: 0, matvecs: 0, residuals: vec![], deflation_dim: 0 })
            }
            SolverBackend::Cg => {
                let op = LaplaceOperator::new(self.k, s);
                let spec = SolveSpec::cg()
                    .with_tol(self.cfg.solve_tol)
                    .with_max_iters(self.cfg.max_solver_iters);
                let r = api::solve(&op, rhs, &spec);
                (r.x.clone(), InnerStats::from(&r, 0))
            }
            SolverBackend::Pcg => {
                let op = LaplaceOperator::new(self.k, s);
                // Jacobi from the exact Newton-operator diagonal (O(n)
                // thanks to the `diag` override; S changes per Newton
                // step, so the preconditioner is rebuilt each time).
                let spec = SolveSpec::pcg()
                    .with_jacobi(&op)
                    .with_tol(self.cfg.solve_tol)
                    .with_max_iters(self.cfg.max_solver_iters);
                let r = api::solve(&op, rhs, &spec);
                (r.x.clone(), InnerStats::from(&r, 0))
            }
            SolverBackend::DefCg(_) => {
                let op = LaplaceOperator::new(self.k, s);
                let spec = SolveSpec::defcg()
                    .with_tol(self.cfg.solve_tol)
                    .with_max_iters(self.cfg.max_solver_iters);
                let mgr = self.recycler.as_mut().expect("recycler present for DefCg");
                let dim = mgr.k_active();
                let r = mgr.solve_next(&op, rhs, None, &spec);
                (r.x.clone(), InnerStats::from(&r, dim))
            }
        }
    }

    /// Predict latent values at test points given the fit, using
    /// `f* = K*ᵀ a` (MAP plug-in; K* is the train×test cross-Gram).
    pub fn predict_latent(&self, cross: &Mat, fit: &LaplaceFit) -> Vec<f64> {
        assert_eq!(cross.rows(), self.k.n());
        cross.matvec_t(&fit.a_hat)
    }
}

struct InnerStats {
    iterations: usize,
    matvecs: usize,
    residuals: Vec<f64>,
    deflation_dim: usize,
}

impl InnerStats {
    fn from(r: &SolveResult, deflation_dim: usize) -> Self {
        InnerStats {
            iterations: r.iterations,
            matvecs: r.matvecs,
            residuals: r.residuals.clone(),
            deflation_dim,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digits::{self, DigitsConfig};
    use crate::gp::kernel::RbfKernel;
    use crate::util::rng::Rng;

    /// Small synthetic 2-cluster classification problem.
    fn toy_problem(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat) {
        let mut rng = Rng::new(seed);
        let d = 3;
        let mut x = Mat::zeros(n, d);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let cls = if i % 2 == 0 { 1.0 } else { -1.0 };
            for j in 0..d {
                x[(i, j)] = rng.normal() * 0.5 + cls * 1.5 * ((j == 0) as i32 as f64);
            }
            y[i] = cls;
        }
        let k = RbfKernel::new(1.5, 1.0).gram(&x);
        (x, y, k)
    }

    fn fit_with(backend: SolverBackend, n: usize, seed: u64) -> LaplaceFit {
        let (_x, y, k) = toy_problem(n, seed);
        let kern = DenseKernel::new(k);
        let cfg = LaplaceConfig {
            solver: backend,
            solve_tol: 1e-8,
            newton_tol: 1e-4,
            max_newton: 40,
            max_solver_iters: 0,
        };
        LaplaceGpc::new(&kern, &y, cfg).fit()
    }

    #[test]
    fn newton_increases_psi_monotonically() {
        let fit = fit_with(SolverBackend::Cholesky, 60, 1);
        assert!(fit.converged);
        for w in fit.steps.windows(2) {
            assert!(
                w[1].psi >= w[0].psi - 1e-6,
                "Ψ decreased: {} -> {}",
                w[0].psi,
                w[1].psi
            );
        }
    }

    #[test]
    fn all_backends_agree_on_the_mode() {
        let chol = fit_with(SolverBackend::Cholesky, 50, 2);
        let cg = fit_with(SolverBackend::Cg, 50, 2);
        let pcg = fit_with(SolverBackend::Pcg, 50, 2);
        let defcg = fit_with(
            SolverBackend::DefCg(RecycleConfig { k: 4, l: 8, ..Default::default() }),
            50,
            2,
        );
        let ll = chol.final_log_lik();
        for (name, fit) in [("cg", &cg), ("pcg", &pcg), ("defcg", &defcg)] {
            assert!(
                (fit.final_log_lik() - ll).abs() / ll.abs() < 1e-5,
                "{name} {} vs chol {}",
                fit.final_log_lik(),
                ll
            );
        }
        // Modes agree pointwise.
        for (u, v) in chol.f_hat.iter().zip(&cg.f_hat) {
            assert!((u - v).abs() < 1e-3);
        }
    }

    #[test]
    fn laplace_operator_diag_is_exact() {
        let (_x, _y, k) = toy_problem(30, 7);
        let kern = DenseKernel::new(k.clone());
        let s: Vec<f64> = (0..30).map(|i| 0.1 + 0.01 * i as f64).collect();
        let op = LaplaceOperator::new(&kern, &s);
        let mut fast = vec![0.0; 30];
        op.diag(&mut fast);
        let mut probed = vec![0.0; 30];
        crate::solvers::probe_diag(&op, &mut probed);
        for (f, p) in fast.iter().zip(&probed) {
            assert!((f - p).abs() < 1e-12, "exact {f} vs probed {p}");
        }
        // And it matches the closed form directly.
        for i in 0..30 {
            assert_eq!(fast[i], 1.0 + s[i] * s[i] * k[(i, i)]);
        }
    }

    #[test]
    fn mode_fits_training_labels() {
        let (_x, y, k) = toy_problem(80, 3);
        let kern = DenseKernel::new(k);
        let mut gpc = LaplaceGpc::new(
            &kern,
            &y,
            LaplaceConfig { solver: SolverBackend::Cholesky, newton_tol: 1e-6, ..Default::default() },
        );
        let fit = gpc.fit();
        // The latent mode should classify the (separable) training set well.
        let correct = y
            .iter()
            .zip(&fit.f_hat)
            .filter(|(&yi, &fi)| yi * fi > 0.0)
            .count();
        assert!(correct as f64 / y.len() as f64 > 0.9, "correct = {correct}");
    }

    #[test]
    fn defcg_recycling_saves_iterations_on_later_newton_steps() {
        let n = 120;
        let (_x, y, k) = digits_like_system(n, 4);
        let kern = DenseKernel::new(k);
        let mk_cfg = |solver| LaplaceConfig {
            solver,
            solve_tol: 1e-5,
            newton_tol: 1e-3,
            max_newton: 15,
            max_solver_iters: 0,
        };
        let cg_fit = LaplaceGpc::new(&kern, &y, mk_cfg(SolverBackend::Cg)).fit();
        let def_fit = LaplaceGpc::new(
            &kern,
            &y,
            mk_cfg(SolverBackend::DefCg(RecycleConfig { k: 8, l: 12, ..Default::default() })),
        )
        .fit();
        // Sum inner iterations over Newton steps 2.. (step 1 has no basis).
        let cg_total: usize = cg_fit.steps.iter().skip(1).map(|s| s.solver_iterations).sum();
        let def_total: usize = def_fit.steps.iter().skip(1).map(|s| s.solver_iterations).sum();
        assert!(
            def_total < cg_total,
            "def-CG total {def_total} >= CG total {cg_total}"
        );
    }

    /// A digit-like kernel system (uses the synthetic MNIST generator).
    fn digits_like_system(n: usize, seed: u64) -> (Mat, Vec<f64>, Mat) {
        let ds = digits::generate(&DigitsConfig { n, seed, ..Default::default() });
        let k = RbfKernel::new(1.0, 10.0).gram(&ds.x);
        (ds.x, ds.y, k)
    }

    #[test]
    fn parallel_dense_kernel_fits_identically() {
        // 300 > ParDenseOp::PAR_THRESHOLD: the sharded matvec is exercised
        // for real, and (being bitwise-equal to serial) the whole Newton
        // trajectory must match exactly.
        let (_x, y, k) = toy_problem(300, 6);
        let cfg = LaplaceConfig {
            solver: SolverBackend::Cg,
            solve_tol: 1e-8,
            newton_tol: 1e-4,
            max_newton: 30,
            max_solver_iters: 0,
        };
        let serial = DenseKernel::new(k.clone());
        let fit_s = LaplaceGpc::new(&serial, &y, cfg.clone()).fit();
        let par = DenseKernel::parallel(k, Arc::new(ThreadPool::new(4)));
        let fit_p = LaplaceGpc::new(&par, &y, cfg).fit();
        assert_eq!(fit_s.steps.len(), fit_p.steps.len());
        for (u, v) in fit_s.f_hat.iter().zip(&fit_p.f_hat) {
            assert_eq!(u, v);
        }
    }

    #[test]
    fn predict_latent_on_train_equals_f_hat() {
        let (x, y, k) = toy_problem(40, 5);
        let kern = DenseKernel::new(k.clone());
        let mut gpc = LaplaceGpc::new(
            &kern,
            &y,
            LaplaceConfig { solver: SolverBackend::Cholesky, newton_tol: 1e-8, ..Default::default() },
        );
        let fit = gpc.fit();
        // cross-gram of train with train = K, so prediction = K a = f̂.
        let kk = RbfKernel::new(1.5, 1.0).cross_gram(&x, &x);
        let pred = gpc.predict_latent(&kk, &fit);
        for (p, f) in pred.iter().zip(&fit.f_hat) {
            assert!((p - f).abs() < 1e-8);
        }
    }

    #[test]
    #[should_panic(expected = "labels must be ±1")]
    fn rejects_bad_labels() {
        let k = Mat::identity(3);
        let kern = DenseKernel::new(k);
        let y = vec![1.0, 0.0, -1.0];
        let _ = LaplaceGpc::new(&kern, &y, LaplaceConfig::default());
    }
}
