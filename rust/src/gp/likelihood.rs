//! Logistic (Bernoulli-logit) likelihood for binary GPC.
//!
//! With labels `y ∈ {−1, +1}` and latent `f`, the paper's §3 likelihood is
//! `p(yᵢ | fᵢ) = σ(yᵢ fᵢ) = 1 / (1 + exp(−yᵢ fᵢ))`. This module provides
//! the three quantities the Laplace/Newton loop needs:
//!
//! * `log p(y|f) = Σᵢ log σ(yᵢ fᵢ)` — evaluated with the numerically
//!   stable `log(1 + e⁻ᶻ)` form;
//! * gradient `∇ᵢ = (yᵢ + 1)/2 − πᵢ` with `πᵢ = σ(fᵢ)`;
//! * Hessian diagonal `Hᵢᵢ = πᵢ (1 − πᵢ)` of `−∇∇ log p` (the paper's H,
//!   which is diagonal and PSD for the logit link).

/// Numerically stable logistic sigmoid.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        let e = (-z).exp();
        1.0 / (1.0 + e)
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable `log σ(z) = −log(1 + e^{−z})`.
#[inline]
pub fn log_sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        -(-z).exp().ln_1p()
    } else {
        z - z.exp().ln_1p()
    }
}

/// Logistic likelihood over a label vector.
#[derive(Clone, Debug, Default)]
pub struct Logistic;

impl Logistic {
    /// `log p(y | f)`; `y[i] ∈ {−1, +1}`.
    pub fn log_lik(&self, y: &[f64], f: &[f64]) -> f64 {
        assert_eq!(y.len(), f.len());
        y.iter().zip(f).map(|(&yi, &fi)| log_sigmoid(yi * fi)).sum()
    }

    /// Gradient of `log p(y|f)` w.r.t. f: `(y+1)/2 − σ(f)`.
    pub fn grad(&self, y: &[f64], f: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), f.len());
        assert_eq!(y.len(), out.len());
        for i in 0..y.len() {
            out[i] = 0.5 * (y[i] + 1.0) - sigmoid(f[i]);
        }
    }

    /// Diagonal of `H = −∇∇ log p(y|f)`: `π (1 − π)`, independent of y.
    pub fn hess_diag(&self, f: &[f64], out: &mut [f64]) {
        assert_eq!(f.len(), out.len());
        for i in 0..f.len() {
            let p = sigmoid(f[i]);
            out[i] = (p * (1.0 - p)).max(0.0);
        }
    }

    /// Predictive class probability for latent mean `f` (MAP plug-in).
    pub fn predict(&self, f: f64) -> f64 {
        sigmoid(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::forall;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(100.0) > 1.0 - 1e-12);
        assert!(sigmoid(-100.0) < 1e-12);
        // symmetry σ(−z) = 1 − σ(z)
        for z in [-3.0, -0.5, 0.2, 7.0] {
            assert!((sigmoid(-z) - (1.0 - sigmoid(z))).abs() < 1e-12);
        }
    }

    #[test]
    fn log_sigmoid_stable_at_extremes() {
        assert!(log_sigmoid(800.0).abs() < 1e-12);
        let v = log_sigmoid(-800.0);
        assert!((v + 800.0).abs() < 1e-9, "{v}");
        assert!(v.is_finite());
    }

    #[test]
    fn grad_is_finite_difference_of_loglik() {
        forall("∇ log p matches FD", 20, |g| {
            let n = g.usize_in(1, 10);
            let f = g.normal_vec(n);
            let y: Vec<f64> = (0..n).map(|_| if g.bool() { 1.0 } else { -1.0 }).collect();
            let lik = Logistic;
            let mut grad = vec![0.0; n];
            lik.grad(&y, &f, &mut grad);
            let eps = 1e-6;
            let mut ok = true;
            for i in 0..n {
                let mut fp = f.clone();
                fp[i] += eps;
                let mut fm = f.clone();
                fm[i] -= eps;
                let fd = (lik.log_lik(&y, &fp) - lik.log_lik(&y, &fm)) / (2.0 * eps);
                ok &= (fd - grad[i]).abs() < 1e-5;
            }
            ok
        });
    }

    #[test]
    fn hess_is_negative_second_derivative() {
        forall("H matches −FD²", 20, |g| {
            let n = g.usize_in(1, 8);
            let f = g.normal_vec(n);
            let y: Vec<f64> = (0..n).map(|_| if g.bool() { 1.0 } else { -1.0 }).collect();
            let lik = Logistic;
            let mut h = vec![0.0; n];
            lik.hess_diag(&f, &mut h);
            let eps = 1e-4;
            let mut ok = true;
            for i in 0..n {
                let mut fp = f.clone();
                fp[i] += eps;
                let mut fm = f.clone();
                fm[i] -= eps;
                let f0 = lik.log_lik(&y, &f);
                let fd2 =
                    (lik.log_lik(&y, &fp) - 2.0 * f0 + lik.log_lik(&y, &fm)) / (eps * eps);
                ok &= (-fd2 - h[i]).abs() < 1e-4;
            }
            ok
        });
    }

    #[test]
    fn hess_bounded_by_quarter() {
        // π(1−π) ≤ 1/4, attained at f = 0 — this bound gives the paper's
        // eigenvalue containment λ(A) ∈ [1, n·max K / 4].
        let lik = Logistic;
        let f: Vec<f64> = (-50..50).map(|i| i as f64 / 5.0).collect();
        let mut h = vec![0.0; f.len()];
        lik.hess_diag(&f, &mut h);
        for (i, &v) in h.iter().enumerate() {
            assert!((0.0..=0.25 + 1e-15).contains(&v), "h[{i}] = {v}");
        }
        // maximum at f=0
        let mut h0 = vec![0.0];
        lik.hess_diag(&[0.0], &mut h0);
        assert!((h0[0] - 0.25).abs() < 1e-15);
    }

    #[test]
    fn loglik_is_monotone_in_margin() {
        let lik = Logistic;
        // larger y·f => larger log-likelihood
        assert!(lik.log_lik(&[1.0], &[2.0]) > lik.log_lik(&[1.0], &[1.0]));
        assert!(lik.log_lik(&[-1.0], &[-2.0]) > lik.log_lik(&[-1.0], &[-1.0]));
    }
}
