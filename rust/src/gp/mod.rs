//! Gaussian-process classification — the paper's flagship workload (§3).
//!
//! Binary GPC with a logistic link and a Laplace approximation to the
//! posterior, following Kuss & Rasmussen (2006) / Rasmussen & Williams
//! §3.7.3. Mode-finding is Newton's method; each Newton step requires one
//! SPD solve with
//!
//! ```text
//!   A⁽ⁱ⁾ = I + H^½ K H^½          (paper Eq. 10)
//!   b⁽ⁱ⁾ = H^½ K (H f⁽ⁱ⁾ + ∇ log p(y | f⁽ⁱ⁾))   (paper Eq. 9)
//! ```
//!
//! — exactly the sequence of related SPD systems that subspace recycling
//! targets. The solver backend is pluggable: dense Cholesky (exact
//! baseline), CG, or def-CG(k, ℓ) with a [`crate::solvers::recycle::RecycleManager`].

pub mod hyper;
pub mod inducing;
pub mod kernel;
pub mod laplace;
pub mod likelihood;
pub mod predict;
pub mod regression;

pub use kernel::RbfKernel;
pub use laplace::{LaplaceConfig, LaplaceGpc, NewtonStepStats, SolverBackend};
