//! Laplace predictive distribution (Rasmussen & Williams, Alg. 3.2).
//!
//! After mode-finding (the part the paper accelerates), classification
//! needs the predictive class probability at test points x*:
//!
//! ```text
//!   mean      f̄* = k*ᵀ ∇log p(y|f̂) = k*ᵀ a
//!   variance  v*  = k(x*,x*) − vᵀv,   v = L⁻¹ (W^½ k*),  B = I + W^½KW^½ = LLᵀ
//!   prob      p(y*=+1) ≈ σ( f̄* / √(1 + π v*/8) )        (MacKay's probit approx.)
//! ```
//!
//! The `B` factorization reuses the same matrix the Newton systems solve
//! against, so a direct-backend fit gets prediction almost for free.

use crate::gp::laplace::{KernelOp, LaplaceFit};
use crate::gp::likelihood::Logistic;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;

/// Predictive engine built from a completed Laplace fit.
pub struct LaplacePredictor {
    /// Cholesky factor of B = I + W^½ K W^½.
    b_chol: Cholesky,
    /// W^½ at the mode.
    s: Vec<f64>,
    /// a = K⁻¹ f̂ (from the stable Newton iteration).
    a_hat: Vec<f64>,
}

/// One test point's predictive summary.
#[derive(Clone, Debug, PartialEq)]
pub struct Prediction {
    pub mean: f64,
    pub variance: f64,
    /// p(y* = +1 | x*).
    pub prob: f64,
}

impl LaplacePredictor {
    /// Build from the training kernel (needs the dense K), the fit, and
    /// the training labels.
    pub fn new(k: &dyn KernelOp, fit: &LaplaceFit, _y: &[f64]) -> Result<Self, String> {
        let n = k.n();
        let kd = k.dense().ok_or("LaplacePredictor needs a dense kernel")?;
        let lik = Logistic;
        let mut h = vec![0.0; n];
        lik.hess_diag(&fit.f_hat, &mut h);
        let s: Vec<f64> = h.iter().map(|v| v.sqrt()).collect();
        let mut b = Mat::from_fn(n, n, |i, j| s[i] * kd[(i, j)] * s[j]);
        b.add_diag(1.0);
        let b_chol = Cholesky::factor(&b).map_err(|e| format!("B not SPD: {e}"))?;
        Ok(LaplacePredictor { b_chol, s, a_hat: fit.a_hat.clone() })
    }

    /// Predict for one test point given its train-cross column `k_star`
    /// (length n) and prior variance `k_ss = k(x*, x*)`.
    pub fn predict(&self, k_star: &[f64], k_ss: f64) -> Prediction {
        let n = self.s.len();
        assert_eq!(k_star.len(), n);
        let mean = crate::linalg::vec_ops::dot(k_star, &self.a_hat);
        // v = L⁻¹ (s ∘ k*)
        let sk: Vec<f64> = (0..n).map(|i| self.s[i] * k_star[i]).collect();
        let v = self.b_chol.solve_lower(&sk);
        let variance = (k_ss - crate::linalg::vec_ops::dot(&v, &v)).max(0.0);
        // MacKay's probit-style correction of the plug-in probability.
        let kappa = 1.0 / (1.0 + std::f64::consts::PI * variance / 8.0).sqrt();
        let prob = crate::gp::likelihood::sigmoid(kappa * mean);
        Prediction { mean, variance, prob }
    }

    /// Batch prediction for the columns of a train×test cross-Gram.
    pub fn predict_batch(&self, cross: &Mat, k_ss: &[f64]) -> Vec<Prediction> {
        assert_eq!(cross.cols(), k_ss.len());
        (0..cross.cols())
            .map(|j| self.predict(&cross.col(j), k_ss[j]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::digits::{generate, DigitsConfig};
    use crate::gp::kernel::RbfKernel;
    use crate::gp::laplace::{DenseKernel, LaplaceConfig, LaplaceGpc, SolverBackend};
    use crate::util::rng::Rng;

    fn fitted(n: usize) -> (DenseKernel, LaplaceFit, Vec<f64>, Mat, RbfKernel) {
        let ds = generate(&DigitsConfig { n, seed: 31, ..Default::default() });
        let kernel = RbfKernel::new(1.0, 10.0);
        let k = DenseKernel::new(kernel.gram(&ds.x));
        let mut gpc = LaplaceGpc::new(
            &k,
            &ds.y,
            LaplaceConfig {
                solver: SolverBackend::Cholesky,
                newton_tol: 1e-4,
                max_newton: 20,
                ..Default::default()
            },
        );
        let fit = gpc.fit();
        (k, fit, ds.y, ds.x, kernel)
    }

    #[test]
    fn variance_bounded_by_prior_and_nonnegative() {
        let (k, fit, y, x, kernel) = fitted(60);
        let p = LaplacePredictor::new(&k, &fit, &y).unwrap();
        let mut rng = Rng::new(1);
        let test = Mat::randn(10, x.cols(), &mut rng);
        let cross = kernel.cross_gram(&x, &test);
        let kss: Vec<f64> = (0..10).map(|j| kernel.eval(test.row(j), test.row(j))).collect();
        for pred in p.predict_batch(&cross, &kss) {
            assert!(pred.variance >= 0.0);
            assert!(pred.variance <= kernel.amplitude * kernel.amplitude + 1e-9);
            assert!((0.0..=1.0).contains(&pred.prob));
        }
    }

    #[test]
    fn variance_shrinks_near_training_data() {
        let (k, fit, y, x, kernel) = fitted(60);
        let p = LaplacePredictor::new(&k, &fit, &y).unwrap();
        // At a training point the posterior variance must be below the
        // prior; far away it approaches the prior variance.
        let at_train = p.predict(&kernel.cross_gram(&x, &x.take_rows(&[0])).col(0), kernel.eval(x.row(0), x.row(0)));
        let mut far = vec![100.0; x.cols()];
        far[0] = -100.0;
        let far_m = Mat::from_vec(1, x.cols(), far);
        let at_far = p.predict(&kernel.cross_gram(&x, &far_m).col(0), kernel.eval(far_m.row(0), far_m.row(0)));
        assert!(at_train.variance < at_far.variance);
        assert!((at_far.variance - 1.0).abs() < 1e-3, "far var {}", at_far.variance);
        // Far from data the probability collapses to ~1/2.
        assert!((at_far.prob - 0.5).abs() < 1e-3);
    }

    #[test]
    fn probabilities_track_labels_on_training_set() {
        let (k, fit, y, x, kernel) = fitted(80);
        let p = LaplacePredictor::new(&k, &fit, &y).unwrap();
        let cross = kernel.cross_gram(&x, &x);
        let kss: Vec<f64> = (0..x.rows()).map(|j| kernel.eval(x.row(j), x.row(j))).collect();
        let preds = p.predict_batch(&cross, &kss);
        let correct = preds
            .iter()
            .zip(&y)
            .filter(|(pr, &yi)| (pr.prob > 0.5) == (yi > 0.0))
            .count();
        assert!(correct as f64 / y.len() as f64 > 0.95, "{correct}/{}", y.len());
    }

    #[test]
    fn matches_explicit_formula_small_n() {
        // Direct check against v* = kss − k*ᵀ(K + W⁻¹)⁻¹k* via dense
        // inverse on a tiny problem (equivalent form of the B-based one).
        let (k, fit, y, x, kernel) = fitted(12);
        let p = LaplacePredictor::new(&k, &fit, &y).unwrap();
        use crate::gp::laplace::KernelOp;
        let kd = k.dense().unwrap();
        let n = 12;
        let lik = Logistic;
        let mut h = vec![0.0; n];
        lik.hess_diag(&fit.f_hat, &mut h);
        // (K + W⁻¹)⁻¹ computed densely.
        let mut kw = kd.clone();
        for i in 0..n {
            kw[(i, i)] += 1.0 / h[i].max(1e-300);
        }
        let kw_ch = Cholesky::factor(&kw).unwrap();
        let mut rng = Rng::new(2);
        let t = Mat::randn(1, x.cols(), &mut rng);
        let kstar = kernel.cross_gram(&x, &t).col(0);
        let kss = kernel.eval(t.row(0), t.row(0));
        let sol = kw_ch.solve(&kstar);
        let want = kss - crate::linalg::vec_ops::dot(&kstar, &sol);
        let got = p.predict(&kstar, kss).variance;
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}
