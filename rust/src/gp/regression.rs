//! GP regression with hyperparameter adaptation — the paper's first
//! motivating example (§1): "Model adaptation in Gaussian process models
//! requires the solution of the problem k⁻¹_θ,XX y for a sequence of
//! parameter estimates θ."
//!
//! Each candidate θ = (amplitude, lengthscale, noise) asks for
//! `(K_θ + σ²I) α = y`; neighbouring candidates have similar Gram
//! matrices, so the recycled subspace transfers across the *hyperparameter*
//! sequence (not just a Newton sequence). This module implements:
//!
//! * the regression posterior (mean prediction, log marginal likelihood);
//! * a coordinate-descent hyperparameter adapter whose inner solves run
//!   through one shared [`RecycleManager`].

use crate::gp::kernel::RbfKernel;
use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::dot;
use crate::solvers::api::SolveSpec;
use crate::solvers::recycle::{RecycleConfig, RecycleManager};
use crate::solvers::SpdOperator;

/// Hyperparameters of the regression model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RegressionParams {
    pub amplitude: f64,
    pub lengthscale: f64,
    /// Observation noise standard deviation σ.
    pub noise: f64,
}

/// The regularized kernel operator `K + σ²I` (matrix-free over a dense K).
///
/// This is the borrowed-`Mat` sibling of
/// `solvers::algebra::ShiftedOp(DenseOp(K), σ²)` — same arithmetic, same
/// exact diagonal. Prefer the `ShiftedOp` view when sweeping a σ-grid
/// over one shared base operator (see `gp::hyper::sigma_grid_search`).
pub struct RegularizedKernelOp<'a> {
    k: &'a Mat,
    sigma2: f64,
}

impl<'a> RegularizedKernelOp<'a> {
    pub fn new(k: &'a Mat, noise: f64) -> Self {
        RegularizedKernelOp { k, sigma2: noise * noise }
    }
}

impl<'a> SpdOperator for RegularizedKernelOp<'a> {
    fn n(&self) -> usize {
        self.k.rows()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.k.matvec_into(x, y);
        for i in 0..x.len() {
            y[i] += self.sigma2 * x[i];
        }
    }

    /// Fused block form `K·X + σ²X`: the cache-blocked panel kernel over
    /// K plus an elementwise shift — per column the exact single-vector
    /// float sequence.
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.k.block_matvec_into(xs, ys);
        for (yv, xv) in ys.data_mut().iter_mut().zip(xs.data()) {
            *yv += self.sigma2 * xv;
        }
    }

    /// Exact diagonal `K_ii + σ²` (see the [`SpdOperator::diag`] contract).
    fn diag(&self, out: &mut [f64]) {
        self.k.diag_into(out);
        for o in out.iter_mut() {
            *o += self.sigma2;
        }
    }
}

/// A fitted regression state for one hyperparameter setting.
#[derive(Clone, Debug)]
pub struct RegressionFit {
    pub params: RegressionParams,
    /// α = (K + σ²I)⁻¹ y.
    pub alpha: Vec<f64>,
    /// Inner-solver iterations spent on this fit.
    pub solver_iterations: usize,
    /// Data-fit part of the log marginal likelihood: −½ yᵀα.
    pub data_fit: f64,
}

/// One evaluation step of the adapter.
#[derive(Clone, Debug)]
pub struct AdaptStep {
    pub params: RegressionParams,
    pub objective: f64,
    pub solver_iterations: usize,
    pub deflation_dim: usize,
}

/// GP regression over a fixed training set with a shared recycle manager.
pub struct GpRegression<'a> {
    x: &'a Mat,
    y: &'a [f64],
    mgr: RecycleManager,
    spec: SolveSpec,
}

impl<'a> GpRegression<'a> {
    pub fn new(x: &'a Mat, y: &'a [f64], recycle: RecycleConfig, tol: f64) -> Self {
        assert_eq!(x.rows(), y.len());
        GpRegression {
            x,
            y,
            mgr: RecycleManager::new(recycle),
            spec: SolveSpec::defcg().with_tol(tol),
        }
    }

    /// Solve `(K_θ + σ²I) α = y` with the recycled subspace carried from
    /// the previous hyperparameter setting.
    pub fn fit(&mut self, p: RegressionParams) -> RegressionFit {
        let kernel = RbfKernel::new(p.amplitude, p.lengthscale);
        let k = kernel.gram(self.x);
        let op = RegularizedKernelOp::new(&k, p.noise);
        let r = self.mgr.solve_next(&op, self.y, None, &self.spec);
        let data_fit = -0.5 * dot(self.y, &r.x);
        RegressionFit {
            params: p,
            alpha: r.x,
            solver_iterations: r.iterations,
            data_fit,
        }
    }

    /// Exact log marginal likelihood (Cholesky; used as the adapter's
    /// objective on moderate n):
    /// `log p(y|X,θ) = −½ yᵀα − ½ log|K+σ²I| − n/2 log 2π`.
    pub fn log_marginal(&self, p: RegressionParams) -> f64 {
        let kernel = RbfKernel::new(p.amplitude, p.lengthscale);
        let mut k = kernel.gram(self.x);
        k.add_diag(p.noise * p.noise);
        let ch = Cholesky::factor(&k).expect("K + σ²I SPD");
        let alpha = ch.solve(self.y);
        let n = self.y.len() as f64;
        -0.5 * dot(self.y, &alpha)
            - 0.5 * ch.log_det()
            - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }

    /// Predictive mean at test points: `f* = K*ᵀ α`.
    pub fn predict_mean(&self, p: RegressionParams, fit: &RegressionFit, x_test: &Mat) -> Vec<f64> {
        let kernel = RbfKernel::new(p.amplitude, p.lengthscale);
        kernel.cross_gram(x_test, self.x).matvec(&fit.alpha)
    }

    /// Coordinate-descent adaptation over a lengthscale ladder: evaluates
    /// each candidate's marginal likelihood, with all the inner solves
    /// sharing the recycled subspace. Returns the visited steps.
    pub fn adapt_lengthscale(
        &mut self,
        base: RegressionParams,
        ladder: &[f64],
    ) -> Vec<AdaptStep> {
        let mut steps = Vec::new();
        for &ls in ladder {
            let p = RegressionParams { lengthscale: ls, ..base };
            let fit = self.fit(p);
            let objective = self.log_marginal(p);
            steps.push(AdaptStep {
                params: p,
                objective,
                solver_iterations: fit.solver_iterations,
                deflation_dim: self.mgr.k_active(),
            });
        }
        steps
    }

    pub fn manager(&self) -> &RecycleManager {
        &self.mgr
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Smooth 1-D-manifold regression data embedded in 5 dims.
    fn make_data(n: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Mat::zeros(n, 5);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let t = 4.0 * (i as f64 / n as f64) - 2.0;
            for j in 0..5 {
                x[(i, j)] = t * (j as f64 + 1.0).sqrt() + 0.01 * rng.normal();
            }
            y[i] = (2.0 * t).sin() + 0.05 * rng.normal();
        }
        (x, y)
    }

    fn params(ls: f64) -> RegressionParams {
        RegressionParams { amplitude: 1.0, lengthscale: ls, noise: 0.1 }
    }

    #[test]
    fn fit_matches_cholesky_solution() {
        let (x, y) = make_data(60, 1);
        let mut gp = GpRegression::new(&x, &y, RecycleConfig::default(), 1e-10);
        let p = params(1.5);
        let fit = gp.fit(p);
        // Direct solve reference.
        let mut k = RbfKernel::new(1.0, 1.5).gram(&x);
        k.add_diag(0.01);
        let want = Cholesky::factor(&k).unwrap().solve(&y);
        for (u, v) in fit.alpha.iter().zip(&want) {
            assert!((u - v).abs() < 1e-6, "{u} vs {v}");
        }
    }

    #[test]
    fn predictions_interpolate_training_data() {
        let (x, y) = make_data(80, 2);
        let mut gp = GpRegression::new(&x, &y, RecycleConfig::default(), 1e-8);
        let p = params(1.0);
        let fit = gp.fit(p);
        let pred = gp.predict_mean(p, &fit, &x);
        // With small noise the posterior mean tracks y closely.
        let mse: f64 =
            pred.iter().zip(&y).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / y.len() as f64;
        assert!(mse < 0.01, "mse = {mse}");
    }

    #[test]
    fn recycling_across_hyperparameter_ladder_saves_iterations() {
        // The paper's §1 scenario: a sequence of θ estimates. Compare
        // total iterations with and without subspace transfer.
        let (x, y) = make_data(120, 3);
        let ladder: Vec<f64> = vec![2.0, 1.9, 1.8, 1.7, 1.6, 1.5];
        let base = params(2.0);

        let mut with = GpRegression::new(&x, &y, RecycleConfig { k: 8, l: 12, ..Default::default() }, 1e-8);
        let steps_with = with.adapt_lengthscale(base, &ladder);

        let mut without =
            GpRegression::new(&x, &y, RecycleConfig { k: 0, l: 0, ..Default::default() }, 1e-8);
        let steps_without = without.adapt_lengthscale(base, &ladder);

        let tot = |s: &[AdaptStep]| s.iter().skip(1).map(|t| t.solver_iterations).sum::<usize>();
        assert!(
            tot(&steps_with) < tot(&steps_without),
            "recycled {} >= plain {}",
            tot(&steps_with),
            tot(&steps_without)
        );
        // First candidates identical (no basis yet).
        assert_eq!(
            steps_with[0].solver_iterations,
            steps_without[0].solver_iterations
        );
    }

    #[test]
    fn marginal_likelihood_prefers_sane_lengthscale() {
        let (x, y) = make_data(60, 4);
        let gp = GpRegression::new(&x, &y, RecycleConfig::default(), 1e-8);
        let tiny = gp.log_marginal(params(0.01)); // overfits noise
        let sane = gp.log_marginal(params(1.0));
        let huge = gp.log_marginal(params(100.0)); // underfits everything
        assert!(sane > tiny, "sane {sane} <= tiny {tiny}");
        assert!(sane > huge, "sane {sane} <= huge {huge}");
    }

    #[test]
    fn adapt_reports_deflation_growth() {
        let (x, y) = make_data(60, 5);
        let mut gp =
            GpRegression::new(&x, &y, RecycleConfig { k: 4, l: 8, ..Default::default() }, 1e-7);
        let steps = gp.adapt_lengthscale(params(1.2), &[1.2, 1.1, 1.0]);
        assert_eq!(steps.len(), 3);
        assert!(steps.last().unwrap().deflation_dim > 0);
        assert!(steps.iter().all(|s| s.objective.is_finite()));
    }
}
