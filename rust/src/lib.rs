//! # krr — Krylov subspace recycling for sequences of SPD systems
//!
//! A three-layer (Rust + JAX + Pallas, AOT via XLA/PJRT) reproduction of
//! *Krylov Subspace Recycling for Fast Iterative Least-Squares in Machine
//! Learning* (de Roos & Hennig, 2017).
//!
//! The library solves **sequences** of symmetric positive definite linear
//! systems `A⁽ⁱ⁾ x⁽ⁱ⁾ = b⁽ⁱ⁾` — the shape that Newton loops, Laplace
//! approximations and GP hyperparameter adaptation produce — and transfers
//! spectral information between consecutive systems via **deflated
//! conjugate gradients** (Saad et al., 2000) with harmonic-Ritz recycling
//! (Morgan, 1995).
//!
//! Layer map (see DESIGN.md):
//! * [`solvers`] — the unified [`solvers::SolveSpec`] API (one
//!   `solve(op, b, &spec)` entry point across CG / PCG / def-CG /
//!   block CG, with preconditioning and deflation as data), the
//!   **block-first** operator trait ([`solvers::SpdOperator`] with
//!   `apply_block`), the operator algebra ([`solvers::algebra`]:
//!   shifted / scaled / sum / low-rank views over one base operator),
//!   the underlying kernels, Cholesky, Lanczos, recycling state, and the
//!   pool-sharded parallel dense operator (`ParDenseOp`).
//! * [`gp`] — GP classification with Laplace/Newton (the paper's workload).
//! * [`coordinator`] — the solve-service that owns recycling across a
//!   sequence and dispatches matvec traffic.
//! * [`runtime`] — the artifact engine: a pure-Rust native backend by
//!   default, the PJRT/XLA path behind the `pjrt` feature.
//! * [`linalg`], [`data`], [`util`] — substrates built from scratch.
// Style allowances for hand-rolled numerical kernels: explicit index
// loops mirror the paper's algorithm statements and keep bounds visible.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod gp;
pub mod linalg;
pub mod runtime;
pub mod solvers;
pub mod util;
