//! Cholesky factorization `A = L Lᵀ` and solves.
//!
//! This is the paper's exact baseline (Table 1, "Cholesky" column) and the
//! inner small-system solver inside deflated CG (`WᵀAW μ = WᵀA r`,
//! Algorithm 1 line 11). The factorization is the standard right-looking
//! variant with a column inner loop expressed as dot products over the
//! already-computed rows of L, which keeps memory access contiguous for
//! row-major storage.

use crate::linalg::mat::Mat;
use crate::linalg::vec_ops;

/// A computed Cholesky factorization (lower factor).
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

/// Factorization failure: matrix not positive definite within tolerance.
#[derive(Debug, Clone)]
pub struct NotSpd {
    /// Pivot index where the failure occurred.
    pub at: usize,
    /// Value of the failing pivot.
    pub pivot: f64,
}

impl std::fmt::Display for NotSpd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix not SPD: pivot {} at index {}", self.pivot, self.at)
    }
}

impl std::error::Error for NotSpd {}

impl Cholesky {
    /// Factorize a symmetric positive definite matrix.
    pub fn factor(a: &Mat) -> Result<Cholesky, NotSpd> {
        assert!(a.is_square(), "Cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = A[i,j] - sum_k L[i,k] L[j,k]  over k < j
                let (li, lj) = (l.row(i), l.row(j));
                let s = a[(i, j)] - vec_ops::dot(&li[..j], &lj[..j]);
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return Err(NotSpd { at: i, pivot: s });
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// The lower-triangular factor L.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    pub fn n(&self) -> usize {
        self.l.rows()
    }

    /// Solve A x = b (two triangular solves), allocating.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve A x = b in place.
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.n();
        assert_eq!(x.len(), n, "rhs size mismatch");
        // Forward: L y = b
        for i in 0..n {
            let s = vec_ops::dot(&self.l.row(i)[..i], &x[..i]);
            x[i] = (x[i] - s) / self.l[(i, i)];
        }
        // Backward: Lᵀ x = y  (column access on L = row access on Lᵀ)
        for i in (0..n).rev() {
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.l[(k, i)] * x[k];
            }
            x[i] = s / self.l[(i, i)];
        }
    }

    /// Solve A X = B column-by-column.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows(), self.n());
        let mut x = Mat::zeros(b.rows(), b.cols());
        let mut col = vec![0.0; b.rows()];
        for j in 0..b.cols() {
            for i in 0..b.rows() {
                col[i] = b[(i, j)];
            }
            self.solve_in_place(&mut col);
            x.set_col(j, &col);
        }
        x
    }

    /// log |A| = 2 Σ log L_ii (needed for the GP marginal likelihood).
    pub fn log_det(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.n() {
            s += self.l[(i, i)].ln();
        }
        2.0 * s
    }

    /// Solve L y = b (forward substitution only).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            let s = vec_ops::dot(&self.l.row(i)[..i], &y[..i]);
            y[i] = (y[i] - s) / self.l[(i, i)];
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn factor_known_matrix() {
        // A = [[4, 2], [2, 3]] -> L = [[2, 0], [1, sqrt(2)]]
        let a = Mat::from_vec(2, 2, vec![4., 2., 2., 3.]);
        let ch = Cholesky::factor(&a).unwrap();
        assert!((ch.l()[(0, 0)] - 2.0).abs() < 1e-12);
        assert!((ch.l()[(1, 0)] - 1.0).abs() < 1e-12);
        assert!((ch.l()[(1, 1)] - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn llt_reconstructs_a() {
        forall("L·Lᵀ == A", 25, |g| {
            let n = g.usize_in(1, 20);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e4));
            let ch = Cholesky::factor(&a).unwrap();
            let rec = ch.l().matmul(&ch.l().transpose());
            rec.max_abs_diff(&a) < 1e-8 * (1.0 + a.fro_norm())
        });
    }

    #[test]
    fn solve_recovers_x() {
        forall("A·solve(b) == b", 25, |g| {
            let n = g.usize_in(1, 20);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e4));
            let x_true = g.normal_vec(n);
            let b = a.matvec(&x_true);
            let x = Cholesky::factor(&a).unwrap().solve(&b);
            x.iter().zip(&x_true).all(|(u, v)| (u - v).abs() < 1e-6)
        });
    }

    #[test]
    fn solve_mat_matches_columnwise() {
        let mut rng = Rng::new(42);
        let a = Mat::rand_spd(8, 100.0, &mut rng);
        let b = Mat::randn(8, 3, &mut rng);
        let ch = Cholesky::factor(&a).unwrap();
        let x = ch.solve_mat(&b);
        for j in 0..3 {
            let xj = ch.solve(&b.col(j));
            for i in 0..8 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-12);
            }
        }
        // And A X ≈ B
        let rec = a.matmul(&x);
        assert!(rec.max_abs_diff(&b) < 1e-8);
    }

    #[test]
    fn log_det_matches_identity_scaling() {
        // det(c·I_n) = c^n
        let n = 6;
        let c = 2.5;
        let mut a = Mat::identity(n);
        a.scale_in_place(c);
        let ld = Cholesky::factor(&a).unwrap().log_det();
        assert!((ld - n as f64 * c.ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 1.]); // eigenvalues 3, -1
        let e = Cholesky::factor(&a).unwrap_err();
        assert_eq!(e.at, 1);
        assert!(e.pivot <= 0.0);
    }

    #[test]
    fn solve_lower_is_forward_substitution() {
        let a = Mat::from_vec(2, 2, vec![4., 2., 2., 3.]);
        let ch = Cholesky::factor(&a).unwrap();
        let y = ch.solve_lower(&[2.0, 1.0 + 2f64.sqrt()]);
        // L y = b with L = [[2,0],[1,sqrt2]] -> y = [1, 1]
        assert!((y[0] - 1.0).abs() < 1e-12);
        assert!((y[1] - 1.0).abs() < 1e-12);
    }
}
