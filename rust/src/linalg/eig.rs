//! Symmetric and generalized-symmetric eigensolvers.
//!
//! * [`sym_eig`] — full eigendecomposition of a real symmetric matrix via
//!   Householder tridiagonalization (EISPACK `tred2`) followed by the
//!   implicit-shift QL iteration (`tql2`). Eigenvalues ascend.
//! * [`sym_tridiag_eig`] — QL directly on a tridiagonal (used for the
//!   Lanczos path and by `sym_eig`).
//! * [`gen_sym_eig`] — the harmonic-projection problem of the paper,
//!   Eq. (7): `G u = θ F u` with `G = (AZ)ᵀ(AZ)` SPD and `F = (AZ)ᵀZ`
//!   symmetric. Reduced to a standard symmetric problem with the Cholesky
//!   factor of `G`: `S w = μ w`, `S = L⁻¹ F L⁻ᵀ`, `θ = 1/μ`, `u = L⁻ᵀ w`.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;

/// Eigendecomposition result: `values[i]` ascending with eigenvector
/// `vectors.col(i)`.
#[derive(Clone, Debug)]
pub struct EigResult {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

/// Householder tridiagonalization with accumulation (EISPACK tred2).
/// Returns (d, e, z): diagonal, off-diagonal (e[0] unused), and the
/// orthogonal accumulation matrix such that `zᵀ a z = tridiag(d, e)`.
fn tred2(a: &Mat) -> (Vec<f64>, Vec<f64>, Mat) {
    let n = a.rows();
    let mut z = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        let mut scale = 0.0;
        if l > 0 {
            for k in 0..=l {
                scale += z[(i, k)].abs();
            }
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let mut f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                f = 0.0;
                for j in 0..=l {
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    f += e[j] * z[(i, j)];
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let upd = f * e[k] + g * z[(i, k)];
                        z[(j, k)] -= upd;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        let l = i;
        if d[i] != 0.0 {
            for j in 0..l {
                let mut g = 0.0;
                for k in 0..l {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..l {
                    let upd = g * z[(k, i)];
                    z[(k, j)] -= upd;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..l {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }
    (d, e, z)
}

/// Implicit-shift QL on a symmetric tridiagonal, accumulating eigenvectors
/// into `z` (EISPACK tql2). `d` diagonal, `e` sub-diagonal with e[0] unused.
fn tql2(d: &mut [f64], e: &mut [f64], z: &mut Mat) -> Result<(), String> {
    let n = d.len();
    if n == 0 {
        return Ok(());
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal to split.
            let mut m = l;
            while m < n - 1 {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(format!("tql2: no convergence at eigenvalue {l}"));
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    // Sort ascending, permuting vectors.
    for i in 0..n {
        let mut kmin = i;
        for j in (i + 1)..n {
            if d[j] < d[kmin] {
                kmin = j;
            }
        }
        if kmin != i {
            d.swap(i, kmin);
            for r in 0..n {
                let tmp = z[(r, i)];
                z[(r, i)] = z[(r, kmin)];
                z[(r, kmin)] = tmp;
            }
        }
    }
    Ok(())
}

/// Full symmetric eigendecomposition. Panics on non-square input; returns
/// an error only if QL fails to converge (essentially never for symmetric
/// input).
pub fn sym_eig(a: &Mat) -> Result<EigResult, String> {
    assert!(a.is_square(), "sym_eig needs square input");
    let (mut d, mut e, mut z) = tred2(a);
    tql2(&mut d, &mut e, &mut z)?;
    Ok(EigResult { values: d, vectors: z })
}

/// Eigendecomposition of a symmetric tridiagonal given diagonal `diag` and
/// sub-diagonal `off` (len n-1). Used on the Lanczos T matrix.
pub fn sym_tridiag_eig(diag: &[f64], off: &[f64]) -> Result<EigResult, String> {
    let n = diag.len();
    assert!(off.len() + 1 == n || (n == 0 && off.is_empty()), "off-diagonal length");
    let mut d = diag.to_vec();
    let mut e = vec![0.0; n];
    for i in 1..n {
        e[i] = off[i - 1];
    }
    let mut z = Mat::identity(n);
    tql2(&mut d, &mut e, &mut z)?;
    Ok(EigResult { values: d, vectors: z })
}

/// Generalized symmetric-definite problem `G u = θ F u` (paper Eq. 7) with
/// `G` SPD and `F` symmetric. Returns pairs (θ_j, u_j) sorted by **θ
/// descending in magnitude** with infinite θ (μ ≈ 0) filtered out; the
/// def-CG recycling step selects the leading k.
pub fn gen_sym_eig(g_mat: &Mat, f_mat: &Mat) -> Result<Vec<(f64, Vec<f64>)>, String> {
    assert!(g_mat.is_square() && f_mat.is_square());
    assert_eq!(g_mat.rows(), f_mat.rows());
    let n = g_mat.rows();
    let ch = Cholesky::factor(g_mat).map_err(|e| format!("G not SPD: {e}"))?;
    // S = L⁻¹ F L⁻ᵀ, built column-wise: first X = L⁻¹ F (forward solve per
    // column of F), then S = L⁻¹ Xᵀ  (since (L⁻¹ F L⁻ᵀ) = L⁻¹ (L⁻¹ Fᵀ)ᵀ and
    // F symmetric).
    let mut x = Mat::zeros(n, n);
    for j in 0..n {
        let col = ch.solve_lower(&f_mat.col(j));
        x.set_col(j, &col);
    }
    let xt = x.transpose();
    let mut s = Mat::zeros(n, n);
    for j in 0..n {
        let col = ch.solve_lower(&xt.col(j));
        s.set_col(j, &col);
    }
    s.symmetrize();
    let eig = sym_eig(&s)?;
    // θ = 1/μ; back-transform u = L⁻ᵀ w via the Cholesky backward solve.
    let mut out: Vec<(f64, Vec<f64>)> = Vec::with_capacity(n);
    // scale for the μ≈0 cutoff
    let mu_max = eig.values.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    for j in 0..n {
        let mu = eig.values[j];
        if mu.abs() <= 1e-14 * (1.0 + mu_max) {
            continue; // θ infinite: direction lies in null(F) after scaling
        }
        let w = eig.vectors.col(j);
        // Solve Lᵀ u = w.
        let l = ch.l();
        let mut u = w.clone();
        for i in (0..n).rev() {
            let mut t = u[i];
            for k in (i + 1)..n {
                t -= l[(k, i)] * u[k];
            }
            u[i] = t / l[(i, i)];
        }
        let theta = 1.0 / mu;
        if !theta.is_finite() {
            continue; // μ denormal enough to overflow θ — as useless as μ = 0
        }
        out.push((theta, u));
    }
    // total_cmp: a non-finite θ slipping through (e.g. NaN from a
    // degenerate backsolve) must never panic the caller's thread — the
    // callers run on service drainers.
    out.sort_by(|a, b| b.0.abs().total_cmp(&a.0.abs()));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    fn eig_residual(a: &Mat, eig: &EigResult) -> f64 {
        // max_j ‖A v_j − λ_j v_j‖
        let mut worst = 0.0f64;
        for j in 0..a.rows() {
            let v = eig.vectors.col(j);
            let av = a.matvec(&v);
            let mut r = 0.0;
            for i in 0..a.rows() {
                r += (av[i] - eig.values[j] * v[i]).powi(2);
            }
            worst = worst.max(r.sqrt());
        }
        worst
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let e = sym_eig(&a).unwrap();
        for (i, &v) in e.values.iter().enumerate() {
            assert!((v - (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 1, 3
        let a = Mat::from_vec(2, 2, vec![2., 1., 1., 2.]);
        let e = sym_eig(&a).unwrap();
        assert!((e.values[0] - 1.0).abs() < 1e-12);
        assert!((e.values[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn eig_residuals_small_random_spd() {
        forall("A v == λ v", 15, |g| {
            let n = g.usize_in(2, 25);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e5));
            let e = sym_eig(&a).unwrap();
            eig_residual(&a, &e) < 1e-7 * (1.0 + a.fro_norm())
        });
    }

    #[test]
    fn eigenvectors_orthonormal() {
        forall("VᵀV == I", 15, |g| {
            let n = g.usize_in(2, 20);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e4));
            let e = sym_eig(&a).unwrap();
            let vtv = e.vectors.t_matmul(&e.vectors);
            vtv.max_abs_diff(&Mat::identity(n)) < 1e-9
        });
    }

    #[test]
    fn eigenvalues_ascend_and_match_trace() {
        forall("tr(A) == Σλ", 15, |g| {
            let n = g.usize_in(2, 20);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e3));
            let e = sym_eig(&a).unwrap();
            let ascending = e.values.windows(2).all(|w| w[0] <= w[1] + 1e-12);
            let tr: f64 = (0..n).map(|i| a[(i, i)]).sum();
            let sum: f64 = e.values.iter().sum();
            ascending && (tr - sum).abs() < 1e-8 * (1.0 + tr.abs())
        });
    }

    #[test]
    fn tridiag_eig_matches_dense() {
        let diag = vec![2.0, 3.0, 4.0, 5.0];
        let off = vec![1.0, 0.5, 0.25];
        let t = Mat::from_fn(4, 4, |i, j| {
            if i == j {
                diag[i]
            } else if i + 1 == j || j + 1 == i {
                off[i.min(j)]
            } else {
                0.0
            }
        });
        let e1 = sym_tridiag_eig(&diag, &off).unwrap();
        let e2 = sym_eig(&t).unwrap();
        for (a, b) in e1.values.iter().zip(&e2.values) {
            assert!((a - b).abs() < 1e-10);
        }
        assert!(eig_residual(&t, &e1) < 1e-10);
    }

    #[test]
    fn gen_sym_eig_residuals() {
        // G u = θ F u with random SPD G and symmetric F.
        forall("G u == θ F u", 10, |g| {
            let n = g.usize_in(2, 12);
            let gm = Mat::from_vec(n, n, g.spd_matrix(n, 100.0));
            let mut fm = {
                let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
                Mat::randn(n, n, &mut rng)
            };
            fm.symmetrize();
            let pairs = gen_sym_eig(&gm, &fm).unwrap();
            if pairs.is_empty() {
                return true;
            }
            pairs.iter().all(|(theta, u)| {
                let gu = gm.matvec(u);
                let fu = fm.matvec(u);
                let mut r = 0.0;
                let mut scale = 0.0;
                for i in 0..n {
                    r += (gu[i] - theta * fu[i]).powi(2);
                    scale += gu[i].powi(2) + (theta * fu[i]).powi(2);
                }
                r.sqrt() <= 1e-6 * (1.0 + scale.sqrt())
            })
        });
    }

    #[test]
    fn gen_sym_eig_identity_g_reduces_to_inverse_eigs() {
        // G = I: I u = θ F u  ⇔  F u = (1/θ) u.
        let mut rng = Rng::new(17);
        let mut f = Mat::randn(5, 5, &mut rng);
        f.symmetrize();
        let pairs = gen_sym_eig(&Mat::identity(5), &f).unwrap();
        let fe = sym_eig(&f).unwrap();
        let mut thetas: Vec<f64> = pairs.iter().map(|(t, _)| 1.0 / t).collect();
        thetas.sort_by(|a, b| a.total_cmp(b));
        let mut expect: Vec<f64> = fe.values.iter().copied().filter(|v| v.abs() > 1e-12).collect();
        expect.sort_by(|a, b| a.total_cmp(b));
        assert_eq!(thetas.len(), expect.len());
        for (a, b) in thetas.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn one_by_one() {
        let a = Mat::from_vec(1, 1, vec![3.0]);
        let e = sym_eig(&a).unwrap();
        assert_eq!(e.values, vec![3.0]);
        assert!((e.vectors[(0, 0)].abs() - 1.0).abs() < 1e-15);
    }
}
