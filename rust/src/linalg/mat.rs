//! Row-major dense matrix with cache-blocked kernels.

use crate::linalg::vec_ops;
use crate::util::precision;
use crate::util::rng::Rng;
use std::fmt;

/// Row-major dense `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if cmax < self.cols { "…" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Mat {
    // ---- constructors -------------------------------------------------

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Mat { rows, cols, data }
    }

    /// Random i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Mat { rows, cols, data }
    }

    /// Random SPD matrix with log-spaced spectrum in [1, cond].
    pub fn rand_spd(n: usize, cond: f64, rng: &mut Rng) -> Mat {
        let mut g = crate::util::quickprop::Gen::from_rng(rng.fork());
        Mat::from_vec(n, n, g.spd_matrix(n, cond))
    }

    // ---- accessors -----------------------------------------------------

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Copy column `j` into `out` — the allocation-free [`Mat::col`],
    /// for callers reading columns inside solver iteration loops.
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.rows);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self[(i, j)];
        }
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    // ---- elementwise ----------------------------------------------------

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn scale_in_place(&mut self, s: f64) {
        vec_ops::scale(&mut self.data, s);
    }

    pub fn add_in_place(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        vec_ops::axpy(1.0, &other.data, &mut self.data);
    }

    /// self += s * I (square only).
    pub fn add_diag(&mut self, s: f64) {
        assert!(self.is_square());
        for i in 0..self.rows {
            self[(i, i)] += s;
        }
    }

    /// Symmetrize: self <- (self + selfᵀ)/2.
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let m = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = m;
                self[(j, i)] = m;
            }
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        vec_ops::norm2(&self.data)
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    // ---- products --------------------------------------------------------

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into a caller buffer. Row-major rows are contiguous, so each
    /// output element is one `dot` — this auto-vectorizes well.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec dim");
        assert_eq!(y.len(), self.rows, "matvec dim");
        for i in 0..self.rows {
            y[i] = vec_ops::dot(self.row(i), x);
        }
    }

    /// Column-panel width of [`Mat::block_matvec_into`]: wide enough that
    /// each streamed A row is reused across many right-hand columns, small
    /// enough that the gathered panel (`BLOCK_PANEL · rows` doubles) stays
    /// cache-resident while A streams past it.
    pub const BLOCK_PANEL: usize = 16;

    /// `ys = A · xs` — the block (multi-vector) matvec behind
    /// [`crate::solvers::SpdOperator::apply_block`].
    ///
    /// Computed in column panels of up to [`Mat::BLOCK_PANEL`]: the panel's
    /// columns are gathered once into contiguous buffers, then every row of
    /// A is read **once per panel** and dotted against each of them. Each
    /// output element is the same `dot(row, column)` the per-column
    /// [`Mat::matvec_into`] loop computes, so the result is **bitwise
    /// identical** to `xs.cols()` single matvecs — the block form changes
    /// memory traffic (A streamed once per panel instead of once per
    /// column), never the float sequence.
    pub fn block_matvec_into(&self, xs: &Mat, ys: &mut Mat) {
        assert_eq!(xs.rows(), self.cols, "block_matvec dim");
        assert_eq!(ys.rows(), self.rows, "block_matvec dim");
        assert_eq!(xs.cols(), ys.cols(), "block_matvec dim");
        let cols: Vec<Vec<f64>> = (0..xs.cols()).map(|j| xs.col(j)).collect();
        self.block_matvec_rows(0, self.rows, &cols, ys);
    }

    /// The panel-dot kernel of [`Mat::block_matvec_into`] restricted to
    /// rows `lo..hi`: `out[i - lo][j] = dot(A.row(i), cols[j])`, with the
    /// operand columns pre-gathered into contiguous buffers by the
    /// caller. This single implementation serves both the serial
    /// [`Mat::block_matvec_into`] (full row range) and the row shards of
    /// `solvers::ParDenseOp::apply_block`, so the bitwise
    /// column-equivalence contract lives in exactly one loop nest.
    pub(crate) fn block_matvec_rows(&self, lo: usize, hi: usize, cols: &[Vec<f64>], out: &mut Mat) {
        debug_assert!(lo <= hi && hi <= self.rows);
        assert_eq!(out.rows(), hi - lo, "block_matvec rows dim");
        assert_eq!(out.cols(), cols.len(), "block_matvec dim");
        let k = cols.len();
        let mut j0 = 0;
        while j0 < k {
            let jw = (k - j0).min(Self::BLOCK_PANEL);
            for i in lo..hi {
                let row = self.row(i);
                for (jj, col) in cols[j0..j0 + jw].iter().enumerate() {
                    out[(i - lo, j0 + jj)] = vec_ops::dot(row, col);
                }
            }
            j0 += jw;
        }
    }

    /// y = Aᵀ x (allocating). Column access: accumulate row-wise to stay
    /// cache-friendly.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "matvec_t dim");
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                vec_ops::axpy(xi, self.row(i), &mut y);
            }
        }
        y
    }

    /// Copy the main diagonal into `out` (square matrices only) — the
    /// exact-`diag` building block shared by the dense operator overrides
    /// of `SpdOperator::diag`.
    pub fn diag_into(&self, out: &mut [f64]) {
        assert!(self.is_square(), "diag_into needs a square matrix");
        assert_eq!(out.len(), self.rows, "diag dimension mismatch");
        for (i, o) in out.iter_mut().enumerate() {
            *o = self[(i, i)];
        }
    }

    /// y += Σⱼ coef[j] · colⱼ — the skinny update at the heart of the
    /// deflated solvers (`x += W γ`). Zero coefficients skip their column
    /// entirely, which keeps the common sparse-γ case cheap and leaves the
    /// float sequence identical to the hand-rolled loops it replaces.
    pub fn add_scaled_cols(&self, coef: &[f64], y: &mut [f64]) {
        assert_eq!(coef.len(), self.cols, "add_scaled_cols dim");
        assert_eq!(y.len(), self.rows, "add_scaled_cols dim");
        for j in 0..self.cols {
            let c = coef[j];
            if c != 0.0 {
                for i in 0..self.rows {
                    y[i] += c * self[(i, j)];
                }
            }
        }
    }

    /// y −= Σⱼ coef[j] · colⱼ — the Jacobi-deflation composition helper
    /// (direction deflection `p −= W μ`). See [`Mat::add_scaled_cols`].
    pub fn sub_scaled_cols(&self, coef: &[f64], y: &mut [f64]) {
        assert_eq!(coef.len(), self.cols, "sub_scaled_cols dim");
        assert_eq!(y.len(), self.rows, "sub_scaled_cols dim");
        for j in 0..self.cols {
            let c = coef[j];
            if c != 0.0 {
                for i in 0..self.rows {
                    y[i] -= c * self[(i, j)];
                }
            }
        }
    }

    /// C = A · B, blocked i-k-j loop order (B rows stream through cache).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul dim {}x{} · {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut c = Mat::zeros(self.rows, b.cols);
        const BK: usize = 64;
        for kb in (0..self.cols).step_by(BK) {
            let kend = (kb + BK).min(self.cols);
            for i in 0..self.rows {
                let crow = c.row_mut(i);
                for k in kb..kend {
                    let aik = self.data[i * self.cols + k];
                    if aik != 0.0 {
                        vec_ops::axpy(aik, &b.data[k * b.cols..(k + 1) * b.cols], crow);
                    }
                }
            }
        }
        c
    }

    /// C = Aᵀ · B without forming Aᵀ.
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "t_matmul dim");
        let mut c = Mat::zeros(self.cols, b.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = b.row(k);
            for i in 0..self.cols {
                let aki = arow[i];
                if aki != 0.0 {
                    vec_ops::axpy(aki, brow, c.row_mut(i));
                }
            }
        }
        c
    }

    /// Extract a sub-matrix by row indices (gathers rows).
    pub fn take_rows(&self, idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(idx.len(), self.cols);
        for (dst, &src) in idx.iter().enumerate() {
            m.row_mut(dst).copy_from_slice(self.row(src));
        }
        m
    }

    /// f32 copy of the buffer (for the XLA boundary). Goes through
    /// [`precision::demote`] so the precision loss is explicit.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| precision::demote(x)).collect()
    }

    /// Build from an f32 buffer (exact widening).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&x| precision::promote(x)).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn identity_matvec() {
        let i = Mat::identity(5);
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(i.matvec(&x), x.to_vec());
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_associates_with_matvec() {
        forall("(A·B)x == A(Bx)", 20, |g| {
            let n = g.usize_in(1, 15);
            let m = g.usize_in(1, 15);
            let k = g.usize_in(1, 15);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let a = Mat::randn(n, m, &mut rng);
            let b = Mat::randn(m, k, &mut rng);
            let x = g.normal_vec(k);
            let lhs = a.matmul(&b).matvec(&x);
            let rhs = a.matvec(&b.matvec(&x));
            lhs.iter().zip(&rhs).all(|(u, v)| (u - v).abs() < 1e-9)
        });
    }

    #[test]
    fn transpose_roundtrip_and_t_matmul() {
        forall("AᵀB == transpose(A)·B", 20, |g| {
            let n = g.usize_in(1, 12);
            let m = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let a = Mat::randn(n, m, &mut rng);
            let b = Mat::randn(n, k, &mut rng);
            let fast = a.t_matmul(&b);
            let slow = a.transpose().matmul(&b);
            fast.max_abs_diff(&slow) < 1e-10
        });
    }

    #[test]
    fn matvec_t_matches_transpose() {
        forall("Aᵀx == transpose(A)·x", 20, |g| {
            let n = g.usize_in(1, 12);
            let m = g.usize_in(1, 12);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let a = Mat::randn(n, m, &mut rng);
            let x = g.normal_vec(n);
            let fast = a.matvec_t(&x);
            let slow = a.transpose().matvec(&x);
            fast.iter().zip(&slow).all(|(u, v)| (u - v).abs() < 1e-10)
        });
    }

    #[test]
    fn block_matvec_bitwise_matches_column_loop() {
        // The contract the whole block-first operator API leans on: the
        // panel kernel must be float-for-float the per-column matvec loop,
        // including ragged panels (k not a multiple of BLOCK_PANEL) and
        // the degenerate k = 1.
        let mut rng = Rng::new(42);
        let a = Mat::randn(37, 37, &mut rng);
        for k in [1usize, 2, Mat::BLOCK_PANEL, Mat::BLOCK_PANEL + 1, 33] {
            let xs = Mat::randn(37, k, &mut rng);
            let mut ys = Mat::zeros(37, k);
            a.block_matvec_into(&xs, &mut ys);
            for j in 0..k {
                let want = a.matvec(&xs.col(j));
                assert_eq!(ys.col(j), want, "k={k} column {j}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "block_matvec dim")]
    fn block_matvec_dim_mismatch_panics() {
        let a = Mat::zeros(3, 3);
        let xs = Mat::zeros(4, 2);
        let mut ys = Mat::zeros(3, 2);
        a.block_matvec_into(&xs, &mut ys);
    }

    #[test]
    fn rand_spd_is_spd() {
        let mut rng = Rng::new(7);
        let a = Mat::rand_spd(12, 1e3, &mut rng);
        assert!(a.is_square());
        assert!(a.max_abs_diff(&a.transpose()) < 1e-9);
        // positive definiteness via Cholesky existence is tested in cholesky.rs
        let x = vec![1.0; 12];
        let q = crate::linalg::vec_ops::dot(&x, &a.matvec(&x));
        assert!(q > 0.0);
    }

    #[test]
    fn take_rows_and_cols() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 10 + j) as f64);
        let s = a.take_rows(&[2, 0]);
        assert_eq!(s.row(0), &[20., 21., 22.]);
        assert_eq!(s.row(1), &[0., 1., 2.]);
        assert_eq!(a.col(1), vec![1., 11., 21., 31.]);
    }

    #[test]
    fn add_diag_and_symmetrize() {
        let mut a = Mat::from_vec(2, 2, vec![1., 2., 4., 5.]);
        a.add_diag(1.0);
        assert_eq!(a.data(), &[2., 2., 4., 6.]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 3.0);
        assert_eq!(a[(1, 0)], 3.0);
    }

    #[test]
    fn f32_roundtrip() {
        let a = Mat::from_vec(2, 2, vec![1.5, -2.25, 3.0, 0.125]);
        let b = Mat::from_f32(2, 2, &a.to_f32());
        assert_eq!(a, b); // exactly representable values
    }

    #[test]
    #[should_panic(expected = "matmul dim")]
    fn matmul_dim_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn scaled_cols_helpers_match_naive() {
        let w = Mat::from_vec(3, 2, vec![1., 4., 2., 5., 3., 6.]); // cols [1,2,3], [4,5,6]
        let coef = [2.0, -1.0];
        let mut y = vec![10.0, 10.0, 10.0];
        w.add_scaled_cols(&coef, &mut y); // y += 2*[1,2,3] - [4,5,6]
        assert_eq!(y, vec![8.0, 9.0, 10.0]);
        w.sub_scaled_cols(&coef, &mut y);
        assert_eq!(y, vec![10.0, 10.0, 10.0]);
        // Zero coefficients leave y bit-identical (columns are skipped).
        let mut z = vec![1.25, -0.5, 3.0];
        let before = z.clone();
        w.add_scaled_cols(&[0.0, 0.0], &mut z);
        assert_eq!(z, before);
    }
}
