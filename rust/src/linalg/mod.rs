//! Dense linear algebra substrate, written from scratch.
//!
//! No BLAS/LAPACK bindings exist in this offline environment, so this
//! module implements the dense kernels the rest of the library needs:
//!
//! * [`mat`] — row-major `Mat` with blocked matmul / matvec;
//! * [`vec_ops`] — unrolled dot/axpy/norm primitives (the CG hot path);
//! * [`cholesky`] — LLᵀ factorization, solves, log-determinant;
//! * [`qr`] — Householder QR with thin-Q extraction;
//! * [`eig`] — symmetric eigensolver (tridiagonalization + implicit-shift
//!   QL) and the generalized symmetric-definite problem `G u = θ F u`
//!   needed for harmonic-Ritz extraction (paper Eq. 7).
//!
//! Numerics are `f64` throughout: the solver layer needs full precision;
//! the XLA artifact path (f32) converts at the boundary.

pub mod cholesky;
pub mod eig;
pub mod mat;
pub mod qr;
pub mod vec_ops;

pub use cholesky::Cholesky;
pub use mat::Mat;
