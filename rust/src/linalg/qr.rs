//! Householder QR factorization with thin-Q extraction.
//!
//! Used to (re)orthonormalize recycled subspace bases `W` (numerical
//! stability of deflation degrades when the Ritz vectors become nearly
//! dependent — the effect the paper's §3 discussion attributes stagnation
//! to) and in tests as an orthogonality oracle.

use crate::linalg::mat::Mat;
use crate::linalg::vec_ops;

/// Compact WY-free Householder QR: stores the reflectors and R.
#[derive(Clone, Debug)]
pub struct Qr {
    /// m x n; below-diagonal holds the Householder vectors (v_j, with
    /// implicit leading 1), upper triangle holds R.
    qr: Mat,
    /// Scaling betas for each reflector.
    betas: Vec<f64>,
}

impl Qr {
    /// Factor an m x n matrix with m >= n.
    pub fn factor(a: &Mat) -> Qr {
        let (m, n) = (a.rows(), a.cols());
        assert!(m >= n, "Qr::factor requires m >= n (got {m}x{n})");
        let mut qr = a.clone();
        let mut betas = vec![0.0; n];
        for j in 0..n {
            // Build the Householder vector for column j below row j.
            let mut norm2 = 0.0;
            for i in j..m {
                norm2 += qr[(i, j)] * qr[(i, j)];
            }
            let norm = norm2.sqrt();
            if norm == 0.0 {
                betas[j] = 0.0;
                continue;
            }
            let a0 = qr[(j, j)];
            let alpha = if a0 >= 0.0 { -norm } else { norm };
            // v = x - alpha e1, normalized so v[0] = 1.
            let v0 = a0 - alpha;
            // beta = -v0 / alpha  (standard LAPACK-style tau with v0-normalized v)
            let beta = -v0 / alpha;
            for i in (j + 1)..m {
                qr[(i, j)] /= v0;
            }
            qr[(j, j)] = alpha;
            betas[j] = beta;
            // Apply reflector to the trailing columns.
            for k in (j + 1)..n {
                // w = vᵀ A[:,k]
                let mut w = qr[(j, k)];
                for i in (j + 1)..m {
                    w += qr[(i, j)] * qr[(i, k)];
                }
                w *= beta;
                qr[(j, k)] -= w;
                for i in (j + 1)..m {
                    let vij = qr[(i, j)];
                    qr[(i, k)] -= w * vij;
                }
            }
        }
        Qr { qr, betas }
    }

    /// Thin Q (m x n) with orthonormal columns.
    pub fn thin_q(&self) -> Mat {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        let mut q = Mat::zeros(m, n);
        for i in 0..n {
            q[(i, i)] = 1.0;
        }
        // Accumulate reflectors in reverse order: Q = H_0 H_1 ... H_{n-1} E.
        for j in (0..n).rev() {
            let beta = self.betas[j];
            if beta == 0.0 {
                continue;
            }
            for k in 0..n {
                let mut w = q[(j, k)];
                for i in (j + 1)..m {
                    w += self.qr[(i, j)] * q[(i, k)];
                }
                w *= beta;
                q[(j, k)] -= w;
                for i in (j + 1)..m {
                    let vij = self.qr[(i, j)];
                    q[(i, k)] -= w * vij;
                }
            }
        }
        q
    }

    /// Upper-triangular R (n x n).
    pub fn r(&self) -> Mat {
        let n = self.qr.cols();
        let mut r = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r[(i, j)] = self.qr[(i, j)];
            }
        }
        r
    }

    /// Numerical rank of R with relative tolerance.
    pub fn rank(&self, rel_tol: f64) -> usize {
        let n = self.qr.cols();
        let dmax = (0..n).fold(0.0f64, |m, i| m.max(self.qr[(i, i)].abs()));
        if dmax == 0.0 {
            return 0;
        }
        (0..n).filter(|&i| self.qr[(i, i)].abs() > rel_tol * dmax).count()
    }

    /// Least-squares solve min ‖Ax − b‖ via R x = Qᵀ b.
    pub fn solve_ls(&self, b: &[f64]) -> Vec<f64> {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        assert_eq!(b.len(), m);
        let mut y = b.to_vec();
        // Apply Qᵀ = H_{n-1} ... H_0 to b.
        for j in 0..n {
            let beta = self.betas[j];
            if beta == 0.0 {
                continue;
            }
            let mut w = y[j];
            for i in (j + 1)..m {
                w += self.qr[(i, j)] * y[i];
            }
            w *= beta;
            y[j] -= w;
            for i in (j + 1)..m {
                y[i] -= w * self.qr[(i, j)];
            }
        }
        // Back substitution R x = y[..n].
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.qr[(i, k)] * x[k];
            }
            let d = self.qr[(i, i)];
            x[i] = if d.abs() > 1e-300 { s / d } else { 0.0 };
        }
        x
    }
}

/// Modified Gram–Schmidt orthonormalization of the columns of `a` against
/// themselves (and optionally an existing orthonormal basis `against`).
/// Returns the orthonormal basis; columns that collapse below `tol` are
/// dropped. Cheaper than full QR for the k ≪ n recycling bases, and the
/// method the deflation literature uses in-loop.
pub fn mgs_orthonormalize(a: &Mat, against: Option<&Mat>, tol: f64) -> Mat {
    let m = a.rows();
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for j in 0..a.cols() {
        let mut v = a.col(j);
        if let Some(q) = against {
            for jq in 0..q.cols() {
                let qc = q.col(jq);
                let c = vec_ops::dot(&qc, &v);
                vec_ops::axpy(-c, &qc, &mut v);
            }
        }
        for existing in &cols {
            let c = vec_ops::dot(existing, &v);
            vec_ops::axpy(-c, existing, &mut v);
        }
        // Second pass (re-orthogonalization) for numerical robustness.
        if let Some(q) = against {
            for jq in 0..q.cols() {
                let qc = q.col(jq);
                let c = vec_ops::dot(&qc, &v);
                vec_ops::axpy(-c, &qc, &mut v);
            }
        }
        for existing in &cols {
            let c = vec_ops::dot(existing, &v);
            vec_ops::axpy(-c, existing, &mut v);
        }
        let norm = vec_ops::norm2(&v);
        if norm > tol {
            vec_ops::scale(&mut v, 1.0 / norm);
            cols.push(v);
        }
    }
    let mut q = Mat::zeros(m, cols.len());
    for (j, c) in cols.iter().enumerate() {
        q.set_col(j, c);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    fn orthonormality_error(q: &Mat) -> f64 {
        let qtq = q.t_matmul(q);
        qtq.max_abs_diff(&Mat::identity(q.cols()))
    }

    #[test]
    fn qr_reconstructs() {
        forall("Q·R == A", 20, |g| {
            let m = g.usize_in(1, 20);
            let n = g.usize_in(1, m + 1).min(m);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let a = Mat::randn(m, n, &mut rng);
            let qr = Qr::factor(&a);
            let rec = qr.thin_q().matmul(&qr.r());
            rec.max_abs_diff(&a) < 1e-9 * (1.0 + a.fro_norm())
        });
    }

    #[test]
    fn thin_q_is_orthonormal() {
        forall("QᵀQ == I", 20, |g| {
            let m = g.usize_in(2, 25);
            let n = g.usize_in(1, m).min(m);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let a = Mat::randn(m, n, &mut rng);
            orthonormality_error(&Qr::factor(&a).thin_q()) < 1e-10
        });
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(6, 4, &mut rng);
        let r = Qr::factor(&a).r();
        for i in 0..4 {
            for j in 0..i {
                assert_eq!(r[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn least_squares_matches_normal_equations() {
        forall("QR ls == normal eq", 15, |g| {
            let m = g.usize_in(5, 25);
            let n = g.usize_in(1, 5);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let a = Mat::randn(m, n, &mut rng);
            let b = g.normal_vec(m);
            let x = Qr::factor(&a).solve_ls(&b);
            // Normal equations: AᵀA x = Aᵀ b
            let ata = a.t_matmul(&a);
            let atb = a.matvec_t(&b);
            let x2 = crate::linalg::Cholesky::factor(&ata).unwrap().solve(&atb);
            x.iter().zip(&x2).all(|(u, v)| (u - v).abs() < 1e-6)
        });
    }

    #[test]
    fn rank_detects_deficiency() {
        let mut rng = Rng::new(5);
        let mut a = Mat::randn(8, 3, &mut rng);
        // Make column 2 a copy of column 0.
        let c0 = a.col(0);
        a.set_col(2, &c0);
        assert_eq!(Qr::factor(&a).rank(1e-10), 2);
    }

    #[test]
    fn mgs_orthonormalizes_and_drops_dependent() {
        let mut rng = Rng::new(8);
        let mut a = Mat::randn(10, 4, &mut rng);
        let c1 = a.col(1);
        a.set_col(3, &c1); // dependent column
        let q = mgs_orthonormalize(&a, None, 1e-10);
        assert_eq!(q.cols(), 3);
        assert!(orthonormality_error(&q) < 1e-10);
    }

    #[test]
    fn mgs_against_external_basis() {
        let mut rng = Rng::new(9);
        let base = Qr::factor(&Mat::randn(12, 3, &mut rng)).thin_q();
        let a = Mat::randn(12, 2, &mut rng);
        let q = mgs_orthonormalize(&a, Some(&base), 1e-10);
        // q columns orthogonal to base columns
        let cross = base.t_matmul(&q);
        assert!(cross.fro_norm() < 1e-10);
        assert!(orthonormality_error(&q) < 1e-10);
    }

    #[test]
    fn qr_handles_zero_column() {
        let a = Mat::zeros(5, 2);
        let qr = Qr::factor(&a);
        assert_eq!(qr.rank(1e-12), 0);
        let x = qr.solve_ls(&[1.0; 5]);
        assert_eq!(x, vec![0.0, 0.0]);
    }
}
