//! Vector primitives on `&[f64]` — the CG inner loop.
//!
//! These are written with 4-way manual unrolling so LLVM reliably
//! auto-vectorizes them; they are the L3 hot path when running with the
//! native (non-XLA) backend and are benchmarked in `benches/bench_linalg`.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// y = x + beta * y  (the CG direction update `p = r + beta p`)
#[inline]
pub fn xpby(x: &[f64], beta: f64, y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = xi + beta * *yi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// a <- a * s
#[inline]
pub fn scale(a: &mut [f64], s: f64) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

/// out = a - b
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// Maximum absolute entry.
#[inline]
pub fn max_abs(a: &[f64]) -> f64 {
    a.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::forall;

    #[test]
    fn dot_simple() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn dot_matches_naive_on_odd_lengths() {
        forall("dot == naive", 50, |g| {
            let n = g.usize_in(1, 40);
            let a = g.normal_vec(n);
            let b = g.normal_vec(n);
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            (dot(&a, &b) - naive).abs() <= 1e-12 * (1.0 + naive.abs())
        });
    }

    #[test]
    fn axpy_and_xpby() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        let mut p = [1.0, 1.0];
        xpby(&[5.0, 6.0], 3.0, &mut p); // p = x + beta p
        assert_eq!(p, [8.0, 9.0]);
    }

    #[test]
    fn norm_and_scale() {
        let mut a = [3.0, 4.0];
        assert_eq!(norm2(&a), 5.0);
        scale(&mut a, 2.0);
        assert_eq!(a, [6.0, 8.0]);
        assert_eq!(max_abs(&[-7.0, 3.0]), 7.0);
    }

    #[test]
    fn sub_works() {
        let mut out = [0.0; 3];
        sub(&[5.0, 5.0, 5.0], &[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, [4.0, 3.0, 2.0]);
    }
}
