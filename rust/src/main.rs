//! `krr` — CLI entry point.
//!
//! Subcommands regenerate each of the paper's tables/figures, run the
//! end-to-end GPC workload, or start the solve service demo:
//!
//! ```text
//! krr table1 [--n 512] [--tol 1e-5] [--backend engine|native]
//! krr fig1 | fig2 | fig3 | fig4 | ablation
//! krr demo-digits          # render a few synthetic digits as ASCII art
//! ```

fn main() {
    krr::experiments::cli_main();
}
