//! The PJRT engine: compile artifacts once, execute many times.
//!
//! Follows the reference wiring of /opt/xla-example/load_hlo.rs:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are compiled lazily on
//! first call and cached for the process lifetime. Large operands (the
//! Gram matrix) are uploaded once as device buffers and passed by
//! reference via `execute_b`.

use crate::runtime::manifest::{ArtifactMeta, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Host-side tensor value passed to / returned from an engine call.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn param(v: f32) -> Tensor {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn mat(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { shape: vec![rows, cols], data }
    }

    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: data.iter().map(|&x| x as f32).collect() }
    }

    pub fn to_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&x| x as f64).collect()
    }

    fn to_literal(&self) -> Result<Literal> {
        let lit = Literal::vec1(&self.data);
        if self.shape.len() == 1 {
            return Ok(lit);
        }
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &Literal, shape: &[usize]) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor { shape: shape.to_vec(), data })
    }
}

/// The engine. `Send + Sync`: the PJRT CPU client supports concurrent
/// dispatch, and the executable cache is mutex-guarded.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    exes: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
}

// SAFETY: the xla wrapper types hold raw pointers into the PJRT C API.
// PJRT clients, loaded executables and buffers are documented thread-safe
// for concurrent Execute/Transfer calls; all mutable engine state (the
// lazy compile cache) is behind a Mutex.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load the engine from an artifact directory (e.g. `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| anyhow!("loading manifest: {e}"))?;
        let client = PjRtClient::cpu()?;
        crate::log_info!(
            "engine up: platform={} devices={} artifacts={} sizes={:?}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len(),
            manifest.sizes
        );
        Ok(Engine { client, dir, manifest, exes: Mutex::new(HashMap::new()) })
    }

    /// Whether an artifact directory looks usable (lets tests and examples
    /// skip gracefully when `make artifacts` has not run).
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Compile (or fetch the cached) executable for `name`.
    fn executable(&self, name: &str) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.meta(name)?;
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp)?);
        crate::log_debug!("compiled {name} in {:.3}s", t0.elapsed().as_secs_f64());
        // Double-checked insert: racing threads may both compile; last wins
        // (both executables are valid).
        self.exes.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (e.g. at service startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Upload a tensor to device memory (for operands reused across calls).
    pub fn upload(&self, t: &Tensor) -> Result<PjRtBuffer> {
        Ok(self
            .client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?)
    }

    fn unpack_outputs(&self, meta: &ArtifactMeta, result: Literal) -> Result<Vec<Tensor>> {
        // Artifacts are lowered with return_tuple=True: the single output
        // buffer is a tuple literal with `meta.outputs.len()` elements.
        let mut result = result;
        let parts = result.decompose_tuple()?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, got {}",
                meta.name,
                meta.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, &spec.shape))
            .collect()
    }

    fn check_args(&self, meta: &ArtifactMeta, shapes: &[Vec<usize>]) -> Result<()> {
        if shapes.len() != meta.inputs.len() {
            bail!(
                "artifact {}: expected {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                shapes.len()
            );
        }
        for (i, (got, want)) in shapes.iter().zip(&meta.inputs).enumerate() {
            if *got != want.shape {
                bail!(
                    "artifact {}: input {i} shape {:?} != expected {:?}",
                    meta.name,
                    got,
                    want.shape
                );
            }
        }
        Ok(())
    }

    /// Execute with host tensors (uploads everything per call).
    pub fn call(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self.meta(name)?.clone();
        let shapes: Vec<_> = args.iter().map(|a| a.shape.clone()).collect();
        self.check_args(&meta, &shapes)?;
        let exe = self.executable(name)?;
        let literals: Vec<Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let out = exe.execute::<Literal>(&literals)?;
        let lit = out[0][0].to_literal_sync()?;
        self.unpack_outputs(&meta, lit)
    }

    /// Execute with pre-uploaded device buffers (the hot path: `K` stays
    /// resident; small vectors are uploaded by the caller per call).
    pub fn call_b(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Tensor>> {
        let meta = self.meta(name)?.clone();
        let exe = self.executable(name)?;
        let out = exe.execute_b::<&PjRtBuffer>(args)?;
        let lit = out[0][0].to_literal_sync()?;
        self.unpack_outputs(&meta, lit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrips() {
        let t = Tensor::mat(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn tensor_f64_conversion() {
        let t = Tensor::from_f64(vec![3], &[1.0, 2.5, -3.0]);
        assert_eq!(t.data, vec![1.0f32, 2.5, -3.0]);
        assert_eq!(t.to_f64(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn scalar_and_param_shapes() {
        assert_eq!(Tensor::scalar(2.0).shape, Vec::<usize>::new());
        assert_eq!(Tensor::param(2.0).shape, vec![1]);
    }

    #[test]
    fn available_detects_missing_dir() {
        assert!(!Engine::available("/definitely/not/a/dir"));
    }
}
