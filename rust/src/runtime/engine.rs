//! The compute engine: one call surface, two backends.
//!
//! [`Engine`] executes the artifact family (`gram_n{n}`, `kmatvec_n{n}`,
//! `amatvec_n{n}`, `newton_stats_n{n}`, `newton_update_n{n}`,
//! `gram_matvec_free_n{n}`) behind a single typed API:
//!
//! * [`crate::runtime::native::NativeEngine`] — the pure-Rust fallback,
//!   always available. Interprets each artifact in f32 (the artifact
//!   family's precision) against the built-in manifest, so the entire
//!   system builds and runs fully offline.
//! * `runtime::pjrt::PjrtEngine` (feature `pjrt`) — compiles the HLO text
//!   lowered by `python/compile/aot.py` on a PJRT client and keeps large
//!   operands (the Gram matrix) resident in device memory.
//!
//! Callers hold an `Engine` and never branch on the backend; [`Buffer`]
//! abstracts "operand kept resident across calls" the same way.

use crate::runtime::error::{EngineError, Result};
use crate::runtime::manifest::Manifest;
use crate::runtime::native::NativeEngine;
#[cfg(feature = "pjrt")]
use crate::runtime::pjrt::PjrtEngine;
use std::path::Path;

/// Host-side tensor value passed to / returned from an engine call.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn param(v: f32) -> Tensor {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn mat(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), rows * cols);
        Tensor { shape: vec![rows, cols], data }
    }

    pub fn from_f64(shape: Vec<usize>, data: &[f64]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: data.iter().map(|&x| x as f32).collect() }
    }

    pub fn to_f64(&self) -> Vec<f64> {
        self.data.iter().map(|&x| x as f64).collect()
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An operand kept resident across engine calls (the Gram matrix, the
/// per-solve scaling vector). On the native backend this is simply the
/// host tensor; on the PJRT backend it is a device buffer uploaded once,
/// paired with its logical shape (device buffers don't carry one).
pub enum Buffer {
    Native(Tensor),
    #[cfg(feature = "pjrt")]
    Pjrt(xla::PjRtBuffer, Vec<usize>),
}

// SAFETY (pjrt only): PJRT buffers are documented thread-safe for
// concurrent Execute/Transfer calls; the native variant is a plain tensor.
#[cfg(feature = "pjrt")]
unsafe impl Send for Buffer {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Buffer {}

impl Buffer {
    /// Logical shape of the resident operand.
    pub fn shape(&self) -> &[usize] {
        match self {
            Buffer::Native(t) => &t.shape,
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(_, shape) => shape,
        }
    }

    /// Download the buffer back to a host tensor, shape preserved on
    /// both backends.
    pub fn tensor(&self) -> Result<Tensor> {
        match self {
            Buffer::Native(t) => Ok(t.clone()),
            #[cfg(feature = "pjrt")]
            Buffer::Pjrt(b, shape) => {
                let flat = crate::runtime::pjrt::buffer_to_tensor(b)?;
                if flat.data.len() != shape.iter().product::<usize>() {
                    return Err(EngineError::new(format!(
                        "device buffer holds {} elements, logical shape {:?}",
                        flat.data.len(),
                        shape
                    )));
                }
                Ok(Tensor { shape: shape.clone(), data: flat.data })
            }
        }
    }
}

/// The engine: `Send + Sync`, cheap to share behind an `Arc`.
pub enum Engine {
    Native(NativeEngine),
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtEngine),
}

// SAFETY (pjrt only): see PjrtEngine — the PJRT CPU client supports
// concurrent dispatch and all mutable state is mutex-guarded. The native
// variant is automatically Send + Sync; these impls widen the enum when
// the non-auto variant is compiled in.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

impl Engine {
    /// The built-in native engine (embedded manifest; no artifact files).
    pub fn native() -> Engine {
        Engine::Native(NativeEngine::embedded())
    }

    /// Load the engine from an artifact directory (e.g. `artifacts/`).
    ///
    /// With the `pjrt` feature this compiles the directory's HLO artifacts
    /// on a PJRT client; the default build interprets the directory's
    /// manifest natively (artifact *files* are not needed).
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        Engine::load_impl(dir.as_ref())
    }

    #[cfg(feature = "pjrt")]
    fn load_impl(dir: &Path) -> Result<Engine> {
        Ok(Engine::Pjrt(PjrtEngine::load(dir)?))
    }

    #[cfg(not(feature = "pjrt"))]
    fn load_impl(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| EngineError::new(e).context("loading manifest"))?;
        Ok(Engine::Native(NativeEngine::new(manifest)))
    }

    /// Best-available engine: artifacts in `dir` when present, the
    /// built-in native engine otherwise. Never fails — malformed artifact
    /// directories fall back to native with a warning.
    pub fn auto(dir: impl AsRef<Path>) -> Engine {
        let dir = dir.as_ref();
        if Engine::available(dir) {
            match Engine::load(dir) {
                Ok(e) => return e,
                Err(err) => {
                    crate::log_warn!(
                        "engine: cannot load {}: {err}; using the native fallback",
                        dir.display()
                    );
                }
            }
        }
        Engine::native()
    }

    /// Whether an artifact directory looks usable (lets tests and examples
    /// pick the artifact path only when `make artifacts` has run).
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }

    /// Backend name, for logs and reports.
    pub fn backend_name(&self) -> &'static str {
        match self {
            Engine::Native(_) => "native",
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(_) => "pjrt",
        }
    }

    pub fn manifest(&self) -> &Manifest {
        match self {
            Engine::Native(ne) => ne.manifest(),
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(pe) => pe.manifest(),
        }
    }

    /// Pre-compile a set of artifacts (e.g. at service startup). The
    /// native backend has nothing to compile and only validates names.
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        match self {
            Engine::Native(ne) => {
                for n in names {
                    ne.manifest().require(n).map_err(EngineError::new)?;
                }
                Ok(())
            }
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(pe) => pe.warmup(names),
        }
    }

    /// Upload a tensor for reuse across calls.
    pub fn upload(&self, t: &Tensor) -> Result<Buffer> {
        match self {
            Engine::Native(_) => Ok(Buffer::Native(t.clone())),
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(pe) => Ok(Buffer::Pjrt(pe.upload(t)?, t.shape.clone())),
        }
    }

    /// Execute with host tensors (uploads everything per call).
    pub fn call(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        match self {
            Engine::Native(ne) => {
                let refs: Vec<&Tensor> = args.iter().collect();
                ne.call(name, &refs)
            }
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(pe) => pe.call(name, args),
        }
    }

    /// Execute with resident buffers (the hot path: `K` stays resident;
    /// small vectors are uploaded by the caller per call). Input shapes
    /// are validated against the manifest on **both** backends — buffers
    /// carry their logical shape, so a bad resident operand fails with
    /// the same typed error everywhere instead of an opaque XLA fault.
    pub fn call_b(&self, name: &str, args: &[&Buffer]) -> Result<Vec<Tensor>> {
        {
            let meta = self.manifest().require(name).map_err(EngineError::new)?;
            let shapes: Vec<&[usize]> = args.iter().map(|b| b.shape()).collect();
            meta.check_inputs(&shapes).map_err(EngineError::new)?;
        }
        match self {
            Engine::Native(ne) => {
                let mut refs: Vec<&Tensor> = Vec::with_capacity(args.len());
                for b in args {
                    match b {
                        Buffer::Native(t) => refs.push(t),
                        #[cfg(feature = "pjrt")]
                        Buffer::Pjrt(..) => {
                            return Err(EngineError::new(
                                "device buffer handed to the native engine",
                            ))
                        }
                    }
                }
                ne.call(name, &refs)
            }
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(pe) => {
                let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
                for b in args {
                    match b {
                        Buffer::Pjrt(d, _) => bufs.push(d),
                        Buffer::Native(_) => {
                            return Err(EngineError::new(
                                "host buffer handed to the pjrt engine",
                            ))
                        }
                    }
                }
                pe.call_b(name, &bufs)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_f64_conversion() {
        let t = Tensor::from_f64(vec![3], &[1.0, 2.5, -3.0]);
        assert_eq!(t.data, vec![1.0f32, 2.5, -3.0]);
        assert_eq!(t.to_f64(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn scalar_and_param_shapes() {
        assert_eq!(Tensor::scalar(2.0).shape, Vec::<usize>::new());
        assert_eq!(Tensor::scalar(2.0).element_count(), 1);
        assert_eq!(Tensor::param(2.0).shape, vec![1]);
    }

    #[test]
    fn available_detects_missing_dir() {
        assert!(!Engine::available("/definitely/not/a/dir"));
    }

    #[test]
    fn auto_falls_back_to_native() {
        let e = Engine::auto("/definitely/not/a/dir");
        assert_eq!(e.backend_name(), "native");
        assert!(e.manifest().sizes.contains(&64));
    }

    #[test]
    fn buffer_roundtrips_on_native() {
        let e = Engine::native();
        let t = Tensor::mat(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = e.upload(&t).unwrap();
        assert_eq!(b.tensor().unwrap(), t);
    }

    #[test]
    fn call_b_validates_buffer_shapes() {
        let e = Engine::native();
        let bad = e.upload(&Tensor::vec(vec![0.0; 3])).unwrap();
        let err = e.call_b("kmatvec_n8", &[&bad, &bad]).unwrap_err();
        assert!(format!("{err}").contains("shape"), "{err}");
        assert!(e.call_b("nonexistent", &[]).is_err());
    }

    #[test]
    fn warmup_validates_names() {
        let e = Engine::native();
        assert!(e.warmup(&["gram_n64", "kmatvec_n64"]).is_ok());
        assert!(e.warmup(&["nonexistent"]).is_err());
    }
}
