//! Engine error type.
//!
//! The offline default build has no `anyhow`; this is the one error type
//! the runtime layer needs — a message, optionally chained with context.

use std::fmt;

/// Engine-layer error: a human-readable message.
#[derive(Clone, PartialEq, Eq)]
pub struct EngineError {
    msg: String,
}

impl EngineError {
    pub fn new(msg: impl Into<String>) -> EngineError {
        EngineError { msg: msg.into() }
    }

    /// Wrap with an outer context message (innermost cause last).
    pub fn context(self, ctx: impl fmt::Display) -> EngineError {
        EngineError { msg: format!("{ctx}: {}", self.msg) }
    }

    pub fn msg(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "EngineError({})", self.msg)
    }
}

impl std::error::Error for EngineError {}

impl From<String> for EngineError {
    fn from(msg: String) -> EngineError {
        EngineError { msg }
    }
}

impl From<&str> for EngineError {
    fn from(msg: &str) -> EngineError {
        EngineError { msg: msg.to_string() }
    }
}

/// Runtime-layer result alias.
pub type Result<T> = std::result::Result<T, EngineError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context() {
        let e = EngineError::new("inner");
        assert_eq!(format!("{e}"), "inner");
        let e = e.context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest: inner");
        assert_eq!(format!("{e:?}"), "EngineError(loading manifest: inner)");
    }

    #[test]
    fn conversions() {
        let a: EngineError = "x".into();
        let b: EngineError = String::from("x").into();
        assert_eq!(a, b);
        assert_eq!(a.msg(), "x");
    }
}
