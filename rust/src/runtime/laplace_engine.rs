//! Fused engine-side Laplace driver.
//!
//! The generic [`crate::gp::laplace::LaplaceGpc`] calls the kernel
//! operator once per elementwise stage, which on the engine backend means
//! several host↔device round trips per Newton step. This driver instead
//! invokes the **fused L2 artifacts** — `newton_stats_n{n}` (π, ∇, H, s,
//! b_rw, rhs, log-lik in ONE executable around the L1 matvec kernel) and
//! `newton_update_n{n}` (a, f′ = K a, log-lik, quadratic term) — so each
//! Newton step costs exactly two engine calls plus the inner CG solve.
//! This is the L2 item of the performance pass (EXPERIMENTS.md §Perf).

use crate::gp::laplace::{LaplaceFit, NewtonStepStats};
use crate::runtime::error::Result;
use crate::runtime::ops::{EngineKernel, EngineSpdOperator};
use crate::solvers::api::{self, SolveSpec};
use crate::solvers::recycle::{RecycleConfig, RecycleManager};
use std::time::Instant;

/// Configuration for the fused engine Laplace run.
#[derive(Clone, Debug)]
pub struct EngineLaplaceConfig {
    /// Inner-solve tolerance. The artifacts are f32: tolerances below
    /// ~1e-6 are clamped by a stagnation guard rather than spinning.
    pub solve_tol: f64,
    pub newton_tol: f64,
    pub max_newton: usize,
    /// def-CG recycling; `None` runs plain CG inside each Newton step.
    pub recycle: Option<RecycleConfig>,
}

impl Default for EngineLaplaceConfig {
    fn default() -> Self {
        EngineLaplaceConfig {
            solve_tol: 1e-5,
            newton_tol: 1.0,
            max_newton: 20,
            recycle: Some(RecycleConfig::default()),
        }
    }
}

/// Run the full Laplace/Newton loop against a device-resident kernel.
pub fn fit(kernel: &EngineKernel, y: &[f64], cfg: &EngineLaplaceConfig) -> Result<LaplaceFit> {
    use crate::gp::laplace::KernelOp;
    let n = kernel.n();
    assert_eq!(y.len(), n);
    let mut f = vec![0.0; n];
    let mut a_hat = vec![0.0; n];
    let mut steps: Vec<NewtonStepStats> = Vec::new();
    let mut cumulative = 0.0;
    let mut psi_prev = f64::NEG_INFINITY;
    let mut converged = false;
    let mut recycler = cfg.recycle.clone().map(RecycleManager::new);

    for it in 1..=cfg.max_newton {
        // ONE engine call: all Newton-step quantities (Eq. 9) fused.
        let (rhs, s, b_rw, _loglik_pre) = kernel.newton_stats(&f, y)?;

        // Inner solve on the fused A = I + SKS artifact operator, with the
        // f32-floor guards on (see solvers::cg docs); the plain-CG path
        // additionally runs residual replacement every 25 iterations.
        let solve_start = Instant::now();
        let op = EngineSpdOperator::new(kernel, &s);
        let knobs = |spec: SolveSpec| {
            spec.with_tol(cfg.solve_tol.max(2e-7)) // f32 floor
                .with_stall_window(60)
                .with_recompute_every(25)
        };
        let (z, iters, matvecs, trace, defl_dim) = match recycler.as_mut() {
            Some(mgr) => {
                let dim = mgr.k_active();
                let r = mgr.solve_next(&op, &rhs, None, &knobs(SolveSpec::defcg()));
                (r.x, r.iterations, r.matvecs, r.residuals, dim)
            }
            None => {
                let r = api::solve(&op, &rhs, &knobs(SolveSpec::cg()));
                (r.x, r.iterations, r.matvecs, r.residuals, 0)
            }
        };
        let solve_seconds = solve_start.elapsed().as_secs_f64();
        cumulative += solve_seconds;

        // ONE engine call: a = b_rw − s∘z, f' = K a, log-lik, quad.
        let (f_new, a_new, loglik, quad) = kernel.newton_update(&b_rw, &s, &z, y)?;
        f = f_new;
        a_hat = a_new;
        let psi = loglik - 0.5 * quad;

        steps.push(NewtonStepStats {
            newton_iter: it,
            log_lik: loglik,
            psi,
            solver_iterations: iters,
            solver_matvecs: matvecs,
            residual_trace: trace,
            deflation_dim: defl_dim,
            solve_seconds,
            cumulative_seconds: cumulative,
        });

        let dpsi = psi - psi_prev;
        if it > 1 && dpsi.abs() < cfg.newton_tol {
            converged = true;
            break;
        }
        psi_prev = psi;
    }

    Ok(LaplaceFit { f_hat: f, a_hat, steps, converged })
}
