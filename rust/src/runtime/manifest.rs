//! Artifact manifest parsing (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py`; describes every lowered entry point
//! with its file name and input/output shapes, so the rust engine can
//! validate calls before handing them to XLA.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Tensor spec (f32 only — the artifact family is single-precision).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_scalar(&self) -> bool {
        self.shape.is_empty()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Problem size this entry was lowered for.
    pub n: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    /// Validate call-argument shapes against this entry's input specs.
    /// Every backend funnels through here, so the error wording is
    /// identical across native and PJRT (tests assert on it).
    pub fn check_inputs(&self, shapes: &[&[usize]]) -> Result<(), String> {
        if shapes.len() != self.inputs.len() {
            return Err(format!(
                "artifact {}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                shapes.len()
            ));
        }
        for (i, (got, want)) in shapes.iter().zip(&self.inputs).enumerate() {
            if *got != want.shape.as_slice() {
                return Err(format!(
                    "artifact {}: input {i} shape {:?} != expected {:?}",
                    self.name, got, want.shape
                ));
            }
        }
        Ok(())
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Feature dimension (784 for the 28×28 image workload).
    pub dim: usize,
    /// Problem sizes the artifact family covers.
    pub sizes: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let dim = j.get("dim").as_usize().ok_or("manifest: missing dim")?;
        let sizes = j
            .get("sizes")
            .as_arr()
            .ok_or("manifest: missing sizes")?
            .iter()
            .map(|v| v.as_usize().ok_or("manifest: bad size"))
            .collect::<Result<Vec<_>, _>>()?;
        let arts = j
            .get("artifacts")
            .as_obj()
            .ok_or("manifest: missing artifacts")?;
        let mut artifacts = BTreeMap::new();
        for (name, meta) in arts {
            let spec_list = |key: &str| -> Result<Vec<TensorSpec>, String> {
                meta.get(key)
                    .as_arr()
                    .ok_or_else(|| format!("manifest: {name}.{key} missing"))?
                    .iter()
                    .map(|s| {
                        let shape = s
                            .get("shape")
                            .as_arr()
                            .ok_or("bad shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or("bad dim"))
                            .collect::<Result<Vec<_>, _>>()?;
                        Ok(TensorSpec { shape })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: meta
                        .get("file")
                        .as_str()
                        .ok_or_else(|| format!("manifest: {name}.file missing"))?
                        .to_string(),
                    n: meta
                        .get("n")
                        .as_usize()
                        .ok_or_else(|| format!("manifest: {name}.n missing"))?,
                    inputs: spec_list("inputs")?,
                    outputs: spec_list("outputs")?,
                },
            );
        }
        Ok(Manifest { dim, sizes, artifacts })
    }

    pub fn load(path: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Manifest::parse(&text)
    }

    /// The built-in manifest served by the native fallback engine
    /// (`rust/manifests/native.json`, compiled into the binary): the
    /// full artifact family at dim 784 for sizes 8…2048. Keeping it as a
    /// real manifest *file* means the native and PJRT backends go through
    /// the identical validation path.
    pub fn native_embedded() -> Manifest {
        Manifest::parse(include_str!("../../manifests/native.json"))
            .expect("embedded native manifest must parse")
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.get(name)
    }

    /// Like [`Manifest::get`] but with the canonical error message every
    /// backend emits for a missing entry (tests assert on the wording).
    pub fn require(&self, name: &str) -> Result<&ArtifactMeta, String> {
        self.artifacts
            .get(name)
            .ok_or_else(|| format!("unknown artifact '{name}'"))
    }

    /// Artifact name for an entry-point stem at size n (e.g. "gram", 128
    /// -> "gram_n128"), if present.
    pub fn entry(&self, stem: &str, n: usize) -> Option<&ArtifactMeta> {
        self.artifacts.get(&format!("{stem}_n{n}"))
    }

    /// The largest artifact size ≤ n, for picking a family member.
    pub fn best_size_for(&self, n: usize) -> Option<usize> {
        self.sizes.iter().copied().filter(|&s| s <= n).max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "dim": 784,
        "sizes": [8, 16],
        "artifacts": {
            "gram_n8": {
                "file": "gram_n8.hlo.txt",
                "n": 8,
                "inputs": [{"shape": [8, 784], "dtype": "f32"},
                           {"shape": [1], "dtype": "f32"},
                           {"shape": [1], "dtype": "f32"}],
                "outputs": [{"shape": [8, 8], "dtype": "f32"}]
            },
            "cg_update_n8": {
                "file": "cg_update_n8.hlo.txt",
                "n": 8,
                "inputs": [{"shape": [8], "dtype": "f32"}],
                "outputs": [{"shape": [], "dtype": "f32"}]
            }
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.dim, 784);
        assert_eq!(m.sizes, vec![8, 16]);
        assert_eq!(m.artifacts.len(), 2);
        let g = m.get("gram_n8").unwrap();
        assert_eq!(g.file, "gram_n8.hlo.txt");
        assert_eq!(g.inputs.len(), 3);
        assert_eq!(g.inputs[0].shape, vec![8, 784]);
        assert_eq!(g.inputs[0].element_count(), 8 * 784);
        assert_eq!(g.outputs[0].shape, vec![8, 8]);
    }

    #[test]
    fn scalar_specs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let c = m.get("cg_update_n8").unwrap();
        assert!(c.outputs[0].is_scalar());
        assert_eq!(c.outputs[0].element_count(), 1);
    }

    #[test]
    fn entry_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.entry("gram", 8).is_some());
        assert!(m.entry("gram", 16).is_none());
        assert_eq!(m.best_size_for(12), Some(8));
        assert_eq!(m.best_size_for(100), Some(16));
        assert_eq!(m.best_size_for(4), None);
    }

    #[test]
    fn check_inputs_validates_count_and_shape() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let g = m.get("gram_n8").unwrap();
        let x: &[usize] = &[8, 784];
        let p: &[usize] = &[1];
        assert!(g.check_inputs(&[x, p, p]).is_ok());
        let err = g.check_inputs(&[x, p]).unwrap_err();
        assert!(err.contains("expected 3 inputs"), "{err}");
        let bad: &[usize] = &[3];
        let err = g.check_inputs(&[bad, p, p]).unwrap_err();
        assert!(err.contains("input 0 shape"), "{err}");
    }

    #[test]
    fn embedded_native_manifest_is_complete() {
        let m = Manifest::native_embedded();
        assert_eq!(m.dim, 784);
        assert!(m.sizes.contains(&64) && m.sizes.contains(&512) && m.sizes.contains(&2048));
        for &n in &m.sizes {
            for stem in [
                "gram",
                "kmatvec",
                "amatvec",
                "newton_stats",
                "newton_update",
                "gram_matvec_free",
            ] {
                let meta = m.entry(stem, n).unwrap_or_else(|| panic!("missing {stem}_n{n}"));
                assert_eq!(meta.n, n);
                assert!(!meta.inputs.is_empty() && !meta.outputs.is_empty());
            }
            assert_eq!(m.entry("gram", n).unwrap().inputs[0].shape, vec![n, 784]);
            assert_eq!(m.entry("kmatvec", n).unwrap().inputs[0].shape, vec![n, n]);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"dim": 1, "sizes": [], "artifacts": {"x": {}}}"#).is_err());
    }
}
