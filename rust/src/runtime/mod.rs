//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The rust side of the three-layer architecture. `make artifacts` (python,
//! build-time only) lowers the L2/L1 compute graphs to HLO **text**;
//! [`engine::Engine`] loads those files, compiles each once on the PJRT
//! CPU client (`xla` crate), and exposes a typed call API. The Gram matrix
//! `K` is uploaded to device memory once per problem and stays resident
//! across the O(100) matvecs of a Newton solve ([`ops::EngineKernel`]).
//!
//! Python never runs here: the binary is self-contained given `artifacts/`.

pub mod engine;
pub mod laplace_engine;
pub mod manifest;
pub mod ops;

pub use engine::Engine;
pub use manifest::Manifest;
