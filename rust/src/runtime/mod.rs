//! Runtime: execute the AOT artifact family behind a pluggable backend.
//!
//! The rust side of the three-layer architecture (see DESIGN.md). The
//! artifact call surface — load a manifest, keep the Gram matrix resident,
//! serve `kmatvec`/`amatvec`/fused-Newton calls — is exposed by
//! [`engine::Engine`], which dispatches to one of two backends:
//!
//! * [`native::NativeEngine`] (always compiled, the default): a pure-Rust
//!   f32 interpreter of every artifact, so the whole system builds, tests
//!   and runs **fully offline** with no artifact files and no `xla` crate.
//! * `pjrt::PjrtEngine` (feature `pjrt`): `make artifacts` (python,
//!   build-time only) lowers the L2/L1 compute graphs to HLO **text**;
//!   the engine loads those files, compiles each once on the PJRT CPU
//!   client (`xla` crate), and executes on device. The Gram matrix `K` is
//!   uploaded once per problem and stays resident across the O(100)
//!   matvecs of a Newton solve ([`ops::EngineKernel`]).
//!
//! Python never runs here: given `artifacts/` the binary is
//! self-contained, and without it the native backend serves everything.

pub mod engine;
pub mod error;
pub mod laplace_engine;
pub mod manifest;
pub mod native;
pub mod ops;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use engine::{Buffer, Engine, Tensor};
pub use error::{EngineError, Result};
pub use manifest::Manifest;
pub use native::NativeEngine;
