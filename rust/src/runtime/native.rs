//! The native fallback engine: the artifact family interpreted in Rust.
//!
//! Mirrors the AOT JAX/Pallas artifacts executed by the PJRT backend —
//! same entry-point names, same input/output shapes (validated against a
//! [`Manifest`]), and the same **f32 arithmetic**, so solver-layer code
//! sees the identical precision floor (~1e-6 relative) on either backend
//! and the integration suite runs unchanged against both. Everything here
//! is plain Rust with no external dependencies, which is what makes the
//! default build usable fully offline.
//!
//! Supported entry-point stems (each lowered per size `n`):
//!
//! | stem | computation |
//! |---|---|
//! | `gram` | `K = θ² exp(−‖xᵢ−xⱼ‖² / 2λ²)` over rows of X |
//! | `kmatvec` | `y = K v` |
//! | `amatvec` | `y = p + s ∘ (K (s ∘ p))` (the Newton operator `I + SKS`) |
//! | `newton_stats` | π, ∇, H, s, b_rw, rhs, log-lik fused (Laplace Eq. 9) |
//! | `newton_update` | `a = b_rw − s∘z`, `f' = K a`, log-lik, quad term |
//! | `gram_matvec_free` | `y = K v` without materializing K |

use crate::runtime::engine::Tensor;
use crate::runtime::error::{EngineError, Result};
use crate::runtime::manifest::{ArtifactMeta, Manifest};

/// Pure-Rust engine backend. Holds only the manifest; all compute is
/// stateless and reentrant, so the type is trivially `Send + Sync`.
pub struct NativeEngine {
    manifest: Manifest,
}

impl NativeEngine {
    /// Engine over an explicit manifest (e.g. one read from a directory).
    pub fn new(manifest: Manifest) -> NativeEngine {
        NativeEngine { manifest }
    }

    /// Engine over the built-in manifest (`rust/manifests/native.json`):
    /// dim 784, sizes 8…2048 — the synthetic-MNIST workload family.
    pub fn embedded() -> NativeEngine {
        NativeEngine { manifest: Manifest::native_embedded() }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute one artifact. Validates the argument shapes against the
    /// manifest, then dispatches on the entry-point stem.
    pub fn call(&self, name: &str, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let meta = self.manifest.require(name).map_err(EngineError::new)?;
        check_args(meta, args)?;
        match artifact_stem(name) {
            "gram" => Ok(vec![self.gram(args)]),
            "kmatvec" => Ok(vec![kmatvec(args[0], &args[1].data)]),
            "amatvec" => Ok(vec![amatvec(args[0], &args[1].data, &args[2].data)]),
            "newton_stats" => Ok(newton_stats(args[0], &args[1].data, &args[2].data)),
            "newton_update" => Ok(newton_update(
                args[0],
                &args[1].data,
                &args[2].data,
                &args[3].data,
                &args[4].data,
            )),
            "gram_matvec_free" => Ok(vec![self.gram_matvec_free(args)]),
            other => Err(EngineError::new(format!(
                "artifact '{name}': stem '{other}' has no native implementation"
            ))),
        }
    }

    /// `gram_n{n}`: (X [n,d], θ [1], λ [1]) → K [n,n], all in f32.
    fn gram(&self, args: &[&Tensor]) -> Tensor {
        let x = args[0];
        let (n, d) = (x.shape[0], x.shape[1]);
        let (a2, inv2l2) = rbf_params(args[1].data[0], args[2].data[0]);
        let mut k = vec![0.0f32; n * n];
        for i in 0..n {
            let xi = &x.data[i * d..(i + 1) * d];
            for j in 0..=i {
                let xj = &x.data[j * d..(j + 1) * d];
                let v = rbf_f32(xi, xj, a2, inv2l2);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
        }
        Tensor::mat(n, n, k)
    }

    /// `gram_matvec_free_n{n}`: (X, v, θ, λ) → K v, K never materialized.
    fn gram_matvec_free(&self, args: &[&Tensor]) -> Tensor {
        let (x, v) = (args[0], &args[1].data);
        let (n, d) = (x.shape[0], x.shape[1]);
        let (a2, inv2l2) = rbf_params(args[2].data[0], args[3].data[0]);
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let xi = &x.data[i * d..(i + 1) * d];
            let mut acc = 0.0f32;
            for j in 0..n {
                let xj = &x.data[j * d..(j + 1) * d];
                acc += rbf_f32(xi, xj, a2, inv2l2) * v[j];
            }
            y[i] = acc;
        }
        Tensor::vec(y)
    }
}

/// Strip the trailing `_n{digits}` size suffix from an artifact name.
fn artifact_stem(name: &str) -> &str {
    if let Some(pos) = name.rfind("_n") {
        let suffix = &name[pos + 2..];
        if !suffix.is_empty() && suffix.bytes().all(|b| b.is_ascii_digit()) {
            return &name[..pos];
        }
    }
    name
}

/// Shape validation — delegates to [`ArtifactMeta::check_inputs`], the
/// single validator every backend shares.
fn check_args(meta: &ArtifactMeta, args: &[&Tensor]) -> Result<()> {
    let shapes: Vec<&[usize]> = args.iter().map(|t| t.shape.as_slice()).collect();
    meta.check_inputs(&shapes).map_err(EngineError::new)
}

/// (θ², 1/(2λ²)) in f32.
fn rbf_params(amp: f32, ls: f32) -> (f32, f32) {
    (amp * amp, 1.0 / (2.0 * ls * ls))
}

/// One RBF kernel entry in f32: θ² exp(−‖xi−xj‖²/(2λ²)).
#[inline]
fn rbf_f32(xi: &[f32], xj: &[f32], a2: f32, inv2l2: f32) -> f32 {
    let mut d2 = 0.0f32;
    for (a, b) in xi.iter().zip(xj) {
        let d = a - b;
        d2 += d * d;
    }
    a2 * (-d2 * inv2l2).exp()
}

/// y = K v for a resident row-major n×n Gram tensor, f32 accumulation.
fn kmatvec(k: &Tensor, v: &[f32]) -> Tensor {
    let n = k.shape[0];
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let row = &k.data[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for (kij, vj) in row.iter().zip(v) {
            acc += kij * vj;
        }
        y[i] = acc;
    }
    Tensor::vec(y)
}

/// y = p + s ∘ (K (s ∘ p)) — the fused Newton-operator matvec.
fn amatvec(k: &Tensor, s: &[f32], p: &[f32]) -> Tensor {
    let n = k.shape[0];
    let sp: Vec<f32> = s.iter().zip(p).map(|(a, b)| a * b).collect();
    let ksp = kmatvec(k, &sp);
    let y: Vec<f32> = (0..n).map(|i| p[i] + s[i] * ksp.data[i]).collect();
    Tensor::vec(y)
}

/// Numerically stable f32 logistic sigmoid.
#[inline]
fn sigmoid_f32(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Numerically stable f32 log σ(z) = −log(1 + e^{−z}).
#[inline]
fn log_sigmoid_f32(z: f32) -> f32 {
    if z >= 0.0 {
        -(-z).exp().ln_1p()
    } else {
        z - z.exp().ln_1p()
    }
}

/// `newton_stats_n{n}`: (K, f, y) → (rhs, s, b_rw, loglik). The fused
/// Laplace step statistics (paper Eq. 9): π = σ(f), ∇ = (y+1)/2 − π,
/// H = diag(π(1−π)), s = H^½, b_rw = Hf + ∇, rhs = s ∘ (K b_rw).
fn newton_stats(k: &Tensor, f: &[f32], y: &[f32]) -> Vec<Tensor> {
    let n = f.len();
    let mut s = vec![0.0f32; n];
    let mut b_rw = vec![0.0f32; n];
    let mut loglik = 0.0f32;
    for i in 0..n {
        let pi = sigmoid_f32(f[i]);
        let grad = 0.5 * (y[i] + 1.0) - pi;
        let h = (pi * (1.0 - pi)).max(0.0);
        s[i] = h.sqrt();
        b_rw[i] = h * f[i] + grad;
        loglik += log_sigmoid_f32(y[i] * f[i]);
    }
    let kb = kmatvec(k, &b_rw);
    let rhs: Vec<f32> = (0..n).map(|i| s[i] * kb.data[i]).collect();
    vec![
        Tensor::vec(rhs),
        Tensor::vec(s),
        Tensor::vec(b_rw),
        Tensor::scalar(loglik),
    ]
}

/// `newton_update_n{n}`: (K, b_rw, s, z, y) → (f', a, loglik, quad):
/// a = b_rw − s∘z, f' = K a, loglik = Σ log σ(y∘f'), quad = aᵀ f'.
fn newton_update(k: &Tensor, b_rw: &[f32], s: &[f32], z: &[f32], y: &[f32]) -> Vec<Tensor> {
    let n = b_rw.len();
    let a: Vec<f32> = (0..n).map(|i| b_rw[i] - s[i] * z[i]).collect();
    let f_new = kmatvec(k, &a);
    let mut loglik = 0.0f32;
    let mut quad = 0.0f32;
    for i in 0..n {
        loglik += log_sigmoid_f32(y[i] * f_new.data[i]);
        quad += a[i] * f_new.data[i];
    }
    vec![
        f_new,
        Tensor::vec(a),
        Tensor::scalar(loglik),
        Tensor::scalar(quad),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernel::RbfKernel;
    use crate::linalg::mat::Mat;
    use crate::util::rng::Rng;

    fn features(n: usize, d: usize, seed: u64) -> (Tensor, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::randn(n, d, &mut rng);
        (Tensor::mat(n, d, x.to_f32()), x)
    }

    #[test]
    fn stem_parsing() {
        assert_eq!(artifact_stem("gram_n128"), "gram");
        assert_eq!(artifact_stem("gram_matvec_free_n8"), "gram_matvec_free");
        assert_eq!(artifact_stem("newton_stats_n2048"), "newton_stats");
        assert_eq!(artifact_stem("no_suffix"), "no_suffix");
        assert_eq!(artifact_stem("bad_nx1"), "bad_nx1");
        assert_eq!(artifact_stem("trailing_n"), "trailing_n");
    }

    #[test]
    fn gram_matches_f64_reference() {
        let ne = NativeEngine::embedded();
        // The embedded manifest fixes dim = 784.
        let (x32, x) = features(8, 784, 1);
        let out = ne
            .call("gram_n8", &[&x32, &Tensor::param(1.3), &Tensor::param(9.0)])
            .unwrap();
        let want = RbfKernel::new(1.3, 9.0).gram(&x);
        let got = Mat::from_f32(8, 8, &out[0].data);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn matvec_free_matches_materialized() {
        let ne = NativeEngine::embedded();
        let (x32, _x) = features(8, 784, 2);
        let amp = Tensor::param(1.0);
        let ls = Tensor::param(10.0);
        let k = ne.call("gram_n8", &[&x32, &amp, &ls]).unwrap();
        let v = Tensor::vec((0..8).map(|i| i as f32 - 3.5).collect());
        let dense = ne.call("kmatvec_n8", &[&k[0], &v]).unwrap();
        let free = ne
            .call("gram_matvec_free_n8", &[&x32, &v, &amp, &ls])
            .unwrap();
        for (a, b) in dense[0].data.iter().zip(&free[0].data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn rejects_unknown_and_bad_shapes() {
        let ne = NativeEngine::embedded();
        assert!(ne.call("nonexistent", &[]).is_err());
        let bad = Tensor::vec(vec![0.0; 3]);
        let err = ne.call("kmatvec_n8", &[&bad, &bad]).unwrap_err();
        assert!(format!("{err}").contains("shape"));
        let err = ne.call("kmatvec_n8", &[&bad]).unwrap_err();
        assert!(format!("{err}").contains("inputs"));
    }

    #[test]
    fn f32_likelihood_helpers_match_f64() {
        use crate::gp::likelihood::{log_sigmoid, sigmoid};
        for z in [-20.0f32, -3.0, -0.1, 0.0, 0.1, 3.0, 20.0] {
            assert!((sigmoid_f32(z) as f64 - sigmoid(z as f64)).abs() < 1e-6);
            assert!((log_sigmoid_f32(z) as f64 - log_sigmoid(z as f64)).abs() < 1e-5);
        }
    }
}
