//! Operator adapters: the engine's artifacts as solver-facing traits.
//!
//! [`EngineKernel`] implements [`crate::gp::laplace::KernelOp`] with the
//! Gram matrix resident in engine memory — built once by the `gram_n{n}`
//! artifact and then consumed by `kmatvec` / `amatvec` calls from the CG
//! hot loop. [`EngineSpdOperator`] exposes the Newton operator
//! `A = I + S K S` directly as a [`crate::solvers::SpdOperator`]. All of
//! this is backend-agnostic: the same code runs against the native f32
//! interpreter and (feature `pjrt`) the device-resident PJRT path.
//!
//! Precision note: artifacts are f32 (the TPU-native width) on **both**
//! backends; the solver layer is f64. Relative residuals below ~1e-6 are
//! therefore not reachable through this path — use the f64 native solvers
//! for the paper's Fig. 3 (tol 1e-8) and the engine path for tol ≥ 1e-5
//! workloads.
//!
//! Block applications: the artifact call surface is vector-at-a-time
//! (`kmatvec`/`amatvec` take one operand), so the engine operators keep
//! the default column-loop `apply_block` from
//! [`crate::solvers::SpdOperator`] / [`KernelOp`] — trivially satisfying
//! the column-equivalence contract. A batched `kmatmat_n{n}` artifact
//! (one device call for a whole panel, amortizing the per-call transfer)
//! is the natural next step once the AOT pipeline emits it; consumers
//! already route through `apply_block`, so it would light up everywhere
//! without solver changes.

use crate::gp::laplace::KernelOp;
use crate::runtime::engine::{Buffer, Engine, Tensor};
use crate::runtime::error::{EngineError, Result};
use crate::solvers::SpdOperator;
use std::sync::Arc;

/// Resident Gram matrix with engine-backed matvecs.
pub struct EngineKernel {
    engine: Arc<Engine>,
    n: usize,
    k_buf: Buffer,
    kmatvec_name: String,
    amatvec_name: String,
}

impl EngineKernel {
    /// Build K from features X (n × dim) via the `gram_n{n}` artifact and
    /// keep it resident.
    pub fn from_features(
        engine: Arc<Engine>,
        x: &Tensor,
        amplitude: f64,
        lengthscale: f64,
    ) -> Result<EngineKernel> {
        let n = x.shape[0];
        let gram_name = format!("gram_n{n}");
        let out = engine.call(
            &gram_name,
            &[
                x.clone(),
                Tensor::param(amplitude as f32),
                Tensor::param(lengthscale as f32),
            ],
        )?;
        let k = &out[0];
        let k_buf = engine.upload(k)?;
        Ok(EngineKernel {
            engine,
            n,
            k_buf,
            kmatvec_name: format!("kmatvec_n{n}"),
            amatvec_name: format!("amatvec_n{n}"),
        })
    }

    /// Wrap an existing host-side Gram matrix (uploads it once).
    pub fn from_gram(engine: Arc<Engine>, k: &Tensor) -> Result<EngineKernel> {
        let n = k.shape[0];
        if k.shape != vec![n, n] {
            return Err(EngineError::new(format!(
                "gram must be square, got {:?}",
                k.shape
            )));
        }
        let k_buf = engine.upload(k)?;
        Ok(EngineKernel {
            engine,
            n,
            k_buf,
            kmatvec_name: format!("kmatvec_n{n}"),
            amatvec_name: format!("amatvec_n{n}"),
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Download K to the host (for the Cholesky baseline / tests).
    pub fn download_gram(&self) -> Result<Tensor> {
        self.k_buf.tensor()
    }

    /// y = K v through the engine (f32 internally).
    pub fn kmatvec_f32(&self, v: &[f32]) -> Result<Vec<f32>> {
        let v_buf = self
            .engine
            .upload(&Tensor { shape: vec![self.n], data: v.to_vec() })?;
        let out = self.engine.call_b(&self.kmatvec_name, &[&self.k_buf, &v_buf])?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// y = (I + SKS) p through the fused `amatvec` artifact.
    pub fn amatvec_f32(&self, s: &[f32], p: &[f32]) -> Result<Vec<f32>> {
        let s_buf = self
            .engine
            .upload(&Tensor { shape: vec![self.n], data: s.to_vec() })?;
        let p_buf = self
            .engine
            .upload(&Tensor { shape: vec![self.n], data: p.to_vec() })?;
        let out = self
            .engine
            .call_b(&self.amatvec_name, &[&self.k_buf, &s_buf, &p_buf])?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// Like [`EngineKernel::amatvec_f32`] but with a pre-uploaded `s`
    /// buffer — the CG hot loop applies the same S every iteration, so
    /// [`EngineSpdOperator`] uploads it once.
    pub fn amatvec_f32_buf(&self, s_buf: &Buffer, p: &[f32]) -> Result<Vec<f32>> {
        let p_buf = self
            .engine
            .upload(&Tensor { shape: vec![self.n], data: p.to_vec() })?;
        let out = self
            .engine
            .call_b(&self.amatvec_name, &[&self.k_buf, s_buf, &p_buf])?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// Upload an n-vector once for reuse across calls.
    pub fn upload_vec(&self, v: &[f64]) -> Result<Buffer> {
        self.engine.upload(&Tensor::from_f64(vec![self.n], v))
    }

    /// Run the `newton_stats_n{n}` artifact: (rhs, s, b_rw, loglik).
    pub fn newton_stats(
        &self,
        f: &[f64],
        y: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, f64)> {
        let f_buf = self.engine.upload(&Tensor::from_f64(vec![self.n], f))?;
        let y_buf = self.engine.upload(&Tensor::from_f64(vec![self.n], y))?;
        let name = format!("newton_stats_n{}", self.n);
        let out = self.engine.call_b(&name, &[&self.k_buf, &f_buf, &y_buf])?;
        Ok((
            out[0].to_f64(),
            out[1].to_f64(),
            out[2].to_f64(),
            out[3].data[0] as f64,
        ))
    }

    /// Run the `newton_update_n{n}` artifact: (f', a, loglik, quad).
    pub fn newton_update(
        &self,
        b_rw: &[f64],
        s: &[f64],
        z: &[f64],
        y: &[f64],
    ) -> Result<(Vec<f64>, Vec<f64>, f64, f64)> {
        let n = self.n;
        let b_buf = self.engine.upload(&Tensor::from_f64(vec![n], b_rw))?;
        let s_buf = self.engine.upload(&Tensor::from_f64(vec![n], s))?;
        let z_buf = self.engine.upload(&Tensor::from_f64(vec![n], z))?;
        let y_buf = self.engine.upload(&Tensor::from_f64(vec![n], y))?;
        let name = format!("newton_update_n{n}");
        let out = self
            .engine
            .call_b(&name, &[&self.k_buf, &b_buf, &s_buf, &z_buf, &y_buf])?;
        Ok((
            out[0].to_f64(),
            out[1].to_f64(),
            out[2].data[0] as f64,
            out[3].data[0] as f64,
        ))
    }
}

impl KernelOp for EngineKernel {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, v: &[f64], y: &mut [f64]) {
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        let out = self.kmatvec_f32(&v32).expect("engine kmatvec failed");
        for (yi, o) in y.iter_mut().zip(out) {
            *yi = o as f64;
        }
    }
}

/// The Newton operator `A = I + S K S` served by the fused artifact.
/// `S` is uploaded once at construction; each matvec transfers only the
/// n-vector operand and result.
pub struct EngineSpdOperator<'a> {
    kernel: &'a EngineKernel,
    s_buf: Buffer,
}

impl<'a> EngineSpdOperator<'a> {
    pub fn new(kernel: &'a EngineKernel, s: &[f64]) -> Self {
        assert_eq!(kernel.n(), s.len());
        let s_buf = kernel.upload_vec(s).expect("upload s");
        EngineSpdOperator { kernel, s_buf }
    }
}

impl<'a> SpdOperator for EngineSpdOperator<'a> {
    fn n(&self) -> usize {
        self.kernel.n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let x32: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let out = self
            .kernel
            .amatvec_f32_buf(&self.s_buf, &x32)
            .expect("engine amatvec failed");
        for (yi, o) in y.iter_mut().zip(out) {
            *yi = o as f64;
        }
    }
}

/// Matrix-free operator over raw features (`gram_matvec_free` artifact):
/// the large-n path where K is never materialized.
pub struct EngineMatrixFreeKernel {
    engine: Arc<Engine>,
    n: usize,
    x_buf: Buffer,
    amp: Tensor,
    ls: Tensor,
    name: String,
}

impl EngineMatrixFreeKernel {
    pub fn new(
        engine: Arc<Engine>,
        x: &Tensor,
        amplitude: f64,
        lengthscale: f64,
    ) -> Result<Self> {
        let n = x.shape[0];
        let x_buf = engine.upload(x)?;
        Ok(EngineMatrixFreeKernel {
            engine,
            n,
            x_buf,
            amp: Tensor::param(amplitude as f32),
            ls: Tensor::param(lengthscale as f32),
            name: format!("gram_matvec_free_n{n}"),
        })
    }
}

impl KernelOp for EngineMatrixFreeKernel {
    fn n(&self) -> usize {
        self.n
    }

    fn matvec(&self, v: &[f64], y: &mut [f64]) {
        let v_buf = self
            .engine
            .upload(&Tensor::from_f64(vec![self.n], v))
            .expect("upload");
        let amp_buf = self.engine.upload(&self.amp).expect("upload");
        let ls_buf = self.engine.upload(&self.ls).expect("upload");
        let out = self
            .engine
            .call_b(&self.name, &[&self.x_buf, &v_buf, &amp_buf, &ls_buf])
            .expect("engine gram_matvec_free failed");
        for (yi, o) in y.iter_mut().zip(&out[0].data) {
            *yi = *o as f64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernel::RbfKernel;
    use crate::linalg::mat::Mat;
    use crate::util::rng::Rng;

    fn native_kernel(n: usize, seed: u64) -> (Arc<Engine>, Tensor, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::randn(n, 784, &mut rng);
        let x32 = Tensor::mat(n, 784, x.to_f32());
        (Arc::new(Engine::native()), x32, x)
    }

    #[test]
    fn from_gram_rejects_non_square() {
        let eng = Arc::new(Engine::native());
        let t = Tensor::mat(2, 3, vec![0.0; 6]);
        assert!(EngineKernel::from_gram(eng, &t).is_err());
    }

    #[test]
    fn kernel_matvec_matches_f64_gram() {
        let (eng, x32, x) = native_kernel(8, 3);
        let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();
        let k = RbfKernel::new(1.0, 10.0).gram(&x);
        let v: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let mut got = vec![0.0; 8];
        ek.matvec(&v, &mut got);
        let want = k.matvec(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn download_gram_restores_shape() {
        let (eng, x32, _x) = native_kernel(8, 4);
        let ek = EngineKernel::from_features(eng, &x32, 1.0, 10.0).unwrap();
        let k = ek.download_gram().unwrap();
        assert_eq!(k.shape, vec![8, 8]);
        // Symmetric with θ² on the diagonal.
        for i in 0..8 {
            assert!((k.data[i * 8 + i] - 1.0).abs() < 1e-6);
            for j in 0..8 {
                assert_eq!(k.data[i * 8 + j], k.data[j * 8 + i]);
            }
        }
    }
}
