//! The PJRT backend: compile HLO artifacts once, execute many times.
//!
//! Only compiled with the `pjrt` feature (requires the external `xla`
//! crate, which must be vendored — it is not available offline). Follows
//! the reference wiring of /opt/xla-example/load_hlo.rs:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Executables are compiled lazily on
//! first call and cached for the process lifetime. Large operands (the
//! Gram matrix) are uploaded once as device buffers and passed by
//! reference via `execute_b`.

use crate::runtime::engine::Tensor;
use crate::runtime::error::{EngineError, Result};
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

fn xe(e: impl std::fmt::Debug) -> EngineError {
    EngineError::new(format!("xla: {e:?}"))
}

fn to_literal(t: &Tensor) -> Result<Literal> {
    let lit = Literal::vec1(&t.data);
    if t.shape.len() == 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(xe)
}

fn from_literal(lit: &Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>().map_err(xe)?;
    Ok(Tensor { shape: shape.to_vec(), data })
}

/// Download a device buffer to a host tensor (shape recovered flat: the
/// caller tracks logical shapes; `Buffer::tensor` goes through here).
pub fn buffer_to_tensor(buf: &PjRtBuffer) -> Result<Tensor> {
    let lit = buf.to_literal_sync().map_err(xe)?;
    let data = lit.to_vec::<f32>().map_err(xe)?;
    Ok(Tensor::vec(data))
}

/// The PJRT engine. `Send + Sync`: the PJRT CPU client supports concurrent
/// dispatch, and the executable cache is mutex-guarded.
pub struct PjrtEngine {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    exes: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
}

// SAFETY: the xla wrapper types hold raw pointers into the PJRT C API.
// PJRT clients, loaded executables and buffers are documented thread-safe
// for concurrent Execute/Transfer calls; all mutable engine state (the
// lazy compile cache) is behind a Mutex.
unsafe impl Send for PjrtEngine {}
unsafe impl Sync for PjrtEngine {}

impl PjrtEngine {
    /// Load the engine from an artifact directory (e.g. `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<PjrtEngine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .map_err(|e| EngineError::new(e).context("loading manifest"))?;
        let client = PjRtClient::cpu().map_err(xe)?;
        crate::log_info!(
            "engine up: platform={} devices={} artifacts={} sizes={:?}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len(),
            manifest.sizes
        );
        Ok(PjrtEngine { client, dir, manifest, exes: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.manifest.require(name).map_err(EngineError::new)
    }

    /// Compile (or fetch the cached) executable for `name`.
    fn executable(&self, name: &str) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = crate::util::sync::lock_unpoisoned(&self.exes).get(name) {
            return Ok(exe.clone());
        }
        let meta = self.meta(name)?;
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let path_str = path
            .to_str()
            .ok_or_else(|| EngineError::new("non-utf8 artifact path"))?;
        let proto = HloModuleProto::from_text_file(path_str).map_err(xe)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp).map_err(xe)?);
        crate::log_debug!("compiled {name} in {:.3}s", t0.elapsed().as_secs_f64());
        // Double-checked insert: racing threads may both compile; last wins
        // (both executables are valid).
        crate::util::sync::lock_unpoisoned(&self.exes).insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (e.g. at service startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Upload a tensor to device memory (for operands reused across calls).
    pub fn upload(&self, t: &Tensor) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(xe)
    }

    fn unpack_outputs(&self, meta: &ArtifactMeta, result: Literal) -> Result<Vec<Tensor>> {
        // Artifacts are lowered with return_tuple=True: the single output
        // buffer is a tuple literal with `meta.outputs.len()` elements.
        let mut result = result;
        let parts = result.decompose_tuple().map_err(xe)?;
        if parts.len() != meta.outputs.len() {
            return Err(EngineError::new(format!(
                "artifact {}: expected {} outputs, got {}",
                meta.name,
                meta.outputs.len(),
                parts.len()
            )));
        }
        parts
            .iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| from_literal(lit, &spec.shape))
            .collect()
    }

    /// Execute with host tensors (uploads everything per call).
    pub fn call(&self, name: &str, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self.meta(name)?.clone();
        let shapes: Vec<&[usize]> = args.iter().map(|a| a.shape.as_slice()).collect();
        meta.check_inputs(&shapes).map_err(EngineError::new)?;
        let exe = self.executable(name)?;
        let literals: Vec<Literal> = args.iter().map(to_literal).collect::<Result<_>>()?;
        let out = exe.execute::<Literal>(&literals).map_err(xe)?;
        let lit = out[0][0].to_literal_sync().map_err(xe)?;
        self.unpack_outputs(&meta, lit)
    }

    /// Execute with pre-uploaded device buffers (the hot path: `K` stays
    /// resident; small vectors are uploaded by the caller per call).
    pub fn call_b(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Tensor>> {
        let meta = self.meta(name)?.clone();
        let exe = self.executable(name)?;
        let out = exe.execute_b::<&PjRtBuffer>(args).map_err(xe)?;
        let lit = out[0][0].to_literal_sync().map_err(xe)?;
        self.unpack_outputs(&meta, lit)
    }
}
