//! Operator algebra: cheap SPD views over a base operator.
//!
//! The paper's premise is *sequences of related systems*; in practice the
//! relation is almost always structural — a regularization grid
//! `K + σᵢ²I`, an amplitude grid `θᵢ²·K`, a Newton damping `A + τI`, or a
//! rank-k model update `A + UUᵀ` (Carlberg et al., arXiv:1512.05820;
//! Soodhalter et al., arXiv:2001.10347 treat exactly these families as
//! the recycling primitives). Re-materializing a dense kernel per family
//! member costs `O(n²)` memory traffic and `O(n²d)` assembly each time;
//! these wrappers instead express each member as a **view** that adds
//! `O(n)`–`O(nk)` work per application on top of the shared base:
//!
//! * [`ShiftedOp`] — `A + σI` (σ-grids, Tikhonov ladders, Newton damping);
//! * [`ScaledOp`] — `c·A`, `c > 0` (amplitude grids: `θ²K = ScaledOp(K, θ²)`);
//! * [`SumOp`] — `A + B` (kernel mixtures, additive regularizers);
//! * [`LowRankUpdateOp`] — `A + UUᵀ` (rank-k covariance/model updates).
//!
//! Every wrapper implements [`SpdOperator`] end to end:
//!
//! * `matvec` / [`SpdOperator::apply_block`] forward to the base (so a
//!   view over a [`crate::solvers::DenseOp`] / `ParDenseOp` inherits the
//!   cache-blocked / sharded block kernel) and apply the correction **per
//!   column with the same float sequence as the single-vector path** —
//!   the block-first contract of [`crate::solvers`] holds by induction
//!   through any composition depth;
//! * [`SpdOperator::diag`] is exact-in-the-view: `diag(A)+σ`, `c·diag(A)`,
//!   `diag(A)+diag(B)`, `diag(A)+‖uᵢ‖²` — exact whenever the base
//!   diagonal is exact, so `Jacobi::from_op` stays `O(n)` across a whole
//!   grid of views.
//!
//! Wrappers are generic over ownership: `ShiftedOp::new(&op, σ)` borrows
//! for stack-local grids, `ShiftedOp::new(arc.clone(), σ)` shares an
//! `Arc<dyn SpdOperator + Send + Sync>` for coordinator submission (both
//! via the blanket [`SpdOperator`] impls for `&T` and `Arc<T>`).
//!
//! SPD caveat: the wrappers assert only what is checkable locally
//! (finite σ, `c > 0`, shape agreement). `A + σI` with `σ ≤ −λ_min(A)`,
//! or a sum of an SPD and an indefinite symmetric operator, is not SPD —
//! that remains the caller's contract exactly as with any other
//! [`SpdOperator`] implementation.

use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::dot;
use crate::solvers::{fingerprint_f64s, SpdOperator};

/// The shifted operator `A + σI` — one regularization-grid member as an
/// `O(n)`-per-apply view over the base.
pub struct ShiftedOp<A> {
    base: A,
    sigma: f64,
}

impl<A: SpdOperator> ShiftedOp<A> {
    pub fn new(base: A, sigma: f64) -> Self {
        assert!(sigma.is_finite(), "ShiftedOp needs a finite shift");
        ShiftedOp { base, sigma }
    }

    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    pub fn base(&self) -> &A {
        &self.base
    }
}

impl<A: SpdOperator> SpdOperator for ShiftedOp<A> {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.base.matvec(x, y);
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += self.sigma * xi;
        }
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.base.apply_block(xs, ys);
        for (yv, xv) in ys.data_mut().iter_mut().zip(xs.data()) {
            *yv += self.sigma * xv;
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.base.diag(out);
        for o in out.iter_mut() {
            *o += self.sigma;
        }
    }

    /// The base's fingerprint combined with σ: two σ-grid points over one
    /// base are distinguishable, so per-sequence Jacobi caches rebuild
    /// when the grid moves instead of reusing a diagonal wrong by Δσ.
    fn diag_fingerprint(&self) -> Option<u64> {
        self.base
            .diag_fingerprint()
            .map(|h| fingerprint_f64s(h ^ 0x5417F7ED, [self.sigma]))
    }
}

/// The scaled operator `c·A` (`c > 0`, so SPD-ness is preserved) — e.g.
/// an RBF amplitude grid: `gram(θ, λ) = θ²·gram(1, λ)` makes every
/// amplitude a `ScaledOp` view over one unit-amplitude Gram matrix.
pub struct ScaledOp<A> {
    base: A,
    c: f64,
}

impl<A: SpdOperator> ScaledOp<A> {
    pub fn new(base: A, c: f64) -> Self {
        assert!(c.is_finite() && c > 0.0, "ScaledOp needs a positive scale");
        ScaledOp { base, c }
    }

    pub fn scale(&self) -> f64 {
        self.c
    }

    pub fn base(&self) -> &A {
        &self.base
    }
}

impl<A: SpdOperator> SpdOperator for ScaledOp<A> {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.base.matvec(x, y);
        for yi in y.iter_mut() {
            *yi *= self.c;
        }
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.base.apply_block(xs, ys);
        for yv in ys.data_mut().iter_mut() {
            *yv *= self.c;
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.base.diag(out);
        for o in out.iter_mut() {
            *o *= self.c;
        }
    }

    fn diag_fingerprint(&self) -> Option<u64> {
        self.base
            .diag_fingerprint()
            .map(|h| fingerprint_f64s(h ^ 0x5CA1ED, [self.c]))
    }
}

/// The sum `A + B` of two operators of the same dimension (SPD + SPSD is
/// SPD; the caller owns that contract) — kernel mixtures and additive
/// regularizers without materializing the sum.
pub struct SumOp<A, B> {
    a: A,
    b: B,
}

impl<A: SpdOperator, B: SpdOperator> SumOp<A, B> {
    pub fn new(a: A, b: B) -> Self {
        assert_eq!(a.n(), b.n(), "SumOp needs equal dimensions");
        SumOp { a, b }
    }
}

impl<A: SpdOperator, B: SpdOperator> SpdOperator for SumOp<A, B> {
    fn n(&self) -> usize {
        self.a.n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.a.matvec(x, y);
        let mut t = vec![0.0; x.len()];
        self.b.matvec(x, &mut t);
        for (yi, ti) in y.iter_mut().zip(&t) {
            *yi += ti;
        }
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.a.apply_block(xs, ys);
        let mut t = Mat::zeros(xs.rows(), xs.cols());
        self.b.apply_block(xs, &mut t);
        for (yv, tv) in ys.data_mut().iter_mut().zip(t.data()) {
            *yv += tv;
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.a.diag(out);
        let mut t = vec![0.0; out.len()];
        self.b.diag(&mut t);
        for (o, ti) in out.iter_mut().zip(&t) {
            *o += ti;
        }
    }

    /// Identifiable only when **both** summands are.
    fn diag_fingerprint(&self) -> Option<u64> {
        match (self.a.diag_fingerprint(), self.b.diag_fingerprint()) {
            (Some(ha), Some(hb)) => {
                Some((ha ^ 0x50_AD0D).rotate_left(17) ^ hb.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            }
            _ => None,
        }
    }
}

/// The symmetric low-rank update `A + UUᵀ` with `U ∈ ℝ^{n×k}` — rank-k
/// covariance or model updates (`UUᵀ` is PSD, so SPD-ness of A is
/// preserved) at `O(nk)` per application over the base cost.
pub struct LowRankUpdateOp<A> {
    base: A,
    u: Mat,
}

impl<A: SpdOperator> LowRankUpdateOp<A> {
    pub fn new(base: A, u: Mat) -> Self {
        assert_eq!(u.rows(), base.n(), "LowRankUpdateOp factor dimension mismatch");
        LowRankUpdateOp { base, u }
    }

    /// Rank of the update (columns of U).
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    pub fn factor(&self) -> &Mat {
        &self.u
    }
}

impl<A: SpdOperator> SpdOperator for LowRankUpdateOp<A> {
    fn n(&self) -> usize {
        self.base.n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.base.matvec(x, y);
        let utx = self.u.matvec_t(x);
        self.u.add_scaled_cols(&utx, y);
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        // Block-forward the heavy base; the O(nk·cols) correction runs per
        // column with exactly the single-vector float sequence (the same
        // c-then-i order, zero-coefficient skip, and `coef · u` products
        // as `Mat::add_scaled_cols`), applied in place — no per-column
        // gather/scatter of ys.
        self.base.apply_block(xs, ys);
        let n = self.u.rows();
        for j in 0..xs.cols() {
            let xcol = xs.col(j);
            let utx = self.u.matvec_t(&xcol);
            for (c, &coef) in utx.iter().enumerate() {
                if coef != 0.0 {
                    for i in 0..n {
                        ys[(i, j)] += coef * self.u[(i, c)];
                    }
                }
            }
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.base.diag(out);
        for (i, o) in out.iter_mut().enumerate() {
            let row = self.u.row(i);
            *o += dot(row, row);
        }
    }

    /// The base's fingerprint combined with the factor's shape and a few
    /// strided samples of `U` — enough to tell model updates apart without
    /// touching all of `U`.
    fn diag_fingerprint(&self) -> Option<u64> {
        self.base.diag_fingerprint().map(|h| {
            let data = self.u.data();
            let step = (data.len() / 8).max(1);
            let samples = data.iter().step_by(step).take(8).copied();
            let seed = h ^ (((self.u.rows() as u64) << 32) | self.u.cols() as u64);
            fingerprint_f64s(seed ^ 0x10_0BA2, samples)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{self, DenseOp, ParDenseOp, SolveSpec, StopReason};
    use crate::util::pool::ThreadPool;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// Densely materialize any operator by probing with basis vectors.
    fn materialize(a: &dyn SpdOperator) -> Mat {
        let n = a.n();
        let mut m = Mat::zeros(n, n);
        let mut e = vec![0.0; n];
        let mut y = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            a.matvec(&e, &mut y);
            m.set_col(j, &y);
            e[j] = 0.0;
        }
        m
    }

    /// Assert op ≡ reference matrix on matvec, apply_block, and diag.
    fn assert_matches_dense(op: &dyn SpdOperator, reference: &Mat, tol: f64, tag: &str) {
        let n = reference.rows();
        let mut rng = Rng::new(7);
        // matvec
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let got = op.matvec_alloc(&x);
        let want = reference.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() <= tol * (1.0 + w.abs()), "{tag} matvec: {g} vs {w}");
        }
        // apply_block, including a ragged width
        let k = (Mat::BLOCK_PANEL + 1).min(n);
        let xs = Mat::randn(n, k, &mut rng);
        let mut ys = Mat::zeros(n, k);
        op.apply_block(&xs, &mut ys);
        let want = reference.matmul(&xs);
        assert!(
            ys.max_abs_diff(&want) <= tol * (1.0 + want.fro_norm()),
            "{tag} apply_block: diff {}",
            ys.max_abs_diff(&want)
        );
        // diag
        let mut d = vec![0.0; n];
        op.diag(&mut d);
        for (i, di) in d.iter().enumerate() {
            let w = reference[(i, i)];
            assert!((di - w).abs() <= tol * (1.0 + w.abs()), "{tag} diag[{i}]: {di} vs {w}");
        }
    }

    #[test]
    fn shifted_scaled_sum_lowrank_match_materialized_reference() {
        let mut rng = Rng::new(1);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = Mat::rand_spd(n, 10.0, &mut rng);
        let u = Mat::randn(n, 3, &mut rng);
        let aop = DenseOp::new(&a);
        let bop = DenseOp::new(&b);

        let mut shifted_ref = a.clone();
        shifted_ref.add_diag(0.75);
        assert_matches_dense(&ShiftedOp::new(&aop, 0.75), &shifted_ref, 1e-12, "shifted");

        let mut scaled_ref = a.clone();
        scaled_ref.scale_in_place(2.5);
        assert_matches_dense(&ScaledOp::new(&aop, 2.5), &scaled_ref, 1e-12, "scaled");

        let mut sum_ref = a.clone();
        sum_ref.add_in_place(&b);
        assert_matches_dense(&SumOp::new(&aop, &bop), &sum_ref, 1e-12, "sum");

        let mut lr_ref = a.clone();
        lr_ref.add_in_place(&u.matmul(&u.transpose()));
        assert_matches_dense(&LowRankUpdateOp::new(&aop, u.clone()), &lr_ref, 1e-10, "low-rank");

        // Composition: θ²·A + σI as views over views.
        let composed = ShiftedOp::new(ScaledOp::new(&aop, 4.0), 0.3);
        let mut comp_ref = a.clone();
        comp_ref.scale_in_place(4.0);
        comp_ref.add_diag(0.3);
        assert_matches_dense(&composed, &comp_ref, 1e-12, "θ²A+σI");
    }

    #[test]
    fn algebra_apply_block_is_bitwise_the_matvec_loop() {
        // The column-equivalence contract must hold through composition:
        // block forwarding plus per-column corrections may not change a
        // single float relative to looping matvec over columns.
        let mut rng = Rng::new(2);
        let n = 300; // sharded ParDenseOp base underneath
        let a = Arc::new(Mat::rand_spd(n, 1e4, &mut rng));
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(3)));
        let u = Mat::randn(n, 2, &mut rng);
        let ops: Vec<(&str, Box<dyn SpdOperator + '_>)> = vec![
            ("shifted", Box::new(ShiftedOp::new(&par, 0.5))),
            ("scaled", Box::new(ScaledOp::new(&par, 3.0))),
            ("sum", Box::new(SumOp::new(&par, &par))),
            ("low-rank", Box::new(LowRankUpdateOp::new(&par, u))),
        ];
        for k in [1usize, Mat::BLOCK_PANEL + 1] {
            let xs = Mat::randn(n, k, &mut rng);
            for (tag, op) in &ops {
                let mut want = Mat::zeros(n, k);
                for j in 0..k {
                    want.set_col(j, &op.matvec_alloc(&xs.col(j)));
                }
                let mut ys = Mat::zeros(n, k);
                op.apply_block(&xs, &mut ys);
                assert_eq!(ys, want, "{tag} k={k}");
            }
        }
    }

    #[test]
    fn views_solve_and_jacobi_stays_exact() {
        // A σ-grid member solved through the unified API with auto-Jacobi:
        // the view's diag is exact, so the preconditioner build is O(n).
        let mut rng = Rng::new(3);
        let n = 50;
        let k = Mat::rand_spd(n, 1e4, &mut rng);
        let base = DenseOp::new(&k);
        let op = ShiftedOp::new(&base, 0.09);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let r = solvers::solve(&op, &b, &SolveSpec::pcg().with_jacobi(&op).with_tol(1e-10));
        assert_eq!(r.stop, StopReason::Converged);
        let mut kk = k.clone();
        kk.add_diag(0.09);
        let ax = kk.matvec(&r.x);
        let res: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v) * (u - v)).sum();
        assert!(res.sqrt() / crate::linalg::vec_ops::norm2(&b) < 1e-9);
    }

    #[test]
    fn shifted_sequence_recycles_across_a_sigma_grid() {
        // The paper's §1 hyperparameter scenario expressed as views: one
        // base Gram, a descending σ ladder of ShiftedOp views, one recycle
        // manager. Later grid points must beat their plain-CG cost.
        use crate::solvers::recycle::{RecycleConfig, RecycleManager};
        let mut rng = Rng::new(4);
        let n = 90;
        let k = Mat::rand_spd(n, 1e5, &mut rng);
        let base = DenseOp::new(&k);
        let b: Vec<f64> = (0..n).map(|i| ((i * 3) % 7) as f64 - 2.0).collect();
        let sigmas = [0.5, 0.4, 0.3, 0.25, 0.2];
        let spec = SolveSpec::defcg().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let mut plain = Vec::new();
        let mut recycled = Vec::new();
        for &s in &sigmas {
            let op = ShiftedOp::new(&base, s);
            plain.push(crate::solvers::cg::solve(&op, &b, None, &spec.cg_config()).iterations);
            let r = mgr.solve_next(&op, &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
            recycled.push(r.iterations);
        }
        assert_eq!(plain[0], recycled[0], "first grid point has no basis yet");
        for i in 1..sigmas.len() {
            assert!(
                recycled[i] < plain[i],
                "σ={}: recycled {} >= plain {}",
                sigmas[i],
                recycled[i],
                plain[i]
            );
        }
    }

    #[test]
    fn arc_composition_is_submittable() {
        // ShiftedOp over an Arc'd base is itself Send + Sync and can be
        // Arc'd into the coordinator — the shape SolveService::submit needs.
        let mut rng = Rng::new(5);
        let a = Mat::rand_spd(20, 100.0, &mut rng);
        struct Owned(Mat);
        impl SpdOperator for Owned {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
        }
        let base: Arc<dyn SpdOperator + Send + Sync> = Arc::new(Owned(a.clone()));
        let view: Arc<dyn SpdOperator + Send + Sync> =
            Arc::new(ShiftedOp::new(base.clone(), 1.5));
        let x = vec![1.0; 20];
        let mut want = a.matvec(&x);
        for (w, xi) in want.iter_mut().zip(&x) {
            *w += 1.5 * xi;
        }
        assert_eq!(view.matvec_alloc(&x), want);
    }

    #[test]
    #[should_panic(expected = "positive scale")]
    fn scaled_rejects_nonpositive() {
        let a = Mat::identity(3);
        let _ = ScaledOp::new(DenseOp::new(&a), 0.0);
    }

    #[test]
    #[should_panic(expected = "equal dimensions")]
    fn sum_rejects_dimension_mismatch() {
        let a = Mat::identity(3);
        let b = Mat::identity(4);
        let _ = SumOp::new(DenseOp::new(&a), DenseOp::new(&b));
    }

    #[test]
    #[should_panic(expected = "factor dimension mismatch")]
    fn low_rank_rejects_dimension_mismatch() {
        let a = Mat::identity(3);
        let u = Mat::zeros(4, 2);
        let _ = LowRankUpdateOp::new(DenseOp::new(&a), u);
    }

    #[test]
    fn materialize_helper_roundtrips_dense() {
        let mut rng = Rng::new(6);
        let a = Mat::rand_spd(10, 10.0, &mut rng);
        let m = materialize(&DenseOp::new(&a));
        assert!(m.max_abs_diff(&a) == 0.0);
    }

    #[test]
    fn diag_fingerprints_distinguish_views_and_stay_stable() {
        struct Anon<'a>(&'a Mat);
        impl<'a> SpdOperator for Anon<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(31);
        let a = Arc::new(Mat::rand_spd(20, 100.0, &mut rng));
        let op = DenseOp::new(&a);
        let base_fp = op.diag_fingerprint().expect("dense op must fingerprint");
        assert_eq!(op.diag_fingerprint().unwrap(), base_fp, "stable across calls");
        // The parallel wrapper over the same matrix has the same diagonal,
        // so the same fingerprint — a sequence may swap serial/parallel
        // operators without invalidating its Jacobi.
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(2)));
        assert_eq!(par.diag_fingerprint().unwrap(), base_fp);

        // Shifted views: distinguish grid points, agree within one.
        let s1 = ShiftedOp::new(DenseOp::new(&a), 0.5);
        let s2 = ShiftedOp::new(DenseOp::new(&a), 1.5);
        let s1b = ShiftedOp::new(DenseOp::new(&a), 0.5);
        assert_ne!(s1.diag_fingerprint(), s2.diag_fingerprint());
        assert_eq!(s1.diag_fingerprint(), s1b.diag_fingerprint());
        assert_ne!(s1.diag_fingerprint().unwrap(), base_fp);

        let c1 = ScaledOp::new(DenseOp::new(&a), 2.0);
        let c2 = ScaledOp::new(DenseOp::new(&a), 3.0);
        assert_ne!(c1.diag_fingerprint(), c2.diag_fingerprint());
        assert_ne!(c1.diag_fingerprint().unwrap(), s1.diag_fingerprint().unwrap());

        // A sum is identifiable only when both summands are; an anonymous
        // operator (no override) degrades the whole composition to None.
        assert!(Anon(&a).diag_fingerprint().is_none());
        assert!(SumOp::new(DenseOp::new(&a), Anon(&a)).diag_fingerprint().is_none());
        assert!(SumOp::new(DenseOp::new(&a), DenseOp::new(&a)).diag_fingerprint().is_some());
        assert!(ShiftedOp::new(Anon(&a), 1.0).diag_fingerprint().is_none());

        // Low-rank updates with different factors are distinguishable.
        let u1 = Mat::randn(20, 2, &mut rng);
        let u2 = Mat::randn(20, 2, &mut rng);
        let l1 = LowRankUpdateOp::new(DenseOp::new(&a), u1.clone());
        let l1b = LowRankUpdateOp::new(DenseOp::new(&a), u1);
        let l2 = LowRankUpdateOp::new(DenseOp::new(&a), u2);
        assert_ne!(l1.diag_fingerprint(), l2.diag_fingerprint());
        assert_eq!(l1.diag_fingerprint(), l1b.diag_fingerprint());

        // Blanket impls forward the fingerprint (an Arc'd composed view
        // submitted to the coordinator must stay identifiable).
        let arc: Arc<dyn SpdOperator + Send + Sync> =
            Arc::new(ShiftedOp::new(ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(2))), 0.5));
        assert_eq!(arc.diag_fingerprint(), s1.diag_fingerprint());
        assert_eq!((&arc as &dyn SpdOperator).diag_fingerprint(), s1.diag_fingerprint());
    }
}
