//! The unified solve API: one entry point across every solver family.
//!
//! The paper frames deflation, preconditioning, and augmentation as
//! interchangeable *policies* over one abstract solve (de Roos & Hennig
//! 2017 §2; Soodhalter, de Sturler & Kilmer 2020). This module makes that
//! literal: method choice, preconditioning, deflation, and the
//! storage/stall knobs are all **data** on a single request type,
//! [`SolveSpec`], dispatched through [`solve`] / [`solve_with_x0`]:
//!
//! ```no_run
//! use krr::linalg::mat::Mat;
//! use krr::solvers::{self, DenseOp, SolveSpec};
//! use krr::util::rng::Rng;
//!
//! let mut rng = Rng::new(0);
//! let a = Mat::rand_spd(100, 1e4, &mut rng);
//! let b = vec![1.0; 100];
//! // Plain CG, Jacobi-PCG, and deflated CG are the same call with a
//! // different spec:
//! let op = DenseOp::new(&a);
//! let plain = solvers::solve(&op, &b, &SolveSpec::cg().with_tol(1e-8));
//! let jacobi = solvers::solve(&op, &b, &SolveSpec::pcg().with_jacobi(&op).with_tol(1e-8));
//! assert!(plain.final_residual() <= 1e-8 && jacobi.final_residual() <= 1e-8);
//! ```
//!
//! Dispatch semantics per [`Method`]:
//!
//! * [`Method::Cg`] — the plain Hestenes–Stiefel kernel ([`crate::solvers::cg`]).
//!   Any preconditioner or deflation basis on the spec is deliberately
//!   **not** applied (a plain request stays plain even on a spec cloned
//!   from a richer one).
//! * [`Method::Pcg`] — preconditioned CG. With no preconditioner set this
//!   degenerates to plain CG (the identity preconditioner changes
//!   nothing); with a deflation basis it runs the composed
//!   deflated-preconditioned kernel.
//! * [`Method::DefCg`] — deflated CG (Saad et al. 2000), optionally
//!   composed with the spec's preconditioner. With an empty/no basis it
//!   reduces exactly to (P)CG.
//! * [`Method::BlockCg`] — rank-adaptive block CG (O'Leary 1980;
//!   [`crate::solvers::blockcg::solve_spec`]). Through the single-RHS
//!   entry point the right-hand side becomes a 1-column block, which runs
//!   the *same scalar recurrences* as def-CG; use [`solve_block`] for
//!   genuine multi-RHS workloads. Block requests are first-class policy
//!   carriers: the spec's **deflation basis, preconditioner (explicit or
//!   `auto_jacobi`), `store_l` direction storage, and `stall_window`** all
//!   reach the block kernel, so a block run deflates against a recycled
//!   basis and feeds directions back to the next extraction exactly like
//!   the single-RHS methods. Warm starts are native (`X₀` per column, one
//!   extra block apply for the initial residual), and `recompute_every`
//!   periodically re-derives the active residuals exactly, as in plain
//!   CG. No spec knob is silently ignored by block requests anymore.
//!
//! Beyond the numerical policies, a spec carries the request's
//! **lifecycle** policies: a [`Priority`] class for admission-controlled
//! queues, and a [`SolveControl`] (cancel token + absolute deadline —
//! [`SolveSpec::with_cancel`] / [`SolveSpec::with_deadline`]) that every
//! kernel checks once per iteration, so cancellation and deadlines take
//! effect *mid-solve* with the partial iterate returned.

use crate::linalg::mat::Mat;
use crate::solvers::blockcg::{self, BlockSolveResult};
use crate::solvers::cg::{self, CgConfig};
use crate::solvers::control::{CancelToken, SolveControl};
use crate::solvers::defcg::{self, Deflation};
use crate::solvers::recycle::RecycleBudget;
use crate::solvers::strategy::StrategyChoice;
use crate::solvers::{SolveResult, SpdOperator};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Scheduling class of a request in an admission-controlled queue
/// (`coordinator::SolveService`). Within one sequence queue the drainer
/// pops the most urgent class first, FIFO within a class; the library
/// entry points ([`solve`] etc.) ignore it.
///
/// The derived order makes *smaller* more urgent
/// (`Interactive < Batch`), so `min()` over a queue picks the class to
/// serve.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive (the default): a user is waiting on the result.
    #[default]
    Interactive,
    /// Throughput work (grid searches, refits): yields to interactive
    /// traffic, runs FIFO among itself.
    Batch,
}

/// Which solver family a [`SolveSpec`] requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Plain conjugate gradients.
    Cg,
    /// Preconditioned CG (the spec's preconditioner; identity if unset).
    Pcg,
    /// Deflated CG, optionally composed with a preconditioner.
    DefCg,
    /// Block CG (multi-RHS; single-RHS requests become 1-column blocks).
    BlockCg,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Cg => "cg",
            Method::Pcg => "pcg",
            Method::DefCg => "def-cg",
            Method::BlockCg => "block-cg",
        }
    }
}

/// A symmetric positive definite preconditioner `M ≈ A`, applied as
/// `z = M⁻¹ r`.
///
/// Implementations must be cheap relative to a matvec (the CG loop
/// applies them once per iteration) and must be *fixed* for the duration
/// of a solve — CG's three-term recurrence assumes a constant M.
pub trait Preconditioner: Send + Sync {
    /// z = M⁻¹ r. `z.len() == r.len()`.
    fn apply(&self, r: &[f64], z: &mut [f64]);

    /// Short human-readable tag for logs and metrics.
    fn name(&self) -> &'static str {
        "custom"
    }
}

/// The identity preconditioner: `z = r`. Turns PCG into plain CG
/// (bit-for-bit: copying r and multiplying by nothing changes no float).
#[derive(Clone, Copy, Debug, Default)]
pub struct Identity;

impl Preconditioner for Identity {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        z.copy_from_slice(r);
    }

    fn name(&self) -> &'static str {
        "identity"
    }
}

/// Jacobi (diagonal) preconditioning: `z_i = r_i / a_ii`.
///
/// Build it from an explicit diagonal ([`Jacobi::new`]) or straight from
/// an operator ([`Jacobi::from_op`]), which uses [`SpdOperator::diag`] —
/// exact for operators that override `diag`, n probing matvecs otherwise.
#[derive(Clone, Debug)]
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    /// From the diagonal of A (must be strictly positive — any SPD matrix
    /// has one, so a non-positive entry means the operator is not SPD or
    /// the diagonal is wrong).
    pub fn new(diag: &[f64]) -> Jacobi {
        assert!(
            diag.iter().all(|&d| d > 0.0),
            "Jacobi needs a positive diagonal"
        );
        Jacobi {
            inv_diag: diag.iter().map(|&d| 1.0 / d).collect(),
        }
    }

    /// From an operator via [`SpdOperator::diag`]. Cost: free for exact
    /// overrides (`DenseOp`, `ParDenseOp`, the GPC Newton operator), n
    /// matvecs for the probing default.
    pub fn from_op(a: &dyn SpdOperator) -> Jacobi {
        let mut d = vec![0.0; a.n()];
        a.diag(&mut d);
        Jacobi::new(&d)
    }

    pub fn n(&self) -> usize {
        self.inv_diag.len()
    }
}

impl Preconditioner for Jacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        assert_eq!(r.len(), self.inv_diag.len());
        assert_eq!(z.len(), self.inv_diag.len());
        for i in 0..r.len() {
            z[i] = r[i] * self.inv_diag[i];
        }
    }

    fn name(&self) -> &'static str {
        "jacobi"
    }
}

/// One solve request: the method plus every policy knob, as plain data.
///
/// Built with the builder methods and handed to [`solve`] /
/// [`solve_with_x0`], to [`crate::solvers::recycle::RecycleManager::solve_next`],
/// or to [`crate::coordinator::SequenceHandle::submit`] — the same type end
/// to end from library callers through the coordinator. Cloning is cheap:
/// the preconditioner and deflation basis are `Arc`-shared.
#[derive(Clone)]
pub struct SolveSpec {
    /// Solver family to dispatch to.
    pub method: Method,
    /// Stop when ‖r‖/‖b‖ ≤ tol.
    pub tol: f64,
    /// Iteration cap (0 means `10 n`).
    pub max_iters: usize,
    /// Store the first ℓ (direction, A·direction) pairs for recycling.
    pub store_l: usize,
    /// Stagnation window (0 disables; see [`CgConfig::stall_window`]).
    pub stall_window: usize,
    /// Residual replacement period (0 disables; see
    /// [`CgConfig::recompute_every`]; honored by the plain-CG kernel).
    pub recompute_every: usize,
    /// Optional preconditioner (used by `Pcg` and `DefCg`).
    pub precond: Option<Arc<dyn Preconditioner>>,
    /// Ask the solve site to supply a Jacobi preconditioner built from the
    /// operator's diagonal when `precond` is unset (used by `Pcg` and
    /// `DefCg`). Unlike [`SolveSpec::with_jacobi`] — which bakes a
    /// diagonal into the spec at build time — this defers the build to
    /// whoever runs the solve: [`solve`] builds one per call, while a
    /// recycled sequence ([`crate::solvers::recycle::RecycleManager`], and
    /// therefore the coordinator) builds it **once per sequence** and
    /// reuses it across requests instead of re-deriving the diagonal every
    /// time.
    pub auto_jacobi: bool,
    /// Optional deflation basis (used by `DefCg` and `Pcg`). Inside a
    /// recycled sequence the manager's basis takes precedence over this.
    pub deflation: Option<Arc<Deflation>>,
    /// Scheduling class in an admission-controlled queue (ignored by the
    /// direct library entry points). Defaults to
    /// [`Priority::Interactive`].
    pub priority: Priority,
    /// Cooperative cancellation and wall-clock deadline, checked once
    /// per iteration by every kernel (so both take effect *mid-solve*,
    /// within one operator application, returning the partial iterate).
    /// The coordinator injects each request's future token here; direct
    /// callers attach their own with [`SolveSpec::with_cancel`] /
    /// [`SolveSpec::with_deadline`].
    pub control: SolveControl,
    /// Per-request override of the sequence's
    /// [`crate::solvers::recycle::RecycleBudget`]: inside a recycled
    /// sequence, `Some` takes precedence over
    /// [`crate::solvers::recycle::RecycleConfig::budget`]. Ignored by the
    /// direct (manager-less) entry points, which hold no recycling state
    /// to bound.
    pub budget: Option<RecycleBudget>,
    /// Per-request override of the sequence's recycle-space strategy
    /// (see [`crate::solvers::strategy`]): inside a recycled sequence,
    /// `Some` takes precedence over
    /// [`crate::solvers::recycle::RecycleConfig::strategy`]. Ignored by
    /// the direct entry points, which never extract a basis.
    pub strategy: Option<StrategyChoice>,
}

impl Default for SolveSpec {
    fn default() -> Self {
        SolveSpec::cg()
    }
}

impl SolveSpec {
    /// A spec for `method` with the default CG knobs (tol 1e-5, auto cap).
    pub fn new(method: Method) -> SolveSpec {
        let d = CgConfig::default();
        SolveSpec {
            method,
            tol: d.tol,
            max_iters: d.max_iters,
            store_l: d.store_l,
            stall_window: d.stall_window,
            recompute_every: d.recompute_every,
            precond: None,
            auto_jacobi: false,
            deflation: None,
            priority: Priority::default(),
            control: SolveControl::none(),
            budget: None,
            strategy: None,
        }
    }

    /// Plain CG request.
    pub fn cg() -> SolveSpec {
        SolveSpec::new(Method::Cg)
    }

    /// Preconditioned-CG request (attach a preconditioner with
    /// [`SolveSpec::with_precond`] / [`SolveSpec::with_jacobi`]).
    pub fn pcg() -> SolveSpec {
        SolveSpec::new(Method::Pcg)
    }

    /// Deflated-CG request (attach a basis with
    /// [`SolveSpec::with_deflation`], or let a
    /// [`crate::solvers::recycle::RecycleManager`] supply one).
    pub fn defcg() -> SolveSpec {
        SolveSpec::new(Method::DefCg)
    }

    /// Block-CG request.
    pub fn blockcg() -> SolveSpec {
        SolveSpec::new(Method::BlockCg)
    }

    pub fn with_tol(mut self, tol: f64) -> SolveSpec {
        self.tol = tol;
        self
    }

    pub fn with_max_iters(mut self, max_iters: usize) -> SolveSpec {
        self.max_iters = max_iters;
        self
    }

    pub fn with_store_l(mut self, store_l: usize) -> SolveSpec {
        self.store_l = store_l;
        self
    }

    pub fn with_stall_window(mut self, stall_window: usize) -> SolveSpec {
        self.stall_window = stall_window;
        self
    }

    pub fn with_recompute_every(mut self, recompute_every: usize) -> SolveSpec {
        self.recompute_every = recompute_every;
        self
    }

    /// Attach a preconditioner.
    pub fn with_precond(mut self, p: Arc<dyn Preconditioner>) -> SolveSpec {
        self.precond = Some(p);
        self
    }

    /// Attach a Jacobi preconditioner built from `a`'s diagonal
    /// (exact where [`SpdOperator::diag`] is overridden, probed otherwise).
    pub fn with_jacobi(self, a: &dyn SpdOperator) -> SolveSpec {
        self.with_precond(Arc::new(Jacobi::from_op(a)))
    }

    /// Defer the Jacobi build to the solve site (see
    /// [`SolveSpec::auto_jacobi`]): [`solve`] derives it from the operator
    /// per call; a recycled sequence caches one per sequence. Ignored when
    /// an explicit preconditioner is attached.
    pub fn with_auto_jacobi(mut self) -> SolveSpec {
        self.auto_jacobi = true;
        self
    }

    /// Attach a deflation basis.
    pub fn with_deflation(mut self, d: Deflation) -> SolveSpec {
        self.deflation = Some(Arc::new(d));
        self
    }

    /// Attach an already-shared deflation basis.
    pub fn with_deflation_arc(mut self, d: Arc<Deflation>) -> SolveSpec {
        self.deflation = Some(d);
        self
    }

    /// Set the scheduling class for admission-controlled queues.
    pub fn with_priority(mut self, priority: Priority) -> SolveSpec {
        self.priority = priority;
        self
    }

    /// Shorthand for [`SolveSpec::with_priority`]`(Priority::Batch)`.
    pub fn batch(self) -> SolveSpec {
        self.with_priority(Priority::Batch)
    }

    /// Attach a cancellation token. Raising it from any thread stops the
    /// solve at its next per-iteration check with
    /// [`crate::solvers::StopReason::Cancelled`] and the partial iterate
    /// returned. Submitting a spec that already carries a token through
    /// the coordinator reuses it as the future's token (so the same flag
    /// cancels whether raised directly or via `SolveFuture::cancel`).
    pub fn with_cancel(mut self, token: CancelToken) -> SolveSpec {
        self.control.set_token(token);
        self
    }

    /// Give the request `budget` of wall clock from **now**. The
    /// deadline is absolute: in a queued service, waiting in the queue
    /// counts against it (an admission-controlled system must bound the
    /// caller's total latency, not just the solver's share) — build or
    /// re-arm the spec at submission time, once per request. When it
    /// expires mid-solve, the kernel stops with
    /// [`crate::solvers::StopReason::DeadlineExceeded`] within one
    /// operator application and returns the partial iterate; a queued
    /// request whose deadline passed before it was dequeued completes
    /// without running at all.
    pub fn with_deadline(mut self, budget: Duration) -> SolveSpec {
        self.control.deadline = Some(Instant::now() + budget);
        self
    }

    /// Like [`SolveSpec::with_deadline`], with an explicit absolute
    /// instant.
    pub fn with_deadline_at(mut self, at: Instant) -> SolveSpec {
        self.control.deadline = Some(at);
        self
    }

    /// Override the sequence's [`RecycleBudget`] for this request (see
    /// [`SolveSpec::budget`]).
    pub fn with_budget(mut self, budget: RecycleBudget) -> SolveSpec {
        self.budget = Some(budget);
        self
    }

    /// Override the sequence's recycle-space strategy for this request
    /// (see [`SolveSpec::strategy`]).
    pub fn with_strategy(mut self, strategy: StrategyChoice) -> SolveSpec {
        self.strategy = Some(strategy);
        self
    }

    /// Shorthand for [`SolveSpec::with_strategy`]`(StrategyChoice::Auto)`:
    /// predictive adaptive-k sizing that shrinks to plain CG when
    /// recycling cannot pay.
    pub fn auto_strategy(self) -> SolveSpec {
        self.with_strategy(StrategyChoice::Auto)
    }

    /// The scalar knobs (plus the control handle) as the legacy
    /// per-kernel config.
    pub fn cg_config(&self) -> CgConfig {
        CgConfig {
            tol: self.tol,
            max_iters: self.max_iters,
            store_l: self.store_l,
            stall_window: self.stall_window,
            recompute_every: self.recompute_every,
            control: self.control.clone(),
        }
    }
}

impl std::fmt::Debug for SolveSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SolveSpec")
            .field("method", &self.method)
            .field("tol", &self.tol)
            .field("max_iters", &self.max_iters)
            .field("store_l", &self.store_l)
            .field("stall_window", &self.stall_window)
            .field("recompute_every", &self.recompute_every)
            .field("auto_jacobi", &self.auto_jacobi)
            .field("precond", &self.precond.as_ref().map(|p| p.name()))
            .field("deflation_k", &self.deflation.as_ref().map(|d| d.k()))
            .field("priority", &self.priority)
            .field("deadline", &self.control.deadline)
            .field("budget", &self.budget)
            .field("strategy", &self.strategy)
            .finish()
    }
}

/// Solve `A x = b` according to `spec`, starting from zeros.
///
/// This is the single entry point across all four solver families; the
/// per-family free functions remain as thin shims over the same kernels.
pub fn solve(a: &dyn SpdOperator, b: &[f64], spec: &SolveSpec) -> SolveResult {
    dispatch(a, b, None, spec, spec.deflation.as_deref())
}

/// Like [`solve`], starting from `x0`.
pub fn solve_with_x0(
    a: &dyn SpdOperator,
    b: &[f64],
    x0: &[f64],
    spec: &SolveSpec,
) -> SolveResult {
    dispatch(a, b, Some(x0), spec, spec.deflation.as_deref())
}

/// Multi-RHS entry point: solve `A X = B` with rank-adaptive block CG.
///
/// The spec is honored like the single-RHS methods honor it: `tol`,
/// `max_iters`, `stall_window`, `store_l` (block runs return real
/// [`crate::solvers::StoredDirections`] panels for the next
/// harmonic-Ritz extraction),
/// the deflation basis (projected start + per-iteration deflation) and
/// the preconditioner (explicit, or built from the operator's diagonal
/// under [`SolveSpec::with_auto_jacobi`]). The `method` field is ignored:
/// this *is* the block entry point.
///
/// The iteration drives [`SpdOperator::apply_block`], so operators with a
/// real block kernel pay one data pass per iteration; the result's
/// `matvecs` counts each block apply as its *active* column count
/// (`col_matvecs` has the per-column split — converged and
/// linearly-dependent columns stop paying when they drop).
///
/// For coalescing same-sequence multi-RHS traffic through the
/// coordinator, see `coordinator::SequenceHandle::submit_block`; for a
/// block solve that consumes and feeds a carried recycled basis, see
/// [`crate::solvers::recycle::RecycleManager::solve_block`].
pub fn solve_block(a: &dyn SpdOperator, b: &Mat, spec: &SolveSpec) -> BlockSolveResult {
    solve_block_with(a, b, spec, spec.deflation.as_deref())
}

/// [`solve_block`] with an externally supplied deflation basis — the
/// recycle manager substitutes its carried `(W, AW)` here, overriding any
/// basis on the spec.
pub(crate) fn solve_block_with(
    a: &dyn SpdOperator,
    b: &Mat,
    spec: &SolveSpec,
    defl: Option<&Deflation>,
) -> BlockSolveResult {
    let cfg = spec.cg_config();
    let built = build_auto_jacobi(a, spec);
    let precond: Option<&dyn Preconditioner> = spec
        .precond
        .as_deref()
        .or(built.as_ref().map(|j| j as &dyn Preconditioner));
    blockcg::solve_spec(a, b, None, defl, precond, &cfg)
}

/// The per-call `auto_jacobi` build (a recycled sequence intercepts this
/// earlier and substitutes its per-sequence cached Jacobi instead).
fn build_auto_jacobi(a: &dyn SpdOperator, spec: &SolveSpec) -> Option<Jacobi> {
    if spec.precond.is_none() && spec.auto_jacobi {
        Some(Jacobi::from_op(a))
    } else {
        None
    }
}

/// Shared dispatch used by [`solve`]/[`solve_with_x0`] and the recycle
/// manager (which substitutes its own basis for `defl`).
pub(crate) fn dispatch(
    a: &dyn SpdOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    spec: &SolveSpec,
    defl: Option<&Deflation>,
) -> SolveResult {
    let cfg = spec.cg_config();
    match spec.method {
        Method::Cg => cg::solve(a, b, x0, &cfg),
        Method::Pcg | Method::DefCg => {
            // auto_jacobi: build the preconditioner here, per call. A
            // recycled sequence intercepts this earlier and substitutes
            // its per-sequence cached Jacobi instead.
            let built = build_auto_jacobi(a, spec);
            let precond: Option<&dyn Preconditioner> = spec
                .precond
                .as_deref()
                .or(built.as_ref().map(|j| j as &dyn Preconditioner));
            defcg::solve_precond(a, b, x0, defl, precond, &cfg)
        }
        Method::BlockCg => {
            // The block kernel takes warm starts, deflation and
            // preconditioning natively; a single right-hand side is a
            // 1-column block running def-CG's scalar recurrences (this
            // must never panic: block requests flow through the
            // coordinator's drainer threads).
            let n = a.n();
            assert_eq!(b.len(), n, "rhs dimension mismatch");
            let mut bm = Mat::zeros(n, 1);
            bm.set_col(0, b);
            let x0m = x0.map(|x0| {
                assert_eq!(x0.len(), n, "x0 dimension mismatch");
                let mut m = Mat::zeros(n, 1);
                m.set_col(0, x0);
                m
            });
            let built = build_auto_jacobi(a, spec);
            let precond: Option<&dyn Preconditioner> = spec
                .precond
                .as_deref()
                .or(built.as_ref().map(|j| j as &dyn Preconditioner));
            let r = blockcg::solve_spec(a, &bm, x0m.as_ref(), defl, precond, &cfg);
            SolveResult {
                x: r.x.col(0),
                residuals: r.residuals,
                iterations: r.iterations,
                // The block kernel already counts per column (s = 1 here).
                matvecs: r.matvecs,
                stop: r.stop,
                stored: r.stored,
                seconds: r.seconds,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::ritz::{extract, RitzConfig, RitzSelect};
    use crate::solvers::{DenseOp, StopReason};
    use crate::util::rng::Rng;

    fn system(n: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        (a, b)
    }

    #[test]
    fn every_method_converges_through_the_single_entry_point() {
        let (a, b) = system(60, 1);
        let op = DenseOp::new(&a);
        // Basis for the deflated request.
        let prior = solve(&op, &b, &SolveSpec::cg().with_tol(1e-10).with_store_l(10));
        let (defl, _) = extract(
            None,
            &prior.stored,
            60,
            &RitzConfig { k: 6, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        )
        .unwrap();
        let specs = [
            SolveSpec::cg().with_tol(1e-9),
            SolveSpec::pcg().with_jacobi(&op).with_tol(1e-9),
            SolveSpec::defcg().with_deflation(defl).with_tol(1e-9),
            SolveSpec::blockcg().with_tol(1e-9),
        ];
        for spec in &specs {
            let r = solve(&op, &b, spec);
            assert_eq!(r.stop, StopReason::Converged, "{spec:?}");
            let ax = a.matvec(&r.x);
            let res: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v) * (u - v)).sum();
            assert!(
                res.sqrt() / crate::linalg::vec_ops::norm2(&b) < 1e-8,
                "{spec:?}"
            );
        }
    }

    #[test]
    fn pcg_without_preconditioner_degenerates_to_plain_cg() {
        let (a, b) = system(40, 2);
        let op = DenseOp::new(&a);
        let plain = solve(&op, &b, &SolveSpec::cg().with_tol(1e-9));
        let pcg = solve(&op, &b, &SolveSpec::pcg().with_tol(1e-9));
        assert_eq!(plain.iterations, pcg.iterations);
        assert_eq!(plain.x, pcg.x);
    }

    #[test]
    fn cg_method_ignores_attached_policies() {
        // A plain request stays plain even if the spec carries a
        // preconditioner (e.g. cloned from a richer spec).
        let (a, b) = system(40, 3);
        let op = DenseOp::new(&a);
        let plain = solve(&op, &b, &SolveSpec::cg().with_tol(1e-9));
        let decorated = solve(&op, &b, &SolveSpec::cg().with_jacobi(&op).with_tol(1e-9));
        assert_eq!(plain.x, decorated.x);
        assert_eq!(plain.iterations, decorated.iterations);
    }

    #[test]
    fn jacobi_from_op_matches_explicit_diagonal() {
        let (a, _b) = system(30, 4);
        let op = DenseOp::new(&a);
        let diag: Vec<f64> = (0..30).map(|i| a[(i, i)]).collect();
        let from_diag = Jacobi::new(&diag);
        let from_op = Jacobi::from_op(&op);
        let r: Vec<f64> = (0..30).map(|i| (i as f64) - 14.0).collect();
        let mut z1 = vec![0.0; 30];
        let mut z2 = vec![0.0; 30];
        from_diag.apply(&r, &mut z1);
        from_op.apply(&r, &mut z2);
        assert_eq!(z1, z2, "DenseOp::diag must be exact");
    }

    #[test]
    fn auto_jacobi_matches_explicit_jacobi() {
        // with_auto_jacobi defers the build to the solve site; through the
        // direct entry point that must be float-for-float the eagerly
        // built with_jacobi spec (same operator, same exact diagonal).
        let (a, b) = system(50, 8);
        let op = DenseOp::new(&a);
        let eager = solve(&op, &b, &SolveSpec::pcg().with_jacobi(&op).with_tol(1e-9));
        let auto = solve(&op, &b, &SolveSpec::pcg().with_auto_jacobi().with_tol(1e-9));
        assert_eq!(eager.x, auto.x);
        assert_eq!(eager.iterations, auto.iterations);
        // An explicit preconditioner wins over the flag.
        let ident = solve(
            &op,
            &b,
            &SolveSpec::pcg()
                .with_precond(Arc::new(Identity))
                .with_auto_jacobi()
                .with_tol(1e-9),
        );
        let plain = solve(&op, &b, &SolveSpec::cg().with_tol(1e-9));
        assert_eq!(ident.x, plain.x);
    }

    #[test]
    #[should_panic(expected = "positive diagonal")]
    fn jacobi_rejects_nonpositive_diagonal() {
        let _ = Jacobi::new(&[1.0, 0.0, 2.0]);
    }

    #[test]
    fn identity_preconditioner_copies() {
        let r = [1.0, -2.0, 3.5];
        let mut z = [0.0; 3];
        Identity.apply(&r, &mut z);
        assert_eq!(z, r);
        assert_eq!(Identity.name(), "identity");
    }

    #[test]
    fn blockcg_warm_start_shifts_instead_of_panicking() {
        // Block requests with x0 flow through the coordinator's drainer
        // threads, so they must be handled, not asserted away.
        let (a, b) = system(40, 7);
        let op = DenseOp::new(&a);
        let spec = SolveSpec::blockcg().with_tol(1e-9);
        let cold = solve(&op, &b, &spec);
        assert_eq!(cold.stop, StopReason::Converged);
        // Warm start from the (near-)solution: converges immediately-ish
        // and the answer still satisfies the ORIGINAL system to tol·‖b‖.
        let warm = solve_with_x0(&op, &b, &cold.x, &spec);
        assert_eq!(warm.stop, StopReason::Converged);
        assert!(warm.iterations <= 2, "warm block start took {}", warm.iterations);
        let ax = a.matvec(&warm.x);
        let res: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v) * (u - v)).sum();
        assert!(res.sqrt() / crate::linalg::vec_ops::norm2(&b) <= 1e-9);
        // Warm-starting from an already-converged solution stops at once.
        let again = solve_with_x0(&op, &b, &warm.x, &spec);
        assert_eq!(again.stop, StopReason::Converged);
        assert_eq!(again.iterations, 0);
    }

    #[test]
    fn solve_block_handles_multiple_rhs() {
        let mut rng = Rng::new(5);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let r = solve_block(&DenseOp::new(&a), &b, &SolveSpec::blockcg().with_tol(1e-10));
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.x.max_abs_diff(&x_true) < 1e-5);
    }

    #[test]
    fn every_method_honors_cancellation_through_the_entry_point() {
        // A pre-cancelled spec must stop every family as Cancelled with
        // zero iterations and the start iterate — including the block
        // path, whose entry check must fire before the initial block
        // apply.
        use crate::solvers::control::CancelToken;
        let (a, b) = system(30, 9);
        let op = DenseOp::new(&a);
        let token = CancelToken::new();
        token.cancel();
        for make in [SolveSpec::cg, SolveSpec::pcg, SolveSpec::defcg, SolveSpec::blockcg] {
            let spec = make().with_tol(1e-10).with_cancel(token.clone());
            let r = solve(&op, &b, &spec);
            assert_eq!(r.stop, StopReason::Cancelled, "{spec:?}");
            assert_eq!(r.iterations, 0, "{spec:?}");
            assert_eq!(r.matvecs, 0, "{spec:?}");
            assert_eq!(r.x, vec![0.0; 30], "{spec:?}");
        }
        let r = solve_block(
            &op,
            &{
                let mut m = Mat::zeros(30, 2);
                m.set_col(0, &b);
                m.set_col(1, &b);
                m
            },
            &SolveSpec::blockcg().with_tol(1e-10).with_cancel(token.clone()),
        );
        assert_eq!(r.stop, StopReason::Cancelled);
        assert_eq!(r.matvecs, 0);
        assert_eq!(r.block_matvecs, 0);
    }

    #[test]
    fn deadline_in_the_past_stops_each_method_immediately() {
        use std::time::{Duration, Instant};
        let (a, b) = system(30, 10);
        let op = DenseOp::new(&a);
        let past = Instant::now() - Duration::from_millis(1);
        for make in [SolveSpec::cg, SolveSpec::pcg, SolveSpec::defcg, SolveSpec::blockcg] {
            let spec = make().with_tol(1e-10).with_deadline_at(past);
            let r = solve(&op, &b, &spec);
            assert_eq!(r.stop, StopReason::DeadlineExceeded, "{spec:?}");
            assert_eq!(r.iterations, 0, "{spec:?}");
        }
    }

    #[test]
    fn spec_debug_is_readable() {
        let (a, _b) = system(10, 6);
        let op = DenseOp::new(&a);
        let s = format!("{:?}", SolveSpec::pcg().with_jacobi(&op));
        assert!(s.contains("Pcg") && s.contains("jacobi"), "{s}");
    }
}
