//! Block conjugate gradients (O'Leary, 1980) for multiple right-hand sides.
//!
//! Solves `A X = B` for `s` right-hand sides simultaneously. The block
//! Krylov space sees all `s` residual directions at once, so clustered
//! eigenvalues are resolved faster than by `s` independent CG runs — a
//! complementary axis to subspace recycling: recycling shares information
//! *across time* (a sequence of systems), block CG shares *across columns*
//! (simultaneous systems, e.g. multi-class GPC or batched predictions).
//!
//! The iteration maintains block direction `P ∈ ℝ^{n×s}` and solves small
//! `s×s` systems (`PᵀAP α = RᵀR`-style) per step. Rank-deficient blocks
//! (converged columns) are handled by the pseudo-solve falling back to a
//! QR-based least-squares.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;
use crate::linalg::qr::Qr;
use crate::solvers::{SpdOperator, StopReason};
use std::time::Instant;

/// Result of a block solve.
#[derive(Clone, Debug)]
pub struct BlockSolveResult {
    /// Solutions, one column per RHS.
    pub x: Mat,
    /// Max over columns of relative residual, per iteration.
    pub residuals: Vec<f64>,
    pub iterations: usize,
    /// Block applications (each applies A to all s columns at once).
    pub block_matvecs: usize,
    /// Operator applications counted per column: `block_matvecs · s`.
    /// This is the unit every other solver reports
    /// ([`crate::solvers::SolveResult::matvecs`]) and the one the
    /// coordinator's `total_matvecs` aggregates, so block and single-RHS
    /// work stay comparable on one axis.
    pub matvecs: usize,
    pub stop: StopReason,
    pub seconds: f64,
}

/// Solve A X = B with block CG to relative tolerance `tol` on every column.
pub fn solve(a: &dyn SpdOperator, b: &Mat, tol: f64, max_iters: usize) -> BlockSolveResult {
    let start = Instant::now();
    let n = a.n();
    let s = b.cols();
    assert_eq!(b.rows(), n);
    assert!(s >= 1);
    let max_iters = if max_iters == 0 { 10 * n } else { max_iters };

    let bnorms: Vec<f64> = (0..s)
        .map(|j| {
            let c = b.col(j);
            crate::linalg::vec_ops::norm2(&c).max(1e-300)
        })
        .collect();

    let mut x = Mat::zeros(n, s);
    let mut r = b.clone();
    let mut p = r.clone();
    let rel_max = |r: &Mat| -> f64 {
        (0..s)
            .map(|j| crate::linalg::vec_ops::norm2(&r.col(j)) / bnorms[j])
            .fold(0.0f64, f64::max)
    };
    let mut residuals = vec![rel_max(&r)];
    if residuals[0] <= tol {
        return BlockSolveResult {
            x,
            residuals,
            iterations: 0,
            block_matvecs: 0,
            matvecs: 0,
            stop: StopReason::Converged,
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    // Small s×s solve helper with Cholesky → QR-ls fallback.
    let small_solve = |m: &Mat, rhs: &Mat| -> Mat {
        match Cholesky::factor(m) {
            Ok(ch) => ch.solve_mat(rhs),
            Err(_) => {
                // Rank-deficient block: least-squares per column.
                let qr = Qr::factor(m);
                let mut out = Mat::zeros(m.cols(), rhs.cols());
                for j in 0..rhs.cols() {
                    let sol = qr.solve_ls(&rhs.col(j));
                    out.set_col(j, &sol);
                }
                out
            }
        }
    };

    let mut rtr = r.t_matmul(&r); // s×s
    let mut stop = StopReason::MaxIters;
    let mut iterations = 0;
    let mut block_matvecs = 0;
    // AP through the block-first operator interface: one apply_block per
    // iteration (one data pass over A per panel) instead of s column
    // matvecs; bitwise the same floats by the apply_block contract.
    let mut ap = Mat::zeros(n, s);

    for _ in 0..max_iters {
        a.apply_block(&p, &mut ap);
        block_matvecs += 1;
        let mut ptap = p.t_matmul(&ap);
        ptap.symmetrize();
        // α = (PᵀAP)⁻¹ RᵀR
        let alpha = small_solve(&ptap, &rtr);
        // X += P α; R -= AP α
        let pa = p.matmul(&alpha);
        let apa = ap.matmul(&alpha);
        x.add_in_place(&pa);
        for i in 0..n {
            for j in 0..s {
                r[(i, j)] -= apa[(i, j)];
            }
        }
        iterations += 1;
        residuals.push(rel_max(&r));
        if *residuals.last().unwrap() <= tol {
            stop = StopReason::Converged;
            break;
        }
        let rtr_new = r.t_matmul(&r);
        // β = (RᵀR)⁻¹ R'ᵀR'
        let beta = small_solve(&rtr, &rtr_new);
        rtr = rtr_new;
        // P = R + P β
        let pb = p.matmul(&beta);
        p = r.clone();
        p.add_in_place(&pb);
    }

    BlockSolveResult {
        x,
        residuals,
        iterations,
        block_matvecs,
        matvecs: block_matvecs * s,
        stop,
        seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::{cg, DenseOp};
    use crate::solvers::cg::CgConfig;
    use crate::util::rng::Rng;

    #[test]
    fn solves_multiple_rhs() {
        let mut rng = Rng::new(1);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let r = solve(&DenseOp::new(&a), &b, 1e-10, 0);
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.x.max_abs_diff(&x_true) < 1e-5, "err {}", r.x.max_abs_diff(&x_true));
    }

    #[test]
    fn single_column_matches_cg() {
        let mut rng = Rng::new(2);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let bvec: Vec<f64> = (0..n).map(|i| 1.0 + (i % 4) as f64).collect();
        let mut b = Mat::zeros(n, 1);
        b.set_col(0, &bvec);
        let blk = solve(&DenseOp::new(&a), &b, 1e-9, 0);
        let plain = cg::solve(&DenseOp::new(&a), &bvec, None, &CgConfig::with_tol(1e-9));
        assert_eq!(blk.stop, StopReason::Converged);
        // Same Krylov space => same iteration count (±1 for stopping rule).
        assert!(
            (blk.iterations as isize - plain.iterations as isize).abs() <= 1,
            "block {} vs cg {}",
            blk.iterations,
            plain.iterations
        );
        for i in 0..n {
            assert!((blk.x[(i, 0)] - plain.x[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn block_needs_fewer_iterations_than_worst_single() {
        // s=4 RHS on an ill-conditioned matrix: the block space resolves
        // the extremal eigenvalues once for all columns.
        let mut rng = Rng::new(3);
        let n = 80;
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let b = Mat::randn(n, 4, &mut rng);
        let blk = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(blk.stop, StopReason::Converged);
        let worst_single = (0..4)
            .map(|j| {
                cg::solve(
                    &DenseOp::new(&a),
                    &b.col(j),
                    None,
                    &CgConfig::with_tol(1e-8),
                )
                .iterations
            })
            .max()
            .unwrap();
        assert!(
            blk.iterations < worst_single,
            "block {} >= worst single {}",
            blk.iterations,
            worst_single
        );
    }

    #[test]
    fn handles_duplicate_columns() {
        // Rank-deficient RHS block: duplicate columns must not break the
        // small-solve (falls back to least squares).
        let mut rng = Rng::new(4);
        let n = 25;
        let a = Mat::rand_spd(n, 100.0, &mut rng);
        let mut b = Mat::randn(n, 3, &mut rng);
        let c0 = b.col(0);
        b.set_col(2, &c0);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(r.stop, StopReason::Converged);
        for i in 0..n {
            assert!((r.x[(i, 0)] - r.x[(i, 2)]).abs() < 1e-6);
        }
    }

    #[test]
    fn matvec_accounting_counts_k_per_block_apply() {
        let mut rng = Rng::new(6);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = Mat::randn(n, 4, &mut rng);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.block_matvecs, r.iterations);
        assert_eq!(r.matvecs, 4 * r.block_matvecs, "one block apply = s applications");
    }

    #[test]
    fn zero_rhs_block() {
        let mut rng = Rng::new(5);
        let a = Mat::rand_spd(10, 10.0, &mut rng);
        let b = Mat::zeros(10, 2);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.x.fro_norm(), 0.0);
    }
}
