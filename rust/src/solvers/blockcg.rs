//! Rank-adaptive block conjugate gradients (O'Leary, 1980) for multiple
//! right-hand sides, with breakdown handling, deflation against a recycled
//! basis, and optional preconditioning.
//!
//! Solves `A X = B` for `s` right-hand sides simultaneously. The block
//! Krylov space sees all `s` residual directions at once, so clustered
//! eigenvalues are resolved faster than by `s` independent CG runs — a
//! complementary axis to subspace recycling: recycling shares information
//! *across time* (a sequence of systems), block CG shares *across columns*
//! (simultaneous systems, e.g. multi-class GPC or batched predictions).
//! [`solve_spec`] composes both: the deflated block iteration projects
//! every direction against a recycled `(W, AW)` basis exactly as
//! [`crate::solvers::defcg`] does for one right-hand side, and stores its
//! first ℓ normalized directions so [`crate::solvers::ritz::extract`] can
//! harvest the next basis from multi-RHS traffic. Strategy-sized bases
//! (see [`crate::solvers::strategy`]) flow through this same deflation
//! path unchanged: the block kernel only ever sees the `(W, AW)` pair the
//! manager's strategy chose to retain.
//!
//! # Rank adaptivity
//!
//! The fixed-block iteration of the textbook method breaks down when the
//! residual block loses rank — converged or linearly-dependent columns
//! make the `RᵀZ` / `PᵀAP` Gram matrices singular. This kernel monitors
//! both factorizations and *shrinks the block* instead of stalling:
//!
//! * **converged columns** are frozen in `X` and dropped from the active
//!   block (deflation by convergence, O'Leary §5); the surviving
//!   directions keep A-conjugacy to the full old block through an explicit
//!   conjugation step on drop iterations;
//! * **linearly-dependent residual columns** (duplicate or coalesced
//!   right-hand sides) become *passengers*: the dependence coefficients
//!   are recorded once and the passenger's iterates are reconstructed from
//!   the independent columns — the column converges in lockstep at zero
//!   matvec cost. Dependent columns whose coefficients would *amplify*
//!   the references' errors (near-cancelling combinations, where the
//!   reconstruction could under-report the true residual) are instead
//!   **deferred** to their own single-column follow-up solve;
//! * genuinely **indefinite or non-finite pivots** stop the solve with
//!   [`StopReason::Breakdown`] rather than looping to the iteration cap.
//!
//! # Arithmetic contract
//!
//! For an active block of one column the recurrences reduce *exactly* to
//! the scalar formulas of [`crate::solvers::defcg::solve_precond`]
//! (`α = rᵀz / pᵀAp`, `β = r'ᵀz' / rᵀz`, identical update order), so an
//! `s = 1` block solve reproduces (deflated, preconditioned) CG
//! iteration-for-iteration — pinned by `rust/tests/solve_spec_equivalence.rs`.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;
use crate::linalg::qr::Qr;
use crate::linalg::vec_ops::{axpy, dot, norm2};
use crate::solvers::api::Preconditioner;
use crate::solvers::cg::CgConfig;
use crate::solvers::defcg::Deflation;
use crate::solvers::{SpdOperator, StopReason, StoredDirections};
use std::time::Instant;

/// Result of a block solve.
#[derive(Clone, Debug)]
pub struct BlockSolveResult {
    /// Solutions, one column per RHS.
    pub x: Mat,
    /// Max over columns of relative residual, per iteration.
    pub residuals: Vec<f64>,
    pub iterations: usize,
    /// Block applications (each applies A to every *active* column at once).
    pub block_matvecs: usize,
    /// Operator applications counted per column: the sum over block
    /// applies of the active panel width, i.e. `Σ_j col_matvecs[j]`. This
    /// is the unit every other solver reports
    /// ([`crate::solvers::SolveResult::matvecs`]) and the one the
    /// coordinator's `total_matvecs` aggregates. With no column dropping
    /// it equals `block_matvecs · s`; dropped columns stop paying.
    pub matvecs: usize,
    /// Per-column operator applications: how many block applies column `j`
    /// was part of. Frozen (converged) and passenger (linearly-dependent)
    /// columns stop counting from the iteration they drop, which is what
    /// lets the coordinator's coalescer bill each ticket for exactly the
    /// work its columns caused.
    pub col_matvecs: Vec<usize>,
    pub stop: StopReason,
    /// The first ℓ normalized `(p, A·p)` direction pairs
    /// (`cfg.store_l` columns across iterations) — the same raw material
    /// single-RHS CG feeds to [`crate::solvers::ritz::extract`], so block
    /// traffic contributes to the recycled basis too.
    pub stored: StoredDirections,
    pub seconds: f64,
}

impl BlockSolveResult {
    /// Final max-over-columns relative residual. The trace always holds at
    /// least the initial entry, so this never reports `NaN` (a zero-column
    /// block reports `0.0`).
    pub fn final_residual(&self) -> f64 {
        self.residuals.last().copied().unwrap_or(0.0)
    }
}

/// A column whose residual became linearly dependent on the other active
/// columns: `r_j = defect + Σ_i c_i r_refs[i]` held exactly from the drop
/// iteration on, so `x_j` and `r_j` are reconstructed from the independent
/// columns each iteration at zero matvec cost.
struct Passenger {
    col: usize,
    refs: Vec<usize>,
    coef: Vec<f64>,
    /// `x_snap_j − Σ c_i x_snap_refs[i]`: the constant part of `x_j(t)`.
    x_base: Vec<f64>,
    /// `r_snap_j − Σ c_i r_snap_refs[i]`: the least-squares defect of the
    /// dependence fit (exactly zero for duplicate columns).
    r_defect: Vec<f64>,
}

/// Solve `A X = B` with plain block CG to relative tolerance `tol` on
/// every column. Thin shim over [`solve_spec`] without deflation or
/// preconditioning — prefer building a [`crate::solvers::SolveSpec`] and
/// calling [`crate::solvers::solve_block`] in new code.
pub fn solve(a: &dyn SpdOperator, b: &Mat, tol: f64, max_iters: usize) -> BlockSolveResult {
    let cfg = CgConfig { tol, max_iters, ..Default::default() };
    solve_spec(a, b, None, None, None, &cfg)
}

/// The full kernel: deflated, preconditioned, rank-adaptive block CG.
///
/// * `x0` — optional warm start (one column per RHS; `B`-shaped).
/// * `defl` — recycled `(W, AW)` basis: the start is projected so every
///   initial residual is orthogonal to `W` (with the same exact-recompute
///   and drift safeguard as [`crate::solvers::defcg::solve_precond`]) and
///   every direction is deflated against `W` per iteration.
/// * `precond` — SPD preconditioner `M`; the recurrence runs on
///   `Z = M⁻¹R` while convergence is judged on the true residuals.
/// * `cfg` — tolerance, iteration cap, `store_l` direction storage,
///   `stall_window` stagnation stop, and `recompute_every` residual
///   replacement (one extra block apply over the active columns per
///   period — the same van der Vorst & Ye guard the single-RHS kernel
///   uses against self-converging residual recursions on inexact
///   operators).
pub fn solve_spec(
    a: &dyn SpdOperator,
    b: &Mat,
    x0: Option<&Mat>,
    defl: Option<&Deflation>,
    precond: Option<&dyn Preconditioner>,
    cfg: &CgConfig,
) -> BlockSolveResult {
    let start = Instant::now();
    let n = a.n();
    let s = b.cols();
    assert_eq!(b.rows(), n, "rhs block dimension mismatch");
    assert!(s >= 1, "rhs block needs at least one column");
    let max_iters = cfg.effective_max_iters(n);

    let b_cols: Vec<Vec<f64>> = (0..s).map(|j| b.col(j)).collect();
    let denoms: Vec<f64> = b_cols
        .iter()
        .map(|c| {
            let bn = norm2(c);
            if bn > 0.0 {
                bn
            } else {
                1.0
            }
        })
        .collect();

    let mut x_cols: Vec<Vec<f64>> = match x0 {
        Some(x0) => {
            assert_eq!(x0.rows(), n, "x0 block dimension mismatch");
            assert_eq!(x0.cols(), s, "x0 block dimension mismatch");
            (0..s).map(|j| x0.col(j)).collect()
        }
        None => (0..s).map(|_| vec![0.0; n]).collect(),
    };
    let mut r_cols: Vec<Vec<f64>> = b_cols.clone();
    let mut block_matvecs = 0usize;
    let mut col_matvecs = vec![0usize; s];

    // Entry check: a request that is already cancelled/expired must not
    // pay even the initial-residual block apply (this is also what keeps
    // the deferred-column follow-up solves below free once the main loop
    // stopped on a cancel — they re-enter here with the same control).
    // The reported residuals are those of the untouched start block.
    if let Some(reason) = cfg.control.check() {
        let rels: Vec<f64> = (0..s).map(|j| norm2(&r_cols[j]) / denoms[j]).collect();
        let residuals = vec![rels.iter().fold(0.0f64, |m, &v| m.max(v))];
        let mut x = Mat::zeros(n, s);
        for (j, c) in x_cols.iter().enumerate() {
            x.set_col(j, c);
        }
        return BlockSolveResult {
            x,
            residuals,
            iterations: 0,
            block_matvecs: 0,
            matvecs: 0,
            col_matvecs,
            stop: reason,
            stored: StoredDirections::default(),
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    // One block apply over all s columns, billed to every column.
    let apply_all = |cols: &[Vec<f64>],
                     block_matvecs: &mut usize,
                     col_matvecs: &mut [usize]| {
        let mut xs = Mat::zeros(n, s);
        for (j, c) in cols.iter().enumerate() {
            xs.set_col(j, c);
        }
        let mut ys = Mat::zeros(n, s);
        a.apply_block(&xs, &mut ys);
        *block_matvecs += 1;
        for c in col_matvecs.iter_mut() {
            *c += 1;
        }
        ys
    };

    if x0.is_some() {
        let ax = apply_all(&x_cols, &mut block_matvecs, &mut col_matvecs);
        for j in 0..s {
            for i in 0..n {
                r_cols[j][i] = b_cols[j][i] - ax[(i, j)];
            }
        }
    }

    // Deflated start: factor WᵀAW once, shift every column so its initial
    // residual is W-orthogonal, recompute R = B − A X exactly (stale AW is
    // only an approximation under the current operator — same reasoning as
    // defcg), and revert if any column's residual grew past the drift
    // safeguard.
    let mut defl_active = defl.filter(|d| d.k() > 0);
    let mut wtaw_ch: Option<Cholesky> = None;
    if let Some(d) = defl_active {
        match d.factor_wtaw() {
            Err(_) => {
                crate::log_warn!(
                    "WᵀAW not SPD (k={}); running the block solve undeflated",
                    d.k()
                );
                defl_active = None;
            }
            Ok(ch) => {
                let x_pre = x_cols.clone();
                let r_pre = r_cols.clone();
                let pre_norms: Vec<f64> = r_cols.iter().map(|c| norm2(c)).collect();
                for j in 0..s {
                    let gamma = ch.solve(&d.w.matvec_t(&r_cols[j]));
                    d.w.add_scaled_cols(&gamma, &mut x_cols[j]);
                }
                let ax = apply_all(&x_cols, &mut block_matvecs, &mut col_matvecs);
                for j in 0..s {
                    for i in 0..n {
                        r_cols[j][i] = b_cols[j][i] - ax[(i, j)];
                    }
                }
                let grew = (0..s).any(|j| norm2(&r_cols[j]) > 3.0 * pre_norms[j]);
                if grew {
                    crate::log_debug!(
                        "block deflation shift increased a column residual; \
                         dropping basis for this solve"
                    );
                    x_cols = x_pre;
                    r_cols = r_pre;
                    defl_active = None;
                } else {
                    wtaw_ch = Some(ch);
                }
            }
        }
    }

    let mut rels: Vec<f64> = (0..s).map(|j| norm2(&r_cols[j]) / denoms[j]).collect();
    // Columns deferred to their own follow-up solve (dependent on the
    // others with *amplifying* coefficients — see shed_dependent) are
    // excluded from the in-loop convergence max until they run.
    let mut deferred: Vec<usize> = Vec::new();
    let mut deferred_flag = vec![false; s];
    let live_max = |rels: &[f64], flags: &[bool]| {
        rels.iter()
            .zip(flags)
            .filter(|(_, &d)| !d)
            .fold(0.0f64, |m, (&v, _)| m.max(v))
    };
    let mut residuals = vec![live_max(&rels, &deferred_flag)];
    let mut stored = StoredDirections::default();
    let mut passengers: Vec<Passenger> = Vec::new();
    let mut iterations = 0usize;
    let mut stop = StopReason::MaxIters;

    let finish = |x_cols: &[Vec<f64>],
                  residuals: Vec<f64>,
                  iterations: usize,
                  block_matvecs: usize,
                  col_matvecs: Vec<usize>,
                  stop: StopReason,
                  stored: StoredDirections| {
        let mut x = Mat::zeros(n, s);
        for (j, c) in x_cols.iter().enumerate() {
            x.set_col(j, c);
        }
        BlockSolveResult {
            x,
            residuals,
            iterations,
            block_matvecs,
            matvecs: col_matvecs.iter().sum(),
            col_matvecs,
            stop,
            stored,
            seconds: start.elapsed().as_secs_f64(),
        }
    };

    let mut active: Vec<usize> = (0..s).filter(|&j| rels[j] > cfg.tol).collect();
    if active.is_empty() {
        return finish(
            &x_cols,
            residuals,
            0,
            block_matvecs,
            col_matvecs,
            StopReason::Converged,
            stored,
        );
    }

    // z = M⁻¹ r for a set of columns (a plain copy under no/identity
    // preconditioning, so the unpreconditioned path is arithmetically the
    // defcg kernel's).
    let apply_precond = |cols: &[usize], r_cols: &[Vec<f64>]| -> Vec<Vec<f64>> {
        cols.iter()
            .map(|&j| match precond {
                Some(m) => {
                    let mut z = vec![0.0; n];
                    m.apply(&r_cols[j], &mut z);
                    z
                }
                None => r_cols[j].clone(),
            })
            .collect()
    };

    // Small Gram matrices, computed upper-triangle-first and mirrored so
    // they are exactly symmetric; the 1×1 cases are defcg's scalar dots
    // bitwise.
    let gram = |left: &[Vec<f64>], right: &[Vec<f64>]| -> Mat {
        let k = left.len();
        let mut g = Mat::zeros(k, k);
        for i in 0..k {
            for j in i..k {
                let v = dot(&left[i], &right[j]);
                g[(i, j)] = v;
                g[(j, i)] = v;
            }
        }
        g
    };
    // rz = RᵀZ over the active columns, reading the residual columns in
    // place (no per-iteration clones of n-length vectors just to feed
    // read-only dot products).
    let gram_rz = |cols: &[usize], r_cols: &[Vec<f64>], z: &[Vec<f64>]| -> Mat {
        let k = cols.len();
        let mut g = Mat::zeros(k, k);
        for i in 0..k {
            for j in i..k {
                let v = dot(&r_cols[cols[i]], &z[j]);
                g[(i, j)] = v;
                g[(j, i)] = v;
            }
        }
        g
    };

    // Convert residual columns that became linearly dependent on the
    // other active columns into passengers. Returns the independent
    // survivors; `true` in the second slot when anything was shed.
    let shed_dependent = |active: &[usize],
                          r_cols: &[Vec<f64>],
                          x_cols: &[Vec<f64>],
                          passengers: &mut Vec<Passenger>,
                          deferred: &mut Vec<usize>,
                          deferred_flag: &mut [bool],
                          tol: f64|
     -> (Vec<usize>, bool) {
        let mut keep: Vec<usize> = Vec::with_capacity(active.len());
        let mut qcols: Vec<Vec<f64>> = Vec::new();
        let mut shed = false;
        for &j in active {
            let rn = norm2(&r_cols[j]);
            let mut v = r_cols[j].clone(); // lint:allow(alloc-in-hot-loop) — cold shed path
            for _ in 0..2 {
                for q in &qcols {
                    let c = dot(q, &v);
                    axpy(-c, q, &mut v);
                }
            }
            let dependent = !keep.is_empty() && norm2(&v) <= 1e-10 * rn;
            if !dependent {
                let nv = norm2(&v);
                if nv > 0.0 {
                    let inv = 1.0 / nv;
                    for vi in v.iter_mut() {
                        *vi *= inv;
                    }
                    qcols.push(v);
                }
                keep.push(j);
                continue;
            }
            // Dependence coefficients from a least-squares fit onto the
            // kept residual columns; the defect is carried exactly so the
            // reconstruction is not limited by the fit quality check.
            let mut rk = Mat::zeros(n, keep.len());
            for (t, &kj) in keep.iter().enumerate() {
                rk.set_col(t, &r_cols[kj]);
            }
            let coef = Qr::factor(&rk).solve_ls(&r_cols[j]);
            let mut r_defect = r_cols[j].clone(); // lint:allow(alloc-in-hot-loop) — cold path
            let mut x_base = x_cols[j].clone(); // lint:allow(alloc-in-hot-loop) — cold path
            for (t, &kj) in keep.iter().enumerate() {
                axpy(-coef[t], &r_cols[kj], &mut r_defect);
                axpy(-coef[t], &x_cols[kj], &mut x_base);
            }
            // A passenger inherits its references' errors scaled by
            // Σ|cᵢ|·‖b_ref‖/‖b_j‖. With amplifying coefficients
            // (near-cancelling combinations) the reconstruction would
            // *under-report* the true residual and falsely converge —
            // such columns are DEFERRED to their own follow-up solve
            // after the block finishes, where a dedicated Krylov
            // sequence has no cancellation to amplify.
            let amp: f64 = coef
                .iter()
                .zip(&keep)
                .map(|(c, &kj)| c.abs() * denoms[kj])
                .sum();
            let amplifying = amp > 100.0 * denoms[j];
            if amplifying && norm2(&r_defect) <= 0.1 * tol * denoms[j] {
                deferred.push(j);
                deferred_flag[j] = true;
                shed = true;
                continue;
            }
            // Only shed when the defect cannot mask convergence: the
            // passenger's residual floors at ‖defect‖, which must sit
            // safely below the column's convergence target.
            if norm2(&r_defect) > 0.1 * tol * denoms[j] {
                let nv = norm2(&v);
                if nv > 0.0 {
                    let inv = 1.0 / nv;
                    for vi in v.iter_mut() {
                        *vi *= inv;
                    }
                    qcols.push(v);
                }
                keep.push(j);
                continue;
            }
            let refs = keep.clone(); // lint:allow(alloc-in-hot-loop) — cold shed path
            passengers.push(Passenger { col: j, refs, coef, x_base, r_defect });
            shed = true;
        }
        (keep, shed)
    };

    // Reconstruct every passenger's (x, r) from the current independent
    // columns, latest drop first so chained dependences resolve. The
    // rebuild goes through two reused scratch vectors (a passenger's
    // refs may point at other passenger columns, so the accumulation
    // cannot alias the column arrays), keeping the per-iteration call
    // allocation-free.
    let mut pass_x = vec![0.0; n];
    let mut pass_r = vec![0.0; n];
    let mut update_passengers = |passengers: &[Passenger],
                                 x_cols: &mut [Vec<f64>],
                                 r_cols: &mut [Vec<f64>],
                                 rels: &mut [f64]| {
        for p in passengers.iter().rev() {
            pass_x.copy_from_slice(&p.x_base);
            pass_r.copy_from_slice(&p.r_defect);
            for (t, &j) in p.refs.iter().enumerate() {
                axpy(p.coef[t], &x_cols[j], &mut pass_x);
                axpy(p.coef[t], &r_cols[j], &mut pass_r);
            }
            rels[p.col] = norm2(&pass_r) / denoms[p.col];
            x_cols[p.col].copy_from_slice(&pass_x);
            r_cols[p.col].copy_from_slice(&pass_r);
        }
    };

    // Shed dependent right-hand sides (e.g. coalesced duplicate requests)
    // to passengers BEFORE the first direction is built. The rank check is
    // an explicit MGS over the residual columns, not a factorization
    // failure: exact dependence routinely slips through a Cholesky of RᵀZ
    // with a tiny *positive* pivot and would then break `PᵀAP` instead.
    // A factorization failure after shedding is a genuine breakdown.
    if active.len() > 1 {
        let (kept, shed) = shed_dependent(
            &active,
            &r_cols,
            &x_cols,
            &mut passengers,
            &mut deferred,
            &mut deferred_flag,
            cfg.tol,
        );
        if shed {
            active = kept;
        }
    }
    let z_cols = apply_precond(&active, &r_cols);
    let mut rz = gram_rz(&active, &r_cols, &z_cols);
    let mut rz_ch: Option<Cholesky> = None;
    if active.len() > 1 {
        rz_ch = match Cholesky::factor(&rz) {
            Ok(ch) => Some(ch),
            Err(_) => {
                return finish(
                    &x_cols,
                    residuals,
                    0,
                    block_matvecs,
                    col_matvecs,
                    StopReason::Breakdown,
                    stored,
                );
            }
        };
    }

    // Deflation split in two so no call site ever re-unwraps the basis:
    // `defl_mu` builds μ = (WᵀAW)⁻¹ (AW)ᵀ src when a basis is active,
    // `defl_sub` applies cand −= W μ (both no-ops without a basis).
    let defl_mu = |src: &[f64]| -> Option<Vec<f64>> {
        let (d, ch) = (defl_active?, wtaw_ch.as_ref()?);
        Some(ch.solve(&d.aw.matvec_t(src)))
    };
    let defl_sub = |mu: &Option<Vec<f64>>, cand: &mut Vec<f64>| {
        if let (Some(mu), Some(d)) = (mu, defl_active) {
            d.w.sub_scaled_cols(mu, cand);
        }
    };
    // p₀ = z₀ − W μ₀ per column, μ from z alone (old directions are already
    // deflated) — defcg line 3.
    let mut p_cols: Vec<Vec<f64>> = z_cols
        .iter()
        .map(|z| {
            let mut p = z.clone();
            defl_sub(&defl_mu(z), &mut p);
            p
        })
        .collect();
    // Q's columns are read out through a reused buffer pool sized for
    // the widest possible active block, so the hot loop never allocates
    // column storage. Only the first `a_cnt` entries are live in any
    // iteration.
    let mut q_cols: Vec<Vec<f64>> = vec![vec![0.0; n]; s];
    // Revive scratch for the all-converged-but-a-passenger case (cold
    // path; hoisted so the loop body allocates no index storage).
    let mut revive: Vec<usize> = Vec::new();

    'outer: for _ in 0..max_iters {
        // Cooperative cancel/deadline check, before the block apply (see
        // `cg::solve` — identical placement in every kernel). Frozen,
        // passenger, and active columns are all at a consistent iterate
        // here, so the partial X is returned as-is.
        if let Some(reason) = cfg.control.check() {
            stop = reason;
            break 'outer;
        }
        let a_cnt = active.len();
        // Q = A P through the block-first operator interface: one
        // apply_block over the active panel per iteration.
        let mut pm = Mat::zeros(n, a_cnt);
        for (t, p) in p_cols.iter().enumerate() {
            pm.set_col(t, p);
        }
        let mut qm = Mat::zeros(n, a_cnt);
        a.apply_block(&pm, &mut qm);
        block_matvecs += 1;
        for &j in &active {
            col_matvecs[j] += 1;
        }
        for (t, qc) in q_cols.iter_mut().take(a_cnt).enumerate() {
            qm.col_into(t, qc);
        }

        // PᵀAP with breakdown detection: a non-positive or non-finite
        // pivot stops the solve instead of spinning on a least-squares
        // fallback until the iteration cap.
        let d_gram = gram(&p_cols, &q_cols[..a_cnt]);
        let d_ch = if a_cnt == 1 {
            let d = d_gram[(0, 0)];
            if d <= 0.0 || !d.is_finite() {
                stop = StopReason::Breakdown;
                break 'outer;
            }
            None
        } else {
            match Cholesky::factor(&d_gram) {
                Ok(ch) => Some(ch),
                Err(_) => {
                    stop = StopReason::Breakdown;
                    break 'outer;
                }
            }
        };

        // Feed the recycler: the first ℓ direction columns, normalized
        // with the matching A·p scaling (exactly what single-RHS CG
        // stores).
        for t in 0..a_cnt {
            if stored.len() >= cfg.store_l {
                break;
            }
            let pn = norm2(&p_cols[t]);
            if pn > 0.0 {
                let inv = 1.0 / pn;
                stored.p.push(p_cols[t].iter().map(|v| v * inv).collect());
                stored.ap.push(q_cols[t].iter().map(|v| v * inv).collect());
            }
        }

        // α = (PᵀAP)⁻¹ RᵀZ;  X += P α;  R −= Q α (columnwise axpys, so
        // the 1×1 case is defcg's scalar update bitwise).
        let alpha = match &d_ch {
            None => {
                let mut m = Mat::zeros(1, 1);
                m[(0, 0)] = rz[(0, 0)] / d_gram[(0, 0)];
                m
            }
            Some(ch) => ch.solve_mat(&rz),
        };
        for (t, &j) in active.iter().enumerate() {
            for i in 0..a_cnt {
                let c = alpha[(i, t)];
                axpy(c, &p_cols[i], &mut x_cols[j]);
                axpy(-c, &q_cols[i], &mut r_cols[j]);
            }
        }
        iterations += 1;
        // Residual replacement (van der Vorst & Ye), mirroring cg.rs:
        // every `recompute_every` iterations re-derive R = B − A X for
        // the active columns exactly (one extra block apply). The
        // recursive residual self-converges even on inexact operators,
        // silently sailing past the true precision floor; replacement
        // exposes the floor so `stall_window` can stop the solve.
        if cfg.recompute_every > 0 && iterations % cfg.recompute_every == 0 {
            let mut xs = Mat::zeros(n, a_cnt);
            for (t, &j) in active.iter().enumerate() {
                xs.set_col(t, &x_cols[j]);
            }
            let mut ys = Mat::zeros(n, a_cnt);
            a.apply_block(&xs, &mut ys);
            block_matvecs += 1;
            for (t, &j) in active.iter().enumerate() {
                col_matvecs[j] += 1;
                for i in 0..n {
                    r_cols[j][i] = b_cols[j][i] - ys[(i, t)];
                }
            }
        }
        for &j in &active {
            rels[j] = norm2(&r_cols[j]) / denoms[j];
        }
        update_passengers(&passengers, &mut x_cols, &mut r_cols, &mut rels);
        let rel = live_max(&rels, &deferred_flag);
        residuals.push(rel);
        if rel <= cfg.tol {
            stop = StopReason::Converged;
            break 'outer;
        }
        if cfg.stagnated(&residuals) {
            stop = StopReason::Stagnated;
            break 'outer;
        }

        // Deflation by convergence: freeze finished columns in X and
        // shrink the active block (in place, so the hot loop allocates
        // no index storage).
        active.retain(|&j| rels[j] > cfg.tol);
        let mut dropped = active.len() != a_cnt;
        if active.is_empty() {
            // Every iterated column is at tolerance but a passenger's
            // reconstructed residual is not (moderate amplification below
            // the deferral gate). Re-activate the passenger's *reference*
            // columns — which may have frozen iterations ago while other
            // columns kept the loop alive — and push them further below
            // their own tolerance: that is the only way to pull the
            // passenger down. `max_iters` and `stall_window` bound the
            // attempt; the rebuilt candidate block is explicitly
            // conjugated against the old directions (drop path below).
            revive.clear();
            for p in &passengers {
                if rels[p.col] > cfg.tol {
                    for &r in &p.refs {
                        if !revive.contains(&r) {
                            revive.push(r);
                        }
                    }
                }
            }
            if revive.is_empty() {
                // Unreachable in practice: the live max above tolerance
                // must come from a passenger, and passengers have refs.
                stop = StopReason::Breakdown;
                break 'outer;
            }
            // `active` is empty here, so the swap hands the revived set
            // over and leaves `revive` empty for its next reuse.
            std::mem::swap(&mut active, &mut revive);
            dropped = true;
        }

        let mut z_new = apply_precond(&active, &r_cols);
        let mut rz_new = gram_rz(&active, &r_cols, &z_new);
        let mut rz_new_ch: Option<Cholesky> = None;
        if active.len() > 1 {
            // Factor RᵀZ and watch its pivots: a residual column that fell
            // (numerically) into the span of the others mid-run shows up
            // as a pivot collapse — often a tiny *positive* pivot rather
            // than a clean factorization failure — and both cases route to
            // the explicit MGS rank check, which sheds the dependents to
            // passengers. Steady-state iterations pay only the Gram
            // product they already needed; the O(n·a²) MGS pass runs only
            // on suspect iterations (and once before the loop, where
            // coalesced duplicate right-hand sides actually live).
            let suspect = match Cholesky::factor(&rz_new) {
                Ok(ch) => {
                    let collapsed = (0..active.len()).any(|i| {
                        let piv = ch.l()[(i, i)];
                        piv * piv <= 1e-16 * rz_new[(i, i)]
                    });
                    rz_new_ch = Some(ch);
                    collapsed
                }
                Err(_) => true,
            };
            if suspect {
                let (kept, shed) = shed_dependent(
                    &active,
                    &r_cols,
                    &x_cols,
                    &mut passengers,
                    &mut deferred,
                    &mut deferred_flag,
                    cfg.tol,
                );
                if shed {
                    dropped = true;
                    active = kept;
                    z_new = apply_precond(&active, &r_cols);
                    rz_new = gram_rz(&active, &r_cols, &z_new);
                    rz_new_ch = if active.len() > 1 {
                        match Cholesky::factor(&rz_new) {
                            Ok(ch) => Some(ch),
                            Err(_) => {
                                stop = StopReason::Breakdown;
                                break 'outer;
                            }
                        }
                    } else {
                        None
                    };
                } else if rz_new_ch.is_none() {
                    // The factorization failed outright and nothing was
                    // dependent enough to shed: genuine breakdown.
                    stop = StopReason::Breakdown;
                    break 'outer;
                }
            }
        }

        // Direction update. Steady state (no drop): the O'Leary recursion
        // β = (RᵀZ)⁻¹ R'ᵀZ', which is defcg's β = rz'/rz at one column.
        // On drop iterations the shrunk candidate is conjugated against
        // the *full* old direction block explicitly:
        // β = −(PᵀAP)⁻¹ QᵀZ', so no conjugacy is lost to frozen columns.
        let beta = if !dropped {
            match (&rz_ch, a_cnt) {
                (_, 1) => {
                    let mut m = Mat::zeros(1, 1);
                    m[(0, 0)] = rz_new[(0, 0)] / rz[(0, 0)];
                    m
                }
                (Some(ch), _) => ch.solve_mat(&rz_new),
                (None, _) => {
                    // a > 1 keeps rz factored; a missing factor means the
                    // bookkeeping above broke — fail the solve, never the
                    // process.
                    stop = StopReason::Failed;
                    break 'outer;
                }
            }
        } else {
            let k_new = active.len();
            let mut qtz = Mat::zeros(a_cnt, k_new);
            for (i, q) in q_cols.iter().take(a_cnt).enumerate() {
                for (t, z) in z_new.iter().enumerate() {
                    qtz[(i, t)] = dot(q, z);
                }
            }
            let mut m = match (&d_ch, a_cnt) {
                (_, 1) => {
                    let mut m = Mat::zeros(1, k_new);
                    for t in 0..k_new {
                        m[(0, t)] = qtz[(0, t)] / d_gram[(0, 0)];
                    }
                    m
                }
                (Some(ch), _) => ch.solve_mat(&qtz),
                (None, _) => {
                    // Same invariant as above, for PᵀAP.
                    stop = StopReason::Failed;
                    break 'outer;
                }
            };
            m.scale_in_place(-1.0);
            m
        };
        // Deflate the new directions against W. The one-column steady
        // state deflects z alone — defcg line 11, bitwise (the old
        // direction is already deflated, so the candidate needs no
        // correction in exact arithmetic); its μ is taken BEFORE β mixes
        // the old direction in, which lets each z be consumed as the
        // candidate buffer instead of cloned. Wider blocks deflect the
        // FULL candidate: the matrix β mixes columns, which amplifies
        // round-off drift out of the W-orthogonal complement fast
        // enough to send residuals growing; re-projecting the whole
        // candidate pins the drift back every iteration at the same
        // O(nk) cost.
        let steady_one = a_cnt == 1 && active.len() == 1;
        let mut p_next: Vec<Vec<f64>> = Vec::with_capacity(active.len());
        for (t, z) in z_new.into_iter().enumerate() {
            let pre_mu = if steady_one { defl_mu(&z) } else { None };
            let mut cand = z;
            for (i, p) in p_cols.iter().enumerate() {
                axpy(beta[(i, t)], p, &mut cand);
            }
            let mu = if steady_one { pre_mu } else { defl_mu(&cand) };
            defl_sub(&mu, &mut cand);
            p_next.push(cand);
        }
        p_cols = p_next;
        rz = rz_new;
        rz_ch = rz_new_ch;
    }

    // Deferred columns: each gets its own single-column solve (same
    // deflation/preconditioner/knobs), where a dedicated Krylov sequence
    // computes the solution directly instead of as an amplified
    // difference of the block's columns. This runs whatever way the main
    // loop stopped — the deferred columns deserve their attempt and the
    // returned `x`/trace must reflect every column either way — but a
    // sub-solve failure only downgrades a `Converged` main stop (a main
    // MaxIters/Breakdown already describes the solve). Accounting folds
    // in (the extra applies bill the deferred column); the trace gains
    // one summary entry over ALL columns so `final_residual` is honest.
    // A one-column recursion can never defer again, so this terminates.
    //
    // Exception: a Cancelled/DeadlineExceeded main stop skips the
    // follow-ups entirely. Their entry check would fire immediately
    // anyway (the same expired control), but *before* the warm-start
    // residual is derived — so the no-op sub-result would report the
    // unit start residual and clobber `rels`/the trace with a bogus 1.0
    // on a partial solve whose iterates only ever improved.
    if !deferred.is_empty()
        && !matches!(stop, StopReason::Cancelled | StopReason::DeadlineExceeded)
    {
        for &j in &deferred {
            let mut bj = Mat::zeros(n, 1);
            bj.set_col(0, &b_cols[j]);
            let mut xj = Mat::zeros(n, 1);
            xj.set_col(0, &x_cols[j]);
            let sub = solve_spec(a, &bj, Some(&xj), defl_active, precond, cfg);
            x_cols[j] = sub.x.col(0);
            block_matvecs += sub.block_matvecs;
            col_matvecs[j] += sub.matvecs;
            rels[j] = sub.final_residual();
            if stop == StopReason::Converged && sub.stop != StopReason::Converged {
                stop = sub.stop;
            }
        }
        residuals.push(rels.iter().fold(0.0f64, |m, &v| m.max(v)));
    }

    finish(&x_cols, residuals, iterations, block_matvecs, col_matvecs, stop, stored)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::cg::CgConfig;
    use crate::solvers::{cg, DenseOp};
    use crate::util::rng::Rng;

    #[test]
    fn solves_multiple_rhs() {
        let mut rng = Rng::new(1);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let r = solve(&DenseOp::new(&a), &b, 1e-10, 0);
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.x.max_abs_diff(&x_true) < 1e-5, "err {}", r.x.max_abs_diff(&x_true));
    }

    #[test]
    fn single_column_matches_cg() {
        let mut rng = Rng::new(2);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let bvec: Vec<f64> = (0..n).map(|i| 1.0 + (i % 4) as f64).collect();
        let mut b = Mat::zeros(n, 1);
        b.set_col(0, &bvec);
        let blk = solve(&DenseOp::new(&a), &b, 1e-9, 0);
        let plain = cg::solve(&DenseOp::new(&a), &bvec, None, &CgConfig::with_tol(1e-9));
        assert_eq!(blk.stop, StopReason::Converged);
        // One-column blocks run defcg's scalar recurrences: identical
        // trajectory, identical count.
        assert_eq!(blk.iterations, plain.iterations, "s = 1 must be CG exactly");
        for i in 0..n {
            assert_eq!(blk.x[(i, 0)], plain.x[i], "row {i}");
        }
        assert_eq!(blk.residuals, plain.residuals);
    }

    #[test]
    fn block_needs_fewer_iterations_than_worst_single() {
        // s=4 RHS on an ill-conditioned matrix: the block space resolves
        // the extremal eigenvalues once for all columns.
        let mut rng = Rng::new(3);
        let n = 80;
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let b = Mat::randn(n, 4, &mut rng);
        let blk = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(blk.stop, StopReason::Converged);
        let worst_single = (0..4)
            .map(|j| {
                cg::solve(
                    &DenseOp::new(&a),
                    &b.col(j),
                    None,
                    &CgConfig::with_tol(1e-8),
                )
                .iterations
            })
            .max()
            .unwrap();
        assert!(
            blk.iterations < worst_single,
            "block {} >= worst single {}",
            blk.iterations,
            worst_single
        );
    }

    #[test]
    fn handles_duplicate_columns_by_shedding_passengers() {
        // Rank-deficient RHS block: the duplicate column must become a
        // passenger (reconstructed, not iterated) and the solve must
        // converge instead of stalling on singular Gram matrices.
        let mut rng = Rng::new(4);
        let n = 25;
        let a = Mat::rand_spd(n, 100.0, &mut rng);
        let mut b = Mat::randn(n, 3, &mut rng);
        let c0 = b.col(0);
        b.set_col(2, &c0);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(r.stop, StopReason::Converged);
        for i in 0..n {
            assert!((r.x[(i, 0)] - r.x[(i, 2)]).abs() < 1e-6);
        }
        // The duplicate never entered the iteration: it paid no applies.
        assert_eq!(r.col_matvecs[2], 0, "duplicate column must ride free");
        assert!(r.matvecs < 3 * r.block_matvecs);
    }

    #[test]
    fn general_linear_dependence_is_reconstructed() {
        // col3 = col0 + col1: not a duplicate, still rank-deficient.
        let mut rng = Rng::new(7);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let mut b = Mat::randn(n, 4, &mut rng);
        let sum: Vec<f64> = (0..n).map(|i| b[(i, 0)] + b[(i, 1)]).collect();
        b.set_col(3, &sum);
        let r = solve(&DenseOp::new(&a), &b, 1e-9, 0);
        assert_eq!(r.stop, StopReason::Converged);
        for j in 0..4 {
            let ax = a.matvec(&r.x.col(j));
            let res: f64 = ax
                .iter()
                .zip(&b.col(j))
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt();
            let rel = res / norm2(&b.col(j));
            assert!(rel <= 1e-8, "col {j} rel residual {rel}");
        }
        assert_eq!(r.col_matvecs[3], 0, "dependent column must ride free");
    }

    #[test]
    fn amplifying_dependent_column_is_deferred_and_truly_converges() {
        // b2 = c·(b0 − b1) with b1 ≈ b0 and large c: the column is exactly
        // dependent, but reconstructing it from the block's columns would
        // amplify their errors by ~2c — the reported residual would sail
        // below tolerance while the TRUE residual stays orders of
        // magnitude above it. Such columns must be deferred to their own
        // follow-up solve, and the final solutions must satisfy the
        // original systems for real.
        let mut rng = Rng::new(17);
        let n = 40;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b0 = a.matvec(&(0..n).map(|i| (i as f64).sin()).collect::<Vec<_>>());
        let perturb: Vec<f64> = (0..n).map(|i| 1e-3 * ((i * 13 % 7) as f64 - 3.0)).collect();
        let b1: Vec<f64> = b0.iter().zip(&perturb).map(|(u, v)| u + v).collect();
        let b2: Vec<f64> = b0.iter().zip(&b1).map(|(u, v)| 1e3 * (u - v)).collect();
        let mut b = Mat::zeros(n, 3);
        b.set_col(0, &b0);
        b.set_col(1, &b1);
        b.set_col(2, &b2);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 2000);
        assert_eq!(r.stop, StopReason::Converged, "stopped as {:?}", r.stop);
        for j in 0..3 {
            let ax = a.matvec(&r.x.col(j));
            let res: f64 = ax
                .iter()
                .zip(&b.col(j))
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt();
            let rel = res / norm2(&b.col(j));
            assert!(rel <= 5e-8, "col {j}: TRUE rel residual {rel} (false convergence?)");
        }
        // The deferred column paid for its own follow-up applies.
        assert!(r.col_matvecs[2] > 0, "deferred column must be billed its own solve");
        assert!(!r.final_residual().is_nan());
        assert!(r.final_residual() <= 1e-8);
    }

    #[test]
    fn passenger_references_are_revived_after_freezing() {
        // A passenger with moderate amplification (~40×, below the
        // deferral gate) rides on refs 0/1, while an unrelated column 3
        // iterates on its own schedule. Whoever converges first, the
        // passenger can only reach tolerance if its references are pushed
        // WELL below their own — so refs frozen earlier must be revived
        // when everything else is done, instead of spinning to MaxIters.
        let mut rng = Rng::new(19);
        let n = 50;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b0 = Mat::randn(n, 1, &mut rng).col(0);
        let noise = Mat::randn(n, 1, &mut rng).col(0);
        let b1: Vec<f64> = b0.iter().zip(&noise).map(|(u, v)| u + 0.05 * v).collect();
        let b2: Vec<f64> = b0.iter().zip(&b1).map(|(u, v)| 10.0 * (u - v)).collect();
        let b3 = Mat::randn(n, 1, &mut rng).col(0);
        let mut b = Mat::zeros(n, 4);
        b.set_col(0, &b0);
        b.set_col(1, &b1);
        b.set_col(2, &b2);
        b.set_col(3, &b3);
        let r = solve(&DenseOp::new(&a), &b, 1e-9, 3000);
        assert_eq!(r.stop, StopReason::Converged, "stopped as {:?}", r.stop);
        for j in 0..4 {
            let ax = a.matvec(&r.x.col(j));
            let res: f64 = ax
                .iter()
                .zip(&b.col(j))
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt();
            let rel = res / norm2(&b.col(j));
            assert!(rel <= 1e-7, "col {j}: TRUE rel residual {rel}");
        }
        assert_eq!(r.col_matvecs[2], 0, "the dependent column itself rides free");
    }

    #[test]
    fn mixed_preconverged_and_hard_columns_converge_with_drops() {
        // The seed kernel's stall case: a block holding a pre-converged
        // column (warm start at the solution) and hard columns used to
        // make RᵀR singular and loop on the QR fallback to MaxIters. The
        // rank-adaptive kernel freezes the finished column and converges.
        let mut rng = Rng::new(8);
        let n = 60;
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let x_true = Mat::randn(n, 3, &mut rng);
        let b = a.matmul(&x_true);
        let mut x0 = Mat::zeros(n, 3);
        x0.set_col(1, &x_true.col(1)); // column 1 starts at its solution
        let cfg = CgConfig { tol: 1e-10, ..Default::default() };
        let r = solve_spec(&DenseOp::new(&a), &b, Some(&x0), None, None, &cfg);
        assert_eq!(r.stop, StopReason::Converged, "stopped as {:?}", r.stop);
        assert!(r.x.max_abs_diff(&x_true) < 1e-4);
        // Column 1 paid only the initial residual apply, then froze.
        assert_eq!(r.col_matvecs[1], 1);
        assert!(r.matvecs < 3 * r.block_matvecs);
    }

    #[test]
    fn matvec_accounting_sums_active_panel_widths() {
        let mut rng = Rng::new(6);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b = Mat::randn(n, 4, &mut rng);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.block_matvecs, r.iterations, "cold start: one apply per iteration");
        assert_eq!(r.matvecs, r.col_matvecs.iter().sum::<usize>());
        assert!(r.matvecs <= 4 * r.block_matvecs);
        // Every column was active from the start, so each count is the
        // number of iterations it survived.
        for &c in &r.col_matvecs {
            assert!(c >= 1 && c <= r.iterations);
        }
    }

    #[test]
    fn zero_rhs_block() {
        let mut rng = Rng::new(5);
        let a = Mat::rand_spd(10, 10.0, &mut rng);
        let b = Mat::zeros(10, 2);
        let r = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.x.fro_norm(), 0.0);
        assert!(!r.final_residual().is_nan(), "final residual must never be NaN");
        assert_eq!(r.final_residual(), 0.0);
    }

    #[test]
    fn breakdown_on_indefinite_operator() {
        // An indefinite "SPD" operator must stop as Breakdown, not spin to
        // the iteration cap on the least-squares fallback like the seed
        // kernel did.
        struct Indefinite(Mat);
        impl SpdOperator for Indefinite {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(9);
        let n = 20;
        let mut a = Mat::rand_spd(n, 10.0, &mut rng);
        a.scale_in_place(-1.0); // negative definite: pᵀAp < 0 from step one
        let b = Mat::randn(n, 2, &mut rng);
        let r = solve(&Indefinite(a), &b, 1e-12, 200);
        assert_eq!(r.stop, StopReason::Breakdown, "stopped as {:?}", r.stop);
        assert_eq!(r.iterations, 0, "the first indefinite pivot must stop the solve");
        assert!(!r.final_residual().is_nan());
    }

    #[test]
    fn breakdown_on_nonfinite_operator_output() {
        struct Poisoned(Mat);
        impl SpdOperator for Poisoned {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
                y[0] = f64::NAN;
            }
        }
        let mut rng = Rng::new(10);
        let a = Mat::rand_spd(15, 10.0, &mut rng);
        let b = Mat::randn(15, 2, &mut rng);
        let r = solve(&Poisoned(a), &b, 1e-10, 100);
        assert_eq!(r.stop, StopReason::Breakdown);
        assert!(r.iterations <= 1);
    }

    #[test]
    fn deflated_block_reduces_iterations() {
        // Exact top-k eigenvector basis: the deflated block solve must
        // beat the plain one, and still produce the right answer.
        use crate::linalg::eig::sym_eig;
        let mut rng = Rng::new(11);
        let n = 70;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let e = sym_eig(&a).unwrap();
        let k = 6;
        let mut w = Mat::zeros(n, k);
        for (dst, j) in ((n - k)..n).enumerate() {
            w.set_col(dst, &e.vectors.col(j));
        }
        let aw = a.matmul(&w);
        let defl = Deflation::new(w, aw);
        let b = Mat::randn(n, 4, &mut rng);
        let cfg = CgConfig { tol: 1e-8, ..Default::default() };
        let plain = solve(&DenseOp::new(&a), &b, 1e-8, 0);
        let deflated = solve_spec(&DenseOp::new(&a), &b, None, Some(&defl), None, &cfg);
        assert_eq!(deflated.stop, StopReason::Converged);
        assert!(
            deflated.iterations < plain.iterations,
            "deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
        let x_ref = Cholesky::factor(&a).unwrap().solve(&b.col(0));
        for i in 0..n {
            assert!((deflated.x[(i, 0)] - x_ref[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn deflated_block_keeps_residuals_w_orthogonal() {
        use crate::linalg::eig::sym_eig;
        let mut rng = Rng::new(12);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let e = sym_eig(&a).unwrap();
        let mut w = Mat::zeros(n, 3);
        for (dst, j) in ((n - 3)..n).enumerate() {
            w.set_col(dst, &e.vectors.col(j));
        }
        let aw = a.matmul(&w);
        let defl = Deflation::new(w.clone(), aw);
        let b = Mat::randn(n, 3, &mut rng);
        for cap in [1usize, 3, 7] {
            let cfg = CgConfig { tol: 1e-16, max_iters: cap, ..Default::default() };
            let r = solve_spec(&DenseOp::new(&a), &b, None, Some(&defl), None, &cfg);
            for j in 0..3 {
                let ax = a.matvec(&r.x.col(j));
                let res: Vec<f64> =
                    b.col(j).iter().zip(&ax).map(|(bi, axi)| bi - axi).collect();
                let wtr = w.matvec_t(&res);
                let rel = norm2(&wtr) / norm2(&res).max(1e-300);
                assert!(rel < 1e-8, "col {j}: ‖Wᵀr‖/‖r‖ = {rel} after {cap} iters");
            }
        }
    }

    #[test]
    fn jacobi_preconditioned_block_converges_faster_on_bad_scaling() {
        use crate::solvers::api::Jacobi;
        let mut rng = Rng::new(13);
        let n = 50;
        let base = Mat::rand_spd(n, 1e3, &mut rng);
        let scales: Vec<f64> = (0..n).map(|i| 10f64.powi((i % 4) as i32)).collect();
        let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
        let jac = Jacobi::from_op(&DenseOp::new(&a));
        let b = Mat::randn(n, 3, &mut rng);
        let cfg = CgConfig { tol: 1e-9, ..Default::default() };
        let plain = solve(&DenseOp::new(&a), &b, 1e-9, 0);
        let pre = solve_spec(&DenseOp::new(&a), &b, None, None, Some(&jac), &cfg);
        assert_eq!(pre.stop, StopReason::Converged);
        assert!(
            pre.iterations < plain.iterations,
            "jacobi {} >= plain {}",
            pre.iterations,
            plain.iterations
        );
        for j in 0..3 {
            let ax = a.matvec(&pre.x.col(j));
            let res: f64 = ax
                .iter()
                .zip(&b.col(j))
                .map(|(u, v)| (u - v) * (u - v))
                .sum::<f64>()
                .sqrt();
            assert!(res / norm2(&b.col(j)) <= 1e-8, "col {j}");
        }
    }

    #[test]
    fn stores_normalized_directions_for_recycling() {
        let mut rng = Rng::new(14);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = Mat::randn(n, 4, &mut rng);
        let cfg = CgConfig { tol: 1e-9, store_l: 10, ..Default::default() };
        let r = solve_spec(&DenseOp::new(&a), &b, None, None, None, &cfg);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.stored.len(), 10);
        for (p, ap) in r.stored.p.iter().zip(&r.stored.ap) {
            assert!((norm2(p) - 1.0).abs() < 1e-12);
            let want = a.matvec(p);
            for (u, v) in ap.iter().zip(&want) {
                assert!((u - v).abs() < 1e-9, "AP must match A·p");
            }
        }
    }

    #[test]
    fn warm_start_block() {
        let mut rng = Rng::new(15);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let x_true = Mat::randn(n, 2, &mut rng);
        let b = a.matmul(&x_true);
        let cfg = CgConfig { tol: 1e-9, ..Default::default() };
        let cold = solve_spec(&DenseOp::new(&a), &b, None, None, None, &cfg);
        assert_eq!(cold.stop, StopReason::Converged);
        let warm = solve_spec(&DenseOp::new(&a), &b, Some(&cold.x), None, None, &cfg);
        assert_eq!(warm.stop, StopReason::Converged);
        assert_eq!(warm.iterations, 0, "warm start from the solution stops at once");
        assert_eq!(warm.block_matvecs, 1, "one apply for the initial residual");
    }

    #[test]
    fn stall_window_stops_stagnant_block_solves() {
        // A noisy operator with a per-call error floor: the block solve
        // can never reach tol 1e-13 and must stop as Stagnated.
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Noisy<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for Noisy<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
                let call = self.1.fetch_add(1, Ordering::Relaxed);
                let scale = norm2(y) * 1e-6;
                for (i, v) in y.iter_mut().enumerate() {
                    let h = ((i + 131 * call).wrapping_mul(2654435761)) % 1000;
                    *v += scale * (h as f64 / 1000.0 - 0.5);
                }
            }
        }
        let mut rng = Rng::new(16);
        let a = Mat::rand_spd(50, 1e3, &mut rng);
        let b = Mat::randn(50, 3, &mut rng);
        // recompute_every is what makes the floor VISIBLE: without it the
        // recursive residual self-converges straight through the noise
        // floor and the solve would (falsely) report Converged — the same
        // guard the cg.rs noisy-operator test relies on.
        let cfg = CgConfig {
            tol: 1e-13,
            max_iters: 5000,
            stall_window: 60,
            recompute_every: 10,
            ..Default::default()
        };
        let r = solve_spec(&Noisy(&a, AtomicUsize::new(0)), &b, None, None, None, &cfg);
        assert_eq!(r.stop, StopReason::Stagnated, "stopped as {:?}", r.stop);
        assert!(r.iterations < 5000);
    }
}
