//! The method of conjugate gradients (Hestenes & Stiefel, 1952).
//!
//! This is the paper's iterative baseline and the inner engine that def-CG
//! extends. The implementation records a relative-residual trace (Fig. 3)
//! and can store the first ℓ normalized search directions together with
//! their `A·p` products — the raw material for harmonic-Ritz recycling
//! (§2.3) — at zero extra matvec cost.

use crate::linalg::vec_ops::{axpy, dot, norm2, xpby};
use crate::solvers::control::SolveControl;
use crate::solvers::{SolveResult, SpdOperator, StopReason, StoredDirections};
use std::time::Instant;

/// Configuration for a CG run.
#[derive(Clone, Debug)]
pub struct CgConfig {
    /// Stop when ‖r‖/‖b‖ ≤ tol.
    pub tol: f64,
    /// Iteration cap (0 means `10 n`).
    pub max_iters: usize,
    /// Store the first ℓ (direction, A·direction) pairs for recycling.
    pub store_l: usize,
    /// Stagnation window: stop with [`StopReason::Stagnated`] when the
    /// residual improved by < 0.1% over this many iterations.
    ///
    /// **0 (default) disables the check.** CG residual norms are not
    /// monotone — ill-conditioned systems legitimately plateau for
    /// hundreds of iterations before the superlinear phase — so this is an
    /// opt-in for paths with a known numerical floor: the f32 XLA-artifact
    /// operators (floor ≈ 1e-6 relative) and `AwPolicy::Reuse` recycling
    /// (floor at the sequence drift level).
    pub stall_window: usize,
    /// Residual replacement (van der Vorst & Ye): every this many
    /// iterations, recompute `r = b − A x` exactly (one extra matvec)
    /// instead of trusting the recursion. The recursive residual
    /// self-converges even when the operator is inexact (f32 artifacts),
    /// silently leaving the *true* residual at the precision floor;
    /// replacement exposes the floor so `stall_window` can stop the solve.
    /// 0 (default) disables.
    pub recompute_every: usize,
    /// Cooperative cancellation / wall-clock deadline, checked once per
    /// iteration **before** the operator application — a raised cancel
    /// or expired deadline stops the solve within one application, with
    /// the partial iterate returned ([`StopReason::Cancelled`] /
    /// [`StopReason::DeadlineExceeded`]). The inert default costs one
    /// branch per iteration.
    pub control: SolveControl,
}

impl Default for CgConfig {
    fn default() -> Self {
        CgConfig {
            tol: 1e-5,
            max_iters: 0,
            store_l: 0,
            stall_window: 0,
            recompute_every: 0,
            control: SolveControl::none(),
        }
    }
}

impl CgConfig {
    pub fn with_tol(tol: f64) -> Self {
        CgConfig { tol, ..Default::default() }
    }

    pub(crate) fn effective_max_iters(&self, n: usize) -> usize {
        if self.max_iters == 0 {
            10 * n.max(1)
        } else {
            self.max_iters
        }
    }

    /// True if the residual trace shows < 0.1% improvement over the
    /// window (`now > 0.999 · then`) — the threshold documented on
    /// [`CgConfig::stall_window`] and pinned by
    /// `stagnation_threshold_is_a_tenth_of_a_percent`.
    pub(crate) fn stagnated(&self, residuals: &[f64]) -> bool {
        if self.stall_window == 0 || residuals.len() <= self.stall_window {
            return false;
        }
        let now = residuals[residuals.len() - 1];
        let then = residuals[residuals.len() - 1 - self.stall_window];
        now > 0.999 * then
    }
}

/// Solve `A x = b` with CG starting from `x0` (zeros if `None`).
pub fn solve(
    a: &dyn SpdOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    cfg: &CgConfig,
) -> SolveResult {
    let start = Instant::now();
    let n = a.n();
    assert_eq!(b.len(), n, "rhs dimension mismatch");
    let bnorm = norm2(b);
    let mut matvecs = 0usize;

    let mut x = match x0 {
        Some(x0) => {
            assert_eq!(x0.len(), n);
            x0.to_vec()
        }
        None => vec![0.0; n],
    };

    // r = b - A x
    let mut r = b.to_vec();

    // Entry check: a request that is already cancelled/expired must not
    // pay even the warm-start residual application. Reports the unit
    // placeholder residual of the untouched right-hand side (exact for a
    // zero start; a warm start's true residual would cost the one
    // application a dead request must never pay).
    if let Some(reason) = cfg.control.check() {
        let denom = if bnorm > 0.0 { bnorm } else { 1.0 };
        return SolveResult {
            x,
            residuals: vec![norm2(&r) / denom],
            iterations: 0,
            matvecs,
            stop: reason,
            stored: StoredDirections::default(),
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    if x0.is_some() {
        let ax = a.matvec_alloc(&x);
        matvecs += 1;
        for i in 0..n {
            r[i] -= ax[i];
        }
    }

    let denom = if bnorm > 0.0 { bnorm } else { 1.0 };
    let mut residuals = vec![norm2(&r) / denom];
    let mut stored = StoredDirections::default();

    if residuals[0] <= cfg.tol {
        return SolveResult {
            x,
            residuals,
            iterations: 0,
            matvecs,
            stop: StopReason::Converged,
            stored,
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    let mut p = r.clone();
    let mut rr = dot(&r, &r);
    let mut ap = vec![0.0; n];
    let max_iters = cfg.effective_max_iters(n);
    let mut stop = StopReason::MaxIters;
    let mut iterations = 0;

    for _j in 0..max_iters {
        // Cancellation/deadline check BEFORE the (possibly expensive)
        // operator application: a cancel raised while a matvec is in
        // flight takes effect as soon as it returns, never paying for
        // another one. The iterate is consistent at this point, so the
        // partial result (and any stored directions) is usable as-is.
        if let Some(reason) = cfg.control.check() {
            stop = reason;
            break;
        }
        a.matvec(&p, &mut ap);
        matvecs += 1;
        let d = dot(&p, &ap);
        if d <= 0.0 || !d.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        if stored.len() < cfg.store_l {
            // Store normalized direction and matching A·p scaling.
            let pn = norm2(&p);
            if pn > 0.0 {
                let inv = 1.0 / pn;
                stored.p.push(p.iter().map(|v| v * inv).collect());
                stored.ap.push(ap.iter().map(|v| v * inv).collect());
            }
        }
        let alpha = rr / d;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        iterations += 1;
        // Residual replacement: trade one matvec for an exact residual,
        // defeating the recursion's self-consistency on inexact operators.
        if cfg.recompute_every > 0 && iterations % cfg.recompute_every == 0 {
            a.matvec(&x, &mut ap); // reuse ap as scratch (rebuilt next iter)
            matvecs += 1;
            for i in 0..n {
                r[i] = b[i] - ap[i];
            }
        }
        let rr_new = dot(&r, &r);
        let rel = rr_new.sqrt() / denom;
        residuals.push(rel);
        if rel <= cfg.tol {
            stop = StopReason::Converged;
            break;
        }
        if cfg.stagnated(&residuals) {
            stop = StopReason::Stagnated;
            break;
        }
        let beta = rr_new / rr;
        rr = rr_new;
        xpby(&r, beta, &mut p); // p = r + beta p
    }

    SolveResult {
        x,
        residuals,
        iterations,
        matvecs,
        stop,
        stored,
        seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::DenseOp;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn solves_identity_in_one_iteration() {
        let a = Mat::identity(5);
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let r = solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-12));
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.iterations <= 1);
        for (xi, bi) in r.x.iter().zip(&b) {
            assert!((xi - bi).abs() < 1e-10);
        }
    }

    #[test]
    fn converges_on_random_spd() {
        forall("CG solves SPD", 15, |g| {
            let n = g.usize_in(2, 30);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e3));
            let x_true = g.normal_vec(n);
            let b = a.matvec(&x_true);
            let r = solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-10));
            r.stop == StopReason::Converged
                && r.x.iter().zip(&x_true).all(|(u, v)| (u - v).abs() < 1e-5)
        });
    }

    #[test]
    fn finite_termination_in_exact_arithmetic() {
        // CG terminates in at most n steps (here: well within 2n even with
        // round-off, for a mildly conditioned matrix).
        let mut rng = Rng::new(2);
        let n = 20;
        let a = Mat::rand_spd(n, 100.0, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let r = solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-12));
        assert_eq!(r.stop, StopReason::Converged);
        assert!(r.iterations <= 2 * n, "iterations={}", r.iterations);
    }

    #[test]
    fn residual_trace_matches_true_residual() {
        let mut rng = Rng::new(3);
        let n = 15;
        let a = Mat::rand_spd(n, 50.0, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
        let r = solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-10));
        // recompute ‖b - A x‖/‖b‖ and compare to the last trace entry
        let ax = a.matvec(&r.x);
        let mut res = 0.0;
        for i in 0..n {
            res += (b[i] - ax[i]).powi(2);
        }
        let res = res.sqrt() / norm2(&b);
        let traced = r.final_residual();
        assert!(
            (res - traced).abs() < 1e-8,
            "true {res} vs traced {traced} (recursive residual drift)"
        );
    }

    #[test]
    fn warm_start_reduces_iterations() {
        let mut rng = Rng::new(4);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let b = a.matvec(&x_true);
        let cold = solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-8));
        // Warm start very close to the solution.
        let x0: Vec<f64> = x_true.iter().map(|v| v * (1.0 + 1e-6)).collect();
        let warm = solve(&DenseOp::new(&a), &b, Some(&x0), &CgConfig::with_tol(1e-8));
        assert!(warm.iterations < cold.iterations);
    }

    #[test]
    fn stores_at_most_l_normalized_directions() {
        let mut rng = Rng::new(5);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
        let cfg = CgConfig { tol: 1e-10, max_iters: 0, store_l: 6, ..Default::default() };
        let r = solve(&DenseOp::new(&a), &b, None, &cfg);
        assert_eq!(r.stored.len(), 6.min(r.iterations));
        for (p, ap) in r.stored.p.iter().zip(&r.stored.ap) {
            assert!((norm2(p) - 1.0).abs() < 1e-12);
            // ap must equal A p for the normalized p
            let want = a.matvec(p);
            for (u, v) in ap.iter().zip(&want) {
                assert!((u - v).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn stored_directions_are_a_conjugate() {
        // pᵢᵀ A pⱼ = 0 for i≠j — the defining CG invariant.
        let mut rng = Rng::new(6);
        let n = 25;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| (i * i % 7) as f64 - 3.0).collect();
        let cfg = CgConfig { tol: 1e-12, max_iters: 0, store_l: 8, ..Default::default() };
        let r = solve(&DenseOp::new(&a), &b, None, &cfg);
        for i in 0..r.stored.len() {
            for j in 0..i {
                let paj = dot(&r.stored.p[i], &r.stored.ap[j]);
                assert!(paj.abs() < 1e-8, "p{i}ᵀAp{j} = {paj}");
            }
        }
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = Mat::identity(4);
        let r = solve(&DenseOp::new(&a), &[0.0; 4], None, &CgConfig::default());
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.x, vec![0.0; 4]);
    }

    #[test]
    fn max_iters_respected() {
        let mut rng = Rng::new(7);
        let a = Mat::rand_spd(50, 1e8, &mut rng);
        let b = vec![1.0; 50];
        let cfg = CgConfig { tol: 1e-14, max_iters: 3, store_l: 0, ..Default::default() };
        let r = solve(&DenseOp::new(&a), &b, None, &cfg);
        assert_eq!(r.stop, StopReason::MaxIters);
        assert_eq!(r.iterations, 3);
        assert_eq!(r.matvecs, 3);
    }

    #[test]
    fn stagnation_threshold_is_a_tenth_of_a_percent() {
        // The documented rule on `stall_window`: stagnated iff the
        // residual improved by LESS than 0.1% over the window
        // (now > 0.999 · then). Pinned on synthetic traces so the doc,
        // the code, and this test can never drift apart again.
        let cfg = CgConfig { stall_window: 3, ..Default::default() };
        // 0.2% improvement over the window: still making progress.
        assert!(!cfg.stagnated(&[1.0, 1.0, 1.0, 0.998]));
        // 0.05% improvement: stagnated.
        assert!(cfg.stagnated(&[1.0, 1.0, 1.0, 0.9995]));
        // Exactly 0.1%: the strict inequality says NOT stagnated.
        assert!(!cfg.stagnated(&[1.0, 1.0, 1.0, 0.999]));
        // Window not yet filled (needs window + 1 trace entries): never.
        assert!(!cfg.stagnated(&[1.0, 0.9995, 0.9999]));
        assert!(!cfg.stagnated(&[1.0, 1.0, 1.0]));
        // Disabled window never stagnates.
        let off = CgConfig::default();
        assert!(!off.stagnated(&[1.0, 1.0, 1.0, 1.0, 1.0]));
        // The comparison is against the entry `window` steps back, not the
        // global best: a rebound after early progress still counts as
        // stagnation.
        assert!(cfg.stagnated(&[1.0, 0.5, 0.499, 0.4999, 0.49995]));
    }

    #[test]
    fn stagnation_detected_on_noisy_operator() {
        // An operator with an injected per-call error floor (the noise
        // pattern changes every call, like f32 rounding under different
        // operand values): CG can never reach tol 1e-13 and must stop as
        // Stagnated, not spin to max_iters.
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Noisy<'a>(&'a Mat, AtomicUsize);
        impl<'a> crate::solvers::SpdOperator for Noisy<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
                let call = self.1.fetch_add(1, Ordering::Relaxed);
                let scale = crate::linalg::vec_ops::norm2(y) * 1e-6;
                for (i, v) in y.iter_mut().enumerate() {
                    let h = ((i + 131 * call).wrapping_mul(2654435761)) % 1000;
                    *v += scale * (h as f64 / 1000.0 - 0.5);
                }
            }
        }
        let mut rng = Rng::new(9);
        let a = Mat::rand_spd(60, 1e3, &mut rng);
        let b = vec![1.0; 60];
        let cfg = CgConfig {
            tol: 1e-13,
            max_iters: 5000,
            store_l: 0,
            stall_window: 60,
            recompute_every: 10,
            ..Default::default()
        };
        let r = solve(&Noisy(&a, AtomicUsize::new(0)), &b, None, &cfg);
        assert_eq!(r.stop, StopReason::Stagnated, "stopped as {:?}", r.stop);
        assert!(r.iterations < 5000);
        // The solution should still be decent (floor ~1e-6).
        assert!(r.final_residual() < 1e-4);
    }

    #[test]
    fn precancelled_control_stops_before_the_first_matvec() {
        use crate::solvers::control::{CancelToken, SolveControl};
        let mut rng = Rng::new(30);
        let a = Mat::rand_spd(20, 1e4, &mut rng);
        let token = CancelToken::new();
        token.cancel();
        let mut control = SolveControl::none();
        control.set_token(token);
        let cfg = CgConfig { tol: 1e-12, control, ..Default::default() };
        let r = solve(&DenseOp::new(&a), &vec![1.0; 20], None, &cfg);
        assert_eq!(r.stop, StopReason::Cancelled);
        assert_eq!(r.iterations, 0);
        assert_eq!(r.matvecs, 0, "a cancelled run must not pay operator applications");
        assert_eq!(r.x, vec![0.0; 20], "the start iterate is returned untouched");
        assert!(!r.final_residual().is_nan());
    }

    #[test]
    fn expired_deadline_returns_partial_iterate() {
        // A deadline that expires after a few iterations: the solve must
        // stop as DeadlineExceeded with a *useful* partial x (smaller
        // A-norm error than the zero start — CG's A-norm monotonicity)
        // and consistent stored directions for recycling.
        use crate::solvers::control::SolveControl;
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct CountingSleep<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for CountingSleep<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.1.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_millis(3));
                self.0.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(31);
        let n = 60;
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let b = a.matvec(&x_true);
        let budget = std::time::Duration::from_millis(90);
        let control = SolveControl::deadline_at(std::time::Instant::now() + budget);
        // tol far below what ~30 iterations can reach on cond 1e6: the
        // deadline must fire first.
        let cfg = CgConfig { tol: 1e-15, store_l: 8, control, ..Default::default() };
        let op = CountingSleep(&a, AtomicUsize::new(0));
        let r = solve(&op, &b, None, &cfg);
        assert_eq!(r.stop, StopReason::DeadlineExceeded, "stopped as {:?}", r.stop);
        assert!(r.iterations >= 1, "the budget allowed at least one iteration");
        assert_eq!(r.matvecs, op.1.load(Ordering::SeqCst));
        // Partial progress: A-norm error strictly below the zero start's.
        let a_err = |x: &[f64]| -> f64 {
            let e: Vec<f64> = x.iter().zip(&x_true).map(|(u, v)| u - v).collect();
            dot(&e, &a.matvec(&e)).sqrt()
        };
        assert!(a_err(&r.x) < a_err(&vec![0.0; n]), "partial x must beat the start");
        // Stored pairs are consistent (p normalized, ap = A·p).
        assert!(!r.stored.is_empty());
        for (p, ap) in r.stored.p.iter().zip(&r.stored.ap) {
            assert!((norm2(p) - 1.0).abs() < 1e-12);
            let want = a.matvec(p);
            for (u, v) in ap.iter().zip(&want) {
                assert!((u - v).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn iteration_count_grows_with_condition_number() {
        let mut rng = Rng::new(8);
        let n = 60;
        let easy = Mat::rand_spd(n, 10.0, &mut rng);
        let hard = Mat::rand_spd(n, 1e6, &mut rng);
        let b = vec![1.0; n];
        let cfg = CgConfig::with_tol(1e-8);
        let re = solve(&DenseOp::new(&easy), &b, None, &cfg);
        let rh = solve(&DenseOp::new(&hard), &b, None, &cfg);
        assert!(
            rh.iterations > re.iterations,
            "hard {} <= easy {}",
            rh.iterations,
            re.iterations
        );
    }
}
