//! Cooperative mid-solve control: cancellation and deadlines.
//!
//! Under service load, a solve is not sacred: the caller may lose
//! interest (a disconnected client, a superseded hyperparameter
//! candidate) or may only be able to afford a bounded slice of wall
//! clock. Krylov iterations are short, so the right granularity for
//! both is **once per iteration**: every kernel (`cg`, `pcg`, `defcg`,
//! `blockcg`) calls [`SolveControl::check`] at the top of each
//! iteration, before the operator application, and stops with
//! [`StopReason::Cancelled`] / [`StopReason::DeadlineExceeded`] while
//! returning the **partial iterate** accumulated so far — a cancel or
//! deadline takes effect within one operator application of being
//! raised once the iteration is running (every kernel and the recycle
//! manager also check at *entry*, so a request dead before it starts
//! pays nothing; a cancel landing exactly during a solve's start-up
//! pays at most the constant few warm-start/deflated-start
//! applications), and the work already done is not discarded (a
//! deadline-stopped run still carries its stored `(p, Ap)` panel, which
//! the recycle manager absorbs like any other run's).
//!
//! The control travels on [`crate::solvers::cg::CgConfig`] (and
//! therefore on [`crate::solvers::SolveSpec`], which is how requests
//! reach it): an inert default costs one branch per iteration.

use crate::solvers::StopReason;
use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::Arc;
use std::time::Instant;

/// Shared cancellation flag for one solve request.
///
/// Clones share the flag: the submitting side keeps one clone (the
/// coordinator's `SolveFuture::cancel` flips it), the kernel polls
/// another once per iteration. Cancellation is level-triggered and
/// permanent — there is no un-cancel.
#[derive(Clone, Debug)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

// Manual impl: loom's `AtomicBool` has no `Default`, so the derive would
// not compile under `cfg(loom)`.
impl Default for CancelToken {
    fn default() -> CancelToken {
        CancelToken { flag: Arc::new(AtomicBool::new(false)) }
    }
}

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Raise the flag. Idempotent; takes effect at the target solve's
    /// next per-iteration check.
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// How a control's cancel flag is assembled from tokens.
///
/// A plain request carries one token. A *coalesced block group* in the
/// coordinator carries every member's token under all-of semantics: one
/// member cancelling must not abort its neighbours' shared solve, but
/// when every member has given up the group solve is pure waste and
/// stops.
#[derive(Clone, Debug, Default)]
enum Cancel {
    /// Not cancellable.
    #[default]
    None,
    /// Cancelled when the token is cancelled.
    Token(CancelToken),
    /// Cancelled when **every** token is cancelled (empty = never).
    AllOf(Arc<Vec<CancelToken>>),
}

/// Per-solve control handle: cancel flag plus absolute wall-clock
/// deadline, checked once per iteration by every solver kernel.
///
/// The deadline is an **absolute** [`Instant`]: queue wait counts
/// against it (build the spec — or re-arm [`crate::solvers::SolveSpec::with_deadline`]
/// — when you submit, not once for a whole loop of requests).
#[derive(Clone, Debug, Default)]
pub struct SolveControl {
    cancel: Cancel,
    /// Stop with [`StopReason::DeadlineExceeded`] once `Instant::now()`
    /// reaches this.
    pub deadline: Option<Instant>,
}

impl SolveControl {
    /// Inert control: never cancels, never expires.
    pub fn none() -> SolveControl {
        SolveControl::default()
    }

    /// Deadline-only control (no cancel source).
    pub fn deadline_at(at: Instant) -> SolveControl {
        SolveControl { cancel: Cancel::None, deadline: Some(at) }
    }

    /// Control driven by one cancel token (replaces any previous cancel
    /// source; the deadline is kept).
    pub fn set_token(&mut self, token: CancelToken) {
        self.cancel = Cancel::Token(token);
    }

    /// The single token driving this control, if there is exactly one
    /// (used by the coordinator to reuse a caller-supplied token as the
    /// future's token instead of stacking a second one).
    pub fn token(&self) -> Option<&CancelToken> {
        match &self.cancel {
            Cancel::Token(t) => Some(t),
            _ => None,
        }
    }

    /// Group control: cancelled only when **all** tokens are cancelled.
    /// Used for coalesced block groups so a single member's cancel
    /// cannot abort work its neighbours still want. An empty list never
    /// cancels.
    pub fn all_of(tokens: Vec<CancelToken>, deadline: Option<Instant>) -> SolveControl {
        SolveControl { cancel: Cancel::AllOf(Arc::new(tokens)), deadline }
    }

    pub fn is_cancelled(&self) -> bool {
        match &self.cancel {
            Cancel::None => false,
            Cancel::Token(t) => t.is_cancelled(),
            Cancel::AllOf(v) => !v.is_empty() && v.iter().all(|t| t.is_cancelled()),
        }
    }

    /// The per-iteration check. Cancellation wins over the deadline when
    /// both hold (the caller explicitly gave up; "out of time" is the
    /// weaker statement).
    pub fn check(&self) -> Option<StopReason> {
        if self.is_cancelled() {
            return Some(StopReason::Cancelled);
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                return Some(StopReason::DeadlineExceeded);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn inert_control_never_stops() {
        let c = SolveControl::none();
        assert!(c.check().is_none());
        assert!(!c.is_cancelled());
        assert!(c.token().is_none());
    }

    #[test]
    fn token_cancels_and_is_shared_by_clones() {
        let t = CancelToken::new();
        let mut c = SolveControl::none();
        c.set_token(t.clone());
        let c2 = c.clone();
        assert!(c.check().is_none());
        t.cancel();
        assert_eq!(c.check(), Some(StopReason::Cancelled));
        assert_eq!(c2.check(), Some(StopReason::Cancelled), "clones share the flag");
        t.cancel(); // idempotent
        assert!(t.is_cancelled());
    }

    #[test]
    fn deadline_expires() {
        let c = SolveControl {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..SolveControl::none()
        };
        assert_eq!(c.check(), Some(StopReason::DeadlineExceeded));
        let c = SolveControl {
            deadline: Some(Instant::now() + Duration::from_secs(3600)),
            ..SolveControl::none()
        };
        assert!(c.check().is_none());
    }

    #[test]
    fn cancel_wins_over_expired_deadline() {
        let t = CancelToken::new();
        t.cancel();
        let mut c = SolveControl {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..SolveControl::none()
        };
        c.set_token(t);
        assert_eq!(c.check(), Some(StopReason::Cancelled));
    }

    #[test]
    fn all_of_needs_every_member() {
        let a = CancelToken::new();
        let b = CancelToken::new();
        let c = SolveControl::all_of(vec![a.clone(), b.clone()], None);
        assert!(c.check().is_none());
        a.cancel();
        assert!(c.check().is_none(), "one member must not cancel the group");
        b.cancel();
        assert_eq!(c.check(), Some(StopReason::Cancelled));
        // Empty group: never cancels.
        let empty = SolveControl::all_of(Vec::new(), None);
        assert!(empty.check().is_none());
    }
}
