//! Deflated conjugate gradients — Algorithm 1 of the paper
//! (Saad, Yeung, Erhel & Guyomarc'h, *A deflated version of the conjugate
//! gradient algorithm*, SISC 2000).
//!
//! Given a basis `W ∈ ℝ^{n×k}` of a recycled subspace (approximate
//! eigenvectors from the previous system in the sequence) and its image
//! `AW`, the method:
//!
//! 1. shifts the start point so the initial residual is orthogonal to `W`
//!    (`x₀ = x₋₁ + W (WᵀAW)⁻¹ Wᵀ r₋₁`, line 2);
//! 2. deflates every new direction against `W`
//!    (`p_j = β p_{j−1} + r_j − W μ_j` with `WᵀAW μ_j = WᵀA r_j`, line 11).
//!
//! The iteration then behaves like CG on the projected operator
//! `P_W A` whose spectrum has the deflated eigenvalues removed, so the
//! effective condition number drops to `λ_n / λ_{k+1}` (paper §2.1).
//!
//! Cost per iteration over CG: one k×k triangular solve plus two skinny
//! products with `W`/`AW` — `O(nk)`; no extra matvecs because `WᵀA r =
//! (AW)ᵀ r` reuses the stored `AW`.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::{axpy, dot, norm2};
use crate::solvers::api::{Identity, Preconditioner};
use crate::solvers::cg::CgConfig;
use crate::solvers::{pcg, SolveResult, SpdOperator, StopReason, StoredDirections};
use std::time::Instant;

/// The recycled subspace handed to a deflated solve: the basis `W` and its
/// image `AW` under the *current* system's operator.
///
/// NOTE on sequences: the harmonic-Ritz vectors are extracted from system
/// `i` but reused against system `i+1 ≠ i`. Like the paper, we reuse the
/// stale `A⁽ⁱ⁾W` image as the approximation of `A⁽ⁱ⁺¹⁾W` when the caller
/// does not refresh it ([`Deflation::refresh`] recomputes it exactly with
/// k matvecs; the ablation bench quantifies the difference).
#[derive(Clone, Debug)]
pub struct Deflation {
    pub w: Mat,
    pub aw: Mat,
}

impl Deflation {
    pub fn new(w: Mat, aw: Mat) -> Self {
        assert_eq!(w.rows(), aw.rows());
        assert_eq!(w.cols(), aw.cols());
        Deflation { w, aw }
    }

    pub fn k(&self) -> usize {
        self.w.cols()
    }

    /// A new basis holding only the leading `k` column pairs. Extraction
    /// builds and normalizes columns independently, so this prefix is
    /// bit-for-bit the basis a smaller extraction would have built —
    /// which is why the strategy layer sizes k by *prefix* selection
    /// (see [`crate::solvers::strategy`]).
    pub fn leading_cols(&self, k: usize) -> Deflation {
        let k = k.min(self.k());
        let n = self.w.rows();
        let mut w = Mat::zeros(n, k);
        let mut aw = Mat::zeros(n, k);
        for j in 0..k {
            w.set_col(j, &self.w.col(j));
            aw.set_col(j, &self.aw.col(j));
        }
        Deflation::new(w, aw)
    }

    /// Factor the k×k Gram `WᵀAW` (symmetrized against round-off) — the
    /// small SPD system every deflated kernel solves against, shared by
    /// the single-RHS kernel ([`solve_precond`]) and the block kernel
    /// ([`crate::solvers::blockcg::solve_spec`]). Errs when the recycled
    /// basis is degenerate (rank-deficient `W`, or an indefinite stale
    /// `AW`), which callers treat as "run undeflated".
    pub fn factor_wtaw(&self) -> Result<Cholesky, crate::linalg::cholesky::NotSpd> {
        let mut g = self.w.t_matmul(&self.aw);
        g.symmetrize();
        Cholesky::factor(&g)
    }

    /// Recompute `AW` exactly under a (new) operator with **one block
    /// application** over all k basis columns ([`SpdOperator::apply_block`]
    /// — one data pass over A per panel instead of k column matvecs, same
    /// floats by the block contract). Returns the accounting cost: k
    /// operator applications.
    ///
    /// The refresh is **transactional**: the new image is computed into a
    /// scratch block and committed only after the full application
    /// succeeded, so an operator that panics mid-apply (caught by the
    /// coordinator's worker-panic containment) can never leave `AW` with
    /// columns mixed between two operators — the basis stays either
    /// entirely old or entirely new.
    pub fn refresh(&mut self, a: &dyn SpdOperator) -> usize {
        let k = self.w.cols();
        if k > 0 {
            let mut aw = Mat::zeros(self.w.rows(), k);
            a.apply_block(&self.w, &mut aw);
            self.aw = aw;
        }
        k
    }

    /// Serialize the basis to a byte buffer (own little-endian format:
    /// magic, n, k, then W and AW column-major f64). Lets a service
    /// persist recycled subspaces across process restarts, or transfer
    /// them between workers.
    pub fn to_bytes(&self) -> Vec<u8> {
        let (n, k) = (self.w.rows(), self.k());
        let mut out = Vec::with_capacity(16 + 16 * n * k);
        out.extend_from_slice(b"KRRDEFL1");
        out.extend_from_slice(&(n as u64).to_le_bytes());
        out.extend_from_slice(&(k as u64).to_le_bytes());
        for m in [&self.w, &self.aw] {
            for v in m.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Deserialize a basis written by [`Deflation::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Deflation, String> {
        if bytes.len() < 24 || &bytes[..8] != b"KRRDEFL1" {
            return Err("bad magic".into());
        }
        let rd = |off: usize| {
            let mut le = [0u8; 8];
            le.copy_from_slice(&bytes[off..off + 8]);
            u64::from_le_bytes(le) as usize
        };
        let (n, k) = (rd(8), rd(16));
        let need = 24 + 16 * n * k;
        if bytes.len() != need {
            return Err(format!("length {} != expected {need}", bytes.len()));
        }
        let read_mat = |start: usize| -> crate::linalg::Mat {
            let mut data = Vec::with_capacity(n * k);
            let mut le = [0u8; 8];
            for i in 0..n * k {
                let off = start + 8 * i;
                le.copy_from_slice(&bytes[off..off + 8]);
                data.push(f64::from_le_bytes(le));
            }
            crate::linalg::Mat::from_vec(n, k, data)
        };
        let w = read_mat(24);
        let aw = read_mat(24 + 8 * n * k);
        Ok(Deflation::new(w, aw))
    }
}

/// Deflated-CG solve. With `defl = None` (or an empty basis) this reduces
/// exactly to plain CG. `cfg.store_l` controls how many directions are
/// recorded for the next harmonic-Ritz extraction.
///
/// Thin shim over [`solve_precond`] without a preconditioner — prefer
/// building a [`SolveSpec`] and calling [`crate::solvers::solve`] in new
/// code.
///
/// [`SolveSpec`]: crate::solvers::SolveSpec
pub fn solve(
    a: &dyn SpdOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    defl: Option<&Deflation>,
    cfg: &CgConfig,
) -> SolveResult {
    solve_precond(a, b, x0, defl, None, cfg)
}

/// Fallback when the basis is unusable: plain CG, or PCG when a
/// preconditioner is in play.
fn undeflated(
    a: &dyn SpdOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    precond: Option<&dyn Preconditioner>,
    cfg: &CgConfig,
) -> SolveResult {
    match precond {
        Some(m) => pcg::solve_with(a, b, m, x0, cfg),
        None => crate::solvers::cg::solve(a, b, x0, cfg),
    }
}

/// Deflated CG composed with an optional preconditioner `M` — the
/// "interchangeable policies" kernel behind [`crate::solvers::solve`].
///
/// The iteration is the standard deflated-PCG recurrence: the start shift
/// and the `Wᵀr = 0` constraint are exactly Saad's Algorithm 1, while the
/// direction recursion runs on the preconditioned residual
/// `z = M⁻¹ r` (`p ← β p + z − W μ`, `WᵀAW μ = (AW)ᵀ z`). With
/// `precond = None` every float operation matches the historical
/// unpreconditioned def-CG bit-for-bit (the identity preconditioner only
/// copies `r`); with an empty basis it reduces to (P)CG.
pub fn solve_precond(
    a: &dyn SpdOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    defl: Option<&Deflation>,
    precond: Option<&dyn Preconditioner>,
    cfg: &CgConfig,
) -> SolveResult {
    let start = Instant::now();
    let n = a.n();
    assert_eq!(b.len(), n, "rhs dimension mismatch");

    // Entry check, mirroring `cg::solve`: a dead request must not pay
    // the deflated-start applications (warm-start residual + exact r₀
    // recompute) either. The undeflated delegation below re-checks at
    // its own entry, so this covers only the deflated path's pre-loop
    // work.
    if let Some(reason) = cfg.control.check() {
        let bnorm = norm2(b);
        let denom = if bnorm > 0.0 { bnorm } else { 1.0 };
        return SolveResult {
            x: x0.map(|v| v.to_vec()).unwrap_or_else(|| vec![0.0; n]),
            residuals: vec![bnorm / denom],
            iterations: 0,
            matvecs: 0,
            stop: reason,
            stored: StoredDirections::default(),
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    let empty = defl.map(|d| d.k() == 0).unwrap_or(true);
    if empty {
        // Undeflated path; keep a single implementation of the inner loop.
        return undeflated(a, b, x0, precond, cfg);
    }
    let defl = defl.unwrap();
    let ident = Identity;
    let m: &dyn Preconditioner = precond.unwrap_or(&ident);
    let (w, aw) = (&defl.w, &defl.aw);
    let k = defl.k();
    assert_eq!(w.rows(), n, "deflation basis dimension mismatch");

    let bnorm = norm2(b);
    let denom = if bnorm > 0.0 { bnorm } else { 1.0 };
    let mut matvecs = 0usize;

    // WᵀAW (k×k, SPD for SPD A and full-rank W) factored once per solve.
    let wtaw_ch = match defl.factor_wtaw() {
        Ok(ch) => ch,
        Err(_) => {
            // Degenerate recycled basis — fall back to an undeflated solve
            // rather than dividing by a singular projector.
            crate::log_warn!("WᵀAW not SPD (k={k}); falling back to undeflated CG");
            return undeflated(a, b, x0, precond, cfg);
        }
    };

    // r₋₁ = b − A x₋₁
    let mut x = match x0 {
        Some(x0) => {
            assert_eq!(x0.len(), n);
            x0.to_vec()
        }
        None => vec![0.0; n],
    };
    let mut r = b.to_vec();
    if x0.is_some() {
        let ax = a.matvec_alloc(&x);
        matvecs += 1;
        for i in 0..n {
            r[i] -= ax[i];
        }
    }

    // Line 2: x₀ = x₋₁ + W γ,  γ = (WᵀAW)⁻¹ Wᵀ r₋₁.
    let x_pre_shift = x.clone();
    let r_pre_norm = norm2(&r);
    let gamma = wtaw_ch.solve(&w.matvec_t(&r));
    w.add_scaled_cols(&gamma, &mut x);
    // r₀ = b − A x₀ recomputed EXACTLY (one matvec). Saad's update
    // r₀ = r₋₁ − AW γ is free but silently wrong when AW is stale (the
    // recycled basis comes from system i−1): the solver would then
    // converge an incorrect residual recursion and return a wrong
    // solution. One exact matvec buys correctness for every AW policy.
    {
        let ax = a.matvec_alloc(&x);
        matvecs += 1;
        for i in 0..n {
            r[i] = b[i] - ax[i];
        }
    }
    // Shift safeguard: the deflation shift minimizes the A⁻¹-norm of the
    // residual, not the 2-norm, so mild 2-norm growth is normal for
    // harmonic-Ritz bases. But growth beyond a small factor means the
    // basis belongs to a too-different system (fast drift under
    // AwPolicy::Reuse) and deflating with it would poison the direction
    // recursion — revert and run plain CG instead.
    if norm2(&r) > 3.0 * r_pre_norm {
        crate::log_debug!(
            "deflation shift increased residual ({:.3e} -> {:.3e}); dropping basis for this solve",
            r_pre_norm,
            norm2(&r)
        );
        let mut result = undeflated(a, b, Some(&x_pre_shift), precond, cfg);
        result.matvecs += matvecs;
        return result;
    }

    let mut residuals = vec![norm2(&r) / denom];
    let mut stored = StoredDirections::default();

    if residuals[0] <= cfg.tol {
        return SolveResult {
            x,
            residuals,
            iterations: 0,
            matvecs,
            stop: StopReason::Converged,
            stored,
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    // Preconditioned residual z = M⁻¹ r (a plain copy of r under the
    // identity, so the unpreconditioned path is arithmetically unchanged).
    let mut z = vec![0.0; n];
    m.apply(&r, &mut z);

    // Line 3: p₀ = z₀ − W μ₀ with WᵀAW μ₀ = WᵀA z₀ = (AW)ᵀ z₀.
    let deflect = |v: &[f64]| -> Vec<f64> { wtaw_ch.solve(&aw.matvec_t(v)) };
    let mut p = {
        let mu = deflect(&z);
        let mut p = z.clone();
        w.sub_scaled_cols(&mu, &mut p);
        p
    };

    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];
    let max_iters = cfg.effective_max_iters(n);
    let mut stop = StopReason::MaxIters;
    let mut iterations = 0;

    for _j in 0..max_iters {
        // Cooperative cancel/deadline check, before the matvec (see
        // `cg::solve` — identical placement in every kernel). Stopping
        // here keeps the `Wᵀr = 0` constraint of the returned partial
        // iterate intact: the check sits between iterations, never
        // inside one.
        if let Some(reason) = cfg.control.check() {
            stop = reason;
            break;
        }
        // Lines 6–10: the standard (P)CG sweep.
        a.matvec(&p, &mut ap);
        matvecs += 1;
        let d = dot(&p, &ap);
        if d <= 0.0 || !d.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        if stored.len() < cfg.store_l {
            let pn = norm2(&p);
            if pn > 0.0 {
                let inv = 1.0 / pn;
                stored.p.push(p.iter().map(|v| v * inv).collect());
                stored.ap.push(ap.iter().map(|v| v * inv).collect());
            }
        }
        let alpha = rz / d;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        iterations += 1;
        // Convergence is judged on the unpreconditioned residual.
        let rel = norm2(&r) / denom;
        residuals.push(rel);
        if rel <= cfg.tol {
            stop = StopReason::Converged;
            break;
        }
        if cfg.stagnated(&residuals) {
            stop = StopReason::Stagnated;
            break;
        }
        m.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        // Line 11: p = β p + z − W μ,  WᵀAW μ = (AW)ᵀ z.
        let mu = deflect(&z);
        for i in 0..n {
            p[i] = beta * p[i] + z[i];
        }
        w.sub_scaled_cols(&mu, &mut p);
    }

    SolveResult {
        x,
        residuals,
        iterations,
        matvecs,
        stop,
        stored,
        seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::sym_eig;
    use crate::linalg::mat::Mat;
    use crate::solvers::cg::CgConfig;
    use crate::solvers::DenseOp;
    use crate::util::quickprop::forall;
    use crate::util::rng::Rng;

    /// Deflation basis from the exact top-k eigenvectors of A.
    fn exact_deflation(a: &Mat, k: usize) -> Deflation {
        let e = sym_eig(a).unwrap();
        let n = a.rows();
        let mut w = Mat::zeros(n, k);
        for (dst, j) in ((n - k)..n).enumerate() {
            w.set_col(dst, &e.vectors.col(j));
        }
        let aw = a.matmul(&w);
        Deflation::new(w, aw)
    }

    #[test]
    fn reduces_to_cg_without_basis() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(12, 100.0, &mut rng);
        let b = vec![1.0; 12];
        let cfg = CgConfig::with_tol(1e-10);
        let r1 = solve(&DenseOp::new(&a), &b, None, None, &cfg);
        let r2 = crate::solvers::cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        assert_eq!(r1.iterations, r2.iterations);
        for (u, v) in r1.x.iter().zip(&r2.x) {
            assert_eq!(u, v);
        }
    }

    #[test]
    fn solves_correctly_with_deflation() {
        forall("def-CG solves SPD", 10, |g| {
            let n = g.usize_in(6, 25);
            let a = Mat::from_vec(n, n, g.spd_matrix(n, 1e4));
            let x_true = g.normal_vec(n);
            let b = a.matvec(&x_true);
            let defl = exact_deflation(&a, 3);
            let r = solve(
                &DenseOp::new(&a),
                &b,
                None,
                Some(&defl),
                &CgConfig::with_tol(1e-11),
            );
            r.stop == StopReason::Converged
                && r.x.iter().zip(&x_true).all(|(u, v)| (u - v).abs() < 1e-5)
        });
    }

    #[test]
    fn residual_stays_orthogonal_to_w() {
        // The deflation constraint (paper Eq. 5): Wᵀ r_j = 0 for all j.
        let mut rng = Rng::new(2);
        let n = 30;
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 1.0).collect();
        let defl = exact_deflation(&a, 4);

        // Instrument: run the solver to several iteration caps and check
        // Wᵀ r at each stopping point.
        for cap in [1, 2, 5, 9] {
            let cfg = CgConfig { tol: 1e-16, max_iters: cap, store_l: 0, ..Default::default() };
            let r = solve(&DenseOp::new(&a), &b, None, Some(&defl), &cfg);
            let ax = a.matvec(&r.x);
            let res: Vec<f64> = b.iter().zip(&ax).map(|(bi, axi)| bi - axi).collect();
            let wtr = defl.w.matvec_t(&res);
            let rel = crate::linalg::vec_ops::norm2(&wtr) / crate::linalg::vec_ops::norm2(&res);
            assert!(rel < 1e-8, "‖Wᵀr‖/‖r‖ = {rel} after {cap} iters");
        }
    }

    #[test]
    fn exact_deflation_reduces_iterations() {
        // Deflating the top-k eigenvectors must cut the iteration count for
        // a matrix with a few dominant eigenvalues.
        let mut rng = Rng::new(3);
        let n = 80;
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let b = vec![1.0; n];
        let cfg = CgConfig::with_tol(1e-8);
        let plain = crate::solvers::cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let defl = exact_deflation(&a, 8);
        let deflated = solve(&DenseOp::new(&a), &b, None, Some(&defl), &cfg);
        assert!(
            deflated.iterations < plain.iterations,
            "deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
    }

    #[test]
    fn effective_condition_number_governs_rate() {
        // With the top eigenvalue isolated (λ_n ≫ λ_{n-1}), deflating k=1
        // should make def-CG converge like CG on the easy remainder.
        let n = 40;
        let mut rng = Rng::new(4);
        // Build A = Q D Qᵀ with one huge eigenvalue.
        let q = crate::linalg::qr::Qr::factor(&Mat::randn(n, n, &mut rng)).thin_q();
        let mut d = Mat::zeros(n, n);
        for i in 0..n - 1 {
            d[(i, i)] = 1.0 + i as f64 / n as f64; // in [1, 2]
        }
        d[(n - 1, n - 1)] = 1e6;
        let a = {
            let mut m = q.matmul(&d).matmul(&q.transpose());
            m.symmetrize();
            m
        };
        let b = vec![1.0; n];
        let cfg = CgConfig::with_tol(1e-10);
        let defl = exact_deflation(&a, 1);
        let deflated = solve(&DenseOp::new(&a), &b, None, Some(&defl), &cfg);
        let plain = crate::solvers::cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        // κ_eff = 2 ⇒ very fast convergence.
        assert!(deflated.iterations <= 15, "deflated took {}", deflated.iterations);
        assert!(deflated.iterations < plain.iterations);
    }

    #[test]
    fn falls_back_to_cg_on_rank_deficient_w() {
        let mut rng = Rng::new(5);
        let n = 10;
        let a = Mat::rand_spd(n, 100.0, &mut rng);
        let w = Mat::zeros(n, 2); // rank-0 basis: WᵀAW singular
        let aw = Mat::zeros(n, 2);
        let b = vec![1.0; n];
        let r = solve(
            &DenseOp::new(&a),
            &b,
            None,
            Some(&Deflation::new(w, aw)),
            &CgConfig::with_tol(1e-8),
        );
        assert_eq!(r.stop, StopReason::Converged);
    }

    #[test]
    fn serialization_roundtrips() {
        let mut rng = Rng::new(11);
        let a = Mat::rand_spd(12, 100.0, &mut rng);
        let defl = exact_deflation(&a, 3);
        let bytes = defl.to_bytes();
        let back = Deflation::from_bytes(&bytes).unwrap();
        assert_eq!(back.k(), 3);
        assert_eq!(defl.w.max_abs_diff(&back.w), 0.0);
        assert_eq!(defl.aw.max_abs_diff(&back.aw), 0.0);
    }

    #[test]
    fn deserialization_rejects_garbage() {
        assert!(Deflation::from_bytes(b"short").is_err());
        assert!(Deflation::from_bytes(b"WRONGMAGICxxxxxxxxxxxxxxxx").is_err());
        let mut rng = Rng::new(12);
        let a = Mat::rand_spd(6, 10.0, &mut rng);
        let mut bytes = exact_deflation(&a, 2).to_bytes();
        bytes.truncate(bytes.len() - 1);
        assert!(Deflation::from_bytes(&bytes).is_err());
    }

    #[test]
    fn deserialized_basis_still_deflates() {
        let mut rng = Rng::new(13);
        let n = 50;
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let b = vec![1.0; n];
        let cfg = CgConfig::with_tol(1e-8);
        let defl = exact_deflation(&a, 6);
        let restored = Deflation::from_bytes(&defl.to_bytes()).unwrap();
        let plain = crate::solvers::cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let deflated = solve(&DenseOp::new(&a), &b, None, Some(&restored), &cfg);
        assert!(deflated.iterations < plain.iterations);
    }

    #[test]
    fn refresh_recomputes_aw() {
        let mut rng = Rng::new(6);
        let n = 8;
        let a1 = Mat::rand_spd(n, 10.0, &mut rng);
        let a2 = Mat::rand_spd(n, 10.0, &mut rng);
        let w = crate::linalg::qr::Qr::factor(&Mat::randn(n, 3, &mut rng)).thin_q();
        let mut d = Deflation::new(w.clone(), a1.matmul(&w));
        let cost = d.refresh(&DenseOp::new(&a2));
        assert_eq!(cost, 3);
        assert!(d.aw.max_abs_diff(&a2.matmul(&w)) < 1e-12);
    }

    #[test]
    fn composed_jacobi_deflation_solves_and_keeps_w_orthogonality() {
        // The Jacobi-deflation composition: a badly diagonal-scaled matrix
        // (where Jacobi matters) with a few dominant eigenvalues deflated.
        // The composed kernel must converge to the right answer and keep
        // the deflation constraint Wᵀ r ≈ 0 at every stopping point.
        use crate::solvers::api::Jacobi;
        let mut rng = Rng::new(21);
        let n = 50;
        let base = Mat::rand_spd(n, 1e3, &mut rng);
        let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 4) as f64)).collect();
        let a = Mat::from_fn(n, n, |i, j| base[(i, j)] * scales[i].sqrt() * scales[j].sqrt());
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let jac = Jacobi::new(&diag);
        let defl = exact_deflation(&a, 4);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 6) as f64).collect();
        for cap in [2, 5, 0] {
            let cfg = CgConfig { tol: 1e-10, max_iters: cap, ..Default::default() };
            let r = solve_precond(&DenseOp::new(&a), &b, None, Some(&defl), Some(&jac), &cfg);
            let ax = a.matvec(&r.x);
            let res: Vec<f64> = b.iter().zip(&ax).map(|(bi, axi)| bi - axi).collect();
            let wtr = defl.w.matvec_t(&res);
            let rel = crate::linalg::vec_ops::norm2(&wtr)
                / crate::linalg::vec_ops::norm2(&res).max(1e-300);
            if cap != 0 {
                assert!(rel < 1e-6, "‖Wᵀr‖/‖r‖ = {rel} after {cap} iters");
            } else {
                assert_eq!(r.stop, StopReason::Converged);
                assert!(r.final_residual() <= 1e-10);
            }
        }
    }

    #[test]
    fn precond_none_matches_legacy_defcg_bitwise() {
        // The generalized kernel under the identity must be float-for-float
        // the historical unpreconditioned def-CG.
        let mut rng = Rng::new(22);
        let n = 40;
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| (i as f64).cos() + 2.0).collect();
        let defl = exact_deflation(&a, 5);
        let cfg = CgConfig::with_tol(1e-10);
        let shim = solve(&DenseOp::new(&a), &b, None, Some(&defl), &cfg);
        let ident = crate::solvers::api::Identity;
        let explicit =
            solve_precond(&DenseOp::new(&a), &b, None, Some(&defl), Some(&ident), &cfg);
        assert_eq!(shim.iterations, explicit.iterations);
        assert_eq!(shim.x, explicit.x);
        assert_eq!(shim.residuals, explicit.residuals);
    }

    #[test]
    fn deflated_start_has_w_orthogonal_initial_residual() {
        let mut rng = Rng::new(7);
        let n = 20;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let b: Vec<f64> = (0..n).map(|i| i as f64 - 10.0).collect();
        let defl = exact_deflation(&a, 5);
        let cfg = CgConfig { tol: 1e-16, max_iters: 1, store_l: 0, ..Default::default() };
        // x0 far from solution
        let x0 = vec![100.0; n];
        let r = solve(&DenseOp::new(&a), &b, Some(&x0), Some(&defl), &cfg);
        // matvecs: 1 for r₋₁ + 1 for the exact r₀ recompute + 1 per iteration
        assert_eq!(r.matvecs, 3);
        assert!(r.residuals[0] > 0.0);
    }
}
