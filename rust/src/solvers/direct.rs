//! Dense Cholesky direct solver — the paper's exact baseline.
//!
//! Table 1's first column: `O(n³)` factorization, numerically exact
//! (to machine precision), no recycling possible. Wrapped in the same
//! result type as the iterative solvers so experiments treat all three
//! uniformly.

use crate::linalg::cholesky::Cholesky;
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::norm2;
use crate::solvers::{SolveResult, StopReason, StoredDirections};
use std::time::Instant;

/// Solve `A x = b` by dense Cholesky factorization.
///
/// Panics if `A` is not SPD (the experiments construct well-conditioned
/// systems by design; a production caller should use
/// [`Cholesky::factor`] directly to handle the error).
pub fn solve(a: &Mat, b: &[f64]) -> SolveResult {
    let start = Instant::now();
    let ch = Cholesky::factor(a).expect("direct::solve: matrix not SPD");
    let x = ch.solve(b);
    // Report the true relative residual for comparability.
    let ax = a.matvec(&x);
    let mut r = 0.0;
    for i in 0..b.len() {
        r += (b[i] - ax[i]).powi(2);
    }
    let bn = norm2(b);
    let rel = r.sqrt() / if bn > 0.0 { bn } else { 1.0 };
    SolveResult {
        x,
        residuals: vec![rel],
        iterations: 0,
        matvecs: 0,
        stop: StopReason::Converged,
        stored: StoredDirections::default(),
        seconds: start.elapsed().as_secs_f64(),
    }
}

/// A reusable factorization for solving several right-hand sides against
/// the same matrix (used by the inducing-point baseline).
pub struct DirectSolver {
    ch: Cholesky,
}

impl DirectSolver {
    pub fn new(a: &Mat) -> Result<Self, crate::linalg::cholesky::NotSpd> {
        Ok(DirectSolver { ch: Cholesky::factor(a)? })
    }

    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.ch.solve(b)
    }

    pub fn log_det(&self) -> f64 {
        self.ch.log_det()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn direct_solve_is_exact() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(25, 1e6, &mut rng);
        let x_true: Vec<f64> = (0..25).map(|i| (i as f64).sin()).collect();
        let b = a.matvec(&x_true);
        let r = solve(&a, &b);
        assert!(r.final_residual() < 1e-10);
        for (u, v) in r.x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-6);
        }
    }

    #[test]
    fn direct_solver_reuses_factorization() {
        let mut rng = Rng::new(2);
        let a = Mat::rand_spd(15, 100.0, &mut rng);
        let s = DirectSolver::new(&a).unwrap();
        for seed in 0..3 {
            let mut r2 = Rng::new(seed);
            let b: Vec<f64> = (0..15).map(|_| r2.normal()).collect();
            let x = s.solve(&b);
            let ax = a.matvec(&x);
            for (u, v) in ax.iter().zip(&b) {
                assert!((u - v).abs() < 1e-8);
            }
        }
        assert!(s.log_det().is_finite());
    }

    #[test]
    #[should_panic(expected = "not SPD")]
    fn panics_on_indefinite() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        let _ = solve(&a, &[1.0, 1.0]);
    }
}
