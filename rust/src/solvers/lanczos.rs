//! Lanczos tridiagonalization (paper §2.3's classical route to Ritz pairs).
//!
//! `m` steps of Lanczos with full reorthogonalization produce `V ∈ ℝ^{n×m}`
//! with orthonormal columns and a symmetric tridiagonal `T = Vᵀ A V`. The
//! eigenpairs `(θ, u)` of `T` give Ritz pairs `(θ, V u)` approximating the
//! extremal spectrum of `A`. Used as an alternative recycled-basis source
//! (ablation), and for cheap spectrum estimates in the Fig. 1 experiment
//! at sizes where a dense eigendecomposition would dominate runtime.

use crate::linalg::eig::{sym_tridiag_eig, EigResult};
use crate::linalg::mat::Mat;
use crate::linalg::vec_ops::{axpy, dot, norm2, scale};
use crate::solvers::SpdOperator;

/// Result of a Lanczos run.
#[derive(Clone, Debug)]
pub struct LanczosResult {
    /// Orthonormal Krylov basis (n × m_eff).
    pub v: Mat,
    /// Tridiagonal diagonal (len m_eff).
    pub alpha: Vec<f64>,
    /// Tridiagonal sub-diagonal (len m_eff − 1).
    pub beta: Vec<f64>,
    /// True if the iteration broke down early (invariant subspace found).
    pub breakdown: bool,
}

impl LanczosResult {
    /// Ritz pairs (θ_j, v_j = V u_j), θ ascending.
    pub fn ritz_pairs(&self) -> Result<(Vec<f64>, Mat), String> {
        let EigResult { values, vectors } = sym_tridiag_eig(&self.alpha, &self.beta)?;
        Ok((values, self.v.matmul(&vectors)))
    }
}

/// Run `m` Lanczos steps from start vector `q0` (normalized internally),
/// with full reorthogonalization for numerical robustness.
pub fn lanczos(a: &dyn SpdOperator, q0: &[f64], m: usize) -> LanczosResult {
    let n = a.n();
    assert_eq!(q0.len(), n);
    assert!(m >= 1);
    let m = m.min(n);

    let mut v = Mat::zeros(n, m);
    let mut alpha = Vec::with_capacity(m);
    let mut beta: Vec<f64> = Vec::with_capacity(m.saturating_sub(1));

    let mut q = q0.to_vec();
    let qn = norm2(&q);
    assert!(qn > 0.0, "lanczos start vector must be nonzero");
    scale(&mut q, 1.0 / qn);
    v.set_col(0, &q);

    let mut q_prev: Vec<f64> = vec![0.0; n];
    let mut w = vec![0.0; n];
    let mut breakdown = false;

    for j in 0..m {
        a.matvec(&q, &mut w);
        let aj = dot(&q, &w);
        alpha.push(aj);
        // w <- w - alpha_j q - beta_{j-1} q_prev
        axpy(-aj, &q, &mut w);
        if j > 0 {
            axpy(-beta[j - 1], &q_prev, &mut w);
        }
        // Full reorthogonalization against all previous basis vectors.
        for jj in 0..=j {
            let col = v.col(jj);
            let c = dot(&col, &w);
            axpy(-c, &col, &mut w);
        }
        if j + 1 == m {
            break;
        }
        let bj = norm2(&w);
        if bj < 1e-12 {
            breakdown = true;
            // Shrink the basis to the invariant subspace found.
            let m_eff = j + 1;
            let mut v2 = Mat::zeros(n, m_eff);
            for c in 0..m_eff {
                v2.set_col(c, &v.col(c));
            }
            return LanczosResult { v: v2, alpha, beta, breakdown };
        }
        beta.push(bj);
        q_prev.copy_from_slice(&q);
        q.copy_from_slice(&w);
        scale(&mut q, 1.0 / bj);
        v.set_col(j + 1, &q);
    }

    LanczosResult { v, alpha, beta, breakdown }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eig::sym_eig;
    use crate::solvers::DenseOp;
    use crate::util::rng::Rng;

    #[test]
    fn basis_is_orthonormal() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(30, 1e4, &mut rng);
        let q0: Vec<f64> = (0..30).map(|i| 1.0 + (i % 3) as f64).collect();
        let res = lanczos(&DenseOp::new(&a), &q0, 12);
        let g = res.v.t_matmul(&res.v);
        assert!(g.max_abs_diff(&Mat::identity(res.v.cols())) < 1e-10);
    }

    #[test]
    fn tridiagonal_matches_projection() {
        // T must equal Vᵀ A V.
        let mut rng = Rng::new(2);
        let a = Mat::rand_spd(25, 1e3, &mut rng);
        let q0 = vec![1.0; 25];
        let res = lanczos(&DenseOp::new(&a), &q0, 10);
        let t = res.v.t_matmul(&a.matmul(&res.v));
        for i in 0..10 {
            assert!((t[(i, i)] - res.alpha[i]).abs() < 1e-8);
            if i + 1 < 10 {
                assert!((t[(i, i + 1)] - res.beta[i]).abs() < 1e-8);
            }
            for j in 0..10 {
                if j + 1 < i || j > i + 1 {
                    assert!(t[(i, j)].abs() < 1e-8, "T[{i},{j}] = {}", t[(i, j)]);
                }
            }
        }
    }

    #[test]
    fn extremal_ritz_values_converge_first() {
        let mut rng = Rng::new(3);
        let a = Mat::rand_spd(50, 1e5, &mut rng);
        let exact = sym_eig(&a).unwrap();
        let (lam_min, lam_max) = (exact.values[0], exact.values[49]);
        let q0: Vec<f64> = (0..50).map(|i| ((i * 13 % 17) as f64) - 8.0).collect();
        let res10 = lanczos(&DenseOp::new(&a), &q0, 10);
        let res25 = lanczos(&DenseOp::new(&a), &q0, 25);
        let (theta10, _) = res10.ritz_pairs().unwrap();
        let (theta25, _) = res25.ritz_pairs().unwrap();
        // The dominant eigenvalue converges fast.
        let t_max = *theta25.last().unwrap();
        assert!((t_max - lam_max).abs() / lam_max < 1e-2, "{t_max} vs {lam_max}");
        // The bottom end converges monotonically (slowly: the small
        // eigenvalues of a log-spaced spectrum are clustered) and stays
        // inside the spectrum.
        assert!(theta25[0] <= theta10[0] + 1e-9, "{} vs {}", theta25[0], theta10[0]);
        assert!(theta25[0] >= lam_min - 1e-8 && t_max <= lam_max + 1e-6);
    }

    #[test]
    fn full_run_reproduces_spectrum() {
        // m = n Lanczos is a full tridiagonalization: Ritz values == eigenvalues.
        let mut rng = Rng::new(4);
        let a = Mat::rand_spd(12, 100.0, &mut rng);
        let exact = sym_eig(&a).unwrap();
        let res = lanczos(&DenseOp::new(&a), &vec![1.0; 12], 12);
        let (theta, _) = res.ritz_pairs().unwrap();
        for (t, l) in theta.iter().zip(&exact.values) {
            assert!((t - l).abs() < 1e-7, "{t} vs {l}");
        }
    }

    #[test]
    fn breakdown_on_invariant_subspace() {
        // Start vector is an exact eigenvector -> breakdown after 1 step.
        let a = Mat::from_fn(5, 5, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let q0 = vec![1.0, 0.0, 0.0, 0.0, 0.0];
        let res = lanczos(&DenseOp::new(&a), &q0, 5);
        assert!(res.breakdown);
        assert_eq!(res.v.cols(), 1);
        assert!((res.alpha[0] - 1.0).abs() < 1e-12);
    }
}
