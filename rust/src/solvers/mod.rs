//! Iterative and direct solvers for sequences of SPD systems.
//!
//! The module implements the paper's algorithmic core:
//!
//! * [`cg`] — the method of conjugate gradients (Hestenes–Stiefel) with a
//!   per-iteration trace and optional storage of the first ℓ search
//!   directions (the raw material for subspace recycling);
//! * [`defcg`] — **deflated CG**, Algorithm 1 of the paper (Saad, Yeung,
//!   Erhel & Guyomarc'h, 2000): CG preconditioned by the singular projector
//!   `P_W = I − AW(WᵀAW)⁻¹Wᵀ`;
//! * [`ritz`] — harmonic-Ritz extraction (Morgan, 1995; paper §2.3): builds
//!   `F = (AZ)ᵀZ`, `G = (AZ)ᵀ(AZ)` from quantities stored during the CG
//!   run and solves `G u = θ F u` for approximate eigenpairs;
//! * [`recycle`] — the recycle manager that carries `(W, AW)` from system
//!   `i` to system `i+1` (the "computational transfer learning" of §1);
//! * [`lanczos`] — plain Lanczos tridiagonalization, an alternative Ritz
//!   source and a spectrum-estimation tool;
//! * [`direct`] — dense Cholesky baseline (the paper's exact reference).

pub mod blockcg;
pub mod cg;
pub mod defcg;
pub mod direct;
pub mod lanczos;
pub mod pcg;
pub mod recycle;
pub mod ritz;

use crate::linalg::mat::Mat;

/// Abstract SPD operator `y = A x`.
///
/// Implementations: dense in-core matrices ([`DenseOp`]), the GPC Newton
/// system `A = I + H^½ K H^½` (`gp::laplace`), and the XLA-artifact-backed
/// operator in `runtime` (the three-layer hot path).
pub trait SpdOperator: Sync {
    /// Problem dimension n.
    fn n(&self) -> usize;

    /// y = A x. `y.len() == x.len() == n`.
    fn matvec(&self, x: &[f64], y: &mut [f64]);

    /// Allocating convenience wrapper.
    fn matvec_alloc(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n()];
        self.matvec(x, &mut y);
        y
    }
}

/// Dense in-core operator.
pub struct DenseOp<'a> {
    a: &'a Mat,
}

impl<'a> DenseOp<'a> {
    pub fn new(a: &'a Mat) -> Self {
        assert!(a.is_square(), "DenseOp needs a square matrix");
        DenseOp { a }
    }
}

impl<'a> SpdOperator for DenseOp<'a> {
    fn n(&self) -> usize {
        self.a.rows()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.a.matvec_into(x, y);
    }
}

/// Why a solve stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Relative residual dropped below tolerance.
    Converged,
    /// Iteration cap hit.
    MaxIters,
    /// Numerical breakdown (e.g. pᵀAp ≤ 0, which for true SPD A signals
    /// accumulated round-off).
    Breakdown,
    /// Residual stopped improving (hit a numerical floor — e.g. the f32
    /// precision of the XLA artifact path, or an inexact deflation basis).
    Stagnated,
}

/// Quantities stored from the first ℓ iterations of a (deflated) CG run,
/// exactly the inputs the harmonic-Ritz extraction needs (paper §2.3).
/// Directions are stored **normalized** (‖p‖ = 1) with the matching scaling
/// applied to A·p, which keeps the Gram matrices F, G well-scaled.
#[derive(Clone, Debug, Default)]
pub struct StoredDirections {
    /// Normalized search directions, one column per stored iteration.
    pub p: Vec<Vec<f64>>,
    /// A times the stored (normalized) directions.
    pub ap: Vec<Vec<f64>>,
}

impl StoredDirections {
    pub fn len(&self) -> usize {
        self.p.len()
    }

    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    /// Stack stored directions as matrix columns: returns (P, AP).
    pub fn as_mats(&self, n: usize) -> (Mat, Mat) {
        let l = self.p.len();
        let mut p = Mat::zeros(n, l);
        let mut ap = Mat::zeros(n, l);
        for j in 0..l {
            p.set_col(j, &self.p[j]);
            ap.set_col(j, &self.ap[j]);
        }
        (p, ap)
    }
}

/// Result of one linear solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub x: Vec<f64>,
    /// ‖r_j‖ / ‖b‖ after each iteration, starting with iteration 0's
    /// initial residual (so `residuals.len() == iterations + 1`).
    pub residuals: Vec<f64>,
    pub iterations: usize,
    pub matvecs: usize,
    pub stop: StopReason,
    /// Stored direction/Ap pairs for recycling (empty if ℓ = 0).
    pub stored: StoredDirections,
    /// Wall-clock seconds spent inside the solver.
    pub seconds: f64,
}

impl SolveResult {
    /// Final relative residual.
    pub fn final_residual(&self) -> f64 {
        *self.residuals.last().unwrap_or(&f64::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_op_matches_mat() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(10, 100.0, &mut rng);
        let op = DenseOp::new(&a);
        assert_eq!(op.n(), 10);
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(op.matvec_alloc(&x), a.matvec(&x));
    }

    #[test]
    fn stored_directions_stack() {
        let mut sd = StoredDirections::default();
        sd.p.push(vec![1.0, 0.0]);
        sd.ap.push(vec![2.0, 0.0]);
        sd.p.push(vec![0.0, 1.0]);
        sd.ap.push(vec![0.0, 3.0]);
        let (p, ap) = sd.as_mats(2);
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(1, 1)], 1.0);
        assert_eq!(ap[(0, 0)], 2.0);
        assert_eq!(ap[(1, 1)], 3.0);
        assert_eq!(sd.len(), 2);
        assert!(!sd.is_empty());
    }
}
