//! Iterative and direct solvers for sequences of SPD systems.
//!
//! The module implements the paper's algorithmic core:
//!
//! * [`cg`] — the method of conjugate gradients (Hestenes–Stiefel) with a
//!   per-iteration trace and optional storage of the first ℓ search
//!   directions (the raw material for subspace recycling);
//! * [`defcg`] — **deflated CG**, Algorithm 1 of the paper (Saad, Yeung,
//!   Erhel & Guyomarc'h, 2000): CG preconditioned by the singular projector
//!   `P_W = I − AW(WᵀAW)⁻¹Wᵀ`;
//! * [`ritz`] — harmonic-Ritz extraction (Morgan, 1995; paper §2.3): builds
//!   `F = (AZ)ᵀZ`, `G = (AZ)ᵀ(AZ)` from quantities stored during the CG
//!   run and solves `G u = θ F u` for approximate eigenpairs;
//! * [`recycle`] — the recycle manager that carries `(W, AW)` from system
//!   `i` to system `i+1` (the "computational transfer learning" of §1);
//! * [`lanczos`] — plain Lanczos tridiagonalization, an alternative Ritz
//!   source and a spectrum-estimation tool;
//! * [`direct`] — dense Cholesky baseline (the paper's exact reference);
//! * [`control`] — cooperative request-lifecycle control
//!   ([`CancelToken`] / [`SolveControl`]): every kernel checks its
//!   spec's control once per iteration, so cancellation and wall-clock
//!   deadlines stop a solve mid-run ([`StopReason::Cancelled`] /
//!   [`StopReason::DeadlineExceeded`]) with the partial iterate
//!   returned.
//!
//! All four iterative families are reachable through the **unified solve
//! API** in [`api`]: build a [`SolveSpec`] (method + tolerance +
//! preconditioner + deflation as data) and call [`solve`] /
//! [`solve_with_x0`]. The per-family free functions remain as thin shims
//! over the same kernels.
//!
//! # The block-first operator contract
//!
//! The paper's workloads are dominated by *multi-vector* operator
//! applications: block CG iterates on `A·P` with s columns, the recycle
//! manager refreshes `AW` (k columns) per system, harmonic-Ritz
//! extraction consumes stacked `(Z, AZ)`, and diagonal probing applies A
//! to panels of basis vectors. [`SpdOperator`] therefore exposes two
//! application methods:
//!
//! * [`SpdOperator::matvec`] — `y = A x`, the single-vector primitive;
//! * [`SpdOperator::apply_block`] — `Y = A X`, the multi-vector form.
//!   The default loops `matvec` over columns; implementations override it
//!   when one pass over the operator's data can serve many columns.
//!
//! **Contract:** `apply_block` must produce, column for column, the *same
//! floats* as the matvec loop. Overrides win by reusing operator traffic
//! across columns (the dense panel kernel streams each A row once per
//! [`crate::linalg::mat::Mat::BLOCK_PANEL`] columns instead of once per
//! column), never by reassociating the per-element arithmetic. This keeps
//! every solver trajectory independent of whether a consumer batched its
//! applications — recycled sequences, the bit-for-bit parallel/serial
//! equivalence, and the PR-pinned plain-CG results all survive the block
//! migration unchanged (`rust/tests/operator_algebra.rs` pins this).
//!
//! In-repo overrides: [`DenseOp`] / [`ParDenseOp`] (cache-blocked panel
//! GEMM, row-sharded on the pool for the parallel op), the GPC Newton
//! operator `I + SKS` (`gp::laplace`, fused scale–block-K–scale), and
//! `gp::regression::RegularizedKernelOp` (fused `K·X + σ²X`). The
//! [`algebra`] composers forward blocks to their base operator.
//!
//! # Operator algebra
//!
//! Sequences of *related* systems are usually cheap views over one base
//! operator: `A + σI` across a regularization grid, `c·A` across an
//! amplitude grid, `A + UUᵀ` after a low-rank model update. The
//! [`algebra`] module provides [`ShiftedOp`], [`ScaledOp`], [`SumOp`] and
//! [`LowRankUpdateOp`] wrappers that implement [`SpdOperator`] with exact
//! [`SpdOperator::diag`] and block forwarding, so hyperparameter and
//! Newton families never re-materialize kernels.

pub mod algebra;
pub mod api;
pub mod blockcg;
pub mod cg;
pub mod control;
pub mod defcg;
pub mod direct;
pub mod lanczos;
pub mod pcg;
pub mod recycle;
pub mod ritz;
pub mod strategy;

pub use algebra::{LowRankUpdateOp, ScaledOp, ShiftedOp, SumOp};
pub use api::{
    solve, solve_block, solve_with_x0, Identity, Jacobi, Method, Preconditioner, Priority,
    SolveSpec,
};
pub use control::{CancelToken, SolveControl};
pub use strategy::{RecycleStrategy, StrategyChoice, StrategyDecision};

use crate::linalg::mat::Mat;
use crate::util::pool::ThreadPool;
use std::sync::{Arc, Mutex};

/// Abstract SPD operator `y = A x`.
///
/// Implementations: dense in-core matrices ([`DenseOp`]), the GPC Newton
/// system `A = I + H^½ K H^½` (`gp::laplace`), and the XLA-artifact-backed
/// operator in `runtime` (the three-layer hot path).
pub trait SpdOperator: Sync {
    /// Problem dimension n.
    fn n(&self) -> usize;

    /// y = A x. `y.len() == x.len() == n`.
    fn matvec(&self, x: &[f64], y: &mut [f64]);

    /// Allocating convenience wrapper.
    fn matvec_alloc(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n()];
        self.matvec(x, &mut y);
        y
    }

    /// `Y = A X` — apply the operator to every column of `xs` at once.
    /// `xs` and `ys` are n-row matrices with the same column count.
    ///
    /// # Contract: column equivalence
    ///
    /// The result MUST be, column for column, **bitwise identical** to
    /// calling [`SpdOperator::matvec`] on each column of `xs` — overrides
    /// may only change how operator data is *streamed* (amortizing one
    /// pass over many columns), never the per-element float sequence.
    /// Solver trajectories therefore do not depend on whether a consumer
    /// batched its applications. The default implementation is exactly
    /// that column loop; override it whenever a block application pays:
    ///
    /// * [`DenseOp`] — cache-blocked panel GEMM
    ///   ([`Mat::block_matvec_into`]): each A row streamed once per
    ///   [`Mat::BLOCK_PANEL`] columns instead of once per column;
    /// * [`ParDenseOp`] — the same panel kernel, row-sharded across the
    ///   util pool (one fork/join for the whole block, not per column);
    /// * `gp::laplace::LaplaceOperator` — fused `X + S∘(K(S∘X))` with one
    ///   block kernel application;
    /// * `gp::regression::RegularizedKernelOp` — fused `K·X + σ²X`;
    /// * the [`algebra`] composers — forward the block to their base.
    ///
    /// For accounting, one `apply_block` over k columns counts as **k
    /// operator applications** ([`SolveResult::matvecs`] and the
    /// coordinator's `total_matvecs` both follow this rule).
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        apply_block_via(self.n(), &mut |x, y| self.matvec(x, y), xs, ys)
    }

    /// Write the diagonal of A into `out` (`out.len() == n`).
    ///
    /// # Contract: exact vs probed
    ///
    /// The **default implementation probes**: it applies the operator to
    /// panels of standard basis vectors (via [`SpdOperator::apply_block`],
    /// so operators with a real block kernel amortize the probe) and reads
    /// `out[i] = (A eᵢ)ᵢ` — always correct, but it costs **n operator
    /// applications** (`O(n³)` on a dense operator). Implementations that
    /// can read their diagonal directly MUST override this with an exact
    /// `O(n)` version; in-repo overrides:
    ///
    /// * [`DenseOp`] / [`ParDenseOp`] — `a[(i,i)]`;
    /// * `gp::laplace::LaplaceOperator` (the GPC Newton operator
    ///   `A = I + SKS`) — `1 + sᵢ² K_ii` when the kernel is dense, the
    ///   probing fallback otherwise;
    /// * `gp::regression::RegularizedKernelOp` — `K_ii + σ²`;
    /// * [`ShiftedOp`] — `diag(A) + σ`, [`ScaledOp`] — `c·diag(A)`,
    ///   [`SumOp`] — `diag(A) + diag(B)`, [`LowRankUpdateOp`] —
    ///   `diag(A) + ‖uᵢ‖²` rowwise: exact whenever the base diagonal is
    ///   exact, probing only where the base probes.
    ///
    /// The result feeds [`api::Jacobi::from_op`]; callers building a
    /// Jacobi preconditioner in a hot loop should make sure their
    /// operator overrides this, or amortize the probe across solves (the
    /// recycle manager caches one Jacobi per sequence for
    /// [`SolveSpec::with_auto_jacobi`] requests).
    fn diag(&self, out: &mut [f64]) {
        probe_diag_via(self, out)
    }

    /// An `O(1)` fingerprint identifying this operator's diagonal for
    /// per-sequence caches (the recycle manager's auto-Jacobi).
    ///
    /// # Contract
    ///
    /// Two operators whose fingerprints are both `Some` and **differ**
    /// must have different diagonals (so a cached Jacobi is definitely
    /// stale); equal fingerprints mean "same operator as far as the
    /// diagonal is concerned" to within the sampling resolution. `None`
    /// (the default) means the operator cannot identify itself cheaply —
    /// callers then fall back to coarser keys (dimension only), which is
    /// the pre-fingerprint behavior and the right one for anonymous
    /// drifting sequences.
    ///
    /// Implementations must be `O(1)`-ish: hash a few strided diagonal
    /// samples ([`fingerprint_f64s`]) or combine the base's fingerprint
    /// with the view parameters ([`algebra`] does `σ`, `c`, `U` samples).
    /// Never derive the full diagonal here — that is exactly the cost the
    /// fingerprint exists to avoid.
    fn diag_fingerprint(&self) -> Option<u64> {
        None
    }
}

/// FNV-1a-style hash over f64 bit patterns, the shared helper behind
/// [`SpdOperator::diag_fingerprint`] implementations. Start from a
/// per-type seed so structurally different operators with coincidentally
/// equal samples stay distinguishable.
pub fn fingerprint_f64s(seed: u64, vals: impl IntoIterator<Item = f64>) -> u64 {
    let mut h = seed ^ 0xcbf2_9ce4_8422_2325;
    for v in vals {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Up to 8 strided diagonal samples of a dense matrix, the shared
/// fingerprint input for [`DenseOp`] / [`ParDenseOp`].
fn dense_diag_samples(a: &Mat) -> impl Iterator<Item = f64> + '_ {
    let n = a.rows();
    let step = (n / 8).max(1);
    (0..n).step_by(step).take(8).map(move |i| a[(i, i)])
}

/// Forward every trait method through a shared reference, so operator
/// composers ([`algebra`]) can wrap borrowed operators (`ShiftedOp::new(&op, σ)`).
impl<T: SpdOperator + ?Sized> SpdOperator for &T {
    fn n(&self) -> usize {
        (**self).n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        (**self).matvec(x, y)
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        (**self).apply_block(xs, ys)
    }

    fn diag(&self, out: &mut [f64]) {
        (**self).diag(out)
    }

    fn diag_fingerprint(&self) -> Option<u64> {
        (**self).diag_fingerprint()
    }
}

/// Forward through `Arc`, so composed operators can own a share of their
/// base and travel across threads (`SolveService::submit` takes
/// `Arc<dyn SpdOperator + Send + Sync>`; wrapping that Arc in a
/// [`ShiftedOp`] yields another submittable operator).
impl<T: SpdOperator + Send + Sync + ?Sized> SpdOperator for Arc<T> {
    fn n(&self) -> usize {
        (**self).n()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        (**self).matvec(x, y)
    }

    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        (**self).apply_block(xs, ys)
    }

    fn diag(&self, out: &mut [f64]) {
        (**self).diag(out)
    }

    fn diag_fingerprint(&self) -> Option<u64> {
        (**self).diag_fingerprint()
    }
}

/// Probe the diagonal of an abstract operator with n basis applications,
/// batched into [`Mat::BLOCK_PANEL`]-wide [`SpdOperator::apply_block`]
/// panels so operators with a real block kernel pay one data pass per
/// panel rather than per basis vector.
///
/// This is the [`SpdOperator::diag`] default; it is also exposed so that
/// overrides with a partial fast path (e.g. the Newton operator over a
/// matrix-free kernel) can fall back to probing explicitly.
pub fn probe_diag(a: &dyn SpdOperator, out: &mut [f64]) {
    probe_diag_via(a, out)
}

/// The shared column-loop fallback behind the [`SpdOperator::apply_block`]
/// and `gp::laplace::KernelOp::apply_block` defaults: gather each column
/// of `xs`, apply `matvec`, scatter into `ys`. One implementation keeps
/// the column-equivalence contract enforced in exactly one place.
pub(crate) fn apply_block_via(
    n: usize,
    matvec: &mut dyn FnMut(&[f64], &mut [f64]),
    xs: &Mat,
    ys: &mut Mat,
) {
    assert_eq!(xs.rows(), n, "apply_block dim");
    assert_eq!(ys.rows(), n, "apply_block dim");
    assert_eq!(xs.cols(), ys.cols(), "apply_block dim");
    let mut x = vec![0.0; n];
    let mut y = vec![0.0; n];
    for j in 0..xs.cols() {
        for (i, xi) in x.iter_mut().enumerate() {
            *xi = xs[(i, j)];
        }
        matvec(&x, &mut y);
        ys.set_col(j, &y);
    }
}

fn probe_diag_via<A: SpdOperator + ?Sized>(a: &A, out: &mut [f64]) {
    let n = a.n();
    assert_eq!(out.len(), n, "diag dimension mismatch");
    let mut i0 = 0;
    while i0 < n {
        let iw = (n - i0).min(Mat::BLOCK_PANEL);
        let mut e = Mat::zeros(n, iw);
        let mut y = Mat::zeros(n, iw);
        for j in 0..iw {
            e[(i0 + j, j)] = 1.0;
        }
        a.apply_block(&e, &mut y);
        for j in 0..iw {
            out[i0 + j] = y[(i0 + j, j)];
        }
        i0 += iw;
    }
}

/// Dense in-core operator.
pub struct DenseOp<'a> {
    a: &'a Mat,
}

impl<'a> DenseOp<'a> {
    pub fn new(a: &'a Mat) -> Self {
        assert!(a.is_square(), "DenseOp needs a square matrix");
        DenseOp { a }
    }
}

impl<'a> SpdOperator for DenseOp<'a> {
    fn n(&self) -> usize {
        self.a.rows()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        self.a.matvec_into(x, y);
    }

    /// Cache-blocked panel GEMM ([`Mat::block_matvec_into`]): bitwise the
    /// column loop, with each A row streamed once per panel.
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        self.a.block_matvec_into(xs, ys);
    }

    fn diag(&self, out: &mut [f64]) {
        self.a.diag_into(out);
    }

    fn diag_fingerprint(&self) -> Option<u64> {
        Some(fingerprint_f64s(self.a.rows() as u64, dense_diag_samples(self.a)))
    }
}

/// Dense SPD operator with a row-sharded **parallel** matvec.
///
/// `y = A x` is split into contiguous row blocks, one per pool worker,
/// executed on a shared [`ThreadPool`]. Each block computes the same
/// per-row dot products in the same order as [`Mat::matvec_into`], so the
/// result matches the serial [`DenseOp`] bit-for-bit. Systems below
/// [`ParDenseOp::PAR_THRESHOLD`] rows run serially — fork/join overhead
/// dominates the O(n²) work there.
///
/// The pool must not be the pool the *caller's* job is running on: a
/// fixed-size pool whose workers block on joins of jobs queued behind
/// them can deadlock. The coordinator therefore keeps a dedicated compute
/// pool (see `coordinator::service::SolveService::compute_pool`).
pub struct ParDenseOp {
    a: Arc<Mat>,
    pool: Arc<ThreadPool>,
    /// Reusable shared copy of the matvec operand. The sharded path must
    /// hand every worker an owned handle to `x`, but allocating a fresh
    /// `Arc<Vec<f64>>` per call made every matvec pay a heap round-trip
    /// (visible in `bench_linalg`'s ParDenseOp rows). Instead the one
    /// allocation is parked here between calls and recycled whenever it
    /// is no longer shared; concurrent matvecs on the same operator fall
    /// back to a fresh allocation, so results are unaffected.
    scratch: Mutex<Arc<Vec<f64>>>,
}

impl ParDenseOp {
    /// Row count below which the matvec runs serially.
    pub const PAR_THRESHOLD: usize = 256;

    pub fn new(a: Arc<Mat>, pool: Arc<ThreadPool>) -> Self {
        assert!(a.is_square(), "ParDenseOp needs a square matrix");
        ParDenseOp { a, pool, scratch: Mutex::new(Arc::new(Vec::new())) }
    }

    pub fn mat(&self) -> &Mat {
        &self.a
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Copy `x` into the parked scratch allocation (reusing it when no
    /// previous call still holds it) and return a shareable handle.
    fn shared_input(&self, x: &[f64]) -> Arc<Vec<f64>> {
        let mut g = crate::util::sync::lock_unpoisoned(&self.scratch);
        match Arc::get_mut(&mut *g) {
            Some(buf) => {
                buf.clear();
                buf.extend_from_slice(x);
            }
            // A concurrent matvec is still reading the parked buffer:
            // don't block on it, take a fresh allocation.
            None => *g = Arc::new(x.to_vec()),
        }
        g.clone()
    }
}

impl SpdOperator for ParDenseOp {
    fn n(&self) -> usize {
        self.a.rows()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        let n = self.a.rows();
        assert_eq!(x.len(), n, "matvec dim");
        assert_eq!(y.len(), n, "matvec dim");
        let workers = self.pool.n_workers();
        if n < Self::PAR_THRESHOLD || workers < 2 {
            self.a.matvec_into(x, y);
            return;
        }
        let blocks = workers.min(n);
        let bs = n.div_ceil(blocks);
        let xs = self.shared_input(x);
        let handles: Vec<_> = (0..blocks)
            .map(|bi| {
                let a = self.a.clone();
                let xs = xs.clone();
                self.pool.spawn(move || {
                    let lo = (bi * bs).min(n);
                    let hi = ((bi + 1) * bs).min(n);
                    let mut out = vec![0.0; hi - lo];
                    for (o, i) in out.iter_mut().zip(lo..hi) {
                        *o = crate::linalg::vec_ops::dot(a.row(i), &xs);
                    }
                    out
                })
            })
            .collect();
        for (bi, h) in handles.into_iter().enumerate() {
            let lo = (bi * bs).min(n);
            let block = h.join();
            y[lo..lo + block.len()].copy_from_slice(&block);
        }
    }

    /// Row-sharded block kernel: the operand columns are gathered once
    /// into contiguous buffers shared by all shards, then each worker runs
    /// the same panel-dot kernel as [`Mat::block_matvec_into`] over its
    /// row range — one fork/join for the whole block instead of one per
    /// column, each A row read once per panel, and every output element
    /// the identical `dot(row, column)` of the serial column loop.
    fn apply_block(&self, xs: &Mat, ys: &mut Mat) {
        let n = self.a.rows();
        assert_eq!(xs.rows(), n, "apply_block dim");
        assert_eq!(ys.rows(), n, "apply_block dim");
        assert_eq!(xs.cols(), ys.cols(), "apply_block dim");
        let k = xs.cols();
        let workers = self.pool.n_workers();
        if k == 0 {
            return;
        }
        if n < Self::PAR_THRESHOLD || workers < 2 {
            self.a.block_matvec_into(xs, ys);
            return;
        }
        let cols: Arc<Vec<Vec<f64>>> = Arc::new((0..k).map(|j| xs.col(j)).collect());
        let blocks = workers.min(n);
        let bs = n.div_ceil(blocks);
        let handles: Vec<_> = (0..blocks)
            .map(|bi| {
                let a = self.a.clone();
                let cols = cols.clone();
                self.pool.spawn(move || {
                    let lo = (bi * bs).min(n);
                    let hi = ((bi + 1) * bs).min(n);
                    let mut out = Mat::zeros(hi - lo, k);
                    // The same panel-dot loop nest as the serial kernel —
                    // shared, so the bitwise contract lives in one place.
                    a.block_matvec_rows(lo, hi, &cols, &mut out);
                    out
                })
            })
            .collect();
        for (bi, h) in handles.into_iter().enumerate() {
            let lo = (bi * bs).min(n);
            let block = h.join();
            for r in 0..block.rows() {
                ys.row_mut(lo + r).copy_from_slice(block.row(r));
            }
        }
    }

    fn diag(&self, out: &mut [f64]) {
        self.a.diag_into(out);
    }

    fn diag_fingerprint(&self) -> Option<u64> {
        Some(fingerprint_f64s(self.a.rows() as u64, dense_diag_samples(&self.a)))
    }
}

/// Why a solve stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// Relative residual dropped below tolerance.
    Converged,
    /// Iteration cap hit.
    MaxIters,
    /// Numerical breakdown (e.g. pᵀAp ≤ 0, which for true SPD A signals
    /// accumulated round-off).
    Breakdown,
    /// Residual stopped improving (hit a numerical floor — e.g. the f32
    /// precision of the XLA artifact path, or an inexact deflation basis).
    Stagnated,
    /// The request's [`CancelToken`] was raised; the result carries the
    /// partial iterate at the moment the per-iteration check fired. A
    /// cancelled run's stored directions are **not** absorbed into a
    /// sequence's recycle basis (the caller abandoned the work).
    Cancelled,
    /// The request's wall-clock deadline passed mid-solve; the result
    /// carries the partial iterate. Unlike [`StopReason::Cancelled`],
    /// the partial Krylov work is still wanted: stored directions feed
    /// the recycle basis exactly like a converged run's.
    DeadlineExceeded,
    /// The solve did not produce a result at all (a worker panicked —
    /// e.g. an operator hit an internal assert). The synthetic result
    /// carries the start iterate and an infinite residual; nothing is
    /// absorbed into recycling state.
    Failed,
}

/// Quantities stored from the first ℓ iterations of a (deflated) CG run,
/// exactly the inputs the harmonic-Ritz extraction needs (paper §2.3).
/// Directions are stored **normalized** (‖p‖ = 1) with the matching scaling
/// applied to A·p, which keeps the Gram matrices F, G well-scaled.
#[derive(Clone, Debug, Default)]
pub struct StoredDirections {
    /// Normalized search directions, one column per stored iteration.
    pub p: Vec<Vec<f64>>,
    /// A times the stored (normalized) directions.
    pub ap: Vec<Vec<f64>>,
}

impl StoredDirections {
    pub fn len(&self) -> usize {
        self.p.len()
    }

    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    /// Logical f64 payload in bytes (both panels, `len × 8` per column) —
    /// the quantity `RecycleBudget::max_stored_bytes` caps.
    pub fn bytes(&self) -> usize {
        let p: usize = self.p.iter().map(|c| 8 * c.len()).sum();
        let ap: usize = self.ap.iter().map(|c| 8 * c.len()).sum();
        p + ap
    }

    /// Stack stored directions as matrix columns: returns (P, AP).
    pub fn as_mats(&self, n: usize) -> (Mat, Mat) {
        let l = self.p.len();
        let mut p = Mat::zeros(n, l);
        let mut ap = Mat::zeros(n, l);
        for j in 0..l {
            p.set_col(j, &self.p[j]);
            ap.set_col(j, &self.ap[j]);
        }
        (p, ap)
    }
}

/// Result of one linear solve.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub x: Vec<f64>,
    /// ‖r_j‖ / ‖b‖ after each iteration, starting with iteration 0's
    /// initial residual (so `residuals.len() == iterations + 1`).
    pub residuals: Vec<f64>,
    pub iterations: usize,
    pub matvecs: usize,
    pub stop: StopReason,
    /// Stored direction/Ap pairs for recycling (empty if ℓ = 0).
    pub stored: StoredDirections,
    /// Wall-clock seconds spent inside the solver.
    pub seconds: f64,
}

impl SolveResult {
    /// Final relative residual.
    pub fn final_residual(&self) -> f64 {
        *self.residuals.last().unwrap_or(&f64::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn dense_op_matches_mat() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(10, 100.0, &mut rng);
        let op = DenseOp::new(&a);
        assert_eq!(op.n(), 10);
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(op.matvec_alloc(&x), a.matvec(&x));
    }

    #[test]
    fn par_dense_op_matches_serial_bitwise() {
        let mut rng = Rng::new(2);
        // 300 > PAR_THRESHOLD forces the sharded path; 300 does not divide
        // evenly by 4 workers, exercising the ragged last block.
        let a = Arc::new(Mat::rand_spd(300, 1e4, &mut rng));
        let pool = Arc::new(ThreadPool::new(4));
        let par = ParDenseOp::new(a.clone(), pool);
        let serial = DenseOp::new(&a);
        let x: Vec<f64> = (0..300).map(|i| ((i * 13) % 17) as f64 - 8.0).collect();
        let mut yp = vec![0.0; 300];
        let mut ys = vec![0.0; 300];
        par.matvec(&x, &mut yp);
        serial.matvec(&x, &mut ys);
        assert_eq!(yp, ys, "sharded matvec must match the serial row order");
    }

    #[test]
    fn par_dense_op_small_systems_run_serially() {
        let mut rng = Rng::new(3);
        let a = Arc::new(Mat::rand_spd(10, 100.0, &mut rng));
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(4)));
        assert_eq!(par.n(), 10);
        let x = vec![1.0; 10];
        assert_eq!(par.matvec_alloc(&x), a.matvec(&x));
    }

    #[test]
    fn par_dense_op_solves_under_cg() {
        let mut rng = Rng::new(4);
        let n = 320;
        let a = Arc::new(Mat::rand_spd(n, 1e3, &mut rng));
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(3)));
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 5) as f64).collect();
        let cfg = crate::solvers::cg::CgConfig::with_tol(1e-10);
        let r = crate::solvers::cg::solve(&par, &b, None, &cfg);
        assert_eq!(r.stop, StopReason::Converged);
        let ax = a.matvec(&r.x);
        let num: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v) * (u - v)).sum();
        assert!(num.sqrt() / crate::linalg::vec_ops::norm2(&b) < 1e-9);
    }

    #[test]
    fn par_dense_op_scratch_reuse_keeps_results_correct() {
        // Consecutive sharded matvecs with different operands reuse the
        // parked input buffer; each result must still match serial.
        let mut rng = Rng::new(10);
        let n = 300;
        let a = Arc::new(Mat::rand_spd(n, 1e3, &mut rng));
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(4)));
        for pass in 0..3u64 {
            let x: Vec<f64> = (0..n)
                .map(|i| ((i as u64 * 7 + pass * 13) % 19) as f64 - 9.0)
                .collect();
            let mut yp = vec![0.0; n];
            par.matvec(&x, &mut yp);
            assert_eq!(yp, a.matvec(&x), "pass {pass}");
        }
    }

    #[test]
    fn diag_default_probes_and_overrides_are_exact() {
        // An operator without an override probes with basis matvecs; the
        // dense operators read a[(i,i)] directly. Both must agree.
        struct Plain<'a>(&'a Mat);
        impl<'a> SpdOperator for Plain<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(11);
        let a = Mat::rand_spd(20, 100.0, &mut rng);
        let want: Vec<f64> = (0..20).map(|i| a[(i, i)]).collect();
        let mut probed = vec![0.0; 20];
        Plain(&a).diag(&mut probed);
        assert_eq!(probed, want, "probing default must recover the diagonal");
        let mut exact = vec![0.0; 20];
        DenseOp::new(&a).diag(&mut exact);
        assert_eq!(exact, want);
        let mut par = vec![0.0; 20];
        ParDenseOp::new(Arc::new(a.clone()), Arc::new(ThreadPool::new(2))).diag(&mut par);
        assert_eq!(par, want);
        // The free-function probe matches the trait default.
        let mut free = vec![0.0; 20];
        probe_diag(&Plain(&a), &mut free);
        assert_eq!(free, want);
    }

    #[test]
    fn apply_block_matches_matvec_loop_bitwise() {
        // The block-first contract on all three in-module paths: the trait
        // default (column loop), the DenseOp panel kernel, and the
        // ParDenseOp sharded kernel must agree bitwise with per-column
        // matvecs, including ragged panel widths and k = 1.
        struct Plain<'a>(&'a Mat);
        impl<'a> SpdOperator for Plain<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
        }
        let mut rng = Rng::new(20);
        let n = 300; // above PAR_THRESHOLD: the sharded path runs for real
        let a = Arc::new(Mat::rand_spd(n, 1e4, &mut rng));
        let plain = Plain(&a);
        let dense = DenseOp::new(&a);
        let par = ParDenseOp::new(a.clone(), Arc::new(ThreadPool::new(4)));
        for k in [1usize, 3, Mat::BLOCK_PANEL + 1] {
            let xs = Mat::randn(n, k, &mut rng);
            let mut want = Mat::zeros(n, k);
            for j in 0..k {
                want.set_col(j, &a.matvec(&xs.col(j)));
            }
            for (name, op) in [
                ("default", &plain as &dyn SpdOperator),
                ("dense", &dense),
                ("par", &par),
            ] {
                let mut ys = Mat::zeros(n, k);
                op.apply_block(&xs, &mut ys);
                assert_eq!(ys, want, "{name} apply_block k={k}");
            }
        }
    }

    #[test]
    fn blanket_impls_forward() {
        let mut rng = Rng::new(21);
        let a = Mat::rand_spd(12, 100.0, &mut rng);
        let op = DenseOp::new(&a);
        let by_ref: &DenseOp<'_> = &op;
        assert_eq!(by_ref.n(), 12);
        let x = vec![1.0; 12];
        assert_eq!(by_ref.matvec_alloc(&x), op.matvec_alloc(&x));
        let arc: Arc<dyn SpdOperator + Send + Sync> =
            Arc::new(ParDenseOp::new(Arc::new(a.clone()), Arc::new(ThreadPool::new(2))));
        assert_eq!(arc.matvec_alloc(&x), op.matvec_alloc(&x));
        let mut d1 = vec![0.0; 12];
        let mut d2 = vec![0.0; 12];
        arc.diag(&mut d1);
        op.diag(&mut d2);
        assert_eq!(d1, d2);
    }

    #[test]
    fn stored_directions_stack() {
        let mut sd = StoredDirections::default();
        sd.p.push(vec![1.0, 0.0]);
        sd.ap.push(vec![2.0, 0.0]);
        sd.p.push(vec![0.0, 1.0]);
        sd.ap.push(vec![0.0, 3.0]);
        let (p, ap) = sd.as_mats(2);
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(1, 1)], 1.0);
        assert_eq!(ap[(0, 0)], 2.0);
        assert_eq!(ap[(1, 1)], 3.0);
        assert_eq!(sd.len(), 2);
        assert!(!sd.is_empty());
    }
}
