//! Preconditioned conjugate gradients.
//!
//! The classic *non-singular* preconditioning the paper contrasts
//! deflation against (§2.1: a preconditioner reshapes the whole spectrum,
//! the deflation projector removes part of it and leaves the rest
//! untouched). Included as an ablation baseline: for the GPC systems
//! `A = I + SKS` the diagonal is nearly constant, so Jacobi helps little —
//! which is exactly why the paper reaches for deflation instead.
//!
//! [`solve_with`] is the general kernel over any
//! [`Preconditioner`]; the legacy [`solve`] signature (explicit Jacobi
//! diagonal) remains as a thin shim. Like plain CG, the kernel stores the
//! first ℓ normalized `(p, A·p)` pairs when `cfg.store_l > 0`, so PCG runs
//! can seed harmonic-Ritz recycling too.

use crate::linalg::vec_ops::{axpy, dot, norm2};
use crate::solvers::api::{Jacobi, Preconditioner};
use crate::solvers::cg::CgConfig;
use crate::solvers::{SolveResult, SpdOperator, StopReason, StoredDirections};
use std::time::Instant;

/// Solve `A x = b` with Jacobi (diagonal) preconditioning. `diag` is the
/// diagonal of A (must be strictly positive).
///
/// Thin shim over [`solve_with`] — prefer building a [`SolveSpec`]
/// (`SolveSpec::pcg().with_jacobi(..)`) and calling
/// [`crate::solvers::solve`] in new code.
///
/// [`SolveSpec`]: crate::solvers::SolveSpec
pub fn solve(
    a: &dyn SpdOperator,
    b: &[f64],
    diag: &[f64],
    x0: Option<&[f64]>,
    cfg: &CgConfig,
) -> SolveResult {
    assert_eq!(diag.len(), a.n());
    solve_with(a, b, &Jacobi::new(diag), x0, cfg)
}

/// Solve `A x = b` with the preconditioner `m` (`z = M⁻¹ r` once per
/// iteration). Convergence is still judged on the *unpreconditioned*
/// relative residual ‖r‖/‖b‖.
pub fn solve_with(
    a: &dyn SpdOperator,
    b: &[f64],
    m: &dyn Preconditioner,
    x0: Option<&[f64]>,
    cfg: &CgConfig,
) -> SolveResult {
    let start = Instant::now();
    let n = a.n();
    assert_eq!(b.len(), n);

    let mut x = match x0 {
        Some(x0) => x0.to_vec(),
        None => vec![0.0; n],
    };
    let mut matvecs = 0usize;
    let mut r = b.to_vec();

    // Entry check, mirroring `cg::solve`: a dead request pays nothing,
    // not even the warm-start residual application.
    if let Some(reason) = cfg.control.check() {
        let bn = norm2(b);
        let denom = if bn > 0.0 { bn } else { 1.0 };
        return SolveResult {
            x,
            residuals: vec![norm2(&r) / denom],
            iterations: 0,
            matvecs,
            stop: reason,
            stored: StoredDirections::default(),
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    if x0.is_some() {
        let ax = a.matvec_alloc(&x);
        matvecs += 1;
        for i in 0..n {
            r[i] -= ax[i];
        }
    }
    let bnorm = norm2(b);
    let denom = if bnorm > 0.0 { bnorm } else { 1.0 };
    let mut residuals = vec![norm2(&r) / denom];
    let mut stored = StoredDirections::default();
    if residuals[0] <= cfg.tol {
        return SolveResult {
            x,
            residuals,
            iterations: 0,
            matvecs,
            stop: StopReason::Converged,
            stored,
            seconds: start.elapsed().as_secs_f64(),
        };
    }

    // z = M⁻¹ r; p = z.
    let mut z = vec![0.0; n];
    m.apply(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];
    let max_iters = cfg.effective_max_iters(n);
    let mut stop = StopReason::MaxIters;
    let mut iterations = 0;

    for _ in 0..max_iters {
        // Cooperative cancel/deadline check, before the matvec (see
        // `cg::solve` — identical placement in every kernel).
        if let Some(reason) = cfg.control.check() {
            stop = reason;
            break;
        }
        a.matvec(&p, &mut ap);
        matvecs += 1;
        let d = dot(&p, &ap);
        if d <= 0.0 || !d.is_finite() {
            stop = StopReason::Breakdown;
            break;
        }
        if stored.len() < cfg.store_l {
            // Store normalized direction and matching A·p scaling, exactly
            // like plain CG — the raw material for harmonic-Ritz recycling.
            let pn = norm2(&p);
            if pn > 0.0 {
                let inv = 1.0 / pn;
                stored.p.push(p.iter().map(|v| v * inv).collect());
                stored.ap.push(ap.iter().map(|v| v * inv).collect());
            }
        }
        let alpha = rz / d;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        iterations += 1;
        let rel = norm2(&r) / denom;
        residuals.push(rel);
        if rel <= cfg.tol {
            stop = StopReason::Converged;
            break;
        }
        if cfg.stagnated(&residuals) {
            stop = StopReason::Stagnated;
            break;
        }
        m.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }

    SolveResult {
        x,
        residuals,
        iterations,
        matvecs,
        stop,
        stored,
        seconds: start.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::{cg, DenseOp};
    use crate::util::rng::Rng;

    #[test]
    fn pcg_solves_spd() {
        let mut rng = Rng::new(1);
        let a = Mat::rand_spd(40, 1e4, &mut rng);
        let diag: Vec<f64> = (0..40).map(|i| a[(i, i)]).collect();
        let x_true: Vec<f64> = (0..40).map(|i| (i as f64).sin()).collect();
        let b = a.matvec(&x_true);
        let r = solve(&DenseOp::new(&a), &b, &diag, None, &CgConfig::with_tol(1e-10));
        assert_eq!(r.stop, StopReason::Converged);
        for (u, v) in r.x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn jacobi_helps_on_badly_scaled_diagonal() {
        // D-scaled SPD matrix: Jacobi should beat plain CG clearly.
        let mut rng = Rng::new(2);
        let n = 60;
        let base = Mat::rand_spd(n, 10.0, &mut rng);
        let scales: Vec<f64> = (0..n).map(|i| 10f64.powf((i % 5) as f64)).collect();
        let a = Mat::from_fn(n, n, |i, j| {
            base[(i, j)] * scales[i].sqrt() * scales[j].sqrt()
        });
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let b = vec![1.0; n];
        let cfg = CgConfig::with_tol(1e-8);
        let plain = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let pre = solve(&DenseOp::new(&a), &b, &diag, None, &cfg);
        assert_eq!(pre.stop, StopReason::Converged);
        assert!(
            pre.iterations < plain.iterations,
            "jacobi {} >= plain {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn jacobi_on_unit_diagonal_matches_cg() {
        // diag ≈ const: preconditioning is a no-op up to scaling — same
        // iteration count as CG (the paper's point about GPC systems).
        let mut rng = Rng::new(3);
        let a = Mat::rand_spd(50, 1e3, &mut rng);
        let diag = vec![1.0; 50]; // identity preconditioner
        let b: Vec<f64> = (0..50).map(|i| (i % 3) as f64 + 1.0).collect();
        let cfg = CgConfig::with_tol(1e-9);
        let plain = cg::solve(&DenseOp::new(&a), &b, None, &cfg);
        let pre = solve(&DenseOp::new(&a), &b, &diag, None, &cfg);
        assert_eq!(plain.iterations, pre.iterations);
    }

    #[test]
    fn stores_directions_for_recycling() {
        // Regression: PCG used to return StoredDirections::default() even
        // with store_l > 0, so preconditioned runs could never seed
        // harmonic-Ritz recycling.
        let mut rng = Rng::new(4);
        let n = 30;
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let b: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
        let cfg = CgConfig { tol: 1e-10, max_iters: 0, store_l: 6, ..Default::default() };
        let r = solve(&DenseOp::new(&a), &b, &diag, None, &cfg);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(r.stored.len(), 6.min(r.iterations));
        assert!(!r.stored.is_empty(), "PCG must store (p, Ap) pairs");
        for (p, ap) in r.stored.p.iter().zip(&r.stored.ap) {
            assert!((norm2(p) - 1.0).abs() < 1e-12, "directions are normalized");
            let want = a.matvec(p);
            for (u, v) in ap.iter().zip(&want) {
                assert!((u - v).abs() < 1e-10, "ap must equal A·p");
            }
        }
    }

    #[test]
    fn stored_pcg_directions_seed_ritz_extraction() {
        // End-to-end: a PCG run's stored pairs produce a usable deflation
        // basis that speeds up the next (identical) system.
        use crate::solvers::ritz::{extract, RitzConfig, RitzSelect};
        let mut rng = Rng::new(5);
        let n = 90;
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let b = vec![1.0; n];
        let cfg = CgConfig { tol: 1e-8, max_iters: 0, store_l: 12, ..Default::default() };
        let first = solve(&DenseOp::new(&a), &b, &diag, None, &cfg);
        let (defl, _) = extract(
            None,
            &first.stored,
            n,
            &RitzConfig { k: 8, select: RitzSelect::Largest, min_col_norm: 1e-12 },
        )
        .expect("PCG-stored directions must be extractable");
        let plain = cg::solve(&DenseOp::new(&a), &b, None, &CgConfig::with_tol(1e-8));
        let deflated = crate::solvers::defcg::solve(
            &DenseOp::new(&a),
            &b,
            None,
            Some(&defl),
            &CgConfig::with_tol(1e-8),
        );
        assert!(
            deflated.iterations < plain.iterations,
            "deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
    }

    #[test]
    #[should_panic(expected = "positive diagonal")]
    fn rejects_nonpositive_diag() {
        let a = Mat::identity(3);
        let _ = solve(
            &DenseOp::new(&a),
            &[1.0; 3],
            &[1.0, 0.0, 1.0],
            None,
            &CgConfig::default(),
        );
    }
}
