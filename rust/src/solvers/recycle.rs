//! The recycle manager: subspace transfer across a sequence of systems.
//!
//! This is the "computational transfer learning" loop of the paper's §1:
//! solve system `i`, extract harmonic Ritz vectors from the stored CG
//! directions, and deflate system `i+1` with them. The manager owns the
//! `(W, AW)` state, the def-CG(k, ℓ) hyperparameters, and the policy
//! decisions the paper discusses in §3:
//!
//! * whether to refresh `AW` under the new operator (k extra matvecs,
//!   exact deflation) or reuse the stale image (free, the paper's choice —
//!   valid because consecutive Newton systems differ little);
//! * whether to re-orthonormalize `W` when it degenerates (the stability
//!   issue the paper blames for late-sequence stagnation).
//!
//! Both the single-RHS methods and multi-RHS **block solves** ride the
//! same basis: `BlockCg` requests run deflated block CG against `(W, AW)`
//! and their stored block direction panels feed the next harmonic-Ritz
//! extraction, so coalesced multi-RHS traffic (the coordinator's
//! `submit_block` path) decays in iterations across a sequence exactly
//! like the single-RHS path (deflated block methods as the standard
//! composition — Soodhalter, de Sturler & Kilmer 2020 §10).

use crate::linalg::qr::mgs_orthonormalize;
use crate::solvers::api::{self, Jacobi, Method, Preconditioner, SolveSpec};
use crate::solvers::blockcg::BlockSolveResult;
use crate::solvers::defcg::Deflation;
use crate::solvers::ritz::{self, ExtractFailure, RitzConfig, RitzValue};
use crate::solvers::strategy::{self, EvalContext, StrategyChoice, StrategyDecision};
use crate::solvers::{SolveResult, SpdOperator, StopReason, StoredDirections};
use crate::util::precision::to_f64;
use std::sync::Arc;

/// Policy for keeping `AW` consistent across systems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AwPolicy {
    /// Reuse `A⁽ⁱ⁾W` as the image under `A⁽ⁱ⁺¹⁾`: zero matvecs, but the
    /// deflation projector becomes inexact (error ∝ ‖A⁽ⁱ⁺¹⁾−A⁽ⁱ⁾‖) and the
    /// solve can stall near tight tolerances — the instability the paper's
    /// §3 discussion attributes stagnation to.
    Reuse,
    /// Recompute `AW` exactly with k matvecs per new system. This is what
    /// the paper's overhead estimate accounts for ("W and AW are obtained
    /// in O(n²(ℓ+1)k)"); required when solving below the drift level.
    Refresh,
    /// Reuse when the requested tolerance is loose (≥ 1e-6 — staleness can
    /// stay below the target if the sequence drifts slowly), refresh when
    /// the solve needs to go below the staleness floor. Cheaper than
    /// Refresh but relies on def-CG's shift safeguard when the sequence
    /// drifts fast (early Newton steps).
    Auto,
}

/// Per-sequence memory budget for the recycling state.
///
/// The paper's whole pitch is trading a *small, fixed* amount of state for
/// iteration savings; this struct makes "small, fixed" enforceable. All
/// byte caps count **logical** f64 payload (`len × 8`, not allocator
/// capacities), the same formula [`RecycleManager::bytes_held`] audits.
/// `usize::MAX` means unbounded (the default for the byte caps).
///
/// Enforcement (see DESIGN.md "Memory model & budgets"):
/// * `max_basis_bytes` caps the recycled `(W, AW)` pair. Bases over the
///   cap are truncated to the best-payoff columns — the ones with the
///   smallest relative eigenresidual `‖AW·e_j − θ_j W·e_j‖ / (1 + |θ_j|)`
///   (residual-optimal truncation in the spirit of Neuenhofen & Groß,
///   *Memory-efficient recycling of large Krylov subspaces*).
/// * `max_stored_bytes` caps the stored direction panel: `store_l` is
///   clamped at request-resolution time so panels never grow past the
///   cap, and a panel handed in over the cap (external seeding, a budget
///   tightened mid-sequence) is compressed to its dominant A-weighted
///   modes before extraction (POD-style panel compression à la Carlberg
///   et al., but weighted by the Rayleigh quotient `PᵀAP` the harmonic
///   extraction already computes — zero extra matvecs).
/// * `max_history` caps the per-sequence [`SystemStats`] ring buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecycleBudget {
    /// Cap on the `(W, AW)` basis payload in bytes (`2 · k · n · 8`).
    pub max_basis_bytes: usize,
    /// Cap on the stored `(P, AP)` panel payload in bytes (`2 · ℓ · n · 8`).
    pub max_stored_bytes: usize,
    /// Cap on the number of retained [`SystemStats`] entries.
    pub max_history: usize,
}

impl Default for RecycleBudget {
    fn default() -> Self {
        RecycleBudget {
            max_basis_bytes: usize::MAX,
            max_stored_bytes: usize::MAX,
            // Generous, but bounded: an unbounded history Vec is exactly
            // the leak this budget exists to close (each entry clones its
            // ritz_values) — long-lived service sequences solve millions
            // of systems.
            max_history: 1024,
        }
    }
}

impl RecycleBudget {
    /// Fully unbounded (even the history ring).
    pub fn unbounded() -> Self {
        RecycleBudget {
            max_basis_bytes: usize::MAX,
            max_stored_bytes: usize::MAX,
            max_history: usize::MAX,
        }
    }

    /// Budget sized to hold `basis_cols` basis column pairs and
    /// `stored_cols` panel column pairs at dimension `n` (each pair costs
    /// `2 · n · 8` bytes: one `W`/`P` column plus its `A·` image).
    pub fn capping_cols(n: usize, basis_cols: usize, stored_cols: usize) -> Self {
        RecycleBudget {
            max_basis_bytes: 2 * 8 * n * basis_cols,
            max_stored_bytes: 2 * 8 * n * stored_cols,
            ..Default::default()
        }
    }

    pub fn with_max_basis_bytes(mut self, bytes: usize) -> Self {
        self.max_basis_bytes = bytes;
        self
    }

    pub fn with_max_stored_bytes(mut self, bytes: usize) -> Self {
        self.max_stored_bytes = bytes;
        self
    }

    pub fn with_max_history(mut self, entries: usize) -> Self {
        self.max_history = entries;
        self
    }

    /// How many basis column pairs fit under `max_basis_bytes` at
    /// dimension `n`.
    pub fn basis_cols(&self, n: usize) -> usize {
        if self.max_basis_bytes == usize::MAX {
            usize::MAX
        } else {
            self.max_basis_bytes / (2 * 8 * n.max(1))
        }
    }

    /// How many stored panel column pairs fit under `max_stored_bytes` at
    /// dimension `n`.
    pub fn stored_cols(&self, n: usize) -> usize {
        if self.max_stored_bytes == usize::MAX {
            usize::MAX
        } else {
            self.max_stored_bytes / (2 * 8 * n.max(1))
        }
    }
}

/// def-CG(k, ℓ) hyperparameters plus policies.
#[derive(Clone, Debug)]
pub struct RecycleConfig {
    /// Recycled subspace dimension (paper's k, Table 1 uses 8).
    pub k: usize,
    /// CG iterations whose directions are stored (paper's ℓ, Table 1: 12).
    pub l: usize,
    /// Recycle-space selection strategy: which spectral end extraction
    /// ranks for and how many candidates are retained (see
    /// [`crate::solvers::strategy`]). A per-request
    /// [`SolveSpec::with_strategy`] override takes precedence. The
    /// default, [`StrategyChoice::HarmonicLargest`], is today's
    /// harmonic-Ritz-largest behavior, bitwise-pinned.
    pub strategy: StrategyChoice,
    pub aw_policy: AwPolicy,
    /// Re-orthonormalize W (and refresh AW) when its condition degrades.
    pub stabilize: bool,
    /// Per-sequence memory budget; a per-request
    /// [`SolveSpec::with_budget`] override takes precedence.
    pub budget: RecycleBudget,
}

impl Default for RecycleConfig {
    fn default() -> Self {
        RecycleConfig {
            k: 8,
            l: 12,
            strategy: StrategyChoice::HarmonicLargest,
            // Refresh: exact deflation never harms convergence; its k
            // matvecs/system are what the paper's own overhead estimate
            // budgets for ("W and AW are obtained in O(n²(ℓ+1)k)").
            aw_policy: AwPolicy::Refresh,
            stabilize: false,
            budget: RecycleBudget::default(),
        }
    }
}

/// What the budget enforcement did during the most recent
/// [`RecycleManager::solve_next`] / [`RecycleManager::solve_block`] —
/// surfaced by the coordinator in `SolveReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AbsorbStats {
    /// Basis columns dropped by residual-optimal truncation.
    pub truncated_cols: usize,
    /// Panel columns removed by A-weighted compression before extraction.
    pub compressed_cols: usize,
    /// This run started with a freshly evicted (empty) basis — it ran
    /// degraded (plain CG) and its panel re-warms the basis.
    pub post_eviction: bool,
    /// The harmonic-Ritz extraction failed numerically this run (the
    /// panel was dropped; the previous basis is kept). Counted by
    /// [`RecycleManager::extraction_failures`].
    pub extraction_failed: bool,
}

/// [`RecycleManager::seed`] rejected an external basis whose shape does
/// not fit the operator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SeedError {
    /// Operator dimension the basis must match.
    pub expected_rows: usize,
    /// Row count of the rejected `W`.
    pub got_rows: usize,
}

impl std::fmt::Display for SeedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "seed basis has {} rows but the operator dimension is {}",
            self.got_rows, self.expected_rows
        )
    }
}

impl std::error::Error for SeedError {}

/// Statistics for one solved system in the sequence.
#[derive(Clone, Debug)]
pub struct SystemStats {
    pub index: usize,
    pub iterations: usize,
    pub matvecs: usize,
    pub final_residual: f64,
    pub deflation_dim: usize,
    pub ritz_values: Vec<f64>,
    pub seconds: f64,
}

/// Carries the recycled subspace along a sequence of SPD systems.
pub struct RecycleManager {
    cfg: RecycleConfig,
    defl: Option<Deflation>,
    history: Vec<SystemStats>,
    /// Per-sequence Jacobi, built lazily for the first
    /// [`SolveSpec::with_auto_jacobi`] request and reused by every later
    /// one — the diagonal is derived **once per sequence**, not once per
    /// request. Consecutive systems in a sequence differ little (the
    /// paper's premise), and a Jacobi from a nearby operator is still a
    /// fixed SPD preconditioner, so correctness is untouched; only the
    /// (marginal) preconditioning quality can drift.
    ///
    /// Staleness is keyed on dimension **and** on the operator's
    /// [`SpdOperator::diag_fingerprint`]: a mixed sequence over, say,
    /// `ShiftedOp(K, σ²)` views of one Gram matrix carries σ in the
    /// fingerprint, so hopping to a different σ-grid point rebuilds the
    /// Jacobi instead of silently reusing a diagonal that is wrong by
    /// σ² − σ'². Operators without a fingerprint (`None`) keep the
    /// dimension-only reuse of the drifting-sequence premise.
    /// [`RecycleManager::reset`] drops the cache with the rest of the
    /// sequence state.
    jacobi: Option<(Arc<Jacobi>, Option<u64>)>,
    /// Total systems recorded (monotone; history ring eviction does not
    /// rewind it — `SystemStats::index` keeps numbering from it).
    solved: usize,
    /// Budget-enforcement events (basis truncations + panel compressions),
    /// monotone over the manager's lifetime.
    truncations: u64,
    /// Set by [`RecycleManager::evict_basis`], consumed by the first
    /// completed solve after the eviction (surfaced in [`AbsorbStats`]).
    evicted: bool,
    /// What budget enforcement did during the most recent run.
    last_absorb: AbsorbStats,
    /// Numerical harmonic-Ritz extraction failures (dropped panels),
    /// monotone over the manager's lifetime.
    extraction_failures: u64,
    /// Absorbs where the strategy retained fewer candidates than offered
    /// (k chosen < k offered), monotone.
    strategy_shrinks: u64,
    /// Cumulative positive predicted iteration savings across absorbs.
    predicted_savings_total: f64,
    /// The sizing decision from the most recent absorb (default before
    /// the first extraction and after a cancelled run).
    last_decision: StrategyDecision,
}

impl RecycleManager {
    pub fn new(cfg: RecycleConfig) -> Self {
        RecycleManager {
            cfg,
            defl: None,
            history: Vec::new(),
            jacobi: None,
            solved: 0,
            truncations: 0,
            evicted: false,
            last_absorb: AbsorbStats::default(),
            extraction_failures: 0,
            strategy_shrinks: 0,
            predicted_savings_total: 0.0,
            last_decision: StrategyDecision::default(),
        }
    }

    pub fn config(&self) -> &RecycleConfig {
        &self.cfg
    }

    /// Current recycled basis dimension (0 before the first extraction).
    pub fn k_active(&self) -> usize {
        self.defl.as_ref().map(|d| d.k()).unwrap_or(0)
    }

    /// Current deflation state (for inspection / spectrum plots).
    pub fn deflation(&self) -> Option<&Deflation> {
        self.defl.as_ref()
    }

    /// Per-system statistics collected so far (at most
    /// [`RecycleBudget::max_history`] retained — older entries are
    /// evicted from the front; [`SystemStats::index`] keeps the original
    /// sequence numbering).
    pub fn history(&self) -> &[SystemStats] {
        &self.history
    }

    /// Bytes of per-sequence state this manager holds, by the audited
    /// formula (logical lengths, not allocator capacities):
    ///
    /// * basis: `2 · k · n · 8` (`W` plus `AW`),
    /// * cached Jacobi: `n · 8`,
    /// * history: `len · size_of::<SystemStats>()` plus each entry's
    ///   `ritz_values.len() · 8` heap payload.
    ///
    /// The service-wide `ByteAccountant` sums this across sequences and
    /// tests cross-check it against the live buffer lengths.
    pub fn bytes_held(&self) -> usize {
        let basis = self
            .defl
            .as_ref()
            .map(|d| 2 * 8 * d.w.rows() * d.k())
            .unwrap_or(0);
        let jacobi = self.jacobi.as_ref().map(|(j, _)| 8 * j.n()).unwrap_or(0);
        let history: usize = self
            .history
            .iter()
            .map(|s| std::mem::size_of::<SystemStats>() + 8 * s.ritz_values.len())
            .sum();
        basis + jacobi + history
    }

    /// Whether a recycled basis is currently resident (k > 0). The
    /// scheduler's steal policy reads this through the sequence core's
    /// advisory hint: a basis-free sequence loses nothing by running on
    /// a non-home worker, so it is the preferred steal victim.
    pub fn basis_resident(&self) -> bool {
        self.k_active() > 0
    }

    /// Bytes of the resident `(W, AW)` basis alone — the
    /// cache-locality term the scheduler's steal-cost hint tracks
    /// (history and cached Jacobi are excluded: they are not touched on
    /// the solve hot path). 0 when no basis is resident.
    pub fn basis_bytes(&self) -> usize {
        self.defl
            .as_ref()
            .map(|d| 2 * 8 * d.w.rows() * d.k())
            .unwrap_or(0)
    }

    /// Budget-enforcement events (basis truncations plus panel
    /// compressions) over the manager's lifetime.
    pub fn truncations(&self) -> u64 {
        self.truncations
    }

    /// What budget enforcement did during the most recent completed run.
    pub fn last_absorb(&self) -> AbsorbStats {
        self.last_absorb
    }

    /// Numerical harmonic-Ritz extraction failures (dropped panels) over
    /// the manager's lifetime. Benign-empty extractions (no stored
    /// directions, k = 0) are not failures and are not counted.
    pub fn extraction_failures(&self) -> u64 {
        self.extraction_failures
    }

    /// Absorbs where the strategy retained fewer candidates than the
    /// extraction offered, over the manager's lifetime.
    pub fn strategy_shrinks(&self) -> u64 {
        self.strategy_shrinks
    }

    /// Cumulative positive predicted iteration savings claimed by the
    /// strategy's retained bases, over the manager's lifetime.
    pub fn predicted_savings_total(&self) -> f64 {
        self.predicted_savings_total
    }

    /// The strategy's sizing decision from the most recent absorb —
    /// which rule ran, k chosen vs k offered, and the κ-bound model
    /// terms behind the call.
    pub fn last_decision(&self) -> StrategyDecision {
        self.last_decision
    }

    /// Drop the recycled basis and cached Jacobi, returning the bytes
    /// freed. The sequence **degrades gracefully**: the next solve runs
    /// plain (P)CG, stores directions as usual, and re-warms the basis
    /// through the normal harmonic-Ritz extraction — no request ever
    /// errors because its basis was evicted. History is kept (it is
    /// cheap and carries the payoff signal the evictor uses).
    pub fn evict_basis(&mut self) -> usize {
        let freed = self
            .defl
            .as_ref()
            .map(|d| 2 * 8 * d.w.rows() * d.k())
            .unwrap_or(0)
            + self.jacobi.as_ref().map(|(j, _)| 8 * j.n()).unwrap_or(0);
        if freed > 0 {
            self.evicted = true;
        }
        self.defl = None;
        self.jacobi = None;
        freed
    }

    /// The budget in force for a request: the per-request override when
    /// present, the sequence config's otherwise.
    fn effective_budget(&self, spec: &SolveSpec) -> RecycleBudget {
        spec.budget.unwrap_or(self.cfg.budget)
    }

    /// The strategy in force for a request: the per-request override when
    /// present, the sequence config's otherwise (mirrors the budget
    /// override rule).
    fn effective_strategy(&self, spec: &SolveSpec) -> StrategyChoice {
        spec.strategy.clone().unwrap_or_else(|| self.cfg.strategy.clone())
    }

    /// Seed the manager with an externally chosen basis (e.g. the a-priori
    /// low-rank space of an inducing-point method, as §1.1 suggests).
    ///
    /// The basis is validated up front: a `W` whose row count does not
    /// match the operator dimension is rejected with a clear
    /// [`SeedError`] instead of failing later inside a solve's projection
    /// with an opaque shape panic. Returns the seeded basis dimension.
    pub fn seed(
        &mut self,
        a: &dyn SpdOperator,
        w: crate::linalg::Mat,
    ) -> Result<usize, SeedError> {
        if w.rows() != a.n() {
            let err = SeedError { expected_rows: a.n(), got_rows: w.rows() };
            crate::log_warn!("rejecting external seed basis: {err}");
            return Err(err);
        }
        let mut d = Deflation::new(w.clone(), crate::linalg::Mat::zeros(w.rows(), w.cols()));
        d.refresh(a);
        let k = d.k();
        self.defl = Some(d);
        Ok(k)
    }

    /// Drop the recycled basis (next solve is plain CG) and the cached
    /// per-sequence Jacobi.
    pub fn reset(&mut self) {
        self.defl = None;
        self.history.clear();
        self.jacobi = None;
        self.solved = 0;
        self.evicted = false;
        self.last_absorb = AbsorbStats::default();
        self.last_decision = StrategyDecision::default();
    }

    /// The sequence's cached Jacobi preconditioner, built from `a` on
    /// first use and rebuilt when the sequence dimension changes **or**
    /// when the operator's diagonal fingerprint says this is a
    /// distinguishably different operator (e.g. a new σ-grid point over
    /// the same base Gram). An operator without a fingerprint reuses the
    /// cache at matching dimension — the drifting-sequence premise — but
    /// a *fingerprintable* operator always invalidates a cache whose
    /// fingerprint differs or is unknown: one anonymous request early in
    /// a sequence must not permanently blind the staleness check for
    /// every later identifiable view.
    fn sequence_jacobi(&mut self, a: &dyn SpdOperator) -> Arc<Jacobi> {
        let fp = a.diag_fingerprint();
        let stale = match &self.jacobi {
            None => true,
            Some((j, cached)) => j.n() != a.n() || (fp.is_some() && *cached != fp),
        };
        if stale {
            self.jacobi = Some((Arc::new(Jacobi::from_op(a)), fp));
        }
        self.jacobi.as_ref().unwrap().0.clone()
    }

    /// Keep `(W, AW)` consistent under the *current* operator according to
    /// the AW policy, re-orthonormalizing when `stabilize` asks for it.
    /// Returns the extra operator applications spent.
    fn sync_basis(&mut self, a: &dyn SpdOperator, tol: f64, budget: &RecycleBudget) -> usize {
        let mut extra = 0usize;
        let n = a.n();
        // Budget first: a basis over `max_basis_bytes` (the budget was
        // tightened since the last extraction) is truncated to its
        // leading columns — extraction ordered them by the selection
        // rule, so the leading ones are the chosen end of the spectrum —
        // BEFORE the AW policy spends matvecs refreshing doomed columns.
        let cap = budget.basis_cols(n);
        if self.k_active() > cap {
            let d = self.defl.take().unwrap();
            if cap == 0 {
                crate::log_debug!("budget truncated recycle basis {} -> 0 columns", d.k());
            } else {
                let mut w = crate::linalg::Mat::zeros(n, cap);
                let mut aw = crate::linalg::Mat::zeros(n, cap);
                for j in 0..cap {
                    w.set_col(j, &d.w.col(j));
                    aw.set_col(j, &d.aw.col(j));
                }
                self.defl = Some(Deflation::new(w, aw));
            }
            self.truncations += 1;
        }
        if let Some(d) = self.defl.as_mut() {
            let refresh = match self.cfg.aw_policy {
                AwPolicy::Refresh => true,
                AwPolicy::Reuse => false,
                AwPolicy::Auto => tol < 1e-6,
            };
            if refresh {
                extra += d.refresh(a);
            }
            if self.cfg.stabilize {
                // Re-orthonormalize W when its Gram matrix is far from I,
                // then AW must be recomputed (k matvecs).
                let gram = d.w.t_matmul(&d.w);
                let dev = gram.max_abs_diff(&crate::linalg::Mat::identity(d.k()));
                if dev > 1e-4 {
                    let w = mgs_orthonormalize(&d.w, None, 1e-12);
                    let mut nd =
                        Deflation::new(w.clone(), crate::linalg::Mat::zeros(n, w.cols()));
                    extra += nd.refresh(a);
                    *d = nd;
                }
            }
        }
        extra
    }

    /// The per-request spec as the kernels should see it inside this
    /// sequence: the manager's ℓ overrides `store_l` (every CG-family and
    /// block run feeds the extraction) and `auto_jacobi` requests resolve
    /// to the sequence's cached preconditioner. `block` marks the
    /// multi-RHS entry point, where the kernel preconditions regardless
    /// of the `method` field — there the cache must resolve for every
    /// method (a per-call rebuild in the API layer would re-derive the
    /// diagonal on each request, the exact cost the cache exists to
    /// avoid); on the single-RHS path a plain `Cg` request stays
    /// unpreconditioned, so building the cache for it would be waste.
    fn resolve_spec(
        &mut self,
        a: &dyn SpdOperator,
        spec: &SolveSpec,
        block: bool,
        budget: &RecycleBudget,
    ) -> SolveSpec {
        let mut inner = spec.clone();
        // The stored-panel budget is enforced at the source: clamp ℓ so
        // the kernel never materializes a panel over `max_stored_bytes`.
        // The leading directions carry the dominant spectral content
        // (CG converges extremal eigencomponents first), so clamping
        // beats storing everything and compressing after the fact.
        inner.store_l = self.cfg.l.min(budget.stored_cols(a.n()));
        let wants_precond =
            block || matches!(inner.method, Method::Pcg | Method::DefCg | Method::BlockCg);
        if inner.auto_jacobi && inner.precond.is_none() && wants_precond {
            let j: Arc<dyn Preconditioner> = self.sequence_jacobi(a);
            inner.precond = Some(j);
        }
        inner
    }

    /// Fold a run's stored directions into the recycled basis via
    /// harmonic-Ritz extraction; returns the selected Ritz values.
    ///
    /// # Lifecycle guarantee: cancellation never corrupts the basis
    ///
    /// Absorption happens only **after** a run returned, and only for
    /// runs the caller still wants: converged, iteration-capped,
    /// stagnated, broken-down, and **deadline-stopped** runs all feed
    /// their panels (every stored `(p, Ap)` pair is written at an
    /// iteration boundary, so a partial run's panel is as consistent as
    /// a full run's — partial Krylov work is not discarded). A
    /// [`StopReason::Cancelled`] run is the one exception: the caller
    /// abandoned it, so [`RecycleManager::solve_next`] /
    /// [`RecycleManager::solve_block`] skip this call entirely and the
    /// sequence's `(W, AW)` is left byte-for-byte what it was — there is
    /// no code path that mutates the basis mid-iteration.
    fn absorb(
        &mut self,
        stored: &StoredDirections,
        n: usize,
        budget: &RecycleBudget,
        choice: &StrategyChoice,
        tol: f64,
        timing: Option<(f64, usize)>,
    ) -> Vec<f64> {
        let strat = choice.resolve();
        let mut stats = AbsorbStats {
            post_eviction: std::mem::take(&mut self.evicted),
            ..Default::default()
        };

        // Panel over `max_stored_bytes`? `resolve_spec` clamps `store_l`
        // so the manager's own runs never get here, but seeded panels and
        // budgets tightened mid-sequence can — compress to the dominant
        // A-weighted modes rather than extracting from (or holding) the
        // oversized panel.
        let stored_cap = budget.stored_cols(n);
        let compressed;
        let stored = if stored.len() > stored_cap {
            compressed = compress_panel(stored, n, stored_cap);
            stats.compressed_cols = stored.len() - compressed.len();
            self.truncations += 1;
            &compressed
        } else {
            stored
        };

        // The strategy owns candidate ranking: extraction ranks by its
        // spectral ordering and truncates at the fixed cfg.k exactly as
        // the historical path did — strategies only ever shrink the
        // result to a leading prefix afterwards, so the default
        // (harmonic-largest, keep the full offer) stays bitwise what it
        // always was.
        let ritz_cfg = RitzConfig {
            k: self.cfg.k,
            select: strat.ordering(),
            min_col_norm: 1e-10,
        };
        let mut ritz_values: Vec<f64> = Vec::new();
        match ritz::try_extract(self.defl.as_ref(), stored, n, &ritz_cfg) {
            Ok(ext) => {
                let ritz::Extraction { defl, vals, spectrum } = ext;
                // Residual-optimal truncation (Neuenhofen & Groß): when the
                // extraction produced more columns than `max_basis_bytes`
                // allows, keep the pairs with the smallest relative
                // eigenresidual — the best-converged, highest-payoff
                // directions — rather than blindly keeping the leading end
                // of the selection order. The budget runs FIRST: it is a
                // hard ceiling, so whatever the strategy chooses below can
                // never exceed `RecycleBudget::basis_cols`.
                let cap = budget.basis_cols(n);
                let (defl, vals) = if defl.k() > cap {
                    stats.truncated_cols = defl.k() - cap;
                    self.truncations += 1;
                    truncate_residual_optimal(defl, vals, cap)
                } else {
                    (Some(defl), vals)
                };

                // Predicted-payoff sizing over the post-budget offer.
                let k_offered = defl.as_ref().map(|d| d.k()).unwrap_or(0);
                let ctx = EvalContext {
                    n,
                    tol,
                    k_cap: k_offered,
                    refresh: matches!(self.cfg.aw_policy, AwPolicy::Refresh),
                    matvec_seconds: match timing {
                        Some((s, m)) if m > 0 && s > 0.0 => Some(s / to_f64(m)),
                        _ => None,
                    },
                    proj_col_seconds: if strat.wants_measurement() {
                        defl.as_ref()
                            .and_then(|d| strategy::measure_projection_col_seconds(&d.w, &d.aw))
                    } else {
                        None
                    },
                };
                let kc = strat.choose_k(&spectrum, &ctx);
                let k_chosen = kc.k.min(k_offered);
                let (defl, vals) = if k_chosen < k_offered {
                    self.strategy_shrinks += 1;
                    if k_chosen == 0 {
                        (None, Vec::new())
                    } else {
                        let d = defl.unwrap();
                        (Some(d.leading_cols(k_chosen)), vals[..k_chosen].to_vec())
                    }
                } else {
                    (defl, vals)
                };
                self.last_decision = StrategyDecision {
                    strategy: strat.name(),
                    k_offered,
                    k_chosen,
                    predicted_plain_iters: kc.plain_iters,
                    predicted_deflated_iters: kc.deflated_iters,
                    predicted_overhead: kc.overhead,
                };
                if k_chosen > 0 {
                    self.predicted_savings_total += self.last_decision.predicted_savings().max(0.0);
                }
                ritz_values = vals.iter().map(|v: &RitzValue| v.theta).collect();
                self.defl = defl;
            }
            Err(ExtractFailure::Empty) => {
                self.last_decision =
                    StrategyDecision { strategy: strat.name(), ..Default::default() };
            }
            Err(ExtractFailure::Numerical) => {
                // The panel is dropped but the previous basis survives —
                // count the drop so the coordinator can audit it.
                self.extraction_failures += 1;
                stats.extraction_failed = true;
                self.last_decision =
                    StrategyDecision { strategy: strat.name(), ..Default::default() };
            }
        }
        self.last_absorb = stats;
        ritz_values
    }

    /// Solve the next system in the sequence according to `spec`, then
    /// update the recycled basis from the run's stored directions.
    ///
    /// The manager is **method-aware** — one recycled sequence can serve a
    /// heterogeneous stream of requests:
    ///
    /// * [`Method::DefCg`] consumes the recycled basis and honors the
    ///   spec's preconditioner, running the composed deflated-PCG kernel.
    ///   The manager's state supersedes an explicit `spec.deflation`;
    ///   before the first extraction (empty state) an explicit spec basis
    ///   is used as the seed.
    /// * [`Method::Cg`] / [`Method::Pcg`] never consume the *manager's*
    ///   basis (a plain request stays plain; a `Pcg` spec carrying its own
    ///   explicit basis composes exactly as it would through
    ///   [`crate::solvers::solve`]) but still **feed** it: the manager
    ///   overrides `store_l` with its own ℓ so every run contributes
    ///   directions to the next harmonic-Ritz extraction.
    /// * [`Method::BlockCg`] is a first-class recycling citizen like
    ///   `DefCg`: the (1-column, through this entry point) block runs
    ///   **deflated block CG** against the manager's basis and **feeds**
    ///   its stored direction panels back, so coalesced block traffic
    ///   enjoys the same iteration decay as the single-RHS path. Genuine
    ///   multi-RHS blocks go through [`RecycleManager::solve_block`].
    ///
    /// For every request, the AW-consistency policy (refresh / stabilize)
    /// runs whenever a basis is held: the extraction folds the prior
    /// `(W, AW)` into its Gram matrices, so it must stay consistent under
    /// the current operator even for requests that do not deflate.
    pub fn solve_next(
        &mut self,
        a: &dyn SpdOperator,
        b: &[f64],
        x0: Option<&[f64]>,
        spec: &SolveSpec,
    ) -> SolveResult {
        let n = a.n();
        let consumes_basis = matches!(spec.method, Method::DefCg | Method::BlockCg);

        // Entry check BEFORE the AW policy work: a request that is
        // already cancelled/expired must not pay the k-application AW
        // refresh (or anything else). It leaves no history entry and
        // touches no state — the same contract as the coordinator's
        // dead-on-arrival completion.
        if let Some(reason) = spec.control.check() {
            return SolveResult {
                x: x0.map(|v| v.to_vec()).unwrap_or_else(|| vec![0.0; n]),
                residuals: vec![1.0],
                iterations: 0,
                matvecs: 0,
                stop: reason,
                stored: StoredDirections::default(),
                seconds: 0.0,
            };
        }

        // Policy: keep (W, AW) consistent under the *current* operator.
        // This runs for every request — not just the ones that deflate —
        // because the harmonic-Ritz extraction below folds the prior
        // basis into Z/AZ: a stale AW there would mix data from two
        // different operators and silently corrupt the next basis.
        let budget = self.effective_budget(spec);
        let extra_matvecs = self.sync_basis(a, spec.tol, &budget);

        // Every run stores ℓ directions for the extraction. DefCg and
        // BlockCg consume the manager's basis (falling back to an
        // explicit basis on the spec before the first extraction); Cg
        // runs plain; Pcg honors an explicit spec basis (matching
        // `solvers::solve`) but never the manager's — a preconditioned
        // request only turns into a recycled one by saying DefCg/BlockCg.
        let inner = self.resolve_spec(a, spec, false, &budget);
        let defl = if consumes_basis {
            self.defl.as_ref().or(spec.deflation.as_deref())
        } else {
            spec.deflation.as_deref()
        };
        let mut result = api::dispatch(a, b, x0, &inner, defl);
        result.matvecs += extra_matvecs;

        // Extract the next basis from this run's stored directions — for
        // every stop reason except Cancelled (abandoned work is never
        // absorbed; a DeadlineExceeded partial run still feeds its
        // panel — see `absorb`).
        let ritz_values = if result.stop == StopReason::Cancelled {
            // Nothing was absorbed, so "what budget enforcement did this
            // run" is nothing — don't let a stale previous-run record
            // leak into this run's report. (The eviction flag, consumed
            // only by `absorb`, survives for the next completed run.)
            self.last_absorb = AbsorbStats::default();
            self.last_decision = StrategyDecision::default();
            Vec::new()
        } else {
            let choice = self.effective_strategy(spec);
            self.absorb(
                &result.stored,
                n,
                &budget,
                &choice,
                spec.tol,
                Some((result.seconds, result.matvecs)),
            )
        };

        self.record(
            SystemStats {
                index: self.solved,
                iterations: result.iterations,
                matvecs: result.matvecs,
                final_residual: result.final_residual(),
                deflation_dim: self.k_active(),
                ritz_values,
                seconds: result.seconds,
            },
            &budget,
        );
        result
    }

    /// Append a history entry, ring-evicting from the front past
    /// [`RecycleBudget::max_history`] (`0` keeps no history at all).
    fn record(&mut self, stats: SystemStats, budget: &RecycleBudget) {
        self.solved += 1;
        if budget.max_history == 0 {
            self.history.clear();
            return;
        }
        self.history.push(stats);
        if self.history.len() > budget.max_history {
            let excess = self.history.len() - budget.max_history;
            self.history.drain(..excess);
            // A long-lived ring should not pin the allocation high-water
            // mark of a transiently looser budget.
            if self.history.capacity() > 2 * budget.max_history.max(16) {
                self.history.shrink_to_fit();
            }
        }
    }

    /// Solve a genuine multi-RHS block `A X = B` within the sequence —
    /// the entry point behind the coordinator's `submit_block` coalescing.
    ///
    /// Block solves are first-class recycling citizens: the manager's
    /// basis is consumed (deflated block CG: projected start plus
    /// per-iteration deflation) for `BlockCg`/`DefCg` requests, the AW
    /// policy keeps `(W, AW)` consistent first, `auto_jacobi` resolves to
    /// the sequence's cached preconditioner, and the run's stored block
    /// direction panels **feed** the next harmonic-Ritz extraction — a
    /// sequence of coalesced block requests decays in iterations exactly
    /// like the single-RHS path. A `Cg`-method spec runs the block solve
    /// undeflated but still feeds the basis.
    ///
    /// History/metrics record `matvecs` per column (the sum of active
    /// panel widths over block applies, plus any AW-refresh cost), so
    /// sequence totals stay on one axis with the single-RHS requests;
    /// `BlockSolveResult::col_matvecs` carries the per-column split the
    /// coordinator uses to bill coalesced tickets.
    pub fn solve_block(
        &mut self,
        a: &dyn SpdOperator,
        b: &crate::linalg::Mat,
        spec: &SolveSpec,
    ) -> BlockSolveResult {
        let n = a.n();
        let consumes_basis = matches!(spec.method, Method::DefCg | Method::BlockCg);

        // Entry check BEFORE the AW policy work — see `solve_next`.
        if let Some(reason) = spec.control.check() {
            return BlockSolveResult {
                x: crate::linalg::Mat::zeros(n, b.cols()),
                residuals: vec![1.0],
                iterations: 0,
                block_matvecs: 0,
                matvecs: 0,
                col_matvecs: vec![0; b.cols()],
                stop: reason,
                stored: StoredDirections::default(),
                seconds: 0.0,
            };
        }

        let budget = self.effective_budget(spec);
        let extra_matvecs = self.sync_basis(a, spec.tol, &budget);
        let inner = self.resolve_spec(a, spec, true, &budget);
        let defl = if consumes_basis {
            self.defl.as_ref().or(spec.deflation.as_deref())
        } else {
            spec.deflation.as_deref()
        };
        let mut result = api::solve_block_with(a, b, &inner, defl);
        result.matvecs += extra_matvecs;

        // Same absorb policy as `solve_next`: everything but Cancelled.
        let ritz_values = if result.stop == StopReason::Cancelled {
            self.last_absorb = AbsorbStats::default();
            self.last_decision = StrategyDecision::default();
            Vec::new()
        } else {
            let choice = self.effective_strategy(spec);
            self.absorb(
                &result.stored,
                n,
                &budget,
                &choice,
                spec.tol,
                Some((result.seconds, result.matvecs)),
            )
        };

        self.record(
            SystemStats {
                index: self.solved,
                iterations: result.iterations,
                matvecs: result.matvecs,
                final_residual: result.final_residual(),
                deflation_dim: self.k_active(),
                ritz_values,
                seconds: result.seconds,
            },
            &budget,
        );
        result
    }
}

/// Keep the `cap` Ritz pairs with the smallest relative eigenresidual
/// (the best-converged approximate eigenpairs), preserving their original
/// selection order. `cap == 0` drops the basis entirely.
fn truncate_residual_optimal(
    defl: Deflation,
    vals: Vec<RitzValue>,
    cap: usize,
) -> (Option<Deflation>, Vec<RitzValue>) {
    if cap == 0 {
        return (None, Vec::new());
    }
    let n = defl.w.rows();
    let mut order: Vec<usize> = (0..vals.len()).collect();
    order.sort_by(|&i, &j| vals[i].resid.total_cmp(&vals[j].resid));
    order.truncate(cap);
    order.sort_unstable();
    let mut w = crate::linalg::Mat::zeros(n, cap);
    let mut aw = crate::linalg::Mat::zeros(n, cap);
    let mut kept = Vec::with_capacity(cap);
    for (dst, &src) in order.iter().enumerate() {
        w.set_col(dst, &defl.w.col(src));
        aw.set_col(dst, &defl.aw.col(src));
        kept.push(vals[src].clone());
    }
    (Some(Deflation::new(w, aw)), kept)
}

/// Compress a stored panel to its `m_cap` dominant **A-weighted** modes:
/// solve the small pencil `(PᵀAP) u = θ (PᵀP) u` — both Grams are free,
/// `PᵀAP` reuses the stored images — and keep the combinations with the
/// largest Rayleigh quotient. This is POD-style panel compression
/// (Carlberg et al.), except energy is measured in the A-inner product so
/// the modes that matter for deflation (the extremal eigendirections)
/// survive. Each kept column pair is renormalized jointly, preserving
/// `AP' = A·P'` exactly. Falls back to the leading raw columns when the
/// small pencil is degenerate.
fn compress_panel(stored: &StoredDirections, n: usize, m_cap: usize) -> StoredDirections {
    if m_cap == 0 {
        return StoredDirections::default();
    }
    let (p, ap) = stored.as_mats(n);
    let mut m = p.t_matmul(&p);
    m.symmetrize();
    let mut ga = p.t_matmul(&ap);
    ga.symmetrize();
    // `gen_sym_eig(G, F)` solves `G u = θ F u` with pairs ordered by |θ|
    // descending; with G = PᵀP and F = PᵀAP the returned θ is the
    // *inverse* Rayleigh quotient, so the dominant A-weighted modes are
    // the trailing entries.
    let pairs = match crate::linalg::eig::gen_sym_eig(&m, &ga) {
        Ok(pairs) if !pairs.is_empty() => pairs,
        _ => {
            // Degenerate panel Gram: keep the leading raw directions.
            return StoredDirections {
                p: stored.p.iter().take(m_cap).cloned().collect(),
                ap: stored.ap.iter().take(m_cap).cloned().collect(),
            };
        }
    };
    let mut out = StoredDirections::default();
    for (_, u) in pairs.iter().rev().take(m_cap) {
        let pc = p.matvec(u);
        let norm = crate::linalg::vec_ops::norm2(&pc);
        if !(norm.is_finite() && norm > 1e-12) {
            continue;
        }
        let apc = ap.matvec(u);
        let inv = 1.0 / norm;
        out.p.push(pc.iter().map(|v| v * inv).collect());
        out.ap.push(apc.iter().map(|v| v * inv).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::{DenseOp, StopReason};
    use crate::util::rng::Rng;

    /// A slowly drifting sequence of SPD matrices: A_i = A + εᵢ Δ,
    /// mimicking the Newton sequence of the paper (consecutive systems
    /// differ less and less).
    fn drifting_sequence(n: usize, count: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::new(seed);
        let a0 = Mat::rand_spd(n, 1e4, &mut rng);
        let mut delta = Mat::randn(n, n, &mut rng);
        delta.symmetrize();
        delta.scale_in_place(1e-3 / n as f64);
        (0..count)
            .map(|i| {
                let mut a = a0.clone();
                let scale = 1.0 / (1.0 + i as f64); // shrinking drift
                let mut d = delta.clone();
                d.scale_in_place(scale);
                a.add_in_place(&d);
                // keep strictly SPD
                a.add_diag(1e-6);
                a
            })
            .collect()
    }

    #[test]
    fn sequence_iterations_decrease_with_recycling() {
        let n = 90;
        let seq = drifting_sequence(n, 5, 11);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_max_iters(50_000);

        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let mut plain_iters = Vec::new();
        let mut recycled_iters = Vec::new();
        for a in &seq {
            let op = DenseOp::new(a);
            let plain = crate::solvers::cg::solve(&op, &b, None, &spec.cg_config());
            assert_eq!(plain.stop, StopReason::Converged);
            let rec = mgr.solve_next(&op, &b, None, &spec);
            assert_eq!(rec.stop, StopReason::Converged);
            plain_iters.push(plain.iterations);
            recycled_iters.push(rec.iterations);
        }
        // First system: no basis yet, so identical to plain CG.
        assert_eq!(plain_iters[0], recycled_iters[0]);
        // Every later system must need fewer iterations than plain CG.
        for i in 1..seq.len() {
            assert!(
                recycled_iters[i] < plain_iters[i],
                "system {i}: recycled {} >= plain {}",
                recycled_iters[i],
                plain_iters[i]
            );
        }
    }

    #[test]
    fn history_records_every_system() {
        let n = 40;
        let seq = drifting_sequence(n, 3, 12);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 6, ..Default::default() });
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-6));
        }
        assert_eq!(mgr.history().len(), 3);
        assert_eq!(mgr.history()[0].index, 0);
        assert!(mgr.history()[1].deflation_dim > 0);
        assert!(mgr.history()[2].ritz_values.len() <= 4);
    }

    #[test]
    fn refresh_policy_costs_k_matvecs_but_stays_correct() {
        let n = 50;
        let seq = drifting_sequence(n, 3, 13);
        let b = vec![1.0; n];
        let cfg = RecycleConfig {
            k: 5,
            l: 8,
            aw_policy: AwPolicy::Refresh,
            ..Default::default()
        };
        let mut mgr = RecycleManager::new(cfg);
        for a in &seq {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
            assert_eq!(r.stop, StopReason::Converged);
            // solution check
            let ax = a.matvec(&r.x);
            let num: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v).powi(2)).sum();
            assert!(num.sqrt() / (n as f64).sqrt() < 1e-6);
        }
        // Refresh happened on systems 2 and 3 (k matvecs each).
        assert!(mgr.history()[1].matvecs > mgr.history()[1].iterations);
    }

    #[test]
    fn seed_with_external_basis() {
        let n = 40;
        let mut rng = Rng::new(14);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let w = crate::linalg::qr::Qr::factor(&Mat::randn(n, 6, &mut rng)).thin_q();
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        assert_eq!(mgr.seed(&DenseOp::new(&a), w).expect("matching dims"), 6);
        assert_eq!(mgr.k_active(), 6);
        let b = vec![1.0; n];
        let r = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(r.stop, StopReason::Converged);
    }

    #[test]
    fn seed_rejects_mismatched_rows() {
        let n = 40;
        let mut rng = Rng::new(14);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let w = Mat::randn(n + 3, 4, &mut rng);
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        let err = mgr.seed(&DenseOp::new(&a), w).unwrap_err();
        assert_eq!(err, SeedError { expected_rows: n, got_rows: n + 3 });
        assert!(err.to_string().contains("43"));
        // The manager is untouched: no basis, and the next solve is fine.
        assert_eq!(mgr.k_active(), 0);
        let b = vec![1.0; n];
        let r = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(r.stop, StopReason::Converged);
    }

    #[test]
    fn reset_clears_state() {
        let n = 30;
        let seq = drifting_sequence(n, 2, 15);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-6));
        }
        assert!(mgr.k_active() > 0);
        mgr.reset();
        assert_eq!(mgr.k_active(), 0);
        assert!(mgr.history().is_empty());
    }

    #[test]
    fn plain_requests_feed_the_basis_without_consuming_it() {
        // Method-aware sequence: a Cg request stores directions (feeding
        // the extraction) but runs undeflated; a following DefCg request
        // on the same system then converges faster thanks to the basis the
        // plain run contributed.
        let n = 90;
        let mut rng = Rng::new(17);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let plain = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::cg().with_tol(1e-8));
        assert_eq!(plain.stop, StopReason::Converged);
        assert!(mgr.k_active() > 0, "plain run must feed the basis");
        let deflated =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(deflated.stop, StopReason::Converged);
        assert!(
            deflated.iterations < plain.iterations,
            "deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
    }

    #[test]
    fn block_requests_consume_and_feed_the_basis() {
        let n = 60;
        let mut rng = Rng::new(18);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 6, l: 10, ..Default::default() });
        // Seed the basis with a def-CG run, then interleave a block request.
        let seed = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        let k_before = mgr.k_active();
        assert!(k_before > 0);
        let blk = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::blockcg().with_tol(1e-8));
        assert_eq!(blk.stop, StopReason::Converged);
        // Consumes: the deflated block run on the identical system beats
        // the cold seeding run.
        assert!(
            blk.iterations < seed.iterations,
            "deflated block {} >= cold {}",
            blk.iterations,
            seed.iterations
        );
        // Feeds: the extraction ran on the block run's directions.
        assert!(mgr.k_active() > 0);
        assert_eq!(mgr.history().len(), 2);
        assert!(mgr.history()[1].deflation_dim > 0);
        assert!(!mgr.history()[1].ritz_values.is_empty(), "block runs must feed the basis");
    }

    #[test]
    fn auto_jacobi_is_built_once_per_sequence() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct DiagCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for DiagCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
        }
        let n = 60;
        let seq = drifting_sequence(n, 4, 19);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let ops: Vec<DiagCounting> =
            seq.iter().map(|a| DiagCounting(a, AtomicUsize::new(0))).collect();
        for op in &ops {
            let r = mgr.solve_next(op, &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
        }
        let total: usize = ops.iter().map(|o| o.1.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 1, "the sequence Jacobi must be derived exactly once");
        mgr.reset();
        let r = mgr.solve_next(&ops[0], &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(ops[0].1.load(Ordering::Relaxed), 2, "reset drops the cache");
    }

    #[test]
    fn block_auto_jacobi_resolves_to_the_sequence_cache_for_any_method() {
        // The block kernel preconditions regardless of the spec's method
        // field, so a Cg-method block request with auto_jacobi must hit
        // the per-sequence cache too — not fall through to a per-call
        // rebuild in the API layer (n probing matvecs per request on
        // operators without an exact diagonal).
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct DiagCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for DiagCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
        }
        let n = 40;
        let mut rng = Rng::new(24);
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let op = DiagCounting(&a, AtomicUsize::new(0));
        let rhs = Mat::randn(n, 3, &mut rng);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        let spec = SolveSpec::cg().with_auto_jacobi().with_tol(1e-8);
        let r1 = mgr.solve_block(&op, &rhs, &spec);
        let r2 = mgr.solve_block(&op, &rhs, &spec);
        assert_eq!(r1.stop, StopReason::Converged);
        assert_eq!(r2.stop, StopReason::Converged);
        assert_eq!(
            op.1.load(Ordering::Relaxed),
            1,
            "block auto-jacobi must derive the sequence diagonal exactly once"
        );
    }

    #[test]
    fn jacobi_cache_rebuilds_across_same_n_sigma_grid_points() {
        // The staleness bug this pins: a mixed sequence over ShiftedOp(K, σ²)
        // views of ONE Gram matrix has constant n, but the diagonal differs
        // by σ² across grid points — reusing the cached Jacobi there applies
        // a preconditioner that is wrong by σ₁² − σ₂². The diag fingerprint
        // distinguishes the views, so the cache rebuilds exactly when σ
        // changes and still reuses within one σ.
        use crate::solvers::algebra::ShiftedOp;
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct FingerprintedBase<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for FingerprintedBase<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
            fn diag_fingerprint(&self) -> Option<u64> {
                Some(0xBA5E) // one fixed base identity
            }
        }
        let n = 50;
        let mut rng = Rng::new(23);
        let k = Mat::rand_spd(n, 1e3, &mut rng);
        let base = FingerprintedBase(&k, AtomicUsize::new(0));
        let b = vec![1.0; n];
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });

        let s1 = ShiftedOp::new(&base, 0.5);
        let s2 = ShiftedOp::new(&base, 250.0); // same n, very different diag
        let r = mgr.solve_next(&s1, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(base.1.load(Ordering::Relaxed), 1);
        // Same σ again: the cache must be reused (no new derivation).
        let r = mgr.solve_next(&s1, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(base.1.load(Ordering::Relaxed), 1, "same grid point reuses the Jacobi");
        // Different σ at the same n: the fingerprint must force a rebuild —
        // the reused diagonal would be wrong by σ₂² − σ₁² ≈ 250.
        let r = mgr.solve_next(&s2, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(
            base.1.load(Ordering::Relaxed),
            2,
            "a distinguishable operator must rebuild the sequence Jacobi"
        );
        // And that rebuilt Jacobi must actually match the shifted diagonal:
        // solve the shifted system directly with an exact Jacobi and check
        // the sequence solve used the same (iteration counts agree).
        let direct = crate::solvers::solve(
            &s2,
            &b,
            &SolveSpec::pcg().with_jacobi(&s2).with_tol(1e-8),
        );
        assert_eq!(r.iterations, direct.iterations, "rebuilt Jacobi must be the exact one");
    }

    #[test]
    fn fingerprintable_operator_invalidates_an_anonymous_jacobi_cache() {
        // A sequence whose FIRST auto-jacobi request comes from an
        // operator without a fingerprint caches (J, None). A later
        // *fingerprintable* view of a very different operator must still
        // invalidate that cache — one anonymous request must not blind
        // the staleness check for the rest of the sequence.
        use crate::solvers::algebra::ShiftedOp;
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Anon<'a>(&'a Mat);
        impl<'a> SpdOperator for Anon<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.0.diag_into(out);
            }
        }
        struct FpCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for FpCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
            fn diag_fingerprint(&self) -> Option<u64> {
                Some(0xF00D)
            }
        }
        let n = 40;
        let mut rng = Rng::new(25);
        let k = Mat::rand_spd(n, 1e3, &mut rng);
        let b = vec![1.0; n];
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        // Anonymous first: builds and caches with fingerprint None.
        let r = mgr.solve_next(&Anon(&k), &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        // A fingerprintable, strongly shifted view at the same n: the
        // cache must be invalidated (its diagonal derived fresh), not
        // silently reused with a diagonal wrong by 500.
        let base = FpCounting(&k, AtomicUsize::new(0));
        let shifted = ShiftedOp::new(&base, 500.0);
        let r = mgr.solve_next(&shifted, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(
            base.1.load(Ordering::Relaxed),
            1,
            "a fingerprintable view must rebuild an anonymous cache"
        );
        let direct = crate::solvers::solve(
            &shifted,
            &b,
            &SolveSpec::pcg().with_jacobi(&shifted).with_tol(1e-8),
        );
        assert_eq!(r.iterations, direct.iterations, "rebuilt Jacobi must be the exact one");
    }

    #[test]
    fn solve_block_consumes_feeds_and_records_history() {
        let n = 50;
        let mut rng = Rng::new(20);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 5, l: 8, ..Default::default() });
        mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        let k_before = mgr.k_active();
        assert!(k_before > 0);
        let rhs = Mat::randn(n, 3, &mut rng);
        // Undeflated reference for the same block.
        let plain = crate::solvers::blockcg::solve(&DenseOp::new(&a), &rhs, 1e-8, 0);
        let blk = mgr.solve_block(&DenseOp::new(&a), &rhs, &SolveSpec::blockcg().with_tol(1e-8));
        assert_eq!(blk.stop, StopReason::Converged);
        assert!(
            blk.iterations < plain.iterations,
            "deflated block {} >= plain {}",
            blk.iterations,
            plain.iterations
        );
        assert!(mgr.k_active() > 0, "block directions must feed the extraction");
        assert_eq!(mgr.history().len(), 2);
        assert!(!mgr.history()[1].ritz_values.is_empty());
        assert!(!mgr.history()[1].final_residual.is_nan(), "never NaN (recycle history)");
        // Per-column accounting: the sum of per-column applies plus the
        // AW-refresh cost (k_before applies under the default Refresh
        // policy).
        assert_eq!(blk.matvecs, blk.col_matvecs.iter().sum::<usize>() + k_before);
        assert_eq!(mgr.history()[1].matvecs, blk.matvecs);
        assert!(blk.col_matvecs.iter().sum::<usize>() <= 3 * blk.block_matvecs);
    }

    #[test]
    fn deflated_block_sequence_decays_iterations_with_block_fed_basis() {
        // The multi-RHS recycling loop end to end: a drifting 5-system
        // sequence with s = 4 right-hand sides per system. Deflated block
        // CG through the manager must need strictly fewer block iterations
        // than undeflated block CG on every system after the first, with
        // the basis demonstrably fed from block-run directions.
        let n = 90;
        let seq = drifting_sequence(n, 5, 21);
        let mut rng = Rng::new(22);
        let b = Mat::randn(n, 4, &mut rng);
        let spec = SolveSpec::blockcg().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let mut plain_iters = Vec::new();
        let mut rec_iters = Vec::new();
        for a in &seq {
            let op = DenseOp::new(a);
            let plain = crate::solvers::blockcg::solve(&op, &b, 1e-8, 0);
            assert_eq!(plain.stop, StopReason::Converged);
            let rec = mgr.solve_block(&op, &b, &spec);
            assert_eq!(rec.stop, StopReason::Converged);
            plain_iters.push(plain.iterations);
            rec_iters.push(rec.iterations);
        }
        // First system: no basis yet — identical to the plain block solve.
        assert_eq!(plain_iters[0], rec_iters[0]);
        for i in 1..seq.len() {
            assert!(
                rec_iters[i] < plain_iters[i],
                "system {i}: recycled block {} >= plain block {}",
                rec_iters[i],
                plain_iters[i]
            );
            assert!(
                !mgr.history()[i].ritz_values.is_empty(),
                "system {i}: basis must be fed from block-run directions"
            );
            assert!(mgr.history()[i].deflation_dim > 0);
        }
    }

    #[test]
    fn cancelled_run_never_touches_the_recycle_basis() {
        // The lifecycle guarantee: a Cancelled solve is not absorbed —
        // the sequence's (W, AW) stays byte-for-byte what it was, and a
        // later request still benefits from the pre-cancel basis.
        use crate::solvers::control::CancelToken;
        let n = 80;
        let mut rng = Rng::new(40);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b = vec![1.0; n];
        // Reuse: sync_basis must not refresh AW either, so the state
        // comparison below is exact.
        let mut mgr = RecycleManager::new(RecycleConfig {
            k: 8,
            l: 12,
            aw_policy: AwPolicy::Reuse,
            ..Default::default()
        });
        let seeded =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(seeded.stop, StopReason::Converged);
        let w_before = mgr.deflation().unwrap().w.clone();
        let aw_before = mgr.deflation().unwrap().aw.clone();
        // Pre-cancelled request: the manager's entry check returns before
        // even the AW policy runs — no history entry, zero applications.
        let token = CancelToken::new();
        token.cancel();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_cancel(token);
        let cancelled = mgr.solve_next(&DenseOp::new(&a), &b, None, &spec);
        assert_eq!(cancelled.stop, StopReason::Cancelled);
        assert_eq!(cancelled.matvecs, 0, "a dead request must not pay the AW refresh");
        assert_eq!(mgr.history().len(), 1, "never-run requests leave no history");
        let d = mgr.deflation().unwrap();
        assert_eq!(d.w.max_abs_diff(&w_before), 0.0, "W must be untouched");
        assert_eq!(d.aw.max_abs_diff(&aw_before), 0.0, "AW must be untouched");
        // Mid-solve cancel (token raised after the first iteration by a
        // self-cancelling operator): recorded in history, absorb skipped,
        // basis still byte-identical.
        struct CancelAfterFirst<'a>(&'a Mat, CancelToken);
        impl<'a> SpdOperator for CancelAfterFirst<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
                self.1.cancel();
            }
        }
        let mid_token = CancelToken::new();
        let op = CancelAfterFirst(&a, mid_token.clone());
        let spec = SolveSpec::cg().with_tol(1e-12).with_cancel(mid_token);
        let mid = mgr.solve_next(&op, &b, None, &spec);
        assert_eq!(mid.stop, StopReason::Cancelled);
        assert!(mid.iterations >= 1, "the cancel landed mid-solve");
        assert_eq!(mgr.history().len(), 2, "a run that started is recorded");
        assert!(mgr.history()[1].ritz_values.is_empty(), "but never absorbed");
        let d = mgr.deflation().unwrap();
        assert_eq!(d.w.max_abs_diff(&w_before), 0.0, "W must still be untouched");
        assert_eq!(d.aw.max_abs_diff(&aw_before), 0.0, "AW must still be untouched");
        let after =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(after.stop, StopReason::Converged);
        assert!(after.iterations < seeded.iterations, "the old basis still deflates");
    }

    #[test]
    fn deadline_stopped_run_feeds_directions_that_speed_up_the_next_system() {
        // The acceptance pin: a deadline-bounded solve returns a partial
        // iterate AND its stored direction panel still reduces the next
        // system's iteration count — partial Krylov work is not
        // discarded. The slow operator makes the deadline deterministic:
        // every application sleeps, so a ~100 ms budget admits a handful
        // of iterations of a solve that needs hundreds.
        use std::time::Duration;
        struct Slow<'a>(&'a Mat);
        impl<'a> SpdOperator for Slow<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                std::thread::sleep(Duration::from_millis(2));
                self.0.matvec_into(x, y);
            }
        }
        let n = 90;
        let mut rng = Rng::new(41);
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let b = a.matvec(&x_true);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        // tol far below what the budget can reach: the deadline fires.
        let spec = SolveSpec::defcg().with_tol(1e-15).with_deadline(Duration::from_millis(150));
        let partial = mgr.solve_next(&Slow(&a), &b, None, &spec);
        assert_eq!(partial.stop, StopReason::DeadlineExceeded, "stopped as {:?}", partial.stop);
        assert!(partial.iterations >= 1, "the budget allowed at least one iteration");
        // Partial iterate: strictly closer to the solution in A-norm
        // than the zero start (CG minimizes the A-norm error).
        let a_err = |x: &[f64]| -> f64 {
            let e: Vec<f64> = x.iter().zip(&x_true).map(|(u, v)| u - v).collect();
            crate::linalg::vec_ops::dot(&e, &a.matvec(&e)).sqrt()
        };
        assert!(a_err(&partial.x) < a_err(&vec![0.0; n]));
        // The partial run fed the basis...
        assert!(mgr.k_active() > 0, "deadline-stopped run must feed the basis");
        assert!(!mgr.history()[0].ritz_values.is_empty());
        // ...and that basis reduces iterations on the next system (the
        // fast operator now — the deadline was the slow op's problem).
        let cold = crate::solvers::solve(
            &DenseOp::new(&a),
            &b,
            &SolveSpec::defcg().with_tol(1e-8),
        );
        assert_eq!(cold.stop, StopReason::Converged);
        let warm = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(warm.stop, StopReason::Converged);
        assert!(
            warm.iterations < cold.iterations,
            "deadline-fed basis {} >= cold {}",
            warm.iterations,
            cold.iterations
        );
    }

    #[test]
    fn stabilize_keeps_w_well_conditioned() {
        let n = 60;
        let seq = drifting_sequence(n, 6, 16);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { k: 6, l: 10, stabilize: true, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        }
        if let Some(d) = mgr.deflation() {
            let gram = d.w.t_matmul(&d.w);
            // Diagonal should be ~1 (normalized columns); off-diagonal bounded.
            for i in 0..d.k() {
                assert!((gram[(i, i)] - 1.0).abs() < 1e-6);
            }
        }
    }

    /// Paper-shaped spectrum: a tight bulk log-spaced in `[1, bulk_hi]`
    /// plus `n_out` large outliers log-spaced in `[out_lo, out_hi]`,
    /// rotated by the same three Householder reflections
    /// `Gen::spd_matrix` uses. This is the regime where recycling a
    /// *handful* of directions captures nearly all the payoff — the
    /// spectrum the paper's kernel matrices have — and therefore the
    /// regime where a tight `RecycleBudget` is nearly free.
    fn outlier_spd(
        rng: &mut Rng,
        n: usize,
        n_out: usize,
        bulk_hi: f64,
        out_lo: f64,
        out_hi: f64,
    ) -> Mat {
        let nb = n - n_out;
        let mut a = vec![0.0; n * n];
        for i in 0..nb {
            a[i * n + i] = (bulk_hi.ln() * i as f64 / (nb - 1) as f64).exp();
        }
        for j in 0..n_out {
            let t = j as f64 / (n_out - 1).max(1) as f64;
            a[(nb + j) * n + (nb + j)] = (out_lo.ln() + t * (out_hi.ln() - out_lo.ln())).exp();
        }
        for _ in 0..3 {
            let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm < 1e-12 {
                continue;
            }
            for x in &mut v {
                *x /= norm;
            }
            let mut vta = vec![0.0; n];
            for i in 0..n {
                for j in 0..n {
                    vta[j] += v[i] * a[i * n + j];
                }
            }
            for i in 0..n {
                for j in 0..n {
                    a[i * n + j] -= 2.0 * v[i] * vta[j];
                }
            }
            let mut bv = vec![0.0; n];
            for (i, bvi) in bv.iter_mut().enumerate() {
                let mut s = 0.0;
                for j in 0..n {
                    s += a[i * n + j] * v[j];
                }
                *bvi = s;
            }
            for i in 0..n {
                for j in 0..n {
                    a[i * n + j] -= 2.0 * bv[i] * v[j];
                }
            }
        }
        let mut m = Mat::from_vec(n, n, a);
        m.symmetrize();
        m
    }

    /// Drifting sequence over the outlier spectrum (same drift model as
    /// [`drifting_sequence`]).
    fn drifting_outlier_sequence(n: usize, count: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::new(seed);
        let mut sub = rng.fork();
        let a0 = outlier_spd(&mut sub, n, 3, 1.5, 1e3, 1e4);
        let mut delta = Mat::randn(n, n, &mut rng);
        delta.symmetrize();
        delta.scale_in_place(1e-3 / n as f64);
        (0..count)
            .map(|i| {
                let mut a = a0.clone();
                let mut d = delta.clone();
                d.scale_in_place(1.0 / (1.0 + i as f64));
                a.add_in_place(&d);
                a.add_diag(1e-6);
                a
            })
            .collect()
    }

    /// The ISSUE's acceptance bound: on a paper-shaped (outlier) drifting
    /// suite, a budget capping basis + stored panels at 25% of the
    /// unbounded footprint loses at most 2 iterations per system.
    #[test]
    fn quarter_budget_loses_at_most_two_iterations_per_system() {
        let n = 90;
        let seq = drifting_outlier_sequence(n, 6, 120);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_max_iters(50_000);

        let cfg = RecycleConfig { k: 20, l: 28, ..Default::default() };
        let budget = RecycleBudget::capping_cols(n, 6, 6);
        // 6 + 6 column pairs is exactly 25% of the unbounded 20 + 28.
        assert_eq!(budget.basis_cols(n), 6);
        assert_eq!(budget.stored_cols(n), 6);
        assert!(4 * (budget.basis_cols(n) + budget.stored_cols(n)) <= cfg.k + cfg.l);

        let mut unb = RecycleManager::new(cfg.clone());
        let mut bnd = RecycleManager::new(RecycleConfig { budget, ..cfg });
        let mut unb_iters = Vec::new();
        let mut bnd_iters = Vec::new();
        for a in &seq {
            let op = DenseOp::new(a);
            let ru = unb.solve_next(&op, &b, None, &spec);
            let rb = bnd.solve_next(&op, &b, None, &spec);
            assert_eq!(ru.stop, StopReason::Converged);
            assert_eq!(rb.stop, StopReason::Converged);
            unb_iters.push(ru.iterations);
            bnd_iters.push(rb.iterations);
        }
        for i in 0..seq.len() {
            assert!(
                bnd_iters[i] <= unb_iters[i] + 2,
                "system {i}: bounded {} > unbounded {} + 2 (bounded {:?} vs unbounded {:?})",
                bnd_iters[i],
                unb_iters[i],
                bnd_iters,
                unb_iters
            );
        }
        // The cap really bit: the bounded basis is pinned at 6 columns,
        // truncation events were recorded, and the footprint shrank.
        assert!(bnd.k_active() <= 6);
        assert!(unb.k_active() > 6);
        assert!(bnd.truncations() > 0, "budget never triggered truncation");
        assert!(bnd.bytes_held() < unb.bytes_held());
    }

    /// Check `bytes_held()` against the live buffer sizes after any
    /// interleaving of absorb / truncate / evict / compress — the
    /// invariant the service-wide `ByteAccountant` relies on.
    fn audit(mgr: &RecycleManager) {
        let basis = mgr
            .defl
            .as_ref()
            .map(|d| {
                assert_eq!(d.w.rows(), d.aw.rows());
                assert_eq!(d.w.cols(), d.aw.cols());
                8 * (d.w.rows() * d.w.cols() + d.aw.rows() * d.aw.cols())
            })
            .unwrap_or(0);
        let jacobi = mgr.jacobi.as_ref().map(|(j, _)| 8 * j.n()).unwrap_or(0);
        let history: usize = mgr
            .history
            .iter()
            .map(|s| std::mem::size_of::<SystemStats>() + 8 * s.ritz_values.len())
            .sum();
        assert_eq!(mgr.bytes_held(), basis + jacobi + history);
    }

    #[test]
    fn bytes_held_matches_live_buffers_across_interleavings() {
        let n = 40;
        let seq = drifting_sequence(n, 6, 19);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { k: 6, l: 8, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        audit(&mgr);

        // Plain absorbs (with an auto-Jacobi so the cache contributes).
        let spec = SolveSpec::defcg().with_tol(1e-8).with_auto_jacobi();
        for a in &seq[..2] {
            mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            audit(&mgr);
        }
        assert!(mgr.k_active() > 0);

        // Per-request budget forces basis truncation + panel clamping.
        let tight = spec.clone().with_budget(RecycleBudget::capping_cols(n, 3, 4));
        mgr.solve_next(&DenseOp::new(&seq[2]), &b, None, &tight);
        audit(&mgr);
        assert!(mgr.k_active() <= 3);
        assert!(mgr.truncations() > 0);

        // Eviction frees exactly what the audit formula says it holds.
        let before = mgr.bytes_held();
        let freed = mgr.evict_basis();
        audit(&mgr);
        assert_eq!(mgr.bytes_held(), before - freed);
        assert_eq!(mgr.k_active(), 0);

        // Re-warm, then feed an oversized external panel straight into
        // `absorb` to exercise the A-weighted compression path.
        mgr.solve_next(&DenseOp::new(&seq[3]), &b, None, &spec);
        audit(&mgr);
        let donor = crate::solvers::cg::solve(
            &DenseOp::new(&seq[4]),
            &b,
            None,
            &SolveSpec::cg().with_tol(1e-10).with_store_l(8).cg_config(),
        );
        assert!(donor.stored.len() > 4);
        let squeeze = RecycleBudget::capping_cols(n, 6, 4);
        mgr.absorb(&donor.stored, n, &squeeze, &StrategyChoice::default(), 1e-8, None);
        audit(&mgr);
        assert!(mgr.last_absorb().compressed_cols > 0);

        // Budget of zero basis columns empties the deflation entirely.
        let zero = spec.clone().with_budget(RecycleBudget::capping_cols(n, 0, 4));
        let res = mgr.solve_next(&DenseOp::new(&seq[5]), &b, None, &zero);
        assert_eq!(res.stop, StopReason::Converged);
        audit(&mgr);
        assert_eq!(mgr.k_active(), 0);
    }

    /// The history ring must hold bounded bytes over a long-lived
    /// sequence (the unbounded-Vec leak this PR closes).
    #[test]
    fn history_ring_stays_bounded_over_ten_thousand_solves() {
        let n = 8;
        let a = drifting_sequence(n, 1, 23).remove(0);
        let op = DenseOp::new(&a);
        let b = vec![1.0; n];
        let cfg = RecycleConfig {
            k: 2,
            l: 3,
            budget: RecycleBudget::default().with_max_history(64),
            ..Default::default()
        };
        let mut mgr = RecycleManager::new(cfg);
        let spec = SolveSpec::defcg().with_tol(1e-10);
        let mut peak = 0usize;
        for _ in 0..10_000 {
            mgr.solve_next(&op, &b, None, &spec);
            peak = peak.max(mgr.bytes_held());
        }
        assert_eq!(mgr.history().len(), 64);
        // Index numbering survives ring eviction.
        assert_eq!(mgr.history().last().unwrap().index, 9_999);
        assert_eq!(mgr.history()[0].index, 9_936);
        // Allocator-level bound: the ring shrinks its backing Vec, so the
        // capacity can never track the 10k-entry high-water mark.
        assert!(mgr.history.capacity() <= 2 * 64);
        // The audited footprint is a few KiB, not a 10k-entry history.
        let per_entry = std::mem::size_of::<SystemStats>() + 8 * 2;
        assert!(peak <= 2 * 8 * n * 2 + 8 * n + 64 * per_entry + 1024);
    }

    /// Eviction degrades the sequence to plain CG for one solve, then the
    /// basis re-warms through the normal extraction and recovers the
    /// recycling speedup.
    #[test]
    fn evicted_sequence_degrades_then_rewarm_recovers() {
        let n = 90;
        let seq = drifting_sequence(n, 5, 11);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_max_iters(50_000);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });

        let mut iters = Vec::new();
        for a in &seq[..3] {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
            iters.push(r.iterations);
        }
        assert!(mgr.k_active() > 0);
        let freed = mgr.evict_basis();
        assert!(freed > 0);
        assert_eq!(mgr.k_active(), 0);
        // History survives eviction (it carries the payoff signal).
        assert_eq!(mgr.history().len(), 3);

        // Degraded solve: plain CG, converges, flagged post-eviction.
        let degraded = mgr.solve_next(&DenseOp::new(&seq[3]), &b, None, &spec);
        assert_eq!(degraded.stop, StopReason::Converged);
        assert!(mgr.last_absorb().post_eviction);
        assert!(
            degraded.iterations > iters[2],
            "post-eviction run {} should cost more than recycled run {}",
            degraded.iterations,
            iters[2]
        );
        // ... and its panel re-warmed the basis.
        assert!(mgr.k_active() > 0);

        // Re-warmed solve: recycling speedup is back, flag is consumed.
        let rewarmed = mgr.solve_next(&DenseOp::new(&seq[4]), &b, None, &spec);
        assert_eq!(rewarmed.stop, StopReason::Converged);
        assert!(!mgr.last_absorb().post_eviction);
        assert!(
            rewarmed.iterations < degraded.iterations,
            "re-warmed run {} should beat degraded run {}",
            rewarmed.iterations,
            degraded.iterations
        );
    }

    /// A drifting sequence whose spectrum is essentially flat (κ ≈ 1 + ε):
    /// the regime where deflation can never pay for itself.
    fn drifting_flat_sequence(n: usize, count: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::new(seed);
        let mut delta = Mat::randn(n, n, &mut rng);
        delta.symmetrize();
        delta.scale_in_place(1e-6 / n as f64);
        (0..count)
            .map(|i| {
                let mut a = Mat::identity(n);
                a.scale_in_place(2.0);
                let mut d = delta.clone();
                d.scale_in_place(1.0 / (1.0 + i as f64));
                a.add_in_place(&d);
                a
            })
            .collect()
    }

    /// ISSUE acceptance pin: on a flat spectrum the adaptive evaluator
    /// drives k → 0 and the sequence's total matvecs match plain CG —
    /// the evaluation itself costs zero operator applications.
    #[test]
    fn adaptive_strategy_shrinks_to_plain_cg_on_flat_spectrum() {
        let n = 48;
        let seq = drifting_flat_sequence(n, 4, 29);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { strategy: StrategyChoice::Auto, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        let spec = SolveSpec::defcg().with_tol(1e-8);
        let mut recycled_matvecs = 0usize;
        for a in &seq {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
            recycled_matvecs += r.matvecs;
            // The strategy saw candidates and turned them all down.
            let d = mgr.last_decision();
            assert_eq!(d.strategy, "adaptive-k");
            assert!(d.k_offered > 0, "extraction should offer candidates");
            assert_eq!(d.k_chosen, 0, "flat spectrum must shrink to plain CG: {d:?}");
            assert_eq!(mgr.k_active(), 0);
        }
        assert!(mgr.strategy_shrinks() >= seq.len() as u64);

        // With k pinned at 0 no basis is ever held, so no AW refresh and
        // no deflation: every solve is exactly the plain-CG run.
        let plain_matvecs: usize = seq
            .iter()
            .map(|a| {
                crate::solvers::solve(&DenseOp::new(a), &b, &SolveSpec::cg().with_tol(1e-8))
                    .matvecs
            })
            .sum();
        assert_eq!(
            recycled_matvecs, plain_matvecs,
            "adaptive k=0 sequence must cost exactly plain CG"
        );
    }

    /// On the paper-shaped outlier spectrum the adaptive evaluator keeps
    /// the columns that pay (the outliers) and stops before chasing the
    /// bulk — k lands strictly between 0 and the offer.
    #[test]
    fn adaptive_strategy_keeps_paying_columns_on_outlier_spectrum() {
        let n = 90;
        let seq = drifting_outlier_sequence(n, 4, 131);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { k: 8, l: 12, strategy: StrategyChoice::Auto, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        let spec = SolveSpec::defcg().with_tol(1e-8);
        let mut iters = Vec::new();
        for a in &seq {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
            iters.push(r.iterations);
        }
        let d = mgr.last_decision();
        assert_eq!(d.strategy, "adaptive-k");
        assert!(
            (3..=5).contains(&d.k_chosen),
            "should keep roughly the 3 outlier directions, chose {} of {}",
            d.k_chosen,
            d.k_offered
        );
        assert!(d.k_chosen < d.k_offered, "the bulk should be declined");
        assert!(d.predicted_savings() > 0.0);
        assert!(mgr.strategy_shrinks() >= 1);
        assert!(mgr.predicted_savings_total() > 0.0);
        // The small adaptive basis still delivers the recycling payoff.
        assert!(
            iters[2] < iters[0] && iters[3] < iters[0],
            "recycled runs {iters:?} should beat the cold start"
        );
    }

    /// Satellite: strategy × budget interaction. Whatever strategy is in
    /// force — switched per-request mid-sequence — the chosen k never
    /// exceeds `RecycleBudget::capping_cols`' basis cap, and
    /// `bytes_held()` stays consistent with the live buffers.
    #[test]
    fn strategy_switches_respect_budget_and_byte_accounting() {
        let n = 60;
        let seq = drifting_outlier_sequence(n, 8, 57);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { k: 8, l: 10, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        let budget = RecycleBudget::capping_cols(n, 3, 6);
        let choices = [
            (StrategyChoice::HarmonicLargest, "harmonic-largest"),
            (StrategyChoice::RitzSmallest, "ritz-smallest"),
            (StrategyChoice::TwoSided, "two-sided"),
            (StrategyChoice::Auto, "adaptive-k"),
        ];
        for (i, a) in seq.iter().enumerate() {
            let (choice, name) = &choices[i % choices.len()];
            let spec = SolveSpec::defcg()
                .with_tol(1e-8)
                .with_budget(budget)
                .with_strategy(choice.clone());
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
            audit(&mgr);
            let cap = budget.basis_cols(n);
            assert!(mgr.k_active() <= cap, "basis {} over budget cap {cap}", mgr.k_active());
            let d = mgr.last_decision();
            assert_eq!(d.strategy, *name);
            assert!(d.k_chosen <= cap, "chosen k {} over budget cap {cap}", d.k_chosen);
            assert!(d.k_chosen <= d.k_offered);
            assert!(d.k_offered <= cap, "offer {} over budget cap {cap}", d.k_offered);
        }
    }

    /// Satellite: numerical extraction failures are counted and flagged
    /// instead of only being logged; benign-empty panels are not.
    #[test]
    fn extraction_failures_are_counted_and_flagged() {
        let n = 12;
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        // A degenerate panel whose AP image is zero makes G = (AZ)ᵀ(AZ)
        // singular: the generalized eigensolve fails.
        let mut e1 = vec![0.0; n];
        e1[0] = 1.0;
        let degenerate = StoredDirections { p: vec![e1], ap: vec![vec![0.0; n]] };
        let budget = RecycleBudget::default();
        let vals = mgr.absorb(&degenerate, n, &budget, &StrategyChoice::default(), 1e-8, None);
        assert!(vals.is_empty());
        assert_eq!(mgr.extraction_failures(), 1);
        assert!(mgr.last_absorb().extraction_failed);
        assert_eq!(mgr.k_active(), 0);
        let d = mgr.last_decision();
        assert_eq!(d.strategy, "harmonic-largest");
        assert_eq!(d.k_offered, 0);
        assert_eq!(d.k_chosen, 0);
        // Benign-empty absorb: no stored directions is not a failure.
        mgr.absorb(
            &StoredDirections::default(),
            n,
            &budget,
            &StrategyChoice::default(),
            1e-8,
            None,
        );
        assert_eq!(mgr.extraction_failures(), 1);
        assert!(!mgr.last_absorb().extraction_failed);
    }
}
