//! The recycle manager: subspace transfer across a sequence of systems.
//!
//! This is the "computational transfer learning" loop of the paper's §1:
//! solve system `i`, extract harmonic Ritz vectors from the stored CG
//! directions, and deflate system `i+1` with them. The manager owns the
//! `(W, AW)` state, the def-CG(k, ℓ) hyperparameters, and the policy
//! decisions the paper discusses in §3:
//!
//! * whether to refresh `AW` under the new operator (k extra matvecs,
//!   exact deflation) or reuse the stale image (free, the paper's choice —
//!   valid because consecutive Newton systems differ little);
//! * whether to re-orthonormalize `W` when it degenerates (the stability
//!   issue the paper blames for late-sequence stagnation).
//!
//! Both the single-RHS methods and multi-RHS **block solves** ride the
//! same basis: `BlockCg` requests run deflated block CG against `(W, AW)`
//! and their stored block direction panels feed the next harmonic-Ritz
//! extraction, so coalesced multi-RHS traffic (the coordinator's
//! `submit_block` path) decays in iterations across a sequence exactly
//! like the single-RHS path (deflated block methods as the standard
//! composition — Soodhalter, de Sturler & Kilmer 2020 §10).

use crate::linalg::qr::mgs_orthonormalize;
use crate::solvers::api::{self, Jacobi, Method, Preconditioner, SolveSpec};
use crate::solvers::blockcg::BlockSolveResult;
use crate::solvers::defcg::Deflation;
use crate::solvers::ritz::{self, RitzConfig, RitzValue};
use crate::solvers::{SolveResult, SpdOperator, StopReason, StoredDirections};
use std::sync::Arc;

/// Policy for keeping `AW` consistent across systems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AwPolicy {
    /// Reuse `A⁽ⁱ⁾W` as the image under `A⁽ⁱ⁺¹⁾`: zero matvecs, but the
    /// deflation projector becomes inexact (error ∝ ‖A⁽ⁱ⁺¹⁾−A⁽ⁱ⁾‖) and the
    /// solve can stall near tight tolerances — the instability the paper's
    /// §3 discussion attributes stagnation to.
    Reuse,
    /// Recompute `AW` exactly with k matvecs per new system. This is what
    /// the paper's overhead estimate accounts for ("W and AW are obtained
    /// in O(n²(ℓ+1)k)"); required when solving below the drift level.
    Refresh,
    /// Reuse when the requested tolerance is loose (≥ 1e-6 — staleness can
    /// stay below the target if the sequence drifts slowly), refresh when
    /// the solve needs to go below the staleness floor. Cheaper than
    /// Refresh but relies on def-CG's shift safeguard when the sequence
    /// drifts fast (early Newton steps).
    Auto,
}

/// def-CG(k, ℓ) hyperparameters plus policies.
#[derive(Clone, Debug)]
pub struct RecycleConfig {
    /// Recycled subspace dimension (paper's k, Table 1 uses 8).
    pub k: usize,
    /// CG iterations whose directions are stored (paper's ℓ, Table 1: 12).
    pub l: usize,
    pub select: ritz::RitzSelect,
    pub aw_policy: AwPolicy,
    /// Re-orthonormalize W (and refresh AW) when its condition degrades.
    pub stabilize: bool,
}

impl Default for RecycleConfig {
    fn default() -> Self {
        RecycleConfig {
            k: 8,
            l: 12,
            select: ritz::RitzSelect::Largest,
            // Refresh: exact deflation never harms convergence; its k
            // matvecs/system are what the paper's own overhead estimate
            // budgets for ("W and AW are obtained in O(n²(ℓ+1)k)").
            aw_policy: AwPolicy::Refresh,
            stabilize: false,
        }
    }
}

/// Statistics for one solved system in the sequence.
#[derive(Clone, Debug)]
pub struct SystemStats {
    pub index: usize,
    pub iterations: usize,
    pub matvecs: usize,
    pub final_residual: f64,
    pub deflation_dim: usize,
    pub ritz_values: Vec<f64>,
    pub seconds: f64,
}

/// Carries the recycled subspace along a sequence of SPD systems.
pub struct RecycleManager {
    cfg: RecycleConfig,
    defl: Option<Deflation>,
    history: Vec<SystemStats>,
    /// Per-sequence Jacobi, built lazily for the first
    /// [`SolveSpec::with_auto_jacobi`] request and reused by every later
    /// one — the diagonal is derived **once per sequence**, not once per
    /// request. Consecutive systems in a sequence differ little (the
    /// paper's premise), and a Jacobi from a nearby operator is still a
    /// fixed SPD preconditioner, so correctness is untouched; only the
    /// (marginal) preconditioning quality can drift.
    ///
    /// Staleness is keyed on dimension **and** on the operator's
    /// [`SpdOperator::diag_fingerprint`]: a mixed sequence over, say,
    /// `ShiftedOp(K, σ²)` views of one Gram matrix carries σ in the
    /// fingerprint, so hopping to a different σ-grid point rebuilds the
    /// Jacobi instead of silently reusing a diagonal that is wrong by
    /// σ² − σ'². Operators without a fingerprint (`None`) keep the
    /// dimension-only reuse of the drifting-sequence premise.
    /// [`RecycleManager::reset`] drops the cache with the rest of the
    /// sequence state.
    jacobi: Option<(Arc<Jacobi>, Option<u64>)>,
}

impl RecycleManager {
    pub fn new(cfg: RecycleConfig) -> Self {
        RecycleManager { cfg, defl: None, history: Vec::new(), jacobi: None }
    }

    pub fn config(&self) -> &RecycleConfig {
        &self.cfg
    }

    /// Current recycled basis dimension (0 before the first extraction).
    pub fn k_active(&self) -> usize {
        self.defl.as_ref().map(|d| d.k()).unwrap_or(0)
    }

    /// Current deflation state (for inspection / spectrum plots).
    pub fn deflation(&self) -> Option<&Deflation> {
        self.defl.as_ref()
    }

    /// Per-system statistics collected so far.
    pub fn history(&self) -> &[SystemStats] {
        &self.history
    }

    /// Seed the manager with an externally chosen basis (e.g. the a-priori
    /// low-rank space of an inducing-point method, as §1.1 suggests).
    pub fn seed(&mut self, a: &dyn SpdOperator, w: crate::linalg::Mat) {
        let mut d = Deflation::new(w.clone(), crate::linalg::Mat::zeros(w.rows(), w.cols()));
        d.refresh(a);
        self.defl = Some(d);
    }

    /// Drop the recycled basis (next solve is plain CG) and the cached
    /// per-sequence Jacobi.
    pub fn reset(&mut self) {
        self.defl = None;
        self.history.clear();
        self.jacobi = None;
    }

    /// The sequence's cached Jacobi preconditioner, built from `a` on
    /// first use and rebuilt when the sequence dimension changes **or**
    /// when the operator's diagonal fingerprint says this is a
    /// distinguishably different operator (e.g. a new σ-grid point over
    /// the same base Gram). An operator without a fingerprint reuses the
    /// cache at matching dimension — the drifting-sequence premise — but
    /// a *fingerprintable* operator always invalidates a cache whose
    /// fingerprint differs or is unknown: one anonymous request early in
    /// a sequence must not permanently blind the staleness check for
    /// every later identifiable view.
    fn sequence_jacobi(&mut self, a: &dyn SpdOperator) -> Arc<Jacobi> {
        let fp = a.diag_fingerprint();
        let stale = match &self.jacobi {
            None => true,
            Some((j, cached)) => j.n() != a.n() || (fp.is_some() && *cached != fp),
        };
        if stale {
            self.jacobi = Some((Arc::new(Jacobi::from_op(a)), fp));
        }
        self.jacobi.as_ref().unwrap().0.clone()
    }

    /// Keep `(W, AW)` consistent under the *current* operator according to
    /// the AW policy, re-orthonormalizing when `stabilize` asks for it.
    /// Returns the extra operator applications spent.
    fn sync_basis(&mut self, a: &dyn SpdOperator, tol: f64) -> usize {
        let mut extra = 0usize;
        let n = a.n();
        if let Some(d) = self.defl.as_mut() {
            let refresh = match self.cfg.aw_policy {
                AwPolicy::Refresh => true,
                AwPolicy::Reuse => false,
                AwPolicy::Auto => tol < 1e-6,
            };
            if refresh {
                extra += d.refresh(a);
            }
            if self.cfg.stabilize {
                // Re-orthonormalize W when its Gram matrix is far from I,
                // then AW must be recomputed (k matvecs).
                let gram = d.w.t_matmul(&d.w);
                let dev = gram.max_abs_diff(&crate::linalg::Mat::identity(d.k()));
                if dev > 1e-4 {
                    let w = mgs_orthonormalize(&d.w, None, 1e-12);
                    let mut nd =
                        Deflation::new(w.clone(), crate::linalg::Mat::zeros(n, w.cols()));
                    extra += nd.refresh(a);
                    *d = nd;
                }
            }
        }
        extra
    }

    /// The per-request spec as the kernels should see it inside this
    /// sequence: the manager's ℓ overrides `store_l` (every CG-family and
    /// block run feeds the extraction) and `auto_jacobi` requests resolve
    /// to the sequence's cached preconditioner. `block` marks the
    /// multi-RHS entry point, where the kernel preconditions regardless
    /// of the `method` field — there the cache must resolve for every
    /// method (a per-call rebuild in the API layer would re-derive the
    /// diagonal on each request, the exact cost the cache exists to
    /// avoid); on the single-RHS path a plain `Cg` request stays
    /// unpreconditioned, so building the cache for it would be waste.
    fn resolve_spec(&mut self, a: &dyn SpdOperator, spec: &SolveSpec, block: bool) -> SolveSpec {
        let mut inner = spec.clone();
        inner.store_l = self.cfg.l;
        let wants_precond =
            block || matches!(inner.method, Method::Pcg | Method::DefCg | Method::BlockCg);
        if inner.auto_jacobi && inner.precond.is_none() && wants_precond {
            let j: Arc<dyn Preconditioner> = self.sequence_jacobi(a);
            inner.precond = Some(j);
        }
        inner
    }

    /// Fold a run's stored directions into the recycled basis via
    /// harmonic-Ritz extraction; returns the selected Ritz values.
    ///
    /// # Lifecycle guarantee: cancellation never corrupts the basis
    ///
    /// Absorption happens only **after** a run returned, and only for
    /// runs the caller still wants: converged, iteration-capped,
    /// stagnated, broken-down, and **deadline-stopped** runs all feed
    /// their panels (every stored `(p, Ap)` pair is written at an
    /// iteration boundary, so a partial run's panel is as consistent as
    /// a full run's — partial Krylov work is not discarded). A
    /// [`StopReason::Cancelled`] run is the one exception: the caller
    /// abandoned it, so [`RecycleManager::solve_next`] /
    /// [`RecycleManager::solve_block`] skip this call entirely and the
    /// sequence's `(W, AW)` is left byte-for-byte what it was — there is
    /// no code path that mutates the basis mid-iteration.
    fn absorb(&mut self, stored: &StoredDirections, n: usize) -> Vec<f64> {
        let ritz_cfg = RitzConfig {
            k: self.cfg.k,
            select: self.cfg.select,
            min_col_norm: 1e-10,
        };
        let mut ritz_values: Vec<f64> = Vec::new();
        if let Some((defl, vals)) = ritz::extract(self.defl.as_ref(), stored, n, &ritz_cfg) {
            ritz_values = vals.iter().map(|v: &RitzValue| v.theta).collect();
            self.defl = Some(defl);
        }
        ritz_values
    }

    /// Solve the next system in the sequence according to `spec`, then
    /// update the recycled basis from the run's stored directions.
    ///
    /// The manager is **method-aware** — one recycled sequence can serve a
    /// heterogeneous stream of requests:
    ///
    /// * [`Method::DefCg`] consumes the recycled basis and honors the
    ///   spec's preconditioner, running the composed deflated-PCG kernel.
    ///   The manager's state supersedes an explicit `spec.deflation`;
    ///   before the first extraction (empty state) an explicit spec basis
    ///   is used as the seed.
    /// * [`Method::Cg`] / [`Method::Pcg`] never consume the *manager's*
    ///   basis (a plain request stays plain; a `Pcg` spec carrying its own
    ///   explicit basis composes exactly as it would through
    ///   [`crate::solvers::solve`]) but still **feed** it: the manager
    ///   overrides `store_l` with its own ℓ so every run contributes
    ///   directions to the next harmonic-Ritz extraction.
    /// * [`Method::BlockCg`] is a first-class recycling citizen like
    ///   `DefCg`: the (1-column, through this entry point) block runs
    ///   **deflated block CG** against the manager's basis and **feeds**
    ///   its stored direction panels back, so coalesced block traffic
    ///   enjoys the same iteration decay as the single-RHS path. Genuine
    ///   multi-RHS blocks go through [`RecycleManager::solve_block`].
    ///
    /// For every request, the AW-consistency policy (refresh / stabilize)
    /// runs whenever a basis is held: the extraction folds the prior
    /// `(W, AW)` into its Gram matrices, so it must stay consistent under
    /// the current operator even for requests that do not deflate.
    pub fn solve_next(
        &mut self,
        a: &dyn SpdOperator,
        b: &[f64],
        x0: Option<&[f64]>,
        spec: &SolveSpec,
    ) -> SolveResult {
        let n = a.n();
        let consumes_basis = matches!(spec.method, Method::DefCg | Method::BlockCg);

        // Entry check BEFORE the AW policy work: a request that is
        // already cancelled/expired must not pay the k-application AW
        // refresh (or anything else). It leaves no history entry and
        // touches no state — the same contract as the coordinator's
        // dead-on-arrival completion.
        if let Some(reason) = spec.control.check() {
            return SolveResult {
                x: x0.map(|v| v.to_vec()).unwrap_or_else(|| vec![0.0; n]),
                residuals: vec![1.0],
                iterations: 0,
                matvecs: 0,
                stop: reason,
                stored: StoredDirections::default(),
                seconds: 0.0,
            };
        }

        // Policy: keep (W, AW) consistent under the *current* operator.
        // This runs for every request — not just the ones that deflate —
        // because the harmonic-Ritz extraction below folds the prior
        // basis into Z/AZ: a stale AW there would mix data from two
        // different operators and silently corrupt the next basis.
        let extra_matvecs = self.sync_basis(a, spec.tol);

        // Every run stores ℓ directions for the extraction. DefCg and
        // BlockCg consume the manager's basis (falling back to an
        // explicit basis on the spec before the first extraction); Cg
        // runs plain; Pcg honors an explicit spec basis (matching
        // `solvers::solve`) but never the manager's — a preconditioned
        // request only turns into a recycled one by saying DefCg/BlockCg.
        let inner = self.resolve_spec(a, spec, false);
        let defl = if consumes_basis {
            self.defl.as_ref().or(spec.deflation.as_deref())
        } else {
            spec.deflation.as_deref()
        };
        let mut result = api::dispatch(a, b, x0, &inner, defl);
        result.matvecs += extra_matvecs;

        // Extract the next basis from this run's stored directions — for
        // every stop reason except Cancelled (abandoned work is never
        // absorbed; a DeadlineExceeded partial run still feeds its
        // panel — see `absorb`).
        let ritz_values = if result.stop == StopReason::Cancelled {
            Vec::new()
        } else {
            self.absorb(&result.stored, n)
        };

        self.history.push(SystemStats {
            index: self.history.len(),
            iterations: result.iterations,
            matvecs: result.matvecs,
            final_residual: result.final_residual(),
            deflation_dim: self.k_active(),
            ritz_values,
            seconds: result.seconds,
        });
        result
    }

    /// Solve a genuine multi-RHS block `A X = B` within the sequence —
    /// the entry point behind the coordinator's `submit_block` coalescing.
    ///
    /// Block solves are first-class recycling citizens: the manager's
    /// basis is consumed (deflated block CG: projected start plus
    /// per-iteration deflation) for `BlockCg`/`DefCg` requests, the AW
    /// policy keeps `(W, AW)` consistent first, `auto_jacobi` resolves to
    /// the sequence's cached preconditioner, and the run's stored block
    /// direction panels **feed** the next harmonic-Ritz extraction — a
    /// sequence of coalesced block requests decays in iterations exactly
    /// like the single-RHS path. A `Cg`-method spec runs the block solve
    /// undeflated but still feeds the basis.
    ///
    /// History/metrics record `matvecs` per column (the sum of active
    /// panel widths over block applies, plus any AW-refresh cost), so
    /// sequence totals stay on one axis with the single-RHS requests;
    /// `BlockSolveResult::col_matvecs` carries the per-column split the
    /// coordinator uses to bill coalesced tickets.
    pub fn solve_block(
        &mut self,
        a: &dyn SpdOperator,
        b: &crate::linalg::Mat,
        spec: &SolveSpec,
    ) -> BlockSolveResult {
        let n = a.n();
        let consumes_basis = matches!(spec.method, Method::DefCg | Method::BlockCg);

        // Entry check BEFORE the AW policy work — see `solve_next`.
        if let Some(reason) = spec.control.check() {
            return BlockSolveResult {
                x: crate::linalg::Mat::zeros(n, b.cols()),
                residuals: vec![1.0],
                iterations: 0,
                block_matvecs: 0,
                matvecs: 0,
                col_matvecs: vec![0; b.cols()],
                stop: reason,
                stored: StoredDirections::default(),
                seconds: 0.0,
            };
        }

        let extra_matvecs = self.sync_basis(a, spec.tol);
        let inner = self.resolve_spec(a, spec, true);
        let defl = if consumes_basis {
            self.defl.as_ref().or(spec.deflation.as_deref())
        } else {
            spec.deflation.as_deref()
        };
        let mut result = api::solve_block_with(a, b, &inner, defl);
        result.matvecs += extra_matvecs;

        // Same absorb policy as `solve_next`: everything but Cancelled.
        let ritz_values = if result.stop == StopReason::Cancelled {
            Vec::new()
        } else {
            self.absorb(&result.stored, n)
        };

        self.history.push(SystemStats {
            index: self.history.len(),
            iterations: result.iterations,
            matvecs: result.matvecs,
            final_residual: result.final_residual(),
            deflation_dim: self.k_active(),
            ritz_values,
            seconds: result.seconds,
        });
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::{DenseOp, StopReason};
    use crate::util::rng::Rng;

    /// A slowly drifting sequence of SPD matrices: A_i = A + εᵢ Δ,
    /// mimicking the Newton sequence of the paper (consecutive systems
    /// differ less and less).
    fn drifting_sequence(n: usize, count: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::new(seed);
        let a0 = Mat::rand_spd(n, 1e4, &mut rng);
        let mut delta = Mat::randn(n, n, &mut rng);
        delta.symmetrize();
        delta.scale_in_place(1e-3 / n as f64);
        (0..count)
            .map(|i| {
                let mut a = a0.clone();
                let scale = 1.0 / (1.0 + i as f64); // shrinking drift
                let mut d = delta.clone();
                d.scale_in_place(scale);
                a.add_in_place(&d);
                // keep strictly SPD
                a.add_diag(1e-6);
                a
            })
            .collect()
    }

    #[test]
    fn sequence_iterations_decrease_with_recycling() {
        let n = 90;
        let seq = drifting_sequence(n, 5, 11);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_max_iters(50_000);

        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let mut plain_iters = Vec::new();
        let mut recycled_iters = Vec::new();
        for a in &seq {
            let op = DenseOp::new(a);
            let plain = crate::solvers::cg::solve(&op, &b, None, &spec.cg_config());
            assert_eq!(plain.stop, StopReason::Converged);
            let rec = mgr.solve_next(&op, &b, None, &spec);
            assert_eq!(rec.stop, StopReason::Converged);
            plain_iters.push(plain.iterations);
            recycled_iters.push(rec.iterations);
        }
        // First system: no basis yet, so identical to plain CG.
        assert_eq!(plain_iters[0], recycled_iters[0]);
        // Every later system must need fewer iterations than plain CG.
        for i in 1..seq.len() {
            assert!(
                recycled_iters[i] < plain_iters[i],
                "system {i}: recycled {} >= plain {}",
                recycled_iters[i],
                plain_iters[i]
            );
        }
    }

    #[test]
    fn history_records_every_system() {
        let n = 40;
        let seq = drifting_sequence(n, 3, 12);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 6, ..Default::default() });
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-6));
        }
        assert_eq!(mgr.history().len(), 3);
        assert_eq!(mgr.history()[0].index, 0);
        assert!(mgr.history()[1].deflation_dim > 0);
        assert!(mgr.history()[2].ritz_values.len() <= 4);
    }

    #[test]
    fn refresh_policy_costs_k_matvecs_but_stays_correct() {
        let n = 50;
        let seq = drifting_sequence(n, 3, 13);
        let b = vec![1.0; n];
        let cfg = RecycleConfig {
            k: 5,
            l: 8,
            aw_policy: AwPolicy::Refresh,
            ..Default::default()
        };
        let mut mgr = RecycleManager::new(cfg);
        for a in &seq {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
            assert_eq!(r.stop, StopReason::Converged);
            // solution check
            let ax = a.matvec(&r.x);
            let num: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v).powi(2)).sum();
            assert!(num.sqrt() / (n as f64).sqrt() < 1e-6);
        }
        // Refresh happened on systems 2 and 3 (k matvecs each).
        assert!(mgr.history()[1].matvecs > mgr.history()[1].iterations);
    }

    #[test]
    fn seed_with_external_basis() {
        let n = 40;
        let mut rng = Rng::new(14);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let w = crate::linalg::qr::Qr::factor(&Mat::randn(n, 6, &mut rng)).thin_q();
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        mgr.seed(&DenseOp::new(&a), w);
        assert_eq!(mgr.k_active(), 6);
        let b = vec![1.0; n];
        let r = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(r.stop, StopReason::Converged);
    }

    #[test]
    fn reset_clears_state() {
        let n = 30;
        let seq = drifting_sequence(n, 2, 15);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-6));
        }
        assert!(mgr.k_active() > 0);
        mgr.reset();
        assert_eq!(mgr.k_active(), 0);
        assert!(mgr.history().is_empty());
    }

    #[test]
    fn plain_requests_feed_the_basis_without_consuming_it() {
        // Method-aware sequence: a Cg request stores directions (feeding
        // the extraction) but runs undeflated; a following DefCg request
        // on the same system then converges faster thanks to the basis the
        // plain run contributed.
        let n = 90;
        let mut rng = Rng::new(17);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let plain = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::cg().with_tol(1e-8));
        assert_eq!(plain.stop, StopReason::Converged);
        assert!(mgr.k_active() > 0, "plain run must feed the basis");
        let deflated =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(deflated.stop, StopReason::Converged);
        assert!(
            deflated.iterations < plain.iterations,
            "deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
    }

    #[test]
    fn block_requests_consume_and_feed_the_basis() {
        let n = 60;
        let mut rng = Rng::new(18);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 6, l: 10, ..Default::default() });
        // Seed the basis with a def-CG run, then interleave a block request.
        let seed = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        let k_before = mgr.k_active();
        assert!(k_before > 0);
        let blk = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::blockcg().with_tol(1e-8));
        assert_eq!(blk.stop, StopReason::Converged);
        // Consumes: the deflated block run on the identical system beats
        // the cold seeding run.
        assert!(
            blk.iterations < seed.iterations,
            "deflated block {} >= cold {}",
            blk.iterations,
            seed.iterations
        );
        // Feeds: the extraction ran on the block run's directions.
        assert!(mgr.k_active() > 0);
        assert_eq!(mgr.history().len(), 2);
        assert!(mgr.history()[1].deflation_dim > 0);
        assert!(!mgr.history()[1].ritz_values.is_empty(), "block runs must feed the basis");
    }

    #[test]
    fn auto_jacobi_is_built_once_per_sequence() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct DiagCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for DiagCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
        }
        let n = 60;
        let seq = drifting_sequence(n, 4, 19);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let ops: Vec<DiagCounting> =
            seq.iter().map(|a| DiagCounting(a, AtomicUsize::new(0))).collect();
        for op in &ops {
            let r = mgr.solve_next(op, &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
        }
        let total: usize = ops.iter().map(|o| o.1.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 1, "the sequence Jacobi must be derived exactly once");
        mgr.reset();
        let r = mgr.solve_next(&ops[0], &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(ops[0].1.load(Ordering::Relaxed), 2, "reset drops the cache");
    }

    #[test]
    fn block_auto_jacobi_resolves_to_the_sequence_cache_for_any_method() {
        // The block kernel preconditions regardless of the spec's method
        // field, so a Cg-method block request with auto_jacobi must hit
        // the per-sequence cache too — not fall through to a per-call
        // rebuild in the API layer (n probing matvecs per request on
        // operators without an exact diagonal).
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct DiagCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for DiagCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
        }
        let n = 40;
        let mut rng = Rng::new(24);
        let a = Mat::rand_spd(n, 1e3, &mut rng);
        let op = DiagCounting(&a, AtomicUsize::new(0));
        let rhs = Mat::randn(n, 3, &mut rng);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        let spec = SolveSpec::cg().with_auto_jacobi().with_tol(1e-8);
        let r1 = mgr.solve_block(&op, &rhs, &spec);
        let r2 = mgr.solve_block(&op, &rhs, &spec);
        assert_eq!(r1.stop, StopReason::Converged);
        assert_eq!(r2.stop, StopReason::Converged);
        assert_eq!(
            op.1.load(Ordering::Relaxed),
            1,
            "block auto-jacobi must derive the sequence diagonal exactly once"
        );
    }

    #[test]
    fn jacobi_cache_rebuilds_across_same_n_sigma_grid_points() {
        // The staleness bug this pins: a mixed sequence over ShiftedOp(K, σ²)
        // views of ONE Gram matrix has constant n, but the diagonal differs
        // by σ² across grid points — reusing the cached Jacobi there applies
        // a preconditioner that is wrong by σ₁² − σ₂². The diag fingerprint
        // distinguishes the views, so the cache rebuilds exactly when σ
        // changes and still reuses within one σ.
        use crate::solvers::algebra::ShiftedOp;
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct FingerprintedBase<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for FingerprintedBase<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
            fn diag_fingerprint(&self) -> Option<u64> {
                Some(0xBA5E) // one fixed base identity
            }
        }
        let n = 50;
        let mut rng = Rng::new(23);
        let k = Mat::rand_spd(n, 1e3, &mut rng);
        let base = FingerprintedBase(&k, AtomicUsize::new(0));
        let b = vec![1.0; n];
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });

        let s1 = ShiftedOp::new(&base, 0.5);
        let s2 = ShiftedOp::new(&base, 250.0); // same n, very different diag
        let r = mgr.solve_next(&s1, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(base.1.load(Ordering::Relaxed), 1);
        // Same σ again: the cache must be reused (no new derivation).
        let r = mgr.solve_next(&s1, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(base.1.load(Ordering::Relaxed), 1, "same grid point reuses the Jacobi");
        // Different σ at the same n: the fingerprint must force a rebuild —
        // the reused diagonal would be wrong by σ₂² − σ₁² ≈ 250.
        let r = mgr.solve_next(&s2, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(
            base.1.load(Ordering::Relaxed),
            2,
            "a distinguishable operator must rebuild the sequence Jacobi"
        );
        // And that rebuilt Jacobi must actually match the shifted diagonal:
        // solve the shifted system directly with an exact Jacobi and check
        // the sequence solve used the same (iteration counts agree).
        let direct = crate::solvers::solve(
            &s2,
            &b,
            &SolveSpec::pcg().with_jacobi(&s2).with_tol(1e-8),
        );
        assert_eq!(r.iterations, direct.iterations, "rebuilt Jacobi must be the exact one");
    }

    #[test]
    fn fingerprintable_operator_invalidates_an_anonymous_jacobi_cache() {
        // A sequence whose FIRST auto-jacobi request comes from an
        // operator without a fingerprint caches (J, None). A later
        // *fingerprintable* view of a very different operator must still
        // invalidate that cache — one anonymous request must not blind
        // the staleness check for the rest of the sequence.
        use crate::solvers::algebra::ShiftedOp;
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Anon<'a>(&'a Mat);
        impl<'a> SpdOperator for Anon<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.0.diag_into(out);
            }
        }
        struct FpCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for FpCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
            fn diag_fingerprint(&self) -> Option<u64> {
                Some(0xF00D)
            }
        }
        let n = 40;
        let mut rng = Rng::new(25);
        let k = Mat::rand_spd(n, 1e3, &mut rng);
        let b = vec![1.0; n];
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        // Anonymous first: builds and caches with fingerprint None.
        let r = mgr.solve_next(&Anon(&k), &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        // A fingerprintable, strongly shifted view at the same n: the
        // cache must be invalidated (its diagonal derived fresh), not
        // silently reused with a diagonal wrong by 500.
        let base = FpCounting(&k, AtomicUsize::new(0));
        let shifted = ShiftedOp::new(&base, 500.0);
        let r = mgr.solve_next(&shifted, &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(
            base.1.load(Ordering::Relaxed),
            1,
            "a fingerprintable view must rebuild an anonymous cache"
        );
        let direct = crate::solvers::solve(
            &shifted,
            &b,
            &SolveSpec::pcg().with_jacobi(&shifted).with_tol(1e-8),
        );
        assert_eq!(r.iterations, direct.iterations, "rebuilt Jacobi must be the exact one");
    }

    #[test]
    fn solve_block_consumes_feeds_and_records_history() {
        let n = 50;
        let mut rng = Rng::new(20);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 5, l: 8, ..Default::default() });
        mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        let k_before = mgr.k_active();
        assert!(k_before > 0);
        let rhs = Mat::randn(n, 3, &mut rng);
        // Undeflated reference for the same block.
        let plain = crate::solvers::blockcg::solve(&DenseOp::new(&a), &rhs, 1e-8, 0);
        let blk = mgr.solve_block(&DenseOp::new(&a), &rhs, &SolveSpec::blockcg().with_tol(1e-8));
        assert_eq!(blk.stop, StopReason::Converged);
        assert!(
            blk.iterations < plain.iterations,
            "deflated block {} >= plain {}",
            blk.iterations,
            plain.iterations
        );
        assert!(mgr.k_active() > 0, "block directions must feed the extraction");
        assert_eq!(mgr.history().len(), 2);
        assert!(!mgr.history()[1].ritz_values.is_empty());
        assert!(!mgr.history()[1].final_residual.is_nan(), "never NaN (recycle history)");
        // Per-column accounting: the sum of per-column applies plus the
        // AW-refresh cost (k_before applies under the default Refresh
        // policy).
        assert_eq!(blk.matvecs, blk.col_matvecs.iter().sum::<usize>() + k_before);
        assert_eq!(mgr.history()[1].matvecs, blk.matvecs);
        assert!(blk.col_matvecs.iter().sum::<usize>() <= 3 * blk.block_matvecs);
    }

    #[test]
    fn deflated_block_sequence_decays_iterations_with_block_fed_basis() {
        // The multi-RHS recycling loop end to end: a drifting 5-system
        // sequence with s = 4 right-hand sides per system. Deflated block
        // CG through the manager must need strictly fewer block iterations
        // than undeflated block CG on every system after the first, with
        // the basis demonstrably fed from block-run directions.
        let n = 90;
        let seq = drifting_sequence(n, 5, 21);
        let mut rng = Rng::new(22);
        let b = Mat::randn(n, 4, &mut rng);
        let spec = SolveSpec::blockcg().with_tol(1e-8);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let mut plain_iters = Vec::new();
        let mut rec_iters = Vec::new();
        for a in &seq {
            let op = DenseOp::new(a);
            let plain = crate::solvers::blockcg::solve(&op, &b, 1e-8, 0);
            assert_eq!(plain.stop, StopReason::Converged);
            let rec = mgr.solve_block(&op, &b, &spec);
            assert_eq!(rec.stop, StopReason::Converged);
            plain_iters.push(plain.iterations);
            rec_iters.push(rec.iterations);
        }
        // First system: no basis yet — identical to the plain block solve.
        assert_eq!(plain_iters[0], rec_iters[0]);
        for i in 1..seq.len() {
            assert!(
                rec_iters[i] < plain_iters[i],
                "system {i}: recycled block {} >= plain block {}",
                rec_iters[i],
                plain_iters[i]
            );
            assert!(
                !mgr.history()[i].ritz_values.is_empty(),
                "system {i}: basis must be fed from block-run directions"
            );
            assert!(mgr.history()[i].deflation_dim > 0);
        }
    }

    #[test]
    fn cancelled_run_never_touches_the_recycle_basis() {
        // The lifecycle guarantee: a Cancelled solve is not absorbed —
        // the sequence's (W, AW) stays byte-for-byte what it was, and a
        // later request still benefits from the pre-cancel basis.
        use crate::solvers::control::CancelToken;
        let n = 80;
        let mut rng = Rng::new(40);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b = vec![1.0; n];
        // Reuse: sync_basis must not refresh AW either, so the state
        // comparison below is exact.
        let mut mgr = RecycleManager::new(RecycleConfig {
            k: 8,
            l: 12,
            aw_policy: AwPolicy::Reuse,
            ..Default::default()
        });
        let seeded =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(seeded.stop, StopReason::Converged);
        let w_before = mgr.deflation().unwrap().w.clone();
        let aw_before = mgr.deflation().unwrap().aw.clone();
        // Pre-cancelled request: the manager's entry check returns before
        // even the AW policy runs — no history entry, zero applications.
        let token = CancelToken::new();
        token.cancel();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_cancel(token);
        let cancelled = mgr.solve_next(&DenseOp::new(&a), &b, None, &spec);
        assert_eq!(cancelled.stop, StopReason::Cancelled);
        assert_eq!(cancelled.matvecs, 0, "a dead request must not pay the AW refresh");
        assert_eq!(mgr.history().len(), 1, "never-run requests leave no history");
        let d = mgr.deflation().unwrap();
        assert_eq!(d.w.max_abs_diff(&w_before), 0.0, "W must be untouched");
        assert_eq!(d.aw.max_abs_diff(&aw_before), 0.0, "AW must be untouched");
        // Mid-solve cancel (token raised after the first iteration by a
        // self-cancelling operator): recorded in history, absorb skipped,
        // basis still byte-identical.
        struct CancelAfterFirst<'a>(&'a Mat, CancelToken);
        impl<'a> SpdOperator for CancelAfterFirst<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
                self.1.cancel();
            }
        }
        let mid_token = CancelToken::new();
        let op = CancelAfterFirst(&a, mid_token.clone());
        let spec = SolveSpec::cg().with_tol(1e-12).with_cancel(mid_token);
        let mid = mgr.solve_next(&op, &b, None, &spec);
        assert_eq!(mid.stop, StopReason::Cancelled);
        assert!(mid.iterations >= 1, "the cancel landed mid-solve");
        assert_eq!(mgr.history().len(), 2, "a run that started is recorded");
        assert!(mgr.history()[1].ritz_values.is_empty(), "but never absorbed");
        let d = mgr.deflation().unwrap();
        assert_eq!(d.w.max_abs_diff(&w_before), 0.0, "W must still be untouched");
        assert_eq!(d.aw.max_abs_diff(&aw_before), 0.0, "AW must still be untouched");
        let after =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(after.stop, StopReason::Converged);
        assert!(after.iterations < seeded.iterations, "the old basis still deflates");
    }

    #[test]
    fn deadline_stopped_run_feeds_directions_that_speed_up_the_next_system() {
        // The acceptance pin: a deadline-bounded solve returns a partial
        // iterate AND its stored direction panel still reduces the next
        // system's iteration count — partial Krylov work is not
        // discarded. The slow operator makes the deadline deterministic:
        // every application sleeps, so a ~100 ms budget admits a handful
        // of iterations of a solve that needs hundreds.
        use std::time::Duration;
        struct Slow<'a>(&'a Mat);
        impl<'a> SpdOperator for Slow<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                std::thread::sleep(Duration::from_millis(2));
                self.0.matvec_into(x, y);
            }
        }
        let n = 90;
        let mut rng = Rng::new(41);
        let a = Mat::rand_spd(n, 1e6, &mut rng);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let b = a.matvec(&x_true);
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        // tol far below what the budget can reach: the deadline fires.
        let spec = SolveSpec::defcg().with_tol(1e-15).with_deadline(Duration::from_millis(150));
        let partial = mgr.solve_next(&Slow(&a), &b, None, &spec);
        assert_eq!(partial.stop, StopReason::DeadlineExceeded, "stopped as {:?}", partial.stop);
        assert!(partial.iterations >= 1, "the budget allowed at least one iteration");
        // Partial iterate: strictly closer to the solution in A-norm
        // than the zero start (CG minimizes the A-norm error).
        let a_err = |x: &[f64]| -> f64 {
            let e: Vec<f64> = x.iter().zip(&x_true).map(|(u, v)| u - v).collect();
            crate::linalg::vec_ops::dot(&e, &a.matvec(&e)).sqrt()
        };
        assert!(a_err(&partial.x) < a_err(&vec![0.0; n]));
        // The partial run fed the basis...
        assert!(mgr.k_active() > 0, "deadline-stopped run must feed the basis");
        assert!(!mgr.history()[0].ritz_values.is_empty());
        // ...and that basis reduces iterations on the next system (the
        // fast operator now — the deadline was the slow op's problem).
        let cold = crate::solvers::solve(
            &DenseOp::new(&a),
            &b,
            &SolveSpec::defcg().with_tol(1e-8),
        );
        assert_eq!(cold.stop, StopReason::Converged);
        let warm = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(warm.stop, StopReason::Converged);
        assert!(
            warm.iterations < cold.iterations,
            "deadline-fed basis {} >= cold {}",
            warm.iterations,
            cold.iterations
        );
    }

    #[test]
    fn stabilize_keeps_w_well_conditioned() {
        let n = 60;
        let seq = drifting_sequence(n, 6, 16);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { k: 6, l: 10, stabilize: true, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        }
        if let Some(d) = mgr.deflation() {
            let gram = d.w.t_matmul(&d.w);
            // Diagonal should be ~1 (normalized columns); off-diagonal bounded.
            for i in 0..d.k() {
                assert!((gram[(i, i)] - 1.0).abs() < 1e-6);
            }
        }
    }
}
