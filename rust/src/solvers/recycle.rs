//! The recycle manager: subspace transfer across a sequence of systems.
//!
//! This is the "computational transfer learning" loop of the paper's §1:
//! solve system `i`, extract harmonic Ritz vectors from the stored CG
//! directions, and deflate system `i+1` with them. The manager owns the
//! `(W, AW)` state, the def-CG(k, ℓ) hyperparameters, and the policy
//! decisions the paper discusses in §3:
//!
//! * whether to refresh `AW` under the new operator (k extra matvecs,
//!   exact deflation) or reuse the stale image (free, the paper's choice —
//!   valid because consecutive Newton systems differ little);
//! * whether to re-orthonormalize `W` when it degenerates (the stability
//!   issue the paper blames for late-sequence stagnation).

use crate::linalg::qr::mgs_orthonormalize;
use crate::solvers::api::{self, Jacobi, Method, Preconditioner, SolveSpec};
use crate::solvers::blockcg::BlockSolveResult;
use crate::solvers::defcg::Deflation;
use crate::solvers::ritz::{self, RitzConfig, RitzValue};
use crate::solvers::{SolveResult, SpdOperator};
use std::sync::Arc;

/// Policy for keeping `AW` consistent across systems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AwPolicy {
    /// Reuse `A⁽ⁱ⁾W` as the image under `A⁽ⁱ⁺¹⁾`: zero matvecs, but the
    /// deflation projector becomes inexact (error ∝ ‖A⁽ⁱ⁺¹⁾−A⁽ⁱ⁾‖) and the
    /// solve can stall near tight tolerances — the instability the paper's
    /// §3 discussion attributes stagnation to.
    Reuse,
    /// Recompute `AW` exactly with k matvecs per new system. This is what
    /// the paper's overhead estimate accounts for ("W and AW are obtained
    /// in O(n²(ℓ+1)k)"); required when solving below the drift level.
    Refresh,
    /// Reuse when the requested tolerance is loose (≥ 1e-6 — staleness can
    /// stay below the target if the sequence drifts slowly), refresh when
    /// the solve needs to go below the staleness floor. Cheaper than
    /// Refresh but relies on def-CG's shift safeguard when the sequence
    /// drifts fast (early Newton steps).
    Auto,
}

/// def-CG(k, ℓ) hyperparameters plus policies.
#[derive(Clone, Debug)]
pub struct RecycleConfig {
    /// Recycled subspace dimension (paper's k, Table 1 uses 8).
    pub k: usize,
    /// CG iterations whose directions are stored (paper's ℓ, Table 1: 12).
    pub l: usize,
    pub select: ritz::RitzSelect,
    pub aw_policy: AwPolicy,
    /// Re-orthonormalize W (and refresh AW) when its condition degrades.
    pub stabilize: bool,
}

impl Default for RecycleConfig {
    fn default() -> Self {
        RecycleConfig {
            k: 8,
            l: 12,
            select: ritz::RitzSelect::Largest,
            // Refresh: exact deflation never harms convergence; its k
            // matvecs/system are what the paper's own overhead estimate
            // budgets for ("W and AW are obtained in O(n²(ℓ+1)k)").
            aw_policy: AwPolicy::Refresh,
            stabilize: false,
        }
    }
}

/// Statistics for one solved system in the sequence.
#[derive(Clone, Debug)]
pub struct SystemStats {
    pub index: usize,
    pub iterations: usize,
    pub matvecs: usize,
    pub final_residual: f64,
    pub deflation_dim: usize,
    pub ritz_values: Vec<f64>,
    pub seconds: f64,
}

/// Carries the recycled subspace along a sequence of SPD systems.
pub struct RecycleManager {
    cfg: RecycleConfig,
    defl: Option<Deflation>,
    history: Vec<SystemStats>,
    /// Per-sequence Jacobi, built lazily for the first
    /// [`SolveSpec::with_auto_jacobi`] request and reused by every later
    /// one — the diagonal is derived **once per sequence**, not once per
    /// request. Consecutive systems in a sequence differ little (the
    /// paper's premise), and a Jacobi from a nearby operator is still a
    /// fixed SPD preconditioner, so correctness is untouched; only the
    /// (marginal) preconditioning quality can drift. [`RecycleManager::reset`]
    /// drops it with the rest of the sequence state.
    jacobi: Option<Arc<Jacobi>>,
}

impl RecycleManager {
    pub fn new(cfg: RecycleConfig) -> Self {
        RecycleManager { cfg, defl: None, history: Vec::new(), jacobi: None }
    }

    pub fn config(&self) -> &RecycleConfig {
        &self.cfg
    }

    /// Current recycled basis dimension (0 before the first extraction).
    pub fn k_active(&self) -> usize {
        self.defl.as_ref().map(|d| d.k()).unwrap_or(0)
    }

    /// Current deflation state (for inspection / spectrum plots).
    pub fn deflation(&self) -> Option<&Deflation> {
        self.defl.as_ref()
    }

    /// Per-system statistics collected so far.
    pub fn history(&self) -> &[SystemStats] {
        &self.history
    }

    /// Seed the manager with an externally chosen basis (e.g. the a-priori
    /// low-rank space of an inducing-point method, as §1.1 suggests).
    pub fn seed(&mut self, a: &dyn SpdOperator, w: crate::linalg::Mat) {
        let mut d = Deflation::new(w.clone(), crate::linalg::Mat::zeros(w.rows(), w.cols()));
        d.refresh(a);
        self.defl = Some(d);
    }

    /// Drop the recycled basis (next solve is plain CG) and the cached
    /// per-sequence Jacobi.
    pub fn reset(&mut self) {
        self.defl = None;
        self.history.clear();
        self.jacobi = None;
    }

    /// The sequence's cached Jacobi preconditioner, built from `a` on
    /// first use (or rebuilt if the sequence dimension changed).
    fn sequence_jacobi(&mut self, a: &dyn SpdOperator) -> Arc<Jacobi> {
        let stale = !matches!(&self.jacobi, Some(j) if j.n() == a.n());
        if stale {
            self.jacobi = Some(Arc::new(Jacobi::from_op(a)));
        }
        self.jacobi.as_ref().unwrap().clone()
    }

    /// Solve the next system in the sequence according to `spec`, then
    /// update the recycled basis from the run's stored directions.
    ///
    /// The manager is **method-aware** — one recycled sequence can serve a
    /// heterogeneous stream of requests:
    ///
    /// * [`Method::DefCg`] consumes the recycled basis and honors the
    ///   spec's preconditioner, running the composed deflated-PCG kernel.
    ///   The manager's state supersedes an explicit `spec.deflation`;
    ///   before the first extraction (empty state) an explicit spec basis
    ///   is used as the seed.
    /// * [`Method::Cg`] / [`Method::Pcg`] never consume the *manager's*
    ///   basis (a plain request stays plain; a `Pcg` spec carrying its own
    ///   explicit basis composes exactly as it would through
    ///   [`crate::solvers::solve`]) but still **feed** it: the manager
    ///   overrides `store_l` with its own ℓ so every CG-family run
    ///   contributes directions to the next harmonic-Ritz extraction.
    /// * [`Method::BlockCg`] passes through: the block kernel neither
    ///   consumes nor feeds the basis (it stores no directions), but the
    ///   solve is still recorded in the sequence history.
    ///
    /// For every CG-family request, the AW-consistency policy (refresh /
    /// stabilize) runs whenever a basis is held: the extraction folds the
    /// prior `(W, AW)` into its Gram matrices, so it must stay consistent
    /// under the current operator even for requests that do not deflate.
    /// Block requests skip it (they return before any extraction), so a
    /// basis can sit stale across block traffic until the next CG-family
    /// request refreshes it.
    pub fn solve_next(
        &mut self,
        a: &dyn SpdOperator,
        b: &[f64],
        x0: Option<&[f64]>,
        spec: &SolveSpec,
    ) -> SolveResult {
        let n = a.n();

        if spec.method == Method::BlockCg {
            let result = api::dispatch(a, b, x0, spec, None);
            self.history.push(SystemStats {
                index: self.history.len(),
                iterations: result.iterations,
                matvecs: result.matvecs,
                final_residual: result.final_residual(),
                deflation_dim: 0,
                ritz_values: Vec::new(),
                seconds: result.seconds,
            });
            return result;
        }

        let mut extra_matvecs = 0usize;
        let consumes_basis = spec.method == Method::DefCg;

        // Policy: keep (W, AW) consistent under the *current* operator.
        // This runs for every CG-family request — not just the ones that
        // deflate — because the harmonic-Ritz extraction below folds the
        // prior basis into Z/AZ: a stale AW there would mix data from two
        // different operators and silently corrupt the next basis.
        if let Some(d) = self.defl.as_mut() {
            let refresh = match self.cfg.aw_policy {
                AwPolicy::Refresh => true,
                AwPolicy::Reuse => false,
                AwPolicy::Auto => spec.tol < 1e-6,
            };
            if refresh {
                extra_matvecs += d.refresh(a);
            }
            if self.cfg.stabilize {
                // Re-orthonormalize W when its Gram matrix is far from I,
                // then AW must be recomputed (k matvecs).
                let gram = d.w.t_matmul(&d.w);
                let dev = gram.max_abs_diff(&crate::linalg::Mat::identity(d.k()));
                if dev > 1e-4 {
                    let w = mgs_orthonormalize(&d.w, None, 1e-12);
                    let mut nd = Deflation::new(
                        w.clone(),
                        crate::linalg::Mat::zeros(n, w.cols()),
                    );
                    extra_matvecs += nd.refresh(a);
                    *d = nd;
                }
            }
        }

        // Every CG-family run stores ℓ directions for the extraction.
        // DefCg consumes the manager's basis (falling back to an explicit
        // basis on the spec before the first extraction); Cg runs plain;
        // Pcg honors an explicit spec basis (matching `solvers::solve`)
        // but never the manager's — a preconditioned request only turns
        // into a recycled one by saying Method::DefCg.
        let mut inner = spec.clone();
        inner.store_l = self.cfg.l;
        // auto_jacobi requests resolve to the sequence's cached Jacobi —
        // built once, reused by every later request of the sequence.
        if inner.auto_jacobi
            && inner.precond.is_none()
            && matches!(inner.method, Method::Pcg | Method::DefCg)
        {
            let j: Arc<dyn Preconditioner> = self.sequence_jacobi(a);
            inner.precond = Some(j);
        }
        let defl = if consumes_basis {
            self.defl.as_ref().or(spec.deflation.as_deref())
        } else {
            spec.deflation.as_deref()
        };
        let mut result = api::dispatch(a, b, x0, &inner, defl);
        result.matvecs += extra_matvecs;

        // Extract the next basis from this run's stored directions.
        let ritz_cfg = RitzConfig {
            k: self.cfg.k,
            select: self.cfg.select,
            min_col_norm: 1e-10,
        };
        let mut ritz_values: Vec<f64> = Vec::new();
        if let Some((defl, vals)) = ritz::extract(self.defl.as_ref(), &result.stored, n, &ritz_cfg)
        {
            ritz_values = vals.iter().map(|v: &RitzValue| v.theta).collect();
            self.defl = Some(defl);
        }

        self.history.push(SystemStats {
            index: self.history.len(),
            iterations: result.iterations,
            matvecs: result.matvecs,
            final_residual: result.final_residual(),
            deflation_dim: self.k_active(),
            ritz_values,
            seconds: result.seconds,
        });
        result
    }

    /// Solve a genuine multi-RHS block `A X = B` within the sequence.
    ///
    /// Like the [`Method::BlockCg`] pass-through of
    /// [`RecycleManager::solve_next`], the block kernel neither consumes
    /// nor feeds the recycled basis (it stores no directions), but the
    /// solve is recorded in the sequence history — with `matvecs` counted
    /// per column (`block applies × columns`) so sequence totals stay on
    /// one axis with the single-RHS requests. This is the entry point
    /// behind the coordinator's `submit_block` coalescing.
    pub fn solve_block(
        &mut self,
        a: &dyn SpdOperator,
        b: &crate::linalg::Mat,
        spec: &SolveSpec,
    ) -> BlockSolveResult {
        let result = api::solve_block(a, b, spec);
        self.history.push(SystemStats {
            index: self.history.len(),
            iterations: result.iterations,
            matvecs: result.matvecs,
            final_residual: *result.residuals.last().unwrap_or(&f64::NAN),
            deflation_dim: 0,
            ritz_values: Vec::new(),
            seconds: result.seconds,
        });
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::mat::Mat;
    use crate::solvers::{DenseOp, StopReason};
    use crate::util::rng::Rng;

    /// A slowly drifting sequence of SPD matrices: A_i = A + εᵢ Δ,
    /// mimicking the Newton sequence of the paper (consecutive systems
    /// differ less and less).
    fn drifting_sequence(n: usize, count: usize, seed: u64) -> Vec<Mat> {
        let mut rng = Rng::new(seed);
        let a0 = Mat::rand_spd(n, 1e4, &mut rng);
        let mut delta = Mat::randn(n, n, &mut rng);
        delta.symmetrize();
        delta.scale_in_place(1e-3 / n as f64);
        (0..count)
            .map(|i| {
                let mut a = a0.clone();
                let scale = 1.0 / (1.0 + i as f64); // shrinking drift
                let mut d = delta.clone();
                d.scale_in_place(scale);
                a.add_in_place(&d);
                // keep strictly SPD
                a.add_diag(1e-6);
                a
            })
            .collect()
    }

    #[test]
    fn sequence_iterations_decrease_with_recycling() {
        let n = 90;
        let seq = drifting_sequence(n, 5, 11);
        let b: Vec<f64> = (0..n).map(|i| 1.0 + (i % 7) as f64).collect();
        let spec = SolveSpec::defcg().with_tol(1e-8).with_max_iters(50_000);

        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let mut plain_iters = Vec::new();
        let mut recycled_iters = Vec::new();
        for a in &seq {
            let op = DenseOp::new(a);
            let plain = crate::solvers::cg::solve(&op, &b, None, &spec.cg_config());
            assert_eq!(plain.stop, StopReason::Converged);
            let rec = mgr.solve_next(&op, &b, None, &spec);
            assert_eq!(rec.stop, StopReason::Converged);
            plain_iters.push(plain.iterations);
            recycled_iters.push(rec.iterations);
        }
        // First system: no basis yet, so identical to plain CG.
        assert_eq!(plain_iters[0], recycled_iters[0]);
        // Every later system must need fewer iterations than plain CG.
        for i in 1..seq.len() {
            assert!(
                recycled_iters[i] < plain_iters[i],
                "system {i}: recycled {} >= plain {}",
                recycled_iters[i],
                plain_iters[i]
            );
        }
    }

    #[test]
    fn history_records_every_system() {
        let n = 40;
        let seq = drifting_sequence(n, 3, 12);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 6, ..Default::default() });
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-6));
        }
        assert_eq!(mgr.history().len(), 3);
        assert_eq!(mgr.history()[0].index, 0);
        assert!(mgr.history()[1].deflation_dim > 0);
        assert!(mgr.history()[2].ritz_values.len() <= 4);
    }

    #[test]
    fn refresh_policy_costs_k_matvecs_but_stays_correct() {
        let n = 50;
        let seq = drifting_sequence(n, 3, 13);
        let b = vec![1.0; n];
        let cfg = RecycleConfig {
            k: 5,
            l: 8,
            aw_policy: AwPolicy::Refresh,
            ..Default::default()
        };
        let mut mgr = RecycleManager::new(cfg);
        for a in &seq {
            let r = mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
            assert_eq!(r.stop, StopReason::Converged);
            // solution check
            let ax = a.matvec(&r.x);
            let num: f64 = ax.iter().zip(&b).map(|(u, v)| (u - v).powi(2)).sum();
            assert!(num.sqrt() / (n as f64).sqrt() < 1e-6);
        }
        // Refresh happened on systems 2 and 3 (k matvecs each).
        assert!(mgr.history()[1].matvecs > mgr.history()[1].iterations);
    }

    #[test]
    fn seed_with_external_basis() {
        let n = 40;
        let mut rng = Rng::new(14);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let w = crate::linalg::qr::Qr::factor(&Mat::randn(n, 6, &mut rng)).thin_q();
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        mgr.seed(&DenseOp::new(&a), w);
        assert_eq!(mgr.k_active(), 6);
        let b = vec![1.0; n];
        let r = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(r.stop, StopReason::Converged);
    }

    #[test]
    fn reset_clears_state() {
        let n = 30;
        let seq = drifting_sequence(n, 2, 15);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig::default());
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-6));
        }
        assert!(mgr.k_active() > 0);
        mgr.reset();
        assert_eq!(mgr.k_active(), 0);
        assert!(mgr.history().is_empty());
    }

    #[test]
    fn plain_requests_feed_the_basis_without_consuming_it() {
        // Method-aware sequence: a Cg request stores directions (feeding
        // the extraction) but runs undeflated; a following DefCg request
        // on the same system then converges faster thanks to the basis the
        // plain run contributed.
        let n = 90;
        let mut rng = Rng::new(17);
        let a = Mat::rand_spd(n, 1e5, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 8, l: 12, ..Default::default() });
        let plain = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::cg().with_tol(1e-8));
        assert_eq!(plain.stop, StopReason::Converged);
        assert!(mgr.k_active() > 0, "plain run must feed the basis");
        let deflated =
            mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        assert_eq!(deflated.stop, StopReason::Converged);
        assert!(
            deflated.iterations < plain.iterations,
            "deflated {} >= plain {}",
            deflated.iterations,
            plain.iterations
        );
    }

    #[test]
    fn block_requests_pass_through_without_touching_the_basis() {
        let n = 60;
        let mut rng = Rng::new(18);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 6, l: 10, ..Default::default() });
        // Seed the basis with a def-CG run, then interleave a block request.
        mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        let k_before = mgr.k_active();
        assert!(k_before > 0);
        let blk = mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::blockcg().with_tol(1e-8));
        assert_eq!(blk.stop, StopReason::Converged);
        assert_eq!(mgr.k_active(), k_before, "block runs must not perturb W");
        assert_eq!(mgr.history().len(), 2);
        assert_eq!(mgr.history()[1].deflation_dim, 0);
    }

    #[test]
    fn auto_jacobi_is_built_once_per_sequence() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct DiagCounting<'a>(&'a Mat, AtomicUsize);
        impl<'a> SpdOperator for DiagCounting<'a> {
            fn n(&self) -> usize {
                self.0.rows()
            }
            fn matvec(&self, x: &[f64], y: &mut [f64]) {
                self.0.matvec_into(x, y);
            }
            fn diag(&self, out: &mut [f64]) {
                self.1.fetch_add(1, Ordering::Relaxed);
                self.0.diag_into(out);
            }
        }
        let n = 60;
        let seq = drifting_sequence(n, 4, 19);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 4, l: 8, ..Default::default() });
        let spec = SolveSpec::pcg().with_auto_jacobi().with_tol(1e-8);
        let ops: Vec<DiagCounting> =
            seq.iter().map(|a| DiagCounting(a, AtomicUsize::new(0))).collect();
        for op in &ops {
            let r = mgr.solve_next(op, &b, None, &spec);
            assert_eq!(r.stop, StopReason::Converged);
        }
        let total: usize = ops.iter().map(|o| o.1.load(Ordering::Relaxed)).sum();
        assert_eq!(total, 1, "the sequence Jacobi must be derived exactly once");
        mgr.reset();
        let r = mgr.solve_next(&ops[0], &b, None, &spec);
        assert_eq!(r.stop, StopReason::Converged);
        assert_eq!(ops[0].1.load(Ordering::Relaxed), 2, "reset drops the cache");
    }

    #[test]
    fn solve_block_records_history_without_touching_the_basis() {
        let n = 50;
        let mut rng = Rng::new(20);
        let a = Mat::rand_spd(n, 1e4, &mut rng);
        let b = vec![1.0; n];
        let mut mgr = RecycleManager::new(RecycleConfig { k: 5, l: 8, ..Default::default() });
        mgr.solve_next(&DenseOp::new(&a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        let k_before = mgr.k_active();
        assert!(k_before > 0);
        let rhs = Mat::randn(n, 3, &mut rng);
        let blk = mgr.solve_block(&DenseOp::new(&a), &rhs, &SolveSpec::blockcg().with_tol(1e-8));
        assert_eq!(blk.stop, StopReason::Converged);
        assert_eq!(mgr.k_active(), k_before);
        assert_eq!(mgr.history().len(), 2);
        assert_eq!(mgr.history()[1].matvecs, blk.matvecs);
        assert_eq!(blk.matvecs, 3 * blk.block_matvecs, "per-column accounting");
    }

    #[test]
    fn stabilize_keeps_w_well_conditioned() {
        let n = 60;
        let seq = drifting_sequence(n, 6, 16);
        let b = vec![1.0; n];
        let cfg = RecycleConfig { k: 6, l: 10, stabilize: true, ..Default::default() };
        let mut mgr = RecycleManager::new(cfg);
        for a in &seq {
            mgr.solve_next(&DenseOp::new(a), &b, None, &SolveSpec::defcg().with_tol(1e-8));
        }
        if let Some(d) = mgr.deflation() {
            let gram = d.w.t_matmul(&d.w);
            // Diagonal should be ~1 (normalized columns); off-diagonal bounded.
            for i in 0..d.k() {
                assert!((gram[(i, i)] - 1.0).abs() < 1e-6);
            }
        }
    }
}
